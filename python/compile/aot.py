"""AOT lowering: JAX anchor models → HLO text → artifacts/.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md and
DESIGN.md).

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts

Emits one `<name>.hlo.txt` per anchor variant plus `manifest.json`
describing input shapes (consumed by rust/src/runtime).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    Rust side unwraps a 1-tuple uniformly)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def anchors():
    """(name, fn, example_args) for every artifact."""
    q18 = model.Q18_SHAPES
    q63 = model.Q63_SHAPES
    nb = model.LENET_BATCH
    lenet_params = {k: _spec(*v) for k, v in model.lenet_param_shapes().items()}

    def lenet_naive(x, *flat):
        return model.lenet5_naive(x, _unflatten(flat))

    def lenet_opt(x, *flat):
        return model.lenet5_optimized(x, _unflatten(flat))

    def _unflatten(flat):
        keys = sorted(model.lenet_param_shapes().keys())
        return dict(zip(keys, flat))

    lenet_args = [_spec(nb, 1, 32, 32)] + [
        lenet_params[k] for k in sorted(lenet_params.keys())
    ]
    return [
        (
            "q18_naive",
            model.q18_naive,
            [
                _spec(q18["batch"], q18["in_features"]),
                _spec(q18["in_features"], q18["out_features"]),
                _spec(q18["out_features"]),
            ],
        ),
        (
            "q18_optimized",
            model.q18_optimized,
            [
                _spec(q18["batch"], q18["in_features"]),
                _spec(q18["in_features"], q18["out_features"]),
                _spec(q18["out_features"]),
            ],
        ),
        (
            "q18_algebraic",
            model.q18_algebraic,
            [
                _spec(q18["batch"], q18["in_features"]),
                _spec(q18["in_features"], q18["out_features"]),
                _spec(q18["out_features"]),
            ],
        ),
        (
            "q63_naive",
            model.q63_naive,
            [_spec(q63["m"], q63["k"]), _spec(q63["k"], q63["n"]), _spec(q63["n"])],
        ),
        (
            "q63_optimized",
            model.q63_optimized,
            [_spec(q63["m"], q63["k"]), _spec(q63["k"], q63["n"]), _spec(q63["n"])],
        ),
        ("lenet5_naive", lenet_naive, lenet_args),
        ("lenet5_optimized", lenet_opt, lenet_args),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    for name, fn, example_args in anchors():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "inputs": [list(a.shape) for a in example_args],
            "hlo_chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars, {len(example_args)} inputs)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
