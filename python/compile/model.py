"""Layer 2: JAX compute graphs for the anchor tasks.

Each anchor exists in two variants with identical semantics:
- `*_naive`: the PyTorch-style op-by-op graph (materializes every
  intermediate — the paper's unoptimized starting point);
- `*_optimized`: the paper's optimized kernel as a Pallas call (fused,
  tiled, algebraically simplified).

Both are AOT-lowered by aot.py to HLO text; the Rust runtime executes
both and measures the real wallclock ratio — the ground-truth anchor for
the simulator's fusion/algebraic credit (EXPERIMENTS.md §Anchors).
"""

import jax.numpy as jnp

from .kernels import (
    fused_linear_reduce,
    linear,
    matmul_epilogue,
    maxpool2d,
)
from .kernels import ref

# ----------------------------------------------------------------- Q18

def q18_naive(x, w, b):
    """L2-Q18 as PyTorch writes it: linear -> row-sum -> logsumexp x2,
    each op a separate HLO region (no manual fusion)."""
    return ref.ref_q18_naive(x, w, b)


def q18_optimized(x, w, b):
    """The paper's Appendix-8.1 kernel: double logsumexp removed
    algebraically (size-1 axis), linear+sum fused into one Pallas kernel
    that never materializes the (M, N) intermediate."""
    return fused_linear_reduce(x, w, b)


def q18_algebraic(x, w, b):
    """The FULL algebraic collapse of Q18: since the whole (M, N) linear
    output is row-summed, sum_o (xW + b)[i,o] = x @ rowsum(W) + sum(b) —
    a matvec. This is the exact-FLOP-reducing form of the paper's
    "algebraic and structural simplifications"; it is the *perf* anchor
    the Rust runtime times (the Pallas kernels are correctness anchors:
    interpret mode on CPU measures interpretation overhead, not TPU
    performance — DESIGN.md §8)."""
    wsum = jnp.sum(w, axis=1, keepdims=True)  # (K, 1)
    return x @ wsum + jnp.sum(b)


Q18_SHAPES = dict(batch=128, in_features=2048, out_features=1024)

# ----------------------------------------------------------------- Q63

def q63_naive(x, w, b, divisor=2.0):
    """L2-Q63 unfused: GEMM, then bias, then ReLU, then divide."""
    y = x @ w
    y = y + b[None, :]
    y = jnp.maximum(y, 0.0)
    return y / divisor


def q63_optimized(x, w, b, divisor=2.0):
    """Appendix-8.2 kernel: tiled GEMM with the epilogue fused in."""
    return matmul_epilogue(x, w, b, divisor=divisor, relu=True)


Q63_SHAPES = dict(m=256, k=2048, n=1024)

# --------------------------------------------------------------- LeNet5

def lenet5_naive(x, params):
    """LeNet-5, op-by-op (the L3 baseline graph)."""
    return ref.ref_lenet5(x, params)


def lenet5_optimized(x, params):
    """Appendix-8.3 style: conv via im2col feeding the fused Pallas GEMM
    (bias+ReLU folded in), Pallas max-pool, fused FC layers."""
    y = _conv_bias_relu_im2col(x, params["conv1_w"], params["conv1_b"])
    y = maxpool2d(y)
    y = _conv_bias_relu_im2col(y, params["conv2_w"], params["conv2_b"])
    y = maxpool2d(y)
    y = y.reshape(y.shape[0], -1)
    y = linear(y, params["fc1_w"], params["fc1_b"], relu=True, bm=y.shape[0])
    y = linear(y, params["fc2_w"], params["fc2_b"], relu=True, bm=y.shape[0])
    y = linear(y, params["fc3_w"], params["fc3_b"], relu=False, bm=y.shape[0], bn=10)
    return y


def _conv_bias_relu_im2col(x, w, b):
    """Convolution as im2col + the fused Pallas GEMM.

    The CUDA kernel's implicit-GEMM formulation maps to: extract patches
    (data movement the TPU pipeline overlaps with compute), then one
    MXU-tiled matmul with the bias+ReLU epilogue fused.
    """
    n, c, h, wd = x.shape
    c_out, _, kh, kw = w.shape
    oh, ow = h - kh + 1, wd - kw + 1
    # Patches: (N*OH*OW, C*KH*KW), row-major over output pixels.
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            cols.append(x[:, :, ky : ky + oh, kx : kx + ow])
    patches = jnp.stack(cols, axis=2)  # (N, C, KH*KW, OH, OW)
    patches = patches.transpose(0, 3, 4, 1, 2).reshape(n * oh * ow, c * kh * kw)
    wmat = w.reshape(c_out, c * kh * kw).T  # (C*KH*KW, C_out)
    rows = patches.shape[0]
    bm = rows if rows < 128 else 128
    while rows % bm:
        bm //= 2
    y = linear(patches, wmat, b, relu=True, bm=bm, bn=min(128, c_out), bk=wmat.shape[0])
    return y.reshape(n, oh, ow, c_out).transpose(0, 3, 1, 2)


LENET_BATCH = 16


def lenet_param_shapes():
    """Shape dict for LeNet parameters (f32)."""
    return {
        "conv1_w": (6, 1, 5, 5),
        "conv1_b": (6,),
        "conv2_w": (16, 6, 5, 5),
        "conv2_b": (16,),
        "fc1_w": (400, 120),
        "fc1_b": (120,),
        "fc2_w": (120, 84),
        "fc2_b": (84,),
        "fc3_w": (84, 10),
        "fc3_b": (10,),
    }
