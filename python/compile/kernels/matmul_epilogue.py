"""Tiled matmul with fused bias+ReLU+divide epilogue (Appendix 8.2 analog).

TPU adaptation of the paper's WMMA/split-K CUDA kernel (DESIGN.md
§Hardware-Adaptation):

- the CUDA kernel's 16x16 WMMA fragments + 32x32 block tiles become
  MXU-shaped output tiles (bm x bn, default 128x128) staged through VMEM
  by BlockSpec;
- the CUDA split-K grid.z with an atomicAdd float workspace becomes the
  innermost grid axis iterating K-tiles into an f32 VMEM accumulator —
  grid iteration order guarantees exclusive tile ownership, so no atomics
  and no workspace round-trip;
- the separate epilogue kernel (bias + ReLU + divide + fp16 cast) is
  fused into the final K step, removing one full HBM round-trip of the
  (M, N) intermediate.

VMEM footprint per step: bm*bk + bk*bn + bm*bn f32 (~0.19 MiB at the
default 128/128/256 tiling) — far under the ~16 MiB VMEM budget, leaving
room for double buffering by the pipeline.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk, divisor, relu):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = (y / divisor).astype(o_ref.dtype)


def matmul_epilogue(x, w, b, divisor=1.0, relu=True, bm=128, bn=128, bk=256):
    """out = epilogue(x @ w + b) with the epilogue fused into the GEMM.

    Shapes: x (M, K), w (K, N), b (N,). M/N/K must divide by the tile
    sizes (clamped to the problem size below).
    """
    m, k_dim = x.shape
    _, n = w.shape
    bm = _fit(bm, m)
    bn = _fit(bn, n)
    bk = _fit(bk, k_dim)
    nk = k_dim // bk
    kernel = functools.partial(_kernel, nk=nk, divisor=divisor, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, b)


def _fit(tile, dim):
    """Largest divisor of `dim` that is <= `tile` (tiles must divide the
    problem; BlockSpec has no ragged-edge masking in this kernel)."""
    t = min(tile, dim)
    while dim % t:
        t -= 1
    return t


def linear(x, w, b, relu=True, **tiles):
    """FC layer on the same fused kernel (divisor 1)."""
    return matmul_epilogue(x, w, b, divisor=1.0, relu=relu, **tiles)
