"""Row-wise keepdim logsumexp Pallas kernel.

One batch-row block per grid step; the max/exp/sum/log chain runs on the
VPU over the VMEM-resident block (the CUDA equivalent is a block-level
reduction with warp shuffles).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=1, keepdims=True)
    o_ref[...] = (m + jnp.log(jnp.sum(jnp.exp(x - m), axis=1, keepdims=True))).astype(
        o_ref.dtype
    )


def logsumexp_rows(x, bm=128):
    """Keepdim logsumexp along axis 1 of a 2-D array."""
    m, n = x.shape
    bm = min(bm, m)
    assert m % bm == 0, f"block {bm} must divide rows {m}"
    return pl.pallas_call(
        _kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x)
