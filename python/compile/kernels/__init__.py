"""Layer-1 Pallas kernels (interpret mode) and their jnp reference
oracles. See each module's docstring for the CUDA -> TPU adaptation notes."""

from . import ref  # noqa: F401
from .fused_linear_reduce import fused_linear_reduce  # noqa: F401
from .logsumexp import logsumexp_rows  # noqa: F401
from .matmul_epilogue import linear, matmul_epilogue  # noqa: F401
from .pool import maxpool2d  # noqa: F401
