"""2x2/stride-2 max-pool Pallas kernel (Appendix 8.3 building block).

The CUDA version runs one block per output element with a cooperative
window reduction. On TPU the window fits a vector register reshape: each
(batch, channel) image block is pooled with a reshape + max over the
window axes — a pure VPU operation, no MXU involvement.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, k):
    x = x_ref[...]
    n, c, h, w = x.shape
    x = x.reshape(n, c, h // k, k, w // k, k)
    o_ref[...] = jnp.max(jnp.max(x, axis=5), axis=3)


def maxpool2d(x, k=2):
    """NCHW max pool with kernel=stride=k (no padding)."""
    n, c, h, w = x.shape
    assert h % k == 0 and w % k == 0, f"pool {k} must divide spatial dims {(h, w)}"
    import functools

    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c, h // k, w // k), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, h // k, w // k), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x)
