"""Fused linear + row-sum reduction (Appendix 8.1 analog / L2-Q18 core).

The paper's CUDA kernel stages input tiles through `__shared__` memory,
accumulates per-thread partial dot products with 8-way unrolled FMA
chains, and combines them with warp-shuffle block reductions to emit one
scalar per batch element — after the double logsumexp has been removed
algebraically.

TPU adaptation (DESIGN.md §Hardware-Adaptation):
- the `__shared__` K-tile becomes a BlockSpec-staged VMEM block over the
  innermost grid axis;
- the unrolled FMA accumulators become the MXU contraction of the
  (bm, bk) x (bk, N) block pair;
- the warp-shuffle block reduction becomes a VPU row reduction
  (`jnp.sum(..., axis=1)`) over the block product;
- the bias pre-accumulation (`local_bias_sum`) is folded into the k==0
  step, exactly like the CUDA kernel folds it before the tile loop.

VMEM per step at default (bm=128, bk=512, N<=4096): 128*512 + 512*4096 f32
≈ 8.25 MiB — fits VMEM; shrink bk for larger N.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, nk):
    k = pl.program_id(1)
    partial = jnp.sum(
        jnp.dot(
            x_ref[...].astype(jnp.float32),
            w_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ),
        axis=1,
        keepdims=True,
    )

    @pl.when(k == 0)
    def _first():
        o_ref[...] = (partial + jnp.sum(b_ref[...].astype(jnp.float32))).astype(o_ref.dtype)

    @pl.when(k > 0)
    def _rest():
        o_ref[...] += partial.astype(o_ref.dtype)


def _fit(tile, dim):
    """Largest divisor of `dim` <= `tile`."""
    t = min(tile, dim)
    while dim % t:
        t -= 1
    return t


def fused_linear_reduce(x, w, b, bm=128, bk=512):
    """out[i, 0] = sum_o((x @ w + b)[i, o]) without materializing (M, N).

    Shapes: x (M, K), w (K, N), b (N,).
    """
    m, k_dim = x.shape
    _, n = w.shape
    bm = _fit(bm, m)
    bk = _fit(bk, k_dim)
    nk = k_dim // bk
    kernel = functools.partial(_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, n), lambda i, k: (k, 0)),
            pl.BlockSpec((n,), lambda i, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, b)
