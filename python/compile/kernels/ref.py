"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth (paper §4.3's "reference Torch
implementation"): each kernel in this package must match its `ref_*`
function under `assert_allclose` across the hypothesis-swept shape/dtype
grid in python/tests/.
"""

import jax
import jax.numpy as jnp

__all__ = [
    "ref_fused_linear_reduce",
    "ref_matmul_epilogue",
    "ref_conv2d_bias_relu",
    "ref_maxpool2d",
    "ref_linear",
    "ref_logsumexp",
    "ref_q18_naive",
    "ref_lenet5",
]


def ref_fused_linear_reduce(x, w, b):
    """Appendix 8.1 semantics: per-batch scalar.

    out[i] = sum_o ( (x @ w + b)[i, o] )  with shape (batch, 1).
    """
    y = x @ w + b[None, :]
    return jnp.sum(y, axis=1, keepdims=True)


def ref_matmul_epilogue(x, w, b, divisor):
    """Appendix 8.2 semantics: GEMM + bias + ReLU + scalar divide."""
    y = x @ w + b[None, :]
    return jnp.maximum(y, 0.0) / divisor


def ref_conv2d_bias_relu(x, w, b, stride=1, pad=0):
    """NCHW conv + channel bias + ReLU (Appendix 8.3 building block)."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    y = y + b[None, :, None, None]
    return jnp.maximum(y, 0.0)


def ref_maxpool2d(x, k=2, stride=2):
    """NCHW max pooling, no padding."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, k, k),
        window_strides=(1, 1, stride, stride),
        padding="VALID",
    )


def ref_linear(x, w, b, relu=True):
    """Fully-connected layer with optional ReLU."""
    y = x @ w + b[None, :]
    return jnp.maximum(y, 0.0) if relu else y


def ref_logsumexp(x, axis=1):
    """Keepdim logsumexp (the Q18 op)."""
    m = jnp.max(x, axis=axis, keepdims=True)
    return m + jnp.log(jnp.sum(jnp.exp(x - m), axis=axis, keepdims=True))


def ref_q18_naive(x, w, b):
    """The UNsimplified KernelBench-L2-Q18 chain: linear -> row-sum ->
    logsumexp -> logsumexp (both over a size-1 axis — algebraically
    removable, which is the paper's 20.17x headline)."""
    y = x @ w + b[None, :]
    s = jnp.sum(y, axis=1, keepdims=True)
    l1 = ref_logsumexp(s, axis=1)
    l2 = ref_logsumexp(l1, axis=1)
    return l2


def ref_lenet5(x, params):
    """LeNet-5 forward (Appendix 8.3 / KernelBench L3).

    `params` is a dict with conv1_w/b, conv2_w/b, fc1_w/b, fc2_w/b,
    fc3_w/b. Input is (N, 1, 32, 32).
    """
    y = ref_conv2d_bias_relu(x, params["conv1_w"], params["conv1_b"])
    y = ref_maxpool2d(y)
    y = ref_conv2d_bias_relu(y, params["conv2_w"], params["conv2_b"])
    y = ref_maxpool2d(y)
    y = y.reshape(y.shape[0], -1)
    y = ref_linear(y, params["fc1_w"], params["fc1_b"], relu=True)
    y = ref_linear(y, params["fc2_w"], params["fc2_b"], relu=True)
    y = ref_linear(y, params["fc3_w"], params["fc3_b"], relu=False)
    return y
