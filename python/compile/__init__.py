"""Build-time compile package: L2 JAX models + L1 Pallas kernels + AOT.

Never imported at runtime — the Rust binary consumes only the HLO-text
artifacts this package emits via `python -m compile.aot`.
"""
