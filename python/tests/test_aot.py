"""AOT pipeline: lowering emits parseable HLO text the Rust loader can
consume (format gate — see DESIGN.md: HLO text, never .serialize())."""

import jax
import jax.numpy as jnp

from compile import aot


def test_to_hlo_text_structure():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: the root is a tuple.
    assert "tuple" in text.lower()


def test_pallas_anchor_lowers_to_plain_hlo():
    """Interpret-mode Pallas must not leave custom-calls the CPU PJRT
    client cannot execute."""
    name, fn, args = [a for a in aot.anchors() if a[0] == "q63_optimized"][0]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "HloModule" in text
    assert "mosaic" not in text.lower(), "Mosaic custom-call leaked into AOT artifact"


def test_all_anchors_lower():
    for name, fn, args in aot.anchors():
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        assert text.startswith("HloModule"), name
        assert len(text) > 500, f"{name}: implausibly small HLO"
