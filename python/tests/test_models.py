"""L2 correctness: naive and optimized anchor variants are semantically
identical, and shapes match the manifest the Rust runtime relies on."""

import numpy as np

from compile import model
from compile.kernels import ref


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def lenet_params(seed=0, scale=0.2):
    return {
        k: rand(v, seed + i, scale)
        for i, (k, v) in enumerate(sorted(model.lenet_param_shapes().items()))
    }


def test_q18_variants_agree():
    s = model.Q18_SHAPES
    x = rand((s["batch"], s["in_features"]), 1, 0.05)
    w = rand((s["in_features"], s["out_features"]), 2, 0.05)
    b = rand((s["out_features"],), 3)
    naive = np.asarray(model.q18_naive(x, w, b))
    opt = np.asarray(model.q18_optimized(x, w, b))
    alg = np.asarray(model.q18_algebraic(x, w, b))
    assert naive.shape == (s["batch"], 1)
    np.testing.assert_allclose(opt, naive, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(alg, naive, rtol=2e-3, atol=2e-3)


def test_q63_variants_agree():
    s = model.Q63_SHAPES
    x = rand((s["m"], s["k"]), 4, 0.1)
    w = rand((s["k"], s["n"]), 5, 0.1)
    b = rand((s["n"],), 6)
    naive = np.asarray(model.q63_naive(x, w, b))
    opt = np.asarray(model.q63_optimized(x, w, b))
    np.testing.assert_allclose(opt, naive, rtol=1e-4, atol=1e-4)
    assert (opt >= 0).all()  # ReLU then positive divisor


def test_lenet_variants_agree():
    params = lenet_params()
    x = rand((model.LENET_BATCH, 1, 32, 32), 99, 0.5)
    naive = np.asarray(model.lenet5_naive(x, params))
    opt = np.asarray(model.lenet5_optimized(x, params))
    assert naive.shape == (model.LENET_BATCH, 10)
    np.testing.assert_allclose(opt, naive, rtol=5e-4, atol=5e-4)


def test_lenet_conv_im2col_building_block():
    """The im2col+GEMM conv equals lax.conv on a standalone layer."""
    x = rand((2, 3, 12, 12), 7, 0.5)
    w = rand((8, 3, 5, 5), 8, 0.5)
    b = rand((8,), 9)
    got = np.asarray(model._conv_bias_relu_im2col(x, w, b))
    want = np.asarray(ref.ref_conv2d_bias_relu(x, w, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_anchor_registry_consistent():
    """aot.anchors() must lower-able shapes consistent with the models."""
    from compile import aot

    names = [a[0] for a in aot.anchors()]
    assert names == [
        "q18_naive",
        "q18_optimized",
        "q18_algebraic",
        "q63_naive",
        "q63_optimized",
        "lenet5_naive",
        "lenet5_optimized",
    ]
    for _name, _fn, args in aot.anchors():
        assert all(hasattr(a, "shape") for a in args)
