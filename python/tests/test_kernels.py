"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes (the repro guidance's L1 test contract);
assert_allclose against ref.py is the core correctness signal.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (
    fused_linear_reduce,
    linear,
    logsumexp_rows,
    matmul_epilogue,
    maxpool2d,
    ref,
)

# Keep hypothesis deadlines off: interpret-mode pallas is slow per-call.
COMMON = dict(deadline=None, max_examples=20)


def rand(shape, seed, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ------------------------------------------------------------ matmul

@settings(**COMMON)
@given(
    m=st.sampled_from([8, 16, 64, 128]),
    k=st.sampled_from([32, 64, 256]),
    n=st.sampled_from([16, 32, 128]),
    relu=st.booleans(),
    divisor=st.sampled_from([1.0, 2.0, 3.5]),
    seed=st.integers(0, 2**16),
)
def test_matmul_epilogue_matches_ref(m, k, n, relu, divisor, seed):
    x, w, b = rand((m, k), seed), rand((k, n), seed + 1), rand((n,), seed + 2)
    got = matmul_epilogue(x, w, b, divisor=divisor, relu=relu)
    want = ref.ref_matmul_epilogue(x, w, b, divisor)
    if not relu:
        want = (x @ w + b[None, :]) / divisor
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(np.float32, 2e-5), (jnp.bfloat16, 5e-2)])
def test_matmul_epilogue_dtypes(dtype, tol):
    x = rand((64, 128), 0).astype(dtype)
    w = rand((128, 64), 1).astype(dtype)
    b = rand((64,), 2).astype(dtype)
    got = np.asarray(matmul_epilogue(x, w, b, divisor=2.0), dtype=np.float32)
    want = np.asarray(
        ref.ref_matmul_epilogue(
            x.astype(np.float32), w.astype(np.float32), b.astype(np.float32), 2.0
        )
    )
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_matmul_epilogue_tiling_invariance():
    """Different tile choices must not change the numerics."""
    x, w, b = rand((128, 256), 3), rand((256, 128), 4), rand((128,), 5)
    base = matmul_epilogue(x, w, b, divisor=2.0, bm=128, bn=128, bk=256)
    for bm, bn, bk in [(32, 32, 64), (64, 128, 128), (128, 64, 32)]:
        other = matmul_epilogue(x, w, b, divisor=2.0, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(base, other, rtol=1e-5, atol=1e-5)


def test_matmul_epilogue_autofits_nondivisible_tiles():
    # 100 % 64 != 0: the kernel auto-fits the tile to a divisor (50).
    x, w, b = rand((100, 64), 0), rand((64, 64), 1), rand((64,), 2)
    got = matmul_epilogue(x, w, b, bm=64, divisor=1.0, relu=False)
    want = x @ w + b[None, :]
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ------------------------------------------- fused linear reduce (Q18)

@settings(**COMMON)
@given(
    m=st.sampled_from([8, 32, 128]),
    k=st.sampled_from([64, 256, 512]),
    n=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**16),
)
def test_fused_linear_reduce_matches_ref(m, k, n, seed):
    x, w, b = rand((m, k), seed, 0.3), rand((k, n), seed + 1, 0.3), rand((n,), seed + 2)
    got = fused_linear_reduce(x, w, b)
    want = ref.ref_fused_linear_reduce(x, w, b)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_fused_linear_reduce_equals_q18_chain():
    """The fused kernel must equal the FULL unsimplified Q18 chain —
    the algebraic-removal proof at the anchor scale."""
    x, w, b = rand((128, 512), 7, 0.1), rand((512, 256), 8, 0.1), rand((256,), 9)
    got = fused_linear_reduce(x, w, b)
    want = ref.ref_q18_naive(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------ pooling

@settings(**COMMON)
@given(
    n=st.sampled_from([1, 2, 8]),
    c=st.sampled_from([1, 3, 16]),
    hw=st.sampled_from([4, 8, 28]),
    seed=st.integers(0, 2**16),
)
def test_maxpool_matches_ref(n, c, hw, seed):
    x = rand((n, c, hw, hw), seed)
    got = maxpool2d(x)
    want = ref.ref_maxpool2d(x)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_maxpool_rejects_odd_dims():
    with pytest.raises(AssertionError):
        maxpool2d(rand((1, 1, 5, 4), 0))


# ---------------------------------------------------------- logsumexp

@settings(**COMMON)
@given(
    m=st.sampled_from([8, 128]),
    n=st.sampled_from([1, 16, 512]),
    seed=st.integers(0, 2**16),
)
def test_logsumexp_matches_ref(m, n, seed):
    x = rand((m, n), seed, 3.0)
    got = logsumexp_rows(x)
    want = ref.ref_logsumexp(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_logsumexp_on_singleton_axis_is_identity():
    x = rand((64, 1), 11, 5.0)
    np.testing.assert_allclose(logsumexp_rows(x), x, rtol=1e-6, atol=1e-6)


def test_logsumexp_numerically_stable_for_large_inputs():
    x = rand((8, 32), 13) + 500.0  # exp(500) overflows naive formulations
    got = np.asarray(logsumexp_rows(x))
    assert np.isfinite(got).all()


# -------------------------------------------------------------- linear

def test_linear_relu_flag():
    x, w, b = rand((16, 32), 1), rand((32, 16), 2), rand((16,), 3)
    with_relu = np.asarray(linear(x, w, b, relu=True, bm=16, bn=16, bk=32))
    without = np.asarray(linear(x, w, b, relu=False, bm=16, bn=16, bk=32))
    assert (with_relu >= 0).all()
    assert (without < 0).any()
    np.testing.assert_allclose(
        with_relu, np.maximum(without, 0), rtol=1e-6, atol=1e-6
    )
