#!/usr/bin/env bash
# Serve-daemon smoke (CI): boot `kernelblaster serve` on loopback with a
# log-structured store, drive optimize / batch / stats / shutdown over
# the TCP line protocol, then restart on the same store directory and
# confirm recovery serves the journaled KB. Phase 4 boots a two-tenant
# daemon under --tenant-quota, drives tagged traffic, and asserts each
# tenant recovers from its own store namespace. Talks raw bash /dev/tcp
# so the runner needs no netcat. Run from rust/ (or set KB_BIN).
set -euo pipefail

BIN=${KB_BIN:-target/release/kernelblaster}
HOST=127.0.0.1
PORT=${KB_SERVE_PORT:-7391}
WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT
STORE="$WORK/store"
SAVE="$WORK/kb.json"

wait_ready() {
  for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/$HOST/$PORT") 2>/dev/null; then
      return 0
    fi
    sleep 0.1
  done
  echo "serve_smoke: daemon never bound $HOST:$PORT" >&2
  return 1
}

# Send request lines down one connection and echo every reply line. The
# last request is always shutdown, which closes the listener and with
# it this connection, so the read side terminates on EOF.
drive() {
  exec 3<>"/dev/tcp/$HOST/$PORT"
  printf '%s\n' "$@" >&3
  cat <&3
  exec 3>&-
}

echo "== phase 1: fresh store, full op surface =="
"$BIN" serve --addr "$HOST:$PORT" --gpu H100 --store "$STORE" \
  --workers 2 --epoch-size 2 --trajectories 2 --steps 3 \
  --snapshot-every 2 --save-kb "$SAVE" &
PID=$!
wait_ready
OUT1=$(drive \
  '{"op":"optimize","task":"L1/12_softmax"}' \
  '{"op":"batch","tasks":["L1/01_matmul_square","L1/15_relu"]}' \
  '{"op":"stats"}' \
  '{"op":"shutdown"}')
wait "$PID"
echo "$OUT1"
grep -q '"op":"optimize"' <<<"$OUT1"
grep -q '"op":"batch"' <<<"$OUT1"
grep -q '"store_commits"' <<<"$OUT1"
if grep -q '"ok":false' <<<"$OUT1"; then
  echo "serve_smoke: unexpected error reply in phase 1" >&2
  exit 1
fi
test -f "$STORE/journal.log"
test -f "$STORE/snapshot.json"
# The graceful-shutdown whole-file save must be a loadable kb-v1 doc.
"$BIN" kb stats --path "$SAVE"

echo "== phase 2: restart recovers the store =="
"$BIN" serve --addr "$HOST:$PORT" --gpu H100 --store "$STORE" \
  --workers 2 --epoch-size 2 --trajectories 2 --steps 3 \
  2> "$WORK/stderr2.log" &
PID=$!
wait_ready
OUT2=$(drive \
  '{"op":"stats"}' \
  '{"op":"optimize","task":"L1/15_relu"}' \
  '{"op":"shutdown"}')
wait "$PID"
cat "$WORK/stderr2.log"
echo "$OUT2"
grep -q 'recovered KB' "$WORK/stderr2.log"
grep -q '"kb_states":' <<<"$OUT2"
if grep -q '"kb_states":0[,}]' <<<"$OUT2"; then
  echo "serve_smoke: recovery lost the phase-1 KB" >&2
  exit 1
fi
if grep -q '"ok":false' <<<"$OUT2"; then
  echo "serve_smoke: unexpected error reply in phase 2" >&2
  exit 1
fi

echo "== phase 3: sharded store layout, commit and recover =="
SHSTORE="$WORK/store_sharded"
"$BIN" serve --addr "$HOST:$PORT" --gpu H100 --store "$SHSTORE" \
  --workers 2 --shards 2 --epoch-size 2 --trajectories 2 --steps 3 \
  --snapshot-every 100 2> "$WORK/stderr3.log" &
PID=$!
wait_ready
OUT3=$(drive \
  '{"op":"batch","tasks":["L1/01_matmul_square","L1/12_softmax","L1/15_relu"]}' \
  '{"op":"stats"}' \
  '{"op":"shutdown"}')
wait "$PID"
cat "$WORK/stderr3.log"
echo "$OUT3"
# One journal segment per shard on disk, commits flowing through them.
test -f "$SHSTORE/journal-0.log"
test -f "$SHSTORE/journal-1.log"
grep -q '"store_commits"' <<<"$OUT3"
if grep -q '"ok":false' <<<"$OUT3"; then
  echo "serve_smoke: unexpected error reply in phase 3" >&2
  exit 1
fi
"$BIN" serve --addr "$HOST:$PORT" --gpu H100 --store "$SHSTORE" \
  --workers 2 --shards 2 --epoch-size 2 --trajectories 2 --steps 3 \
  2> "$WORK/stderr4.log" &
PID=$!
wait_ready
OUT4=$(drive '{"op":"stats"}' '{"op":"shutdown"}')
wait "$PID"
cat "$WORK/stderr4.log"
echo "$OUT4"
grep -q 'recovered KB' "$WORK/stderr4.log"
if grep -q '"kb_states":0[,}]' <<<"$OUT4"; then
  echo "serve_smoke: sharded recovery lost the phase-3 KB" >&2
  exit 1
fi

echo "== phase 4: two tenants, quotas, per-tenant recovery =="
TSTORE="$WORK/store_tenants"
"$BIN" serve --addr "$HOST:$PORT" --gpu H100 --store "$TSTORE" \
  --workers 2 --epoch-size 2 --trajectories 2 --steps 3 \
  --snapshot-every 2 --tenant-quota acme=3,zeta=1 2> "$WORK/stderr5.log" &
PID=$!
wait_ready
OUT5=$(drive \
  '{"op":"optimize","tenant":"acme","task":"L1/12_softmax"}' \
  '{"op":"optimize","tenant":"zeta","task":"L1/15_relu"}' \
  '{"op":"stats","tenant":"acme"}' \
  '{"op":"stats","tenant":"zeta"}' \
  '{"op":"shutdown"}')
wait "$PID"
cat "$WORK/stderr5.log"
echo "$OUT5"
# Tagged replies echo the routing tenant; each tenant persists under its
# own namespace directory of the shared store root.
grep -q '"tenant":"acme"' <<<"$OUT5"
grep -q '"tenant":"zeta"' <<<"$OUT5"
if grep -q '"ok":false' <<<"$OUT5"; then
  echo "serve_smoke: unexpected error reply in phase 4" >&2
  exit 1
fi
test -f "$TSTORE/acme/journal.log"
test -f "$TSTORE/zeta/journal.log"
"$BIN" serve --addr "$HOST:$PORT" --gpu H100 --store "$TSTORE" \
  --workers 2 --epoch-size 2 --trajectories 2 --steps 3 \
  2> "$WORK/stderr6.log" &
PID=$!
wait_ready
OUT6=$(drive \
  '{"op":"stats","tenant":"acme"}' \
  '{"op":"stats","tenant":"zeta"}' \
  '{"op":"shutdown"}')
wait "$PID"
cat "$WORK/stderr6.log"
echo "$OUT6"
grep -q 'recovered 2 tenant store(s)' "$WORK/stderr6.log"
if grep -q '"kb_states":0[,}]' <<<"$OUT6"; then
  echo "serve_smoke: tenant recovery lost a phase-4 KB" >&2
  exit 1
fi
echo "serve_smoke: OK"
