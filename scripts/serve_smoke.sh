#!/usr/bin/env bash
# Serve-daemon smoke (CI): boot `kernelblaster serve` on loopback with a
# log-structured store, drive optimize / batch / stats / shutdown over
# the TCP line protocol, then restart on the same store directory and
# confirm recovery serves the journaled KB. Talks raw bash /dev/tcp so
# the runner needs no netcat. Run from rust/ (or set KB_BIN).
set -euo pipefail

BIN=${KB_BIN:-target/release/kernelblaster}
HOST=127.0.0.1
PORT=${KB_SERVE_PORT:-7391}
WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT
STORE="$WORK/store"
SAVE="$WORK/kb.json"

wait_ready() {
  for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/$HOST/$PORT") 2>/dev/null; then
      return 0
    fi
    sleep 0.1
  done
  echo "serve_smoke: daemon never bound $HOST:$PORT" >&2
  return 1
}

# Send request lines down one connection and echo every reply line. The
# last request is always shutdown, which closes the listener and with
# it this connection, so the read side terminates on EOF.
drive() {
  exec 3<>"/dev/tcp/$HOST/$PORT"
  printf '%s\n' "$@" >&3
  cat <&3
  exec 3>&-
}

echo "== phase 1: fresh store, full op surface =="
"$BIN" serve --addr "$HOST:$PORT" --gpu H100 --store "$STORE" \
  --workers 2 --epoch-size 2 --trajectories 2 --steps 3 \
  --snapshot-every 2 --save-kb "$SAVE" &
PID=$!
wait_ready
OUT1=$(drive \
  '{"op":"optimize","task":"L1/12_softmax"}' \
  '{"op":"batch","tasks":["L1/01_matmul_square","L1/15_relu"]}' \
  '{"op":"stats"}' \
  '{"op":"shutdown"}')
wait "$PID"
echo "$OUT1"
grep -q '"op":"optimize"' <<<"$OUT1"
grep -q '"op":"batch"' <<<"$OUT1"
grep -q '"store_commits"' <<<"$OUT1"
if grep -q '"ok":false' <<<"$OUT1"; then
  echo "serve_smoke: unexpected error reply in phase 1" >&2
  exit 1
fi
test -f "$STORE/journal.log"
test -f "$STORE/snapshot.json"
# The graceful-shutdown whole-file save must be a loadable kb-v1 doc.
"$BIN" kb stats --path "$SAVE"

echo "== phase 2: restart recovers the store =="
"$BIN" serve --addr "$HOST:$PORT" --gpu H100 --store "$STORE" \
  --workers 2 --epoch-size 2 --trajectories 2 --steps 3 \
  2> "$WORK/stderr2.log" &
PID=$!
wait_ready
OUT2=$(drive \
  '{"op":"stats"}' \
  '{"op":"optimize","task":"L1/15_relu"}' \
  '{"op":"shutdown"}')
wait "$PID"
cat "$WORK/stderr2.log"
echo "$OUT2"
grep -q 'recovered KB' "$WORK/stderr2.log"
grep -q '"kb_states":' <<<"$OUT2"
if grep -q '"kb_states":0[,}]' <<<"$OUT2"; then
  echo "serve_smoke: recovery lost the phase-1 KB" >&2
  exit 1
fi
if grep -q '"ok":false' <<<"$OUT2"; then
  echo "serve_smoke: unexpected error reply in phase 2" >&2
  exit 1
fi

echo "== phase 3: sharded store layout, commit and recover =="
SHSTORE="$WORK/store_sharded"
"$BIN" serve --addr "$HOST:$PORT" --gpu H100 --store "$SHSTORE" \
  --workers 2 --shards 2 --epoch-size 2 --trajectories 2 --steps 3 \
  --snapshot-every 100 2> "$WORK/stderr3.log" &
PID=$!
wait_ready
OUT3=$(drive \
  '{"op":"batch","tasks":["L1/01_matmul_square","L1/12_softmax","L1/15_relu"]}' \
  '{"op":"stats"}' \
  '{"op":"shutdown"}')
wait "$PID"
cat "$WORK/stderr3.log"
echo "$OUT3"
# One journal segment per shard on disk, commits flowing through them.
test -f "$SHSTORE/journal-0.log"
test -f "$SHSTORE/journal-1.log"
grep -q '"store_commits"' <<<"$OUT3"
if grep -q '"ok":false' <<<"$OUT3"; then
  echo "serve_smoke: unexpected error reply in phase 3" >&2
  exit 1
fi
"$BIN" serve --addr "$HOST:$PORT" --gpu H100 --store "$SHSTORE" \
  --workers 2 --shards 2 --epoch-size 2 --trajectories 2 --steps 3 \
  2> "$WORK/stderr4.log" &
PID=$!
wait_ready
OUT4=$(drive '{"op":"stats"}' '{"op":"shutdown"}')
wait "$PID"
cat "$WORK/stderr4.log"
echo "$OUT4"
grep -q 'recovered KB' "$WORK/stderr4.log"
if grep -q '"kb_states":0[,}]' <<<"$OUT4"; then
  echo "serve_smoke: sharded recovery lost the phase-3 KB" >&2
  exit 1
fi
echo "serve_smoke: OK"
