#!/usr/bin/env python3
"""Serve trend gate: fail CI when per-tenant serving throughput regresses.

Compares a current ``BENCH_serve.json`` (format
``kernelblaster-bench-serve-v2``) against the one uploaded by a previous
CI run and exits non-zero when any (trace, tenant) cell's
``tasks_per_min`` dropped by more than the threshold (default 10%;
wall-clock on shared runners is noisier than the paired-geomean ratios
policy_trend.py gates at 5%).

The gate also enforces the current artifact's tenant-isolation verdicts
regardless of any baseline: every trace's ``isolation_ok`` must be true
— a run where a tenant's KB stopped matching its solo replay
byte-for-byte is a correctness bug, not a trend.

Contract details live in EXPERIMENTS.md §Serve ("Trend tracking").

Rules:
- a missing/unreadable previous artifact passes with a notice: the first
  run on a branch has no baseline, and a gate that fails on missing
  history would block unrelated changes;
- a previous artifact in a different format (e.g. the retired
  ``kernelblaster-bench-serve-v1``, which had no per-tenant rows) passes
  the same way — the two are not comparable;
- (trace, tenant) cells present on only one side are skipped with a
  notice — the trace/tenant roster can drift between revisions;
- a malformed *current* artifact is exit 2 (the build must have produced
  a valid one).

Usage: serve_trend.py CURRENT_JSON PREVIOUS_JSON [--threshold 0.10]
Exit codes: 0 ok / no baseline; 1 regression or isolation failure; 2 bad
invocation or a malformed current artifact.
"""

import argparse
import json
import sys

FORMAT = "kernelblaster-bench-serve-v2"


def load(path, required):
    """Return the parsed artifact or None if missing/not comparable."""
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        if required:
            print(f"serve-trend: cannot read current artifact {path}: {e}")
            sys.exit(2)
        print(f"serve-trend: no previous artifact at {path} ({e}); passing")
        return None
    fmt = doc.get("format")
    if fmt != FORMAT:
        if required:
            print(f"serve-trend: {path} has format {fmt!r}, want {FORMAT!r}")
            sys.exit(2)
        print(
            f"serve-trend: previous artifact has format {fmt!r}, "
            f"not comparable to {FORMAT!r}; passing"
        )
        return None
    return doc


def tenant_cells(doc, path, required):
    """Map (trace, tenant) -> tasks_per_min, or None for a bad baseline."""
    traces = doc.get("traces")
    if not isinstance(traces, list) or not traces:
        if required:
            print(f"serve-trend: {path} has no traces array")
            sys.exit(2)
        print("serve-trend: previous artifact has no traces array; passing")
        return None
    cells = {}
    for trace in traces:
        name = trace.get("name") if isinstance(trace, dict) else None
        rows = trace.get("per_tenant") if isinstance(trace, dict) else None
        if not isinstance(name, str) or not isinstance(rows, list):
            if required:
                print(f"serve-trend: {path} has a trace without name/per_tenant")
                sys.exit(2)
            print("serve-trend: previous artifact has a malformed trace; passing")
            return None
        for row in rows:
            tenant = row.get("tenant") if isinstance(row, dict) else None
            tpm = row.get("tasks_per_min") if isinstance(row, dict) else None
            if not isinstance(tenant, str) or not isinstance(tpm, (int, float)):
                if required:
                    print(
                        f"serve-trend: {path} trace {name!r} has a per_tenant "
                        "row without tenant/tasks_per_min"
                    )
                    sys.exit(2)
                print("serve-trend: previous artifact has a malformed row; passing")
                return None
            cells[(name, tenant)] = float(tpm)
    return cells


def main(argv):
    parser = argparse.ArgumentParser(
        prog="serve_trend.py",
        description="Fail when any (trace, tenant) tasks/min regresses past "
        "the threshold vs a previous BENCH_serve.json, or when the current "
        "run's tenant-isolation verdicts are false.",
    )
    parser.add_argument("current", help="bench JSON of this run")
    parser.add_argument("previous", help="baseline artifact (may be absent)")
    parser.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="allowed fractional drop before failing (default 0.10 = 10%%)",
    )
    try:
        args = parser.parse_args(argv)
    except SystemExit:
        return 2

    doc = load(args.current, required=True)

    # Isolation verdicts gate unconditionally — no baseline needed.
    traces = doc.get("traces")
    if not isinstance(traces, list) or not traces:
        print(f"serve-trend: {args.current} has no traces array")
        return 2
    broken = [
        trace.get("name") if isinstance(trace, dict) else None
        for trace in traces
        if not isinstance(trace, dict) or trace.get("isolation_ok") is not True
    ]
    if broken:
        names = ", ".join(str(n) for n in broken)
        print(f"serve-trend: FAIL — isolation_ok false/missing for: {names}")
        return 1
    print(f"serve-trend: isolation_ok true for all {len(traces)} trace(s)")

    cur = tenant_cells(doc, args.current, required=True)
    prev_doc = load(args.previous, required=False)
    if prev_doc is None:
        return 0
    prev = tenant_cells(prev_doc, args.previous, required=False)
    if prev is None:
        return 0

    regressed = []
    for key in sorted(cur):
        if key not in prev:
            print(f"serve-trend: no baseline cell for {key[0]}/{key[1]}; skipping")
            continue
        cur_tpm, prev_tpm = cur[key], prev[key]
        floor = prev_tpm * (1.0 - args.threshold)
        verdict = "REGRESSED" if cur_tpm < floor else "ok"
        print(
            f"serve-trend: {key[0]}/{key[1]}: tasks/min {prev_tpm:.2f} -> "
            f"{cur_tpm:.2f} (floor {floor:.2f}) {verdict}"
        )
        if cur_tpm < floor:
            regressed.append(f"{key[0]}/{key[1]}")
    for key in sorted(prev):
        if key not in cur:
            print(f"serve-trend: baseline cell {key[0]}/{key[1]} gone; skipping")

    if regressed:
        print(
            f"serve-trend: FAIL — {len(regressed)} cell(s) dropped more than "
            f"{args.threshold:.0%}: {', '.join(regressed)}"
        )
        return 1
    print("serve-trend: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
