#!/usr/bin/env python3
"""Fleet throughput trend gate: fail CI when the scaling grid regresses.

Compares a current ``BENCH_fleet.json`` (format
``kernelblaster-bench-fleet-v2``) against the one uploaded by a previous
CI run and exits non-zero when the **top grid cell**'s ``tasks_per_min``
(max workers x max shards — the headline of the scaling claim) dropped
by more than the threshold (default 10%; wall-clock on shared runners is
noisier than the paired-geomean ratios policy_trend.py gates at 5%).

The gate also enforces the current artifact's determinism verdicts
regardless of any baseline: ``parity.grid_kb_invariant``,
``parity.epoch1_kb_bytes_identical`` and ``parity.epoch1_runs_identical``
must all be true — a fleet run that stopped reproducing the
single-committer KB byte-for-byte is a correctness bug, not a trend.

Contract details live in EXPERIMENTS.md §Fleet ("Trend tracking").

Rules:
- a missing/unreadable previous artifact passes with a notice: the first
  run on a branch has no baseline, and a gate that fails on missing
  history would block unrelated changes;
- a previous artifact in a different format (e.g. the retired
  ``kernelblaster-bench-fleet-v1``) passes the same way — the two are
  not comparable;
- a malformed *current* artifact is exit 2 (the build must have produced
  a valid one).

Usage: fleet_trend.py CURRENT_JSON PREVIOUS_JSON [--threshold 0.10]
Exit codes: 0 ok / no baseline; 1 regression or parity failure; 2 bad
invocation or a malformed current artifact.
"""

import argparse
import json
import sys

FORMAT = "kernelblaster-bench-fleet-v2"
PARITY_KEYS = (
    "grid_kb_invariant",
    "epoch1_kb_bytes_identical",
    "epoch1_runs_identical",
)


def load(path, required):
    """Return the parsed artifact or None if missing/not comparable."""
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        if required:
            print(f"fleet-trend: cannot read current artifact {path}: {e}")
            sys.exit(2)
        print(f"fleet-trend: no previous artifact at {path} ({e}); passing")
        return None
    fmt = doc.get("format")
    if fmt != FORMAT:
        if required:
            print(f"fleet-trend: {path} has format {fmt!r}, want {FORMAT!r}")
            sys.exit(2)
        print(
            f"fleet-trend: previous artifact has format {fmt!r}, "
            f"not comparable to {FORMAT!r}; passing"
        )
        return None
    return doc


def top_throughput(doc, path):
    top = doc.get("top_cell")
    tpm = top.get("tasks_per_min") if isinstance(top, dict) else None
    if not isinstance(tpm, (int, float)):
        print(f"fleet-trend: {path} has no numeric top_cell.tasks_per_min")
        sys.exit(2)
    return top, tpm


def main(argv):
    parser = argparse.ArgumentParser(
        prog="fleet_trend.py",
        description="Fail when the fleet grid's top-cell tasks/min regresses "
        "past the threshold vs a previous BENCH_fleet.json, or when the "
        "current run's KB byte-parity verdicts are false.",
    )
    parser.add_argument("current", help="bench JSON of this run")
    parser.add_argument("previous", help="baseline artifact (may be absent)")
    parser.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="allowed fractional drop before failing (default 0.10 = 10%%)",
    )
    try:
        args = parser.parse_args(argv)
    except SystemExit:
        return 2

    doc = load(args.current, required=True)

    # Determinism verdicts gate unconditionally — no baseline needed.
    parity = doc.get("parity")
    if not isinstance(parity, dict):
        print(f"fleet-trend: {args.current} has no parity section")
        return 2
    broken = [k for k in PARITY_KEYS if parity.get(k) is not True]
    if broken:
        print(f"fleet-trend: FAIL — parity verdict(s) false: {', '.join(broken)}")
        return 1
    print(f"fleet-trend: parity verdicts all true ({', '.join(PARITY_KEYS)})")

    top, cur_tpm = top_throughput(doc, args.current)
    prev_doc = load(args.previous, required=False)
    if prev_doc is None:
        return 0
    _, prev_tpm = top_throughput(prev_doc, args.previous)

    floor = prev_tpm * (1.0 - args.threshold)
    verdict = "REGRESSED" if cur_tpm < floor else "ok"
    print(
        f"fleet-trend: top cell ({top.get('workers')}w x {top.get('shards')}s): "
        f"tasks/min {prev_tpm:.2f} -> {cur_tpm:.2f} (floor {floor:.2f}) {verdict}"
    )
    if cur_tpm < floor:
        print(
            f"fleet-trend: FAIL — top-cell throughput dropped more than "
            f"{args.threshold:.0%}"
        )
        return 1
    print("fleet-trend: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
