#!/usr/bin/env python3
"""Bench trend gate: fail CI when a paired-arm benchmark regresses.

Compares a current bench artifact against the one uploaded by a previous
CI run and exits non-zero when any arm's ``vs_greedy_paired`` ratio
dropped by more than the threshold (default 5%). Two artifact formats
are understood, auto-detected from the document's ``format`` key:

- ``kernelblaster-bench-policy-v1`` (``BENCH_policy.json``) — arms are
  matched by their ``policy`` name;
- ``kernelblaster-bench-sweep-v1`` (``BENCH_sweep.json``) — arms are
  matched by their ``label`` (one per hyperparameter grid point).

Contract details live in EXPERIMENTS.md §Policy ("Trend tracking").

Rules:
- arms present only on one side are reported but never fail the gate
  (adding or removing an arm is a reviewed code change, not a
  regression);
- an arm is skipped when either side has ``paired_cells`` == 0 or a
  non-numeric ratio (the crate serializes degenerate geomeans as null) —
  there is nothing comparable to trend;
- the ``greedy_topk`` baseline arm is skipped (its ratio is 1.0 by
  construction);
- a missing/unreadable previous artifact passes with a notice: the first
  run on a branch has no baseline, and a gate that fails open on missing
  history would block unrelated changes. A previous artifact in a
  *different* format than the current one passes the same way — the two
  are not comparable.

Usage: policy_trend.py CURRENT_JSON PREVIOUS_JSON [--threshold 0.05]
Exit codes: 0 ok / no baseline; 1 regression; 2 bad invocation or a
malformed *current* artifact (the build must have produced a valid one).
"""

import argparse
import json
import sys

# format identifier -> the arm key that names an arm in that format.
FORMATS = {
    "kernelblaster-bench-policy-v1": "policy",
    "kernelblaster-bench-sweep-v1": "label",
}
BASELINE_ARM = "greedy_topk"


def load_arms(path, required, expect_format=None):
    """Return (format, {arm_name: arm_dict}) or None if missing/malformed.

    ``expect_format`` pins the accepted format (used for the previous
    artifact, which must match the current one to be comparable).
    """
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        if required:
            print(f"policy-trend: cannot read current artifact {path}: {e}")
            sys.exit(2)
        print(f"policy-trend: no previous artifact at {path} ({e}); passing")
        return None
    fmt = doc.get("format")
    wanted = [expect_format] if expect_format else sorted(FORMATS)
    if fmt not in wanted:
        if required:
            print(f"policy-trend: {path} has format {fmt!r}, want one of {wanted}")
            sys.exit(2)
        print(
            f"policy-trend: previous artifact has format {fmt!r}, "
            f"not comparable to the current one; passing"
        )
        return None
    key = FORMATS[fmt]
    return fmt, {a.get(key): a for a in doc.get("arms", [])}


def comparable(arm):
    ratio = arm.get("vs_greedy_paired")
    return (
        isinstance(ratio, (int, float))
        and arm.get("paired_cells", 0) > 0
    )


def main(argv):
    parser = argparse.ArgumentParser(
        prog="policy_trend.py",
        description="Fail when a bench arm's vs_greedy_paired regresses past "
        "the threshold vs a previous BENCH_policy.json / BENCH_sweep.json.",
    )
    parser.add_argument("current", help="bench JSON of this run")
    parser.add_argument("previous", help="baseline artifact (may be absent)")
    parser.add_argument(
        "--threshold",
        type=float,
        default=0.05,
        help="allowed fractional drop before failing (default 0.05 = 5%%)",
    )
    try:
        args = parser.parse_args(argv)
    except SystemExit:
        return 2
    threshold = args.threshold

    cur_format, current = load_arms(args.current, required=True)
    loaded = load_arms(args.previous, required=False, expect_format=cur_format)
    if loaded is None:
        return 0
    _, previous = loaded

    regressions = []
    for name, cur in current.items():
        if name == BASELINE_ARM:
            continue
        prev = previous.get(name)
        if prev is None:
            print(f"policy-trend: arm '{name}' is new (no baseline) — skipped")
            continue
        if not comparable(cur) or not comparable(prev):
            print(f"policy-trend: arm '{name}' has no comparable paired cells — skipped")
            continue
        cur_ratio = cur["vs_greedy_paired"]
        prev_ratio = prev["vs_greedy_paired"]
        floor = prev_ratio * (1.0 - threshold)
        verdict = "REGRESSED" if cur_ratio < floor else "ok"
        print(
            f"policy-trend: {name}: vs_greedy_paired {prev_ratio:.4f} -> "
            f"{cur_ratio:.4f} (floor {floor:.4f}) {verdict}"
        )
        if cur_ratio < floor:
            regressions.append(name)
    for name in previous:
        if name != BASELINE_ARM and name not in current:
            print(f"policy-trend: arm '{name}' disappeared — skipped (reviewed change)")

    if regressions:
        print(
            f"policy-trend: FAIL — {len(regressions)} arm(s) regressed more than "
            f"{threshold:.0%}: {', '.join(regressions)}"
        )
        return 1
    print("policy-trend: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
