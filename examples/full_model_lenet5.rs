//! End-to-end driver over the full three-layer stack (the repo's
//! integration proof): optimize the LeNet-5 Level-3 task with the MAIC-RL
//! coordinator (Layer 3), then load the REAL AOT artifacts produced from
//! the JAX/Pallas layers (Layers 2/1) and serve batched inference through
//! the PJRT runtime, reporting latency and throughput.
//!
//!     make artifacts && cargo run --release --example full_model_lenet5
//!
//! Recorded in EXPERIMENTS.md §End-to-end.

use kernelblaster::baselines;
use kernelblaster::gpu::GpuArch;
use kernelblaster::icrl::{self, IcrlConfig};
use kernelblaster::kb::KnowledgeBase;
use kernelblaster::runtime::{anchors, default_artifact_dir, Runtime};
use kernelblaster::tasks::Suite;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // ---------------- Layer 3: the paper's optimization loop ----------
    let suite = Suite::full();
    let task = suite.by_id("L3/01_lenet5").expect("lenet5 registered");
    let arch = GpuArch::h100();
    let base = baselines::baseline_times(task, &arch);
    let mut kb = KnowledgeBase::empty();
    let run = icrl::optimize_task(task, &arch, &mut kb, &IcrlConfig::default(), 0);
    println!("== MAIC-RL optimization of {} ({}) ==", task.id, arch.name);
    println!(
        "naive {:.1}us -> best {:.1}us | {:.2}x vs naive | {:.2}x vs PyTorch (paper: 2.68x)",
        run.naive_time_s * 1e6,
        run.best_time_s * 1e6,
        run.speedup_vs_naive(),
        base.best_s() / run.best_time_s
    );
    println!(
        "kernel launches: {} -> {}",
        task.graph.nodes.len(),
        run.best.schedule.n_launches()
    );
    println!("applied: {}", run.best.applied.join(" -> "));

    // ---------------- Layers 2/1: real artifacts on PJRT --------------
    let rt = Runtime::new(default_artifact_dir())?;
    println!("\n== PJRT runtime ({}) ==", rt.platform());

    // Correctness + timing gates for every anchor pair.
    let cal = anchors::calibrate(&rt, 2, 5)?;
    print!("{}", anchors::render(&cal));

    // Serve batched LeNet-5 inference through the compiled artifact.
    let model = rt.load("lenet5_naive")?;
    let inputs = model.random_inputs(7, 0.5);
    let batch = model.input_shapes[0][0];
    let requests = 64;
    let mut latencies = Vec::with_capacity(requests);
    let t0 = Instant::now();
    for _ in 0..requests {
        let start = Instant::now();
        let out = model.run_f32(&inputs)?;
        latencies.push(start.elapsed().as_secs_f64());
        assert_eq!(out[0].len(), batch * 10);
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[(latencies.len() * 99) / 100];
    println!(
        "\nserved {requests} batched requests (batch={batch}): p50 {:.2}ms p99 {:.2}ms | {:.0} images/s",
        p50 * 1e3,
        p99 * 1e3,
        (requests * batch) as f64 / wall
    );
    Ok(())
}
