//! Continual cross-task learning: run the full L1 → L2 → L3 curriculum
//! with one persistent Knowledge Base and watch the artifact grow while
//! later levels benefit from earlier experience — the paper's core
//! "long-term cross-task learning" contribution (§1 contribution 3).
//!
//!     cargo run --release --example continual_learning

use kernelblaster::experiments::{run_ours, Ctx};
use kernelblaster::gpu::GpuArch;
use kernelblaster::kb::{persist, KnowledgeBase};
use kernelblaster::metrics;
use kernelblaster::tasks::Level;
use kernelblaster::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(false, 42);
    let arch = GpuArch::l40s();
    let mut kb = KnowledgeBase::empty();

    println!("continual curriculum on {} (persistent KB):", arch.name);
    for level in [Level::L1, Level::L2, Level::L3] {
        let (_runs, scores) = run_ours(&ctx, &arch, level, false, &mut kb);
        let s = metrics::summarize(&scores);
        println!(
            "{}: geomean {:.3}x vs PyTorch | valid {:.0}% | KB now {} states / {} attempts / {}",
            level.name(),
            s.summary.geomean,
            s.valid_rate * 100.0,
            kb.states.len(),
            kb.total_attempts(),
            human_bytes(kb.size_bytes()),
        );
    }

    // Persist the final artifact — this file is the "re-usable artifact"
    // the paper releases (initialized databases).
    let path = std::env::temp_dir().join("kernelblaster_continual_kb.json");
    persist::save(&kb, &path)?;
    println!("final KB saved to {}", path.display());
    Ok(())
}
