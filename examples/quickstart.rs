//! Quickstart: optimize one KernelBench-style task with KernelBlaster's
//! MAIC-RL loop and inspect what the agent learned.
//!
//!     cargo run --release --example quickstart
//!
//! This walks the whole public API surface: task suite → driver → harness
//! → knowledge base → persistence.

use kernelblaster::baselines;
use kernelblaster::gpu::GpuArch;
use kernelblaster::icrl::{self, IcrlConfig};
use kernelblaster::kb::{persist, KnowledgeBase};
use kernelblaster::tasks::Suite;

fn main() -> anyhow::Result<()> {
    // 1. Pick a task: the paper's L2-Q18 (linear → sum → double
    //    logsumexp), the 20.17x headline example.
    let suite = Suite::full();
    let task = suite
        .by_id("L2/18_linear_sum_logsumexp2")
        .expect("task registered");
    let arch = GpuArch::h100();
    println!("task: {}  |  GPU model: {}", task.id, arch.name);

    // 2. Reference points: PyTorch eager / torch.compile.
    let base = baselines::baseline_times(task, &arch);
    println!(
        "PyTorch eager {:.1}us | torch.compile {:.1}us",
        base.eager_s * 1e6,
        base.compiled_s * 1e6
    );

    // 3. Run the MAIC-RL driver (Table-2 hyperparameters).
    let mut kb = KnowledgeBase::empty();
    let cfg = IcrlConfig::default();
    let run = icrl::optimize_task(task, &arch, &mut kb, &cfg, 0);

    println!(
        "naive CUDA {:.1}us -> best {:.1}us  ({:.2}x vs naive, {:.2}x vs PyTorch)",
        run.naive_time_s * 1e6,
        run.best_time_s * 1e6,
        run.speedup_vs_naive(),
        base.best_s() / run.best_time_s
    );
    println!("applied: {}", run.best.applied.join(" -> "));
    println!(
        "tokens: {} | states visited: {}",
        run.tokens.total(),
        run.states_visited
    );

    // 4. The Knowledge Base is the reusable cross-task artifact.
    let path = std::env::temp_dir().join("kernelblaster_quickstart_kb.json");
    persist::save(&kb, &path)?;
    println!(
        "knowledge base: {} states, {} -> {}",
        kb.states.len(),
        kernelblaster::util::human_bytes(kb.size_bytes()),
        path.display()
    );
    Ok(())
}
