//! Cross-GPU knowledge transfer (paper Fig. 16): train a Knowledge Base
//! on A6000 Level-1 tasks, then reuse it on H100 — the agent should
//! converge with far fewer new discoveries.
//!
//!     cargo run --release --example cross_gpu_transfer

use kernelblaster::experiments::{run_ours, Ctx};
use kernelblaster::gpu::GpuArch;
use kernelblaster::icrl::TaskRun;
use kernelblaster::kb::KnowledgeBase;
use kernelblaster::tasks::Level;
use kernelblaster::util::stats;

/// Fraction of attempts that introduce a (state, technique) entry absent
/// from the KB at run start — what "discovery" means against a
/// pretrained artifact (entries the trained KB already holds are reuse,
/// not discovery).
fn new_entry_rate(runs: &[TaskRun], kb_before: &KnowledgeBase) -> f64 {
    let mut known: std::collections::BTreeSet<(String, &str)> = kb_before
        .states
        .iter()
        .flat_map(|s| {
            s.opts
                .iter()
                .map(move |o| (s.sig.id(), o.technique.name()))
        })
        .collect();
    let mut discovered = 0usize;
    let mut attempts = 0usize;
    for r in runs {
        for s in &r.steps {
            attempts += 1;
            if known.insert((s.state.id(), s.technique.name())) {
                discovered += 1;
            }
        }
    }
    discovered as f64 / attempts.max(1) as f64
}

fn geomean_vs_naive(runs: &[TaskRun]) -> f64 {
    let v: Vec<f64> = runs
        .iter()
        .filter(|r| r.valid)
        .map(|r| r.speedup_vs_naive())
        .collect();
    stats::geomean(&v)
}

fn main() {
    let ctx = Ctx::new(false, 42);

    // Phase 1: train on A6000 (Ampere).
    let a6000 = GpuArch::a6000();
    let empty = KnowledgeBase::empty();
    let mut kb = KnowledgeBase::empty();
    let (train_runs, _) = run_ours(&ctx, &a6000, Level::L1, false, &mut kb);
    println!(
        "A6000 training: geomean {:.2}x vs naive | discovery rate {:.4}/attempt | KB {} states",
        geomean_vs_naive(&train_runs),
        new_entry_rate(&train_runs, &empty),
        kb.states.len()
    );

    // Phase 2: reuse the trained KB on H100 (Hopper) vs starting fresh.
    let h100 = GpuArch::h100();
    let mut kb_transfer = kb.clone();
    let (transfer_runs, _) = run_ours(&ctx, &h100, Level::L1, false, &mut kb_transfer);
    let mut kb_fresh = KnowledgeBase::empty();
    let (fresh_runs, _) = run_ours(&ctx, &h100, Level::L1, false, &mut kb_fresh);

    let rate_transfer = new_entry_rate(&transfer_runs, &kb);
    let rate_fresh = new_entry_rate(&fresh_runs, &empty);
    println!(
        "H100 with A6000-trained KB: geomean {:.2}x | discovery rate {:.4}/attempt",
        geomean_vs_naive(&transfer_runs),
        rate_transfer
    );
    println!(
        "H100 from scratch:          geomean {:.2}x | discovery rate {:.4}/attempt",
        geomean_vs_naive(&fresh_runs),
        rate_fresh
    );
    println!(
        "transfer cuts the discovery burden by {:.0}% (paper Fig. 16's claim)",
        (1.0 - rate_transfer / rate_fresh) * 100.0
    );
}
