//! Command-line interface (hand-rolled: the offline registry has no
//! clap). Subcommands:
//!
//! - `experiment <name|all> [--quick] [--seed N] [--out DIR]`
//! - `optimize --task <id> [--gpu NAME] [--trajectories N] [--steps N]
//!            [--vendor] [--kb PATH] [--warm-start P1,P2,…]
//!            [--save-kb PATH] [--seed N] [--staged] [--memo PATH]` —
//!   `--staged` turns on the tiered verification pipeline
//!   ([`crate::harness::staged`]); `--memo` persists verdicts across runs
//! - `batch --jobs FILE [--gpu NAME] [--workers N] [--epoch-size N]
//!         [--checkpoint-every N] [--checkpoint PATH] [--kb PATH]
//!         [--save-kb PATH] [--config run.json] …` — fleet batch serving:
//!   streams per-task results as JSON-lines, checkpoints the shared KB
//!   crash-safely (see [`crate::icrl::fleet`])
//! - `serve [--addr HOST:PORT] [--store DIR] [--gpu NAME] [--workers N]
//!         [--throughput] [--snapshot-every N] …` — long-lived daemon:
//!   a TCP line protocol serves optimize/batch requests against the
//!   live KB, persisting every commit through the log-structured store
//!   ([`crate::serve`], [`crate::kb::store`])
//! - `suite --level <L1|L2|L3> [--gpu NAME] [--quick] [--seed N]`
//! - `calibrate [--iters N]` — PJRT anchor measurement
//! - `kb <init|inspect|stats> --path PATH` — single-KB inspection
//! - `kb merge IN1 IN2 … --out PATH` — evidence-weighted KB merge
//! - `kb compact --path IN [--out PATH] [--min-attempts N]
//!              [--gain-floor X] [--max-notes N]`
//! - `kb transfer --path IN --to ARCH [--from ARCH] [--decay X]
//!               [--rekey-threshold X] [--out PATH]`
//! - `kb mine --path IN [--out PATH] …` — run fresh rollouts over the
//!   KB, mine winning technique chains from the replay logs
//!   ([`crate::kb::skills`]) and install them as composite skill entries;
//!   `--skills` on `optimize`/`batch` lets policies draw them
//! - `memo compact --path IN [--out PATH] --max-entries N` — bound a
//!   persistent verification memo (failures evicted first, then LRU);
//!   without `--max-entries`, a `--config` file's
//!   `verify.memo_max_entries` supplies the bound
//!
//! `--policy auto` (on `optimize`/`batch`/`serve`) resolves the search
//! policy from a sweep artifact (`BENCH_sweep.json` or `--sweep FILE`):
//! the arm with the best paired-vs-greedy score wins; a missing or
//! unusable artifact falls back to `greedy_topk` with a stderr notice.
//! - `list` — tasks, experiments, GPUs
//! - `version`
//!
//! The `kb` lifecycle subcommands are thin shells over
//! [`crate::kb::lifecycle`]; run launching goes through
//! [`crate::icrl`] with configs from [`crate::config`]. This module sits
//! *outside* the optimization loop — it only assembles inputs for it.

use crate::baselines;
use crate::experiments::{self, Ctx};
use crate::gpu::GpuArch;
use crate::harness::memo;
use crate::harness::staged::VerifyConfig;
use crate::icrl::{self, IcrlConfig, PolicyConfig, PolicyKind, Schedule, SkillsConfig};
use crate::kb::lifecycle::{self, CompactPolicy, TransferPolicy};
use crate::kb::skills as kb_skills;
use crate::kb::{persist, KnowledgeBase};
use crate::runtime;
use crate::tasks::{Level, Suite};
use crate::util::table::Table;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed flag map: `--key value` and bare `--switch` both supported.
pub struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(String::as_str)
    }

    /// All positionals from index `i` on (e.g. the input files of
    /// `kb merge a.json b.json …`).
    pub fn pos_from(&self, i: usize) -> &[String] {
        self.positional.get(i..).unwrap_or(&[])
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> u64 {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> f64 {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

pub const USAGE: &str = "\
kernelblaster — continual cross-task kernel optimization via MAIC-RL

USAGE:
  kernelblaster experiment <name|all> [--quick] [--seed N] [--out DIR]
  kernelblaster run --config run.json    # config-file launcher
  kernelblaster optimize --task <id> [--gpu H100] [--trajectories N] [--steps N]
                         [--vendor] [--kb PATH] [--warm-start P1,P2,...]
                         [--save-kb PATH] [--seed N]
                         [--policy greedy_topk|epsilon_greedy|ucb_bandit|beam_search|portfolio|thompson|auto]
                         [--sweep BENCH_sweep.json]
                         [--epsilon X] [--ucb-c X] [--beam-width N]
                         [--schedule constant|harmonic|exponential] [--schedule-rate X]
                         [--dedup-distance X]
                         [--staged] [--no-screen] [--no-probe]
                         [--screen-margin X|auto] [--verify-bench FILE]
                         [--probe-seeds N] [--memo PATH]
                         [--skills] [--skill-max-len N] [--skill-min-support N]
                         [--skill-min-gain X] [--skill-max-per-state N]
  kernelblaster batch --jobs FILE [--gpu H100] [--workers 4] [--epoch-size 8]
                      [--shards 1] [--commit-queue 8]
                      [--checkpoint-every N] [--checkpoint PATH] [--kb PATH]
                      [--save-kb PATH] [--trajectories N] [--steps N] [--seed N]
                      [--vendor] [--policy NAME|auto] [--sweep FILE]
                      [--epsilon X] [--ucb-c X]
                      [--beam-width N] [--schedule NAME] [--schedule-rate X]
                      [--dedup-distance X] [--epoch-policies NAME,NAME,...|auto]
                      [--staged] [--no-screen] [--no-probe]
                      [--screen-margin X|auto] [--verify-bench FILE]
                      [--probe-seeds N] [--memo PATH] [--config run.json]
                      [--skills] [--skill-max-len N] [--skill-min-support N]
                      [--skill-min-gain X] [--skill-max-per-state N]
  kernelblaster serve [--addr 127.0.0.1:7070] [--gpu H100] [--store DIR]
                      [--kb PATH] [--save-kb PATH] [--workers 4] [--epoch-size 8]
                      [--shards 1] [--commit-queue 8]
                      [--throughput] [--snapshot-every 64] [--trajectories N]
                      [--steps N] [--seed N] [--vendor] [--policy NAME|auto]
                      [--staged] [--memo PATH] [--memo-max-entries N]
                      [--tenant-quota name=W,name=W] [--base-kb PATH]
                      [--config run.json]
  kernelblaster suite --level <L1|L2|L3> [--gpu H100] [--quick] [--seed N]
  kernelblaster calibrate [--iters N]
  kernelblaster kb <init|inspect|stats> --path PATH
  kernelblaster kb merge IN1 IN2 [...] --out PATH
  kernelblaster kb compact --path IN [--out PATH] [--min-attempts 4]
                           [--gain-floor 1.0] [--max-notes 3]
  kernelblaster kb transfer --path IN --to ARCH [--from ARCH] [--decay 0.5]
                            [--rekey-threshold 1.5] [--out PATH]
  kernelblaster kb mine --path IN [--out PATH] [--gpu H100]
                        [--tasks id,id,...|--jobs FILE] [--trajectories N]
                        [--steps N] [--seed N] [--skill-max-len 3]
                        [--skill-min-support 2] [--skill-min-gain 1.05]
                        [--skill-max-per-state 4]
  kernelblaster memo compact --path IN [--out PATH] [--max-entries N]
                             [--config run.json]
  kernelblaster list
  kernelblaster version

Experiments (paper artifact regenerators — see DESIGN.md §6):
  table3 fig7 fig8 fig9 fig10 fig11 fig12 fig13_14 fig15_16 fig17 fig18
  fig19 ablation_mem minimal_agent continual fleet policy sweep verify
  skills serve
";

/// Run the CLI; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    let args = Args::parse(argv);
    match args.pos(0) {
        Some("experiment") => cmd_experiment(&args),
        Some("run") => cmd_run(&args),
        Some("batch") => cmd_batch(&args),
        Some("serve") => cmd_serve(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("suite") => cmd_suite(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("kb") => cmd_kb(&args),
        Some("memo") => cmd_memo(&args),
        Some("list") => cmd_list(),
        Some("version") => {
            println!("kernelblaster {}", env!("CARGO_PKG_VERSION"));
            0
        }
        _ => {
            eprint!("{USAGE}");
            2
        }
    }
}

fn cmd_experiment(args: &Args) -> i32 {
    let Some(name) = args.pos(1) else {
        eprintln!("experiment: missing name (try `kernelblaster list`)");
        return 2;
    };
    let ctx = Ctx::new(args.has("quick"), args.u64_flag("seed", 42));
    let out_dir = PathBuf::from(args.flag("out").unwrap_or("results"));
    let runs: Vec<(&str, fn(&Ctx) -> experiments::Report)> = if name == "all" {
        experiments::registry()
    } else {
        match experiments::by_name(name) {
            Some(f) => vec![(name, f)],
            None => {
                eprintln!("unknown experiment '{name}' (try `kernelblaster list`)");
                return 2;
            }
        }
    };
    for (n, f) in runs {
        eprintln!("running experiment {n}{} ...", if ctx.quick { " (quick)" } else { "" });
        let report = f(&ctx);
        print!("{}", report.render());
        match report.write_csvs(&out_dir) {
            Ok(files) => {
                for p in files {
                    eprintln!("wrote {}", p.display());
                }
            }
            Err(e) => eprintln!("warning: CSV write failed: {e}"),
        }
    }
    0
}

/// Config-file launcher: run the tasks named in a RunConfig (or the
/// whole suite) and print a summary. The resolved config is archived
/// beside the results for reproducibility.
fn cmd_run(args: &Args) -> i32 {
    let Some(path) = args.flag("config") else {
        eprintln!("run: need --config FILE (see config::RunConfig)");
        return 2;
    };
    let cfg = match crate::config::RunConfig::load(Path::new(path)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 1;
        }
    };
    let arch = cfg.resolve_arch().expect("validated at load");
    let suite = Suite::full();
    let tasks: Vec<&crate::tasks::Task> = if cfg.tasks.is_empty() {
        suite.tasks.iter().collect()
    } else {
        let mut selected = Vec::new();
        for id in &cfg.tasks {
            match suite.by_id(id) {
                Some(t) => selected.push(t),
                None => {
                    eprintln!("unknown task '{id}' in config");
                    return 2;
                }
            }
        }
        selected
    };
    let mut kb = match &cfg.kb_load {
        Some(p) => match load_kb(p) {
            Ok(kb) => kb,
            Err(code) => return code,
        },
        None => KnowledgeBase::empty(),
    };
    if !cfg.warm_start.is_empty() {
        kb = match assemble_warm_start(
            std::mem::take(&mut kb),
            &cfg.warm_start,
            &arch,
            &cfg.transfer,
        ) {
            Ok(kb) => kb,
            Err(code) => return code,
        };
    }
    let runs = icrl::run_suite(&tasks, &arch, &mut kb, &cfg.icrl);
    let mut t = Table::new(&["task", "valid", "vs naive", "vs PyTorch", "tokens"]);
    let mut scores = Vec::new();
    for (task, r) in tasks.iter().zip(&runs) {
        let base = baselines::baseline_times(task, &arch).best_s();
        scores.push(crate::metrics::TaskScore {
            valid: r.valid,
            speedup: base / r.best_time_s,
        });
        t.add_row(vec![
            r.task_id.clone(),
            r.valid.to_string(),
            format!("{:.2}x", r.speedup_vs_naive()),
            format!("{:.2}x", base / r.best_time_s),
            r.tokens.total().to_string(),
        ]);
    }
    print!("{}", t.render());
    let s = crate::metrics::summarize(&scores);
    println!(
        "geomean vs PyTorch: {:.3}x | valid {:.0}% | KB {} states",
        s.summary.geomean,
        s.valid_rate * 100.0,
        kb.states.len()
    );
    if let Some(p) = &cfg.kb_save {
        if let Err(e) = persist::save(&kb, Path::new(p)) {
            eprintln!("failed to save KB: {e}");
            return 1;
        }
        eprintln!("saved KB to {p}");
    }
    0
}

/// Parse a batch job file: one task id per line; blank lines and
/// `#`-comments are skipped.
fn parse_job_file(path: &Path) -> Result<Vec<String>, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    Ok(text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect())
}

/// One task's JSON-lines record for the `batch` stream.
fn task_jsonl(index: usize, run: &icrl::TaskRun) -> String {
    let mut o = crate::util::json::JsonObj::new();
    o.set("event", "task");
    o.set("index", index);
    o.set("task", run.task_id.as_str());
    o.set("valid", run.valid);
    o.set("naive_time_s", run.naive_time_s);
    o.set("best_time_s", run.best_time_s);
    o.set("speedup_vs_naive", run.speedup_vs_naive());
    o.set("tokens", run.tokens.total());
    o.set("states_visited", run.states_visited);
    crate::util::json::Json::Obj(o).to_string_compact()
}

/// Fleet batch serving: run a job file's tasks concurrently over the
/// shared KB, streaming per-task JSON-lines to stdout and checkpointing
/// the KB crash-safely every N commits.
fn cmd_batch(args: &Args) -> i32 {
    use crate::icrl::fleet::{self, FleetObserver};

    // Base config (optional file), then flag overrides.
    let mut cfg = match args.flag("config") {
        Some(p) => match crate::config::RunConfig::load(Path::new(p)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 1;
            }
        },
        None => crate::config::RunConfig::default(),
    };
    if let Some(g) = args.flag("gpu") {
        cfg.gpu = g.to_string();
    }
    cfg.icrl.trajectories = args.usize_flag("trajectories", cfg.icrl.trajectories);
    cfg.icrl.rollout_steps = args.usize_flag("steps", cfg.icrl.rollout_steps);
    cfg.icrl.seed = args.u64_flag("seed", cfg.icrl.seed);
    if args.has("vendor") {
        cfg.icrl.harness.allow_vendor = true;
    }
    // Per-batch policy: flags override the config file's [policy] section
    // (within an epoch the whole fleet runs one policy; per-task policies
    // would break the shared-KB delta semantics' evidence comparability).
    cfg.icrl.policy = match policy_from_flags(args, cfg.icrl.policy) {
        Ok(p) => p,
        Err(code) => return code,
    };
    // Per-epoch policy mix: `--epoch-policies` replaces the config
    // file's fleet.epoch_policies outright (explore-heavy early epochs,
    // exploit later; saturates at the last name). Without it, the CLI
    // hyperparameter flags overlay each config-file entry so `--epsilon`
    // etc. mean the same thing whichever source named the mix — only
    // each entry's kind is the file's to keep.
    // `--epoch-policies auto` hands the mix to the KB-maturity scheduler
    // ([`fleet::auto_epoch_policy`]) instead of naming it by hand.
    if args.flag("epoch-policies") == Some("auto") {
        cfg.fleet.auto_epoch_policies = true;
        cfg.fleet.epoch_policies.clear();
    } else {
        match epoch_policies_from_flags(args, &cfg.icrl.policy) {
            Ok(mix) if !mix.is_empty() => {
                cfg.fleet.epoch_policies = mix;
                cfg.fleet.auto_epoch_policies = false;
            }
            Ok(_) => {
                for i in 0..cfg.fleet.epoch_policies.len() {
                    let entry = cfg.fleet.epoch_policies[i].clone();
                    cfg.fleet.epoch_policies[i] = match policy_hypers_from_flags(args, entry) {
                        Ok(p) => p,
                        Err(code) => return code,
                    };
                }
            }
            Err(code) => return code,
        }
    }
    cfg.icrl.verify = match verify_from_flags(args, cfg.icrl.verify.clone()) {
        Ok(v) => v,
        Err(code) => return code,
    };
    cfg.icrl.skills = match skills_from_flags(args, cfg.icrl.skills.clone()) {
        Ok(s) => s,
        Err(code) => return code,
    };
    cfg.fleet.workers = args.usize_flag("workers", cfg.fleet.workers);
    cfg.fleet.epoch_size = args.usize_flag("epoch-size", cfg.fleet.epoch_size);
    cfg.fleet.checkpoint_every =
        args.usize_flag("checkpoint-every", cfg.fleet.checkpoint_every);
    cfg.fleet.shards = args.usize_flag("shards", cfg.fleet.shards);
    cfg.fleet.commit_queue = args.usize_flag("commit-queue", cfg.fleet.commit_queue);
    if cfg.fleet.workers == 0 || cfg.fleet.epoch_size == 0 {
        eprintln!("batch: --workers and --epoch-size must be positive");
        return 2;
    }
    if cfg.fleet.shards == 0 || cfg.fleet.commit_queue == 0 {
        eprintln!("batch: --shards and --commit-queue must be positive");
        return 2;
    }
    let Some(arch) = GpuArch::by_name(&cfg.gpu) else {
        eprintln!("unknown GPU '{}' (known: A6000 A100 H100 L40S)", cfg.gpu);
        return 2;
    };

    // Task list: the job file wins; a config's `tasks` is the fallback.
    let ids: Vec<String> = match args.flag("jobs") {
        Some(p) => match parse_job_file(Path::new(p)) {
            Ok(ids) => ids,
            Err(e) => {
                eprintln!("batch: failed to read job file: {e}");
                return 1;
            }
        },
        None if !cfg.tasks.is_empty() => cfg.tasks.clone(),
        None => {
            eprintln!("batch: need --jobs FILE (one task id per line) or tasks in --config");
            return 2;
        }
    };
    if ids.is_empty() {
        eprintln!("batch: job list is empty");
        return 2;
    }
    let suite = Suite::full();
    let mut tasks = Vec::with_capacity(ids.len());
    for id in &ids {
        match suite.by_id(id) {
            Some(t) => tasks.push(t),
            None => {
                eprintln!("batch: unknown task '{id}' (try `kernelblaster list`)");
                return 2;
            }
        }
    }

    let mut kb = match args.flag("kb").map(String::from).or(cfg.kb_load.clone()) {
        Some(p) => match load_kb(&p) {
            Ok(kb) => kb,
            Err(code) => return code,
        },
        None => KnowledgeBase::empty(),
    };
    // A config's warm-start priors seed θ₀ exactly as `run` does.
    if !cfg.warm_start.is_empty() {
        kb = match assemble_warm_start(
            std::mem::take(&mut kb),
            &cfg.warm_start,
            &arch,
            &cfg.transfer,
        ) {
            Ok(kb) => kb,
            Err(code) => return code,
        };
    }
    let save_path: Option<String> =
        args.flag("save-kb").map(String::from).or(cfg.kb_save.clone());
    // Checkpoints default onto the save path: a crash mid-batch leaves
    // the latest committed KB where the finished run would have put it.
    let ckpt_path: Option<PathBuf> = args
        .flag("checkpoint")
        .map(PathBuf::from)
        .or_else(|| save_path.as_ref().map(PathBuf::from));
    // An explicit --checkpoint with no cadence means "checkpoint": the
    // densest cadence, not silently nothing.
    if args.has("checkpoint") && cfg.fleet.checkpoint_every == 0 {
        cfg.fleet.checkpoint_every = 1;
        eprintln!("batch: --checkpoint given without --checkpoint-every; defaulting to every commit");
    }
    // And the symmetric misuse: a cadence with nowhere to write.
    if cfg.fleet.checkpoint_every > 0 && ckpt_path.is_none() {
        eprintln!(
            "warning: --checkpoint-every {} but no checkpoint destination \
             (pass --checkpoint PATH or --save-kb PATH); checkpointing disabled",
            cfg.fleet.checkpoint_every
        );
    }

    /// Streams JSON-lines as tasks finish; checkpointing now lives in
    /// the committer's [`fleet::Store`] backend.
    struct BatchObserver;
    impl FleetObserver for BatchObserver {
        fn task_done(&mut self, index: usize, run: &icrl::TaskRun) {
            println!("{}", task_jsonl(index, run));
        }
    }
    let mut obs = BatchObserver;
    // Checkpoint through the whole-file store backend: same atomic
    // writes and the same fail-soft resilience as the old observer
    // (a failed checkpoint warns, it never kills the batch), but the
    // cadence is now counted per commit by the committer itself.
    let use_ckpt = ckpt_path.is_some() && cfg.fleet.checkpoint_every > 0;
    let mut whole_file = fleet::WholeFileStore::new(
        ckpt_path.clone().unwrap_or_default(),
        cfg.fleet.checkpoint_every,
    );
    whole_file.fail_soft = true;
    whole_file.verbose = true;
    let mut null_store = fleet::NullStore;

    eprintln!(
        "batch: {} tasks on {} | {} workers, epochs of {}{}{}",
        tasks.len(),
        arch.name,
        cfg.fleet.workers,
        cfg.fleet.epoch_size,
        if cfg.fleet.shards > 1 {
            format!(", {} commit shards", cfg.fleet.shards)
        } else {
            String::new()
        },
        if cfg.fleet.checkpoint_every > 0 {
            format!(", checkpoint every {} commits", cfg.fleet.checkpoint_every)
        } else {
            String::new()
        }
    );
    let staged = cfg.icrl.verify.staged;
    let memo_path: Option<PathBuf> = if staged {
        cfg.icrl.verify.memo_path.clone().map(PathBuf::from)
    } else {
        None
    };
    let mut verify_memo = memo_path
        .as_deref()
        .map(memo::load_or_cold)
        .unwrap_or_default();
    let start = std::time::Instant::now();
    let store: &mut dyn fleet::Store = if use_ckpt {
        &mut whole_file
    } else {
        &mut null_store
    };
    let outcome = match fleet::run_fleet_store(
        &tasks,
        &arch,
        &mut kb,
        &cfg.icrl,
        &cfg.fleet,
        staged.then_some(&mut verify_memo),
        store,
        &mut obs,
    ) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("batch: persistence failed: {e}");
            return 1;
        }
    };
    let elapsed = start.elapsed().as_secs_f64();

    let valid_speedups: Vec<f64> = outcome
        .runs
        .iter()
        .filter(|r| r.valid)
        .map(|r| r.speedup_vs_naive())
        .collect();
    let mut s = crate::util::json::JsonObj::new();
    s.set("event", "summary");
    s.set("tasks", outcome.runs.len());
    s.set("valid", valid_speedups.len());
    s.set(
        "geomean_vs_naive",
        crate::util::stats::geomean(&valid_speedups),
    );
    s.set("epochs", outcome.epochs);
    s.set("commits", outcome.commits);
    s.set("checkpoints", whole_file.checkpoints());
    s.set("elapsed_s", elapsed);
    s.set(
        "tasks_per_min",
        outcome.runs.len() as f64 / (elapsed / 60.0).max(1e-9),
    );
    s.set("kb_states", kb.states.len());
    // Tier counters only appear when staging ran — the default summary
    // line stays byte-compatible with pre-staging consumers.
    if staged {
        s.set("screen_rejected", outcome.tiers.screen_rejected);
        s.set("probe_rejected", outcome.tiers.probe_rejected);
        s.set("memo_hits", outcome.tiers.memo_hits);
        s.set("full_verifications", outcome.tiers.full_verifications);
        s.set("seeds_executed", outcome.tiers.seeds_executed);
    }
    // Shard-pipeline counters only appear when sharding ran — same
    // byte-compatibility rule as the tier counters above.
    if cfg.fleet.shards > 1 {
        s.set("shards", outcome.shard.shards);
        s.set("sub_commits", outcome.shard.sub_commits);
        s.set("commit_waits", outcome.shard.commit_waits);
        s.set("queue_peak", outcome.shard.queue_peak);
    }
    println!("{}", crate::util::json::Json::Obj(s).to_string_compact());

    if let Some(p) = &memo_path {
        if let Err(e) = memo::save(&verify_memo, p) {
            eprintln!("failed to save memo to {}: {e}", p.display());
            return 1;
        }
        eprintln!(
            "saved memo ({} verdicts) to {}",
            verify_memo.len(),
            p.display()
        );
    }
    if let Some(p) = &save_path {
        // Atomic like the mid-batch checkpoints: the final write must
        // never be the one that tears the advertised recovery path.
        if let Err(e) = fleet::checkpoint_atomic(&kb, Path::new(p)) {
            eprintln!("failed to save KB to {p}: {e}");
            return 1;
        }
        eprintln!(
            "saved KB ({}) to {p}",
            crate::util::human_bytes(kb.size_bytes())
        );
    }
    0
}

/// Parse a `--tenant-quota name=W,name=W` spec into admission weights.
/// Errors are returned as messages so `cmd_serve` can print them and
/// exit 2 (a usage error, like every other malformed flag).
fn parse_tenant_quotas(spec: &str) -> Result<Vec<(String, u64)>, String> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|s| !s.is_empty()) {
        let Some((name, weight)) = part.split_once('=') else {
            return Err(format!("--tenant-quota entry '{part}' is not name=weight"));
        };
        if !crate::kb::store::valid_tenant_name(name) {
            return Err(format!("--tenant-quota: invalid tenant name '{name}'"));
        }
        let w: u64 = weight
            .parse()
            .map_err(|_| format!("--tenant-quota {name}: weight '{weight}' is not an integer"))?;
        if w == 0 {
            return Err(format!("--tenant-quota {name}: weight must be positive"));
        }
        out.push((name.to_string(), w));
    }
    Ok(out)
}

/// `kernelblaster serve` — bind the TCP daemon on `--addr` and serve
/// optimize/batch requests against the live KB until a shutdown request
/// (see [`crate::serve`] for the wire protocol). With `--store DIR` the
/// KB persists through the log-structured store: every commit is a
/// journal append, `--snapshot-every` bounds the replay tail, and an
/// existing store directory is *recovered* (snapshot + journal replay)
/// rather than reloaded from `--kb`.
///
/// Tenant-tagged requests get private lanes: each named tenant's KB
/// lives in its own `<store>/<tenant>/` subdirectory (recovered on
/// boot), `--tenant-quota` sets weighted-fair admission shares, and
/// `--base-kb` warm-starts every new tenant from a shared read-only
/// prior. Untagged requests ride the default lane exactly as before.
fn cmd_serve(args: &Args) -> i32 {
    use crate::kb::store::LogStore;
    use crate::serve::{serve_listener, ServeCore};

    let mut cfg = match args.flag("config") {
        Some(p) => match crate::config::RunConfig::load(Path::new(p)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 1;
            }
        },
        None => crate::config::RunConfig::default(),
    };
    if let Some(g) = args.flag("gpu") {
        cfg.gpu = g.to_string();
    }
    cfg.icrl.trajectories = args.usize_flag("trajectories", cfg.icrl.trajectories);
    cfg.icrl.rollout_steps = args.usize_flag("steps", cfg.icrl.rollout_steps);
    cfg.icrl.seed = args.u64_flag("seed", cfg.icrl.seed);
    if args.has("vendor") {
        cfg.icrl.harness.allow_vendor = true;
    }
    cfg.icrl.policy = match policy_from_flags(args, cfg.icrl.policy) {
        Ok(p) => p,
        Err(code) => return code,
    };
    cfg.icrl.verify = match verify_from_flags(args, cfg.icrl.verify.clone()) {
        Ok(v) => v,
        Err(code) => return code,
    };
    cfg.icrl.skills = match skills_from_flags(args, cfg.icrl.skills.clone()) {
        Ok(s) => s,
        Err(code) => return code,
    };
    cfg.fleet.workers = args.usize_flag("workers", cfg.fleet.workers);
    cfg.fleet.epoch_size = args.usize_flag("epoch-size", cfg.fleet.epoch_size);
    cfg.fleet.shards = args.usize_flag("shards", cfg.fleet.shards);
    cfg.fleet.commit_queue = args.usize_flag("commit-queue", cfg.fleet.commit_queue);
    if cfg.fleet.workers == 0 || cfg.fleet.epoch_size == 0 {
        eprintln!("serve: --workers and --epoch-size must be positive");
        return 2;
    }
    if cfg.fleet.shards == 0 || cfg.fleet.commit_queue == 0 {
        eprintln!("serve: --shards and --commit-queue must be positive");
        return 2;
    }
    let Some(arch) = GpuArch::by_name(&cfg.gpu) else {
        eprintln!("unknown GPU '{}' (known: A6000 A100 H100 L40S)", cfg.gpu);
        return 2;
    };
    // Tenant quotas: the flag's entries override the config section's
    // key by key (the usual flags-over-config precedence).
    if let Some(spec) = args.flag("tenant-quota") {
        match parse_tenant_quotas(spec) {
            Ok(entries) => {
                for (name, w) in entries {
                    cfg.tenant_quotas.insert(name, w);
                }
            }
            Err(e) => {
                eprintln!("serve: {e}");
                return 2;
            }
        }
    }
    let base_kb = match args
        .flag("base-kb")
        .map(String::from)
        .or(cfg.serve_base_kb.clone())
    {
        Some(p) => match load_kb(&p) {
            Ok(kb) => Some(kb),
            Err(code) => return code,
        },
        None => None,
    };

    // KB source. An existing store directory wins outright — recovery
    // (newest snapshot + journal replay) IS the load path, and folding
    // a --kb file or warm-start priors over a recovered KB would leave
    // the journal blind to that mutation.
    let store_dir = args.flag("store").map(PathBuf::from);
    let mut store: Option<LogStore> = None;
    let mut kb = KnowledgeBase::empty();
    if let Some(dir) = &store_dir {
        if LogStore::exists(dir) {
            match LogStore::recover(dir) {
                Ok((recovered, s)) => {
                    if args.has("kb") || !cfg.warm_start.is_empty() {
                        eprintln!(
                            "serve: store {} already exists; ignoring --kb/warm-start",
                            dir.display()
                        );
                    }
                    eprintln!(
                        "serve: recovered KB ({} states, seq {}) from {}",
                        recovered.states.len(),
                        s.stats().last_seq,
                        dir.display()
                    );
                    // A recovered layout is authoritative: batches fall
                    // back to single-segment journaling when the shard
                    // counts disagree (epoch_segments returns None), so
                    // a mismatch is a notice, never an error.
                    if cfg.fleet.shards > 1 && s.stats().shards != cfg.fleet.shards {
                        eprintln!(
                            "serve: store has {} journal segment(s) but --shards {}; \
                             sharded commits disabled for this store",
                            s.stats().shards,
                            cfg.fleet.shards
                        );
                    }
                    kb = recovered;
                    store = Some(s);
                }
                Err(e) => {
                    eprintln!("serve: store recovery failed: {e}");
                    return 1;
                }
            }
        }
    }
    if store.is_none() {
        kb = match args.flag("kb").map(String::from).or(cfg.kb_load.clone()) {
            Some(p) => match load_kb(&p) {
                Ok(kb) => kb,
                Err(code) => return code,
            },
            None => KnowledgeBase::empty(),
        };
        if !cfg.warm_start.is_empty() {
            kb = match assemble_warm_start(
                std::mem::take(&mut kb),
                &cfg.warm_start,
                &arch,
                &cfg.transfer,
            ) {
                Ok(kb) => kb,
                Err(code) => return code,
            };
        }
        if let Some(dir) = &store_dir {
            match LogStore::create_sharded(dir, &kb, cfg.fleet.shards) {
                Ok(s) => {
                    eprintln!(
                        "serve: created store at {}{}",
                        dir.display(),
                        if s.shards() > 1 {
                            format!(" ({} journal segments)", s.shards())
                        } else {
                            String::new()
                        }
                    );
                    store = Some(s);
                }
                Err(e) => {
                    eprintln!("serve: store creation failed: {e}");
                    return 1;
                }
            }
        }
    }
    if let Some(s) = store.as_mut() {
        s.snapshot_every = args.u64_flag("snapshot-every", 64);
    }

    let staged = cfg.icrl.verify.staged;
    let memo_path: Option<PathBuf> = if staged {
        cfg.icrl.verify.memo_path.clone().map(PathBuf::from)
    } else {
        None
    };
    let verify_memo = memo_path
        .as_deref()
        .map(memo::load_or_cold)
        .unwrap_or_default();

    let addr = args.flag("addr").unwrap_or("127.0.0.1:7070").to_string();
    let listener = match std::net::TcpListener::bind(&addr) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("serve: bind {addr}: {e}");
            return 1;
        }
    };

    let mut core = ServeCore::new(arch.clone(), cfg.icrl.clone(), cfg.fleet.clone(), kb);
    core.store = store;
    core.save_path = args
        .flag("save-kb")
        .map(PathBuf::from)
        .or(cfg.kb_save.clone().map(PathBuf::from));
    core.memo = verify_memo;
    core.memo_path = memo_path;
    core.deterministic = !args.has("throughput");
    core.store_dir = store_dir.clone();
    core.base_kb = base_kb;
    core.transfer = cfg.transfer.clone();
    core.quotas = cfg.tenant_quotas.clone();
    core.tenant_snapshot_every = args.u64_flag("snapshot-every", 64);
    match core.recover_tenants() {
        Ok(0) => {}
        Ok(n) => eprintln!("serve: recovered {n} tenant store(s)"),
        Err(e) => {
            eprintln!("serve: {e}");
            return 1;
        }
    }
    eprintln!(
        "serve: listening on {addr} | {} | {} workers{} | {} commits{}",
        arch.name,
        cfg.fleet.workers,
        if cfg.fleet.shards > 1 {
            format!(" x {} commit shards", cfg.fleet.shards)
        } else {
            String::new()
        },
        if core.deterministic {
            "deterministic"
        } else {
            "completion-order"
        },
        if core.store.is_some() {
            format!(" | store: {}", store_dir.as_ref().unwrap().display())
        } else {
            String::new()
        }
    );
    match serve_listener(&mut core, listener) {
        Ok(()) => {
            eprintln!(
                "serve: shut down after {} tasks, {} commits",
                core.total_served(),
                core.total_commits()
            );
            0
        }
        Err(e) => {
            eprintln!("serve: {e}");
            1
        }
    }
}

fn cmd_optimize(args: &Args) -> i32 {
    let suite = Suite::full();
    let Some(task_id) = args.flag("task") else {
        eprintln!("optimize: missing --task (try `kernelblaster list`)");
        return 2;
    };
    let Some(task) = suite.by_id(task_id) else {
        eprintln!("unknown task '{task_id}'");
        return 2;
    };
    let Some(arch) = GpuArch::by_name(args.flag("gpu").unwrap_or("H100")) else {
        eprintln!("unknown GPU (known: A6000 A100 H100 L40S)");
        return 2;
    };
    let mut kb = match args.flag("kb") {
        Some(path) => match persist::load(Path::new(path)) {
            Ok(kb) => kb,
            Err(e) => {
                eprintln!("failed to load KB from {path}: {e}");
                return 1;
            }
        },
        None => KnowledgeBase::empty(),
    };
    // Warm start: merge prior KBs (cross-arch ones are transferred to the
    // target first) into the starting θ₀. A --kb KB joins as a prior.
    if let Some(list) = args.flag("warm-start") {
        let paths: Vec<String> = list
            .split(',')
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect();
        let policy = match transfer_policy_from_flags(args) {
            Ok(p) => p,
            Err(code) => return code,
        };
        kb = match assemble_warm_start(std::mem::take(&mut kb), &paths, &arch, &policy) {
            Ok(kb) => kb,
            Err(code) => return code,
        };
    }
    let mut cfg = IcrlConfig {
        trajectories: args.usize_flag("trajectories", 10),
        rollout_steps: args.usize_flag("steps", 10),
        seed: args.u64_flag("seed", 42),
        ..Default::default()
    };
    cfg.harness.allow_vendor = args.has("vendor");
    cfg.policy = match policy_from_flags(args, cfg.policy) {
        Ok(p) => p,
        Err(code) => return code,
    };
    cfg.verify = match verify_from_flags(args, cfg.verify) {
        Ok(v) => v,
        Err(code) => return code,
    };
    cfg.skills = match skills_from_flags(args, cfg.skills) {
        Ok(s) => s,
        Err(code) => return code,
    };
    // Staged runs go through the verified entry point so memo verdicts
    // flow in (snapshot) and out (delta); the default path stays on the
    // plain driver, bit-identical to the pre-staging CLI.
    let run = if cfg.verify.staged {
        let memo_path = cfg.verify.memo_path.clone().map(PathBuf::from);
        let mut memo = memo_path
            .as_deref()
            .map(memo::load_or_cold)
            .unwrap_or_default();
        let mut cache = crate::harness::VerifyCache::new();
        let (run, delta, tiers) =
            icrl::optimize_task_verified(task, &arch, &mut kb, &cfg, 0, &mut cache, Some(&memo));
        memo.apply_delta(&delta);
        eprintln!(
            "verify tiers: {} screened, {} probe-rejected, {} memo hits, \
             {} full oracle runs, {} seeds executed",
            tiers.screen_rejected,
            tiers.probe_rejected,
            tiers.memo_hits,
            tiers.full_verifications,
            tiers.seeds_executed
        );
        if let Some(p) = &memo_path {
            if let Err(e) = memo::save(&memo, p) {
                eprintln!("failed to save memo to {}: {e}", p.display());
                return 1;
            }
            eprintln!("saved memo ({} verdicts) to {}", memo.len(), p.display());
        }
        run
    } else {
        icrl::optimize_task(task, &arch, &mut kb, &cfg, 0)
    };
    let baselines = baselines::baseline_times(task, &arch);

    let mut t = Table::new(&["metric", "value"]);
    t.add_row(vec!["task".into(), run.task_id.clone()]);
    t.add_row(vec!["gpu".into(), arch.name.to_string()]);
    t.add_row(vec!["policy".into(), cfg.policy.kind.name().to_string()]);
    t.add_row(vec!["valid".into(), run.valid.to_string()]);
    t.add_row(vec![
        "naive CUDA time".into(),
        crate::util::human_duration(run.naive_time_s),
    ]);
    t.add_row(vec![
        "best time".into(),
        crate::util::human_duration(run.best_time_s),
    ]);
    t.add_row(vec![
        "PyTorch best".into(),
        crate::util::human_duration(baselines.best_s()),
    ]);
    t.add_row(vec![
        "speedup vs naive".into(),
        format!("{:.2}x", run.speedup_vs_naive()),
    ]);
    t.add_row(vec![
        "speedup vs PyTorch".into(),
        format!("{:.2}x", baselines.best_s() / run.best_time_s),
    ]);
    t.add_row(vec!["tokens".into(), run.tokens.total().to_string()]);
    t.add_row(vec!["states visited".into(), run.states_visited.to_string()]);
    // Only surfaced when drawing is on — the default table is unchanged.
    if cfg.skills.enabled {
        t.add_row(vec![
            "skills installed".into(),
            kb_skills::count(&kb).to_string(),
        ]);
    }
    t.add_row(vec![
        "techniques applied".into(),
        run.best.applied.join(", "),
    ]);
    print!("{}", t.render());

    if let Some(path) = args.flag("save-kb") {
        if let Err(e) = persist::save(&kb, Path::new(path)) {
            eprintln!("failed to save KB: {e}");
            return 1;
        }
        eprintln!("saved KB ({}) to {path}", crate::util::human_bytes(kb.size_bytes()));
    }
    0
}

fn cmd_suite(args: &Args) -> i32 {
    let level = match args.flag("level") {
        Some("L1") => Level::L1,
        Some("L2") => Level::L2,
        Some("L3") => Level::L3,
        _ => {
            eprintln!("suite: need --level L1|L2|L3");
            return 2;
        }
    };
    let Some(arch) = GpuArch::by_name(args.flag("gpu").unwrap_or("H100")) else {
        eprintln!("unknown GPU (known: A6000 A100 H100 L40S)");
        return 2;
    };
    let ctx = Ctx::new(args.has("quick"), args.u64_flag("seed", 42));
    let mut kb = KnowledgeBase::empty();
    let (runs, scores) = experiments::run_ours(&ctx, &arch, level, args.has("vendor"), &mut kb);
    let mut t = Table::new(&["task", "valid", "vs naive", "vs PyTorch", "tokens"]);
    for (r, s) in runs.iter().zip(&scores) {
        t.add_row(vec![
            r.task_id.clone(),
            r.valid.to_string(),
            format!("{:.2}x", r.speedup_vs_naive()),
            format!("{:.2}x", s.speedup),
            r.tokens.total().to_string(),
        ]);
    }
    print!("{}", t.render());
    let summary = crate::metrics::summarize(&scores);
    println!(
        "geomean vs PyTorch: {:.3}x | valid rate: {:.0}% | KB: {} states, {}",
        summary.summary.geomean,
        summary.valid_rate * 100.0,
        kb.states.len(),
        crate::util::human_bytes(kb.size_bytes())
    );
    0
}

fn cmd_calibrate(args: &Args) -> i32 {
    let rt = match runtime::Runtime::new(runtime::default_artifact_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT init failed: {e}");
            return 1;
        }
    };
    eprintln!(
        "PJRT platform: {} | artifacts: {:?}",
        rt.platform(),
        rt.available()
    );
    match runtime::anchors::calibrate(&rt, 2, args.usize_flag("iters", 10)) {
        Ok(results) => {
            print!("{}", runtime::anchors::render(&results));
            0
        }
        Err(e) => {
            eprintln!("calibration failed: {e} (run `make artifacts` first)");
            1
        }
    }
}

/// Load a KB or print the error and return the CLI failure code.
fn load_kb(path: &str) -> Result<KnowledgeBase, i32> {
    persist::load(Path::new(path)).map_err(|e| {
        eprintln!("failed to load KB from {path}: {e}");
        1
    })
}

/// Save a KB or print the error and return the CLI failure code.
fn save_kb(kb: &KnowledgeBase, path: &str) -> Result<(), i32> {
    persist::save(kb, Path::new(path)).map_err(|e| {
        eprintln!("failed to save KB to {path}: {e}");
        1
    })
}

/// Search-policy config from `--policy` / `--epsilon` / `--ucb-c` /
/// `--beam-width` / `--schedule` / `--schedule-rate` /
/// `--dedup-distance` flags over a base (default or config-file)
/// policy, enforcing the same hyperparameter contract the config-file
/// path validates.
fn policy_from_flags(args: &Args, base: PolicyConfig) -> Result<PolicyConfig, i32> {
    // `--policy auto` resolves the kind *and* hyperparameters from a
    // sweep artifact; explicit hyperparameter flags still overlay the
    // chosen arm, so `--policy auto --epsilon 0.3` means what it says.
    if args.flag("policy") == Some("auto") {
        let path = PathBuf::from(args.flag("sweep").unwrap_or("BENCH_sweep.json"));
        let picked = policy_from_sweep(&path, &base);
        return policy_hypers_from_flags(args, picked);
    }
    let kind = match args.flag("policy") {
        None => base.kind,
        Some(name) => match PolicyKind::from_name(name) {
            Some(k) => k,
            None => {
                eprintln!(
                    "unknown --policy '{name}' (known: {})",
                    PolicyKind::known_names()
                );
                return Err(2);
            }
        },
    };
    // A bare --schedule-rate over a constant schedule would be a silent
    // no-op for the run's policy — reject it here. (The per-entry
    // epoch-mix overlay is deliberately lenient instead: a mix entry
    // that pinned `constant` simply keeps it.)
    if args.flag("schedule").is_none()
        && args.flag("schedule-rate").is_some()
        && base.schedule == Schedule::Constant
    {
        eprintln!(
            "--schedule-rate has no effect on the constant schedule; \
             pass --schedule harmonic|exponential"
        );
        return Err(2);
    }
    policy_hypers_from_flags(args, PolicyConfig { kind, ..base })
}

/// Resolve `--policy auto`: pick the best-measured arm from a
/// `kernelblaster-bench-sweep-v1` artifact (`experiment sweep`'s
/// BENCH_sweep.json). The winner is the arm with the highest finite
/// paired-vs-greedy score over at least one paired cell; the base
/// config's `dedup_distance` is kept (the sweep does not grid it). Any
/// failure — missing file, wrong format, no eligible arm — falls back
/// to `greedy_topk` with a stderr notice rather than refusing to run:
/// auto is an optimization hint, not a correctness input.
fn policy_from_sweep(path: &Path, base: &PolicyConfig) -> PolicyConfig {
    match read_sweep_best(path) {
        Ok((label, score, policy)) => {
            eprintln!(
                "policy auto: picked '{label}' ({:.3}x vs greedy paired) from {}",
                score,
                path.display()
            );
            PolicyConfig {
                dedup_distance: base.dedup_distance,
                ..policy
            }
        }
        Err(why) => {
            eprintln!("policy auto: {why}; falling back to greedy_topk");
            PolicyConfig {
                kind: PolicyKind::GreedyTopK,
                ..base.clone()
            }
        }
    }
}

/// Parse a sweep artifact and return the best arm's (label, paired
/// score, policy). Arms without paired evidence (`paired_cells` = 0 or
/// a non-finite `vs_greedy_paired`) and arms naming unknown policies or
/// schedules are skipped, not errors — an artifact from a newer build
/// may carry arms this binary cannot run.
fn read_sweep_best(path: &Path) -> Result<(String, f64, PolicyConfig), String> {
    use crate::util::json::Json;
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    if j.get("format").and_then(Json::as_str) != Some("kernelblaster-bench-sweep-v1") {
        return Err(format!(
            "{}: not a kernelblaster-bench-sweep-v1 artifact",
            path.display()
        ));
    }
    let arms = j
        .get("arms")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{}: no arms array", path.display()))?;
    let dflt = PolicyConfig::default();
    let mut best: Option<(f64, String, PolicyConfig)> = None;
    for arm in arms {
        let pairs = arm.get("paired_cells").and_then(Json::as_usize).unwrap_or(0);
        let score = arm
            .get("vs_greedy_paired")
            .and_then(Json::as_f64)
            .unwrap_or(f64::NAN);
        if pairs == 0 || !score.is_finite() {
            continue;
        }
        let Some(kind) = arm
            .get("policy")
            .and_then(Json::as_str)
            .and_then(PolicyKind::from_name)
        else {
            continue;
        };
        let Some(schedule) = Schedule::from_parts(
            arm.get("schedule").and_then(Json::as_str).unwrap_or("constant"),
            arm.get("schedule_rate")
                .and_then(Json::as_f64)
                .unwrap_or(Schedule::DEFAULT_RATE),
        ) else {
            continue;
        };
        let label = arm
            .get("label")
            .and_then(Json::as_str)
            .unwrap_or("(unlabeled)")
            .to_string();
        let policy = PolicyConfig {
            kind,
            epsilon: arm.get("epsilon").and_then(Json::as_f64).unwrap_or(dflt.epsilon),
            ucb_c: arm.get("ucb_c").and_then(Json::as_f64).unwrap_or(dflt.ucb_c),
            beam_width: arm
                .get("beam_width")
                .and_then(Json::as_usize)
                .unwrap_or(dflt.beam_width),
            schedule,
            dedup_distance: dflt.dedup_distance,
        };
        if policy.validate().is_err() {
            continue;
        }
        if best.as_ref().map(|(s, _, _)| score > *s).unwrap_or(true) {
            best = Some((score, label, policy));
        }
    }
    best.map(|(s, l, p)| (l, s, p)).ok_or_else(|| {
        format!(
            "{}: no eligible arm (need paired_cells > 0 and a finite vs_greedy_paired)",
            path.display()
        )
    })
}

/// Overlay only the hyperparameter flags (`--epsilon` / `--ucb-c` /
/// `--beam-width` / `--schedule` / `--schedule-rate` /
/// `--dedup-distance`) onto `base`, keeping its kind. Applied to each
/// config-file epoch-mix entry so the shared flags mean the same thing
/// whichever source named the mix (`--policy` changes only the batch
/// default, never a mix entry's kind).
fn policy_hypers_from_flags(args: &Args, base: PolicyConfig) -> Result<PolicyConfig, i32> {
    let schedule = match args.flag("schedule") {
        None => match args.flag("schedule-rate") {
            None => base.schedule,
            // A bare --schedule-rate re-rates the base schedule's kind;
            // a constant base has no rate and keeps its schedule (the
            // would-be-no-op hard error lives in `policy_from_flags`,
            // scoped to the run's own policy).
            Some(_) if base.schedule == Schedule::Constant => base.schedule,
            Some(_) => Schedule::from_parts(
                base.schedule.name(),
                args.f64_flag("schedule-rate", Schedule::DEFAULT_RATE),
            )
            .expect("own names always parse"),
        },
        Some(name) => {
            let rate = args.f64_flag("schedule-rate", Schedule::DEFAULT_RATE);
            match Schedule::from_parts(name, rate) {
                Some(s) => s,
                None => {
                    eprintln!(
                        "unknown --schedule '{name}' (known: {})",
                        Schedule::known_names()
                    );
                    return Err(2);
                }
            }
        }
    };
    let policy = PolicyConfig {
        kind: base.kind,
        epsilon: args.f64_flag("epsilon", base.epsilon),
        ucb_c: args.f64_flag("ucb-c", base.ucb_c),
        beam_width: args.usize_flag("beam-width", base.beam_width),
        schedule,
        dedup_distance: args.f64_flag("dedup-distance", base.dedup_distance),
    };
    if let Err(e) = policy.validate() {
        eprintln!("{e}");
        return Err(2);
    }
    Ok(policy)
}

/// Tiered-verification config from `--staged` / `--no-screen` /
/// `--no-probe` / `--screen-margin` / `--probe-seeds` / `--memo` flags
/// over a base (default or config-file) section, enforcing the same
/// contract the config-file path validates. Flags only ever turn
/// staging on or tune it — absent flags keep the base, so a config
/// file's `verify` section survives untouched.
fn verify_from_flags(args: &Args, base: VerifyConfig) -> Result<VerifyConfig, i32> {
    // `--screen-margin auto` resolves the margin from `experiment
    // verify`'s measured estimate-vs-profile error distribution
    // (`screen_error.suggested_margin` in BENCH_verify.json; point
    // `--verify-bench` at a different artifact). Any failure — missing
    // file, wrong format, pre-screen_error artifact — falls back to the
    // 1.5x default with a stderr notice rather than refusing to run:
    // auto is an optimization hint, not a correctness input.
    let screen_margin = match args.flag("screen-margin") {
        Some("auto") => {
            let path = Path::new(args.flag("verify-bench").unwrap_or("BENCH_verify.json"));
            match read_suggested_margin(path) {
                Ok(m) => {
                    eprintln!(
                        "screen-margin auto: {m:.3}x (measured screen error) from {}",
                        path.display()
                    );
                    m
                }
                Err(why) => {
                    eprintln!("screen-margin auto: {why}; falling back to 1.5x");
                    1.5
                }
            }
        }
        _ => args.f64_flag("screen-margin", base.screen_margin),
    };
    let verify = VerifyConfig {
        staged: base.staged || args.has("staged"),
        screen: base.screen && !args.has("no-screen"),
        probe: base.probe && !args.has("no-probe"),
        screen_margin,
        probe_seeds: args.usize_flag("probe-seeds", base.probe_seeds),
        memo_path: args.flag("memo").map(String::from).or(base.memo_path),
        memo_max_entries: args.usize_flag("memo-max-entries", base.memo_max_entries),
    };
    if let Err(e) = verify.validate() {
        eprintln!("{e}");
        return Err(2);
    }
    Ok(verify)
}

/// Read `screen_error.suggested_margin` from a
/// `kernelblaster-bench-verify-v1` artifact (`experiment verify`'s
/// BENCH_verify.json) — the p95 of the cost model's
/// estimate-vs-profile error, clamped to at least 1.0. Artifacts from
/// before the screen-error section report a descriptive error so the
/// caller can fall back.
fn read_suggested_margin(path: &Path) -> Result<f64, String> {
    use crate::util::json::Json;
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    if j.get("format").and_then(Json::as_str) != Some("kernelblaster-bench-verify-v1") {
        return Err(format!(
            "{}: not a kernelblaster-bench-verify-v1 artifact",
            path.display()
        ));
    }
    let m = j
        .get("screen_error")
        .and_then(|e| e.get("suggested_margin"))
        .and_then(Json::as_f64)
        .ok_or_else(|| {
            format!(
                "{}: no screen_error.suggested_margin (regenerate with \
                 `kernelblaster experiment verify`)",
                path.display()
            )
        })?;
    if !m.is_finite() || m < 1.0 {
        return Err(format!("{}: suggested_margin {m} out of range", path.display()));
    }
    Ok(m)
}

/// Skill-drawing config from `--skills` / `--skill-max-len` /
/// `--skill-min-support` / `--skill-min-gain` / `--skill-max-per-state`
/// flags over a base (default or config-file) section, enforcing the
/// same contract the config-file path validates. Flags only ever turn
/// drawing on or tune the knobs — absent flags keep the base.
fn skills_from_flags(args: &Args, base: SkillsConfig) -> Result<SkillsConfig, i32> {
    let skills = SkillsConfig {
        enabled: base.enabled || args.has("skills"),
        max_len: args.usize_flag("skill-max-len", base.max_len),
        min_support: args.usize_flag("skill-min-support", base.min_support),
        min_gain: args.f64_flag("skill-min-gain", base.min_gain),
        max_per_state: args.usize_flag("skill-max-per-state", base.max_per_state),
    };
    if let Err(e) = skills.validate() {
        eprintln!("{e}");
        return Err(2);
    }
    Ok(skills)
}

/// Parse `--epoch-policies a,b,c` into a per-epoch policy mix: each name
/// becomes the batch policy with its `kind` replaced, so the shared
/// hyperparameter flags (`--epsilon`, `--schedule`, …) apply to every
/// epoch. Returns an empty vec when the flag is absent.
fn epoch_policies_from_flags(args: &Args, base: &PolicyConfig) -> Result<Vec<PolicyConfig>, i32> {
    let Some(list) = args.flag("epoch-policies") else {
        return Ok(Vec::new());
    };
    let mut mix = Vec::new();
    for name in list.split(',').filter(|s| !s.is_empty()) {
        match PolicyKind::from_name(name) {
            Some(kind) => mix.push(PolicyConfig {
                kind,
                ..base.clone()
            }),
            None => {
                eprintln!(
                    "unknown policy '{name}' in --epoch-policies (known: {})",
                    PolicyKind::known_names()
                );
                return Err(2);
            }
        }
    }
    if mix.is_empty() {
        eprintln!("batch: --epoch-policies given but names no policy");
        return Err(2);
    }
    Ok(mix)
}

/// Transfer policy from `--decay` / `--rekey-threshold` flags, enforcing
/// the same decay ∈ [0, 1] contract the config-file path validates.
fn transfer_policy_from_flags(args: &Args) -> Result<TransferPolicy, i32> {
    let dflt = TransferPolicy::default();
    let policy = TransferPolicy {
        decay: args.f64_flag("decay", dflt.decay),
        rekey_threshold: args.f64_flag("rekey-threshold", dflt.rekey_threshold),
    };
    if !(0.0..=1.0).contains(&policy.decay) {
        eprintln!("--decay must be in [0, 1], got {}", policy.decay);
        return Err(2);
    }
    Ok(policy)
}

/// Assemble a warm-start θ₀ for `arch`: an already-loaded KB (if
/// non-empty) joins the priors listed in `paths`, then everything goes
/// through [`icrl::warm_start_kb`]. Shared by `optimize --warm-start`
/// and the config-file launcher.
fn assemble_warm_start(
    base: KnowledgeBase,
    paths: &[String],
    arch: &GpuArch,
    policy: &TransferPolicy,
) -> Result<KnowledgeBase, i32> {
    let mut priors = Vec::new();
    if !base.states.is_empty() {
        priors.push(base);
    }
    for p in paths {
        priors.push(load_kb(p)?);
    }
    if priors.is_empty() {
        eprintln!("warm start: no KBs to seed from");
        return Err(2);
    }
    let kb = icrl::warm_start_kb(&priors, arch, policy);
    eprintln!(
        "warm start: {} priors -> {} states ({} transferred entries)",
        priors.len(),
        kb.states.len(),
        lifecycle::stats(&kb).transferred
    );
    Ok(kb)
}

fn cmd_kb(args: &Args) -> i32 {
    match args.pos(1) {
        Some("init") => {
            let Some(path) = args.flag("path") else {
                eprintln!("kb init: need --path FILE");
                return 2;
            };
            let kb = KnowledgeBase::seed_priors();
            if save_kb(&kb, path).is_err() {
                return 1;
            }
            println!(
                "initialized KB with {} seed states ({}) at {path}",
                kb.states.len(),
                crate::util::human_bytes(kb.size_bytes())
            );
            0
        }
        Some("inspect") => {
            let Some(path) = args.flag("path") else {
                eprintln!("kb inspect: need --path FILE");
                return 2;
            };
            let kb = match load_kb(path) {
                Ok(kb) => kb,
                Err(code) => return code,
            };
            let mut t =
                Table::new(&["state", "visits", "opts", "best technique", "gain", "origin"]);
            for s in &kb.states {
                // A hand-edited KB with a NaN gain must not crash `kb
                // inspect`, and must not win "best technique" either
                // (total_cmp alone would rank positive NaN above +inf) —
                // non-finite gains sort below everything.
                let rank = |o: &&crate::kb::OptEntry| {
                    if o.expected_gain.is_finite() {
                        o.expected_gain
                    } else {
                        f64::NEG_INFINITY
                    }
                };
                let best = s
                    .opts
                    .iter()
                    .max_by(|a, b| rank(a).total_cmp(&rank(b)));
                t.add_row(vec![
                    s.sig.id(),
                    s.visits.to_string(),
                    s.opts.len().to_string(),
                    best.map(|o| o.technique.name().to_string())
                        .unwrap_or_else(|| "-".into()),
                    best.map(|o| format!("{:.2}", o.expected_gain))
                        .unwrap_or_else(|| "-".into()),
                    best.and_then(|o| o.origin.clone())
                        .unwrap_or_else(|| "native".into()),
                ]);
            }
            print!("{}", t.render());
            println!(
                "{} states | {} recorded attempts | {} on disk",
                kb.states.len(),
                kb.total_attempts(),
                crate::util::human_bytes(kb.size_bytes())
            );
            0
        }
        Some("stats") => {
            let Some(path) = args.flag("path") else {
                eprintln!("kb stats: need --path FILE");
                return 2;
            };
            let kb = match load_kb(path) {
                Ok(kb) => kb,
                Err(code) => return code,
            };
            let st = lifecycle::stats(&kb);
            let mut t = Table::new(&["metric", "value"]);
            t.add_row(vec!["arch".into(), st.arch.unwrap_or_else(|| "-".into())]);
            t.add_row(vec!["states".into(), st.states.to_string()]);
            t.add_row(vec!["entries".into(), st.entries.to_string()]);
            t.add_row(vec!["native attempts".into(), st.attempts.to_string()]);
            t.add_row(vec!["successes".into(), st.successes.to_string()]);
            t.add_row(vec![
                "transferred priors".into(),
                st.transferred.to_string(),
            ]);
            t.add_row(vec!["untried entries".into(), st.untried.to_string()]);
            t.add_row(vec!["skills".into(), st.skills.to_string()]);
            t.add_row(vec!["parameter updates".into(), st.updates.to_string()]);
            t.add_row(vec![
                "size".into(),
                crate::util::human_bytes(st.size_bytes),
            ]);
            print!("{}", t.render());
            if st.lineage.is_empty() {
                println!("lineage: (none — never lifecycled)");
            } else {
                println!("lineage:");
                for l in &st.lineage {
                    println!("  - {l}");
                }
            }
            0
        }
        Some("merge") => {
            let inputs = args.pos_from(2);
            if inputs.len() < 2 {
                eprintln!("kb merge: need at least two input KB files");
                return 2;
            }
            let Some(out) = args.flag("out") else {
                eprintln!("kb merge: need --out FILE");
                return 2;
            };
            let mut kbs = Vec::with_capacity(inputs.len());
            for p in inputs {
                match load_kb(p) {
                    Ok(kb) => kbs.push(kb),
                    Err(code) => return code,
                }
            }
            let merged = lifecycle::merge(&kbs);
            if save_kb(&merged, out).is_err() {
                return 1;
            }
            println!(
                "merged {} KBs -> {} states, {} attempts ({}) at {out}",
                kbs.len(),
                merged.states.len(),
                merged.total_attempts(),
                crate::util::human_bytes(merged.size_bytes())
            );
            0
        }
        Some("compact") => {
            let Some(path) = args.flag("path") else {
                eprintln!("kb compact: need --path FILE");
                return 2;
            };
            let kb = match load_kb(path) {
                Ok(kb) => kb,
                Err(code) => return code,
            };
            let dflt = CompactPolicy::default();
            let policy = CompactPolicy {
                min_attempts: args.usize_flag("min-attempts", dflt.min_attempts),
                gain_floor: args.f64_flag("gain-floor", dflt.gain_floor),
                max_notes: args.usize_flag("max-notes", dflt.max_notes),
            };
            let before = kb.size_bytes();
            let compacted = lifecycle::compact(&kb, &policy);
            let out = args.flag("out").unwrap_or(path);
            if save_kb(&compacted, out).is_err() {
                return 1;
            }
            println!(
                "compacted {} -> {} ({} states) at {out}",
                crate::util::human_bytes(before),
                crate::util::human_bytes(compacted.size_bytes()),
                compacted.states.len()
            );
            0
        }
        Some("transfer") => {
            let Some(path) = args.flag("path") else {
                eprintln!("kb transfer: need --path FILE");
                return 2;
            };
            let kb = match load_kb(path) {
                Ok(kb) => kb,
                Err(code) => return code,
            };
            let Some(to) = args.flag("to").and_then(GpuArch::by_name) else {
                eprintln!("kb transfer: need --to ARCH (known: A6000 A100 H100 L40S)");
                return 2;
            };
            // Source arch: --from overrides; else the KB's recorded arch.
            let from = match args.flag("from") {
                Some(name) => match GpuArch::by_name(name) {
                    Some(a) => a,
                    None => {
                        eprintln!("kb transfer: unknown --from arch '{name}'");
                        return 2;
                    }
                },
                None => match kb.arch.as_deref().and_then(GpuArch::by_name) {
                    Some(a) => a,
                    None => {
                        eprintln!(
                            "kb transfer: KB records no source arch; pass --from ARCH"
                        );
                        return 2;
                    }
                },
            };
            let policy = match transfer_policy_from_flags(args) {
                Ok(p) => p,
                Err(code) => return code,
            };
            let transferred = lifecycle::transfer(&kb, &from, &to, &policy);
            let out = args.flag("out").unwrap_or(path);
            if save_kb(&transferred, out).is_err() {
                return 1;
            }
            println!(
                "transferred {} -> {}: {} states ({}) at {out}",
                from.name,
                to.name,
                transferred.states.len(),
                crate::util::human_bytes(transferred.size_bytes())
            );
            0
        }
        Some("mine") => {
            let Some(path) = args.flag("path") else {
                eprintln!("kb mine: need --path FILE");
                return 2;
            };
            let mut kb = match load_kb(path) {
                Ok(kb) => kb,
                Err(code) => return code,
            };
            let Some(arch) = GpuArch::by_name(args.flag("gpu").unwrap_or("H100")) else {
                eprintln!("unknown GPU (known: A6000 A100 H100 L40S)");
                return 2;
            };
            // Tasks whose rollouts supply the replay traces: --tasks or
            // --jobs narrows; default is the whole suite.
            let suite = Suite::full();
            let ids: Vec<String> = if let Some(list) = args.flag("tasks") {
                list.split(',')
                    .filter(|s| !s.is_empty())
                    .map(String::from)
                    .collect()
            } else if let Some(p) = args.flag("jobs") {
                match parse_job_file(Path::new(p)) {
                    Ok(ids) => ids,
                    Err(e) => {
                        eprintln!("kb mine: failed to read job file: {e}");
                        return 1;
                    }
                }
            } else {
                suite.tasks.iter().map(|t| t.id.clone()).collect()
            };
            if ids.is_empty() {
                eprintln!("kb mine: task list is empty");
                return 2;
            }
            let mut tasks = Vec::with_capacity(ids.len());
            for id in &ids {
                match suite.by_id(id) {
                    Some(t) => tasks.push(t),
                    None => {
                        eprintln!("kb mine: unknown task '{id}' (try `kernelblaster list`)");
                        return 2;
                    }
                }
            }
            let scfg = match skills_from_flags(args, SkillsConfig::default()) {
                Ok(s) => s,
                Err(code) => return code,
            };
            // The rollouts that produce the traces run with drawing off:
            // mining compresses *single-technique* winning chains, and
            // the miner skips composite skill-draw samples anyway.
            let icfg = IcrlConfig {
                trajectories: args.usize_flag("trajectories", 4),
                rollout_steps: args.usize_flag("steps", 6),
                seed: args.u64_flag("seed", 42),
                ..Default::default()
            };
            let runs = icrl::run_suite(&tasks, &arch, &mut kb, &icfg);
            let mined = kb_skills::mine_runs(&runs, &scfg);
            let added = kb_skills::install(&mut kb, &mined);
            let out = args.flag("out").unwrap_or(path);
            if save_kb(&kb, out).is_err() {
                return 1;
            }
            println!(
                "mined {} chains over {} tasks -> {} new skills ({} installed total) at {out}",
                mined.len(),
                tasks.len(),
                added,
                kb_skills::count(&kb)
            );
            0
        }
        _ => {
            eprintln!("kb: need init|inspect|stats|merge|compact|transfer|mine");
            2
        }
    }
}

/// `memo <compact>` — maintenance for persistent verification memos.
fn cmd_memo(args: &Args) -> i32 {
    match args.pos(1) {
        Some("compact") => {
            let Some(path) = args.flag("path") else {
                eprintln!("memo compact: need --path FILE");
                return 2;
            };
            // The bound: an explicit --max-entries, else a config
            // file's verify.memo_max_entries (the same knob the serve
            // daemon enforces online), else an error.
            let max = match args.flag("max-entries").and_then(|v| v.parse::<usize>().ok()) {
                Some(m) => m,
                None => {
                    let from_cfg = args
                        .flag("config")
                        .and_then(|p| crate::config::RunConfig::load(Path::new(p)).ok())
                        .map(|c| c.icrl.verify.memo_max_entries)
                        .unwrap_or(0);
                    if from_cfg == 0 {
                        eprintln!(
                            "memo compact: need --max-entries N (or --config with a \
                             nonzero verify.memo_max_entries)"
                        );
                        return 2;
                    }
                    from_cfg
                }
            };
            let mut m = match memo::load(Path::new(path)) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("failed to load memo from {path}: {e}");
                    return 1;
                }
            };
            let before = m.len();
            let evicted = m.compact(max);
            // Compaction closes a recency era: entries recorded after
            // this point outrank everything that survived it.
            m.advance_epoch();
            let out = args.flag("out").unwrap_or(path);
            if let Err(e) = memo::save(&m, Path::new(out)) {
                eprintln!("failed to save memo to {out}: {e}");
                return 1;
            }
            println!(
                "compacted memo {before} -> {} verdicts ({evicted} evicted) at {out}",
                m.len()
            );
            0
        }
        _ => {
            eprintln!("memo: need compact");
            2
        }
    }
}

fn cmd_list() -> i32 {
    println!("experiments:");
    for (name, _) in experiments::registry() {
        println!("  {name}");
    }
    println!("\nGPUs: A6000 A100 H100 L40S");
    println!("\ntasks:");
    for t in Suite::full().tasks {
        println!("  {}", t.id);
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn args_parsing() {
        let a = Args::parse(&argv("optimize --task L1/01_x --vendor --steps 5"));
        assert_eq!(a.pos(0), Some("optimize"));
        assert_eq!(a.flag("task"), Some("L1/01_x"));
        assert!(a.has("vendor"));
        assert_eq!(a.usize_flag("steps", 10), 5);
        assert_eq!(a.usize_flag("missing", 7), 7);
    }

    #[test]
    fn unknown_command_usage() {
        assert_eq!(run(&argv("frobnicate")), 2);
        assert_eq!(run(&[]), 2);
    }

    #[test]
    fn version_and_list_ok() {
        assert_eq!(run(&argv("version")), 0);
        assert_eq!(run(&argv("list")), 0);
    }

    #[test]
    fn optimize_requires_valid_task() {
        assert_eq!(run(&argv("optimize")), 2);
        assert_eq!(run(&argv("optimize --task bogus")), 2);
        assert_eq!(run(&argv("optimize --task L1/01_matmul_square --gpu V100")), 2);
    }

    #[test]
    fn optimize_quick_end_to_end() {
        assert_eq!(
            run(&argv(
                "optimize --task L1/12_softmax --gpu A100 --trajectories 1 --steps 2"
            )),
            0
        );
    }

    #[test]
    fn optimize_policy_flags_select_and_validate() {
        // Every named policy is reachable from the CLI.
        for policy in [
            "greedy_topk",
            "epsilon_greedy",
            "ucb_bandit",
            "beam_search",
            "portfolio",
            "thompson",
        ] {
            assert_eq!(
                run(&argv(&format!(
                    "optimize --task L1/15_relu --gpu A100 --trajectories 1 --steps 2 \
                     --policy {policy}"
                ))),
                0,
                "--policy {policy} failed"
            );
        }
        // Unknown names and invalid hyperparameters are usage errors.
        assert_eq!(
            run(&argv("optimize --task L1/15_relu --policy annealing")),
            2
        );
        assert_eq!(
            run(&argv(
                "optimize --task L1/15_relu --policy epsilon_greedy --epsilon 1.5"
            )),
            2
        );
        assert_eq!(
            run(&argv(
                "optimize --task L1/15_relu --policy beam_search --beam-width 0"
            )),
            2
        );
        assert_eq!(
            run(&argv("optimize --task L1/15_relu --policy ucb_bandit --ucb-c -2")),
            2
        );
    }

    #[test]
    fn optimize_schedule_and_dedup_flags_select_and_validate() {
        // Annealed schedules ride any policy from the CLI.
        for sched in ["constant", "harmonic", "exponential"] {
            assert_eq!(
                run(&argv(&format!(
                    "optimize --task L1/15_relu --gpu A100 --trajectories 1 --steps 2 \
                     --policy epsilon_greedy --schedule {sched} --schedule-rate 0.5"
                ))),
                0,
                "--schedule {sched} failed"
            );
        }
        // Similarity dedup threshold on a beam run.
        assert_eq!(
            run(&argv(
                "optimize --task L1/15_relu --gpu A100 --trajectories 1 --steps 2 \
                 --policy beam_search --beam-width 2 --dedup-distance 1.5"
            )),
            0
        );
        // Unknown schedule / bad rate / bad threshold / a bare rate over
        // the constant schedule are usage errors.
        assert_eq!(
            run(&argv("optimize --task L1/15_relu --schedule cosine")),
            2
        );
        assert_eq!(
            run(&argv("optimize --task L1/15_relu --schedule-rate 0.5")),
            2
        );
        assert_eq!(
            run(&argv(
                "optimize --task L1/15_relu --schedule harmonic --schedule-rate -1"
            )),
            2
        );
        assert_eq!(
            run(&argv("optimize --task L1/15_relu --dedup-distance -0.5")),
            2
        );
    }

    #[test]
    fn batch_epoch_policies_flag_schedules_the_mix() {
        let dir = std::env::temp_dir().join("kb_cli_epoch_mix_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = dir.join("jobs.txt");
        std::fs::write(&jobs, "L1/12_softmax\nL1/15_relu\nL1/01_matmul_square\n").unwrap();
        let jobs_s = jobs.to_str().unwrap();
        assert_eq!(
            run(&argv(&format!(
                "batch --jobs {jobs_s} --gpu A100 --workers 2 --epoch-size 1 \
                 --trajectories 1 --steps 2 \
                 --epoch-policies epsilon_greedy,epsilon_greedy,ucb_bandit"
            ))),
            0
        );
        // Unknown names in the mix are usage errors.
        assert_eq!(
            run(&argv(&format!(
                "batch --jobs {jobs_s} --epoch-policies epsilon_greedy,bogus"
            ))),
            2
        );
        assert_eq!(
            run(&argv(&format!("batch --jobs {jobs_s} --epoch-policies ,"))),
            2
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_hyperparameter_flags_overlay_config_file_epoch_mix() {
        // A config file's epoch mix must see later CLI hyperparameter
        // overrides exactly as a flag-built mix does: `--epsilon 0.6`
        // over a file mix equals a file whose policy already says 0.6.
        let dir = std::env::temp_dir().join("kb_cli_mix_overlay_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = dir.join("jobs.txt");
        std::fs::write(&jobs, "L1/12_softmax\nL1/15_relu\n").unwrap();
        let write_cfg = |name: &str, epsilon: f64| {
            let p = dir.join(name);
            std::fs::write(
                &p,
                format!(
                    r#"{{"gpu":"A100","policy":{{"kind":"epsilon_greedy","epsilon":{epsilon}}},
                        "fleet":{{"epoch_size":1,"epoch_policies":[
                            {{"kind":"epsilon_greedy"}},{{"kind":"ucb_bandit"}}]}}}}"#
                ),
            )
            .unwrap();
            p
        };
        let low = write_cfg("low.json", 0.0);
        let high = write_cfg("high.json", 1.0);
        let run_batch = |cfg_path: &Path, extra: &str, out: &Path| {
            let argv: Vec<String> = format!(
                "batch --jobs {} --config {}{extra} --workers 1 \
                 --trajectories 2 --steps 3 --seed 5 --save-kb {}",
                jobs.to_str().unwrap(),
                cfg_path.display(),
                out.display()
            )
            .split_whitespace()
            .map(String::from)
            .collect();
            assert_eq!(run(&argv), 0);
            std::fs::read(out).unwrap()
        };
        let flag_over_low = run_batch(&low, " --epsilon 1.0", &dir.join("a.json"));
        let native_high = run_batch(&high, "", &dir.join("b.json"));
        let native_low = run_batch(&low, "", &dir.join("c.json"));
        assert_eq!(
            flag_over_low, native_high,
            "--epsilon must overlay the config-file epoch mix"
        );
        assert_ne!(
            native_low, native_high,
            "fixture must be ε-sensitive for the overlay check to mean anything"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn optimize_staged_and_memo_flags_end_to_end() {
        let dir = std::env::temp_dir().join("kb_cli_staged_test");
        std::fs::create_dir_all(&dir).unwrap();
        let memo = dir.join("memo.json");
        let memo_s = memo.to_str().unwrap();
        assert_eq!(
            run(&argv(&format!(
                "optimize --task L1/12_softmax --gpu A100 --trajectories 1 --steps 2 \
                 --staged --memo {memo_s}"
            ))),
            0
        );
        assert!(memo.exists(), "staged run must persist the memo");
        // A second run replays the persisted verdicts and still succeeds.
        assert_eq!(
            run(&argv(&format!(
                "optimize --task L1/12_softmax --gpu A100 --trajectories 1 --steps 2 \
                 --staged --memo {memo_s}"
            ))),
            0
        );
        // Invalid verify knobs are usage errors.
        assert_eq!(
            run(&argv(
                "optimize --task L1/15_relu --staged --screen-margin 0.5"
            )),
            2
        );
        assert_eq!(
            run(&argv("optimize --task L1/15_relu --staged --probe-seeds 0")),
            2
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_staged_memo_and_auto_epochs_end_to_end() {
        let dir = std::env::temp_dir().join("kb_cli_batch_staged_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = dir.join("jobs.txt");
        std::fs::write(&jobs, "L1/12_softmax\nL1/15_relu\n").unwrap();
        let memo = dir.join("memo.json");
        assert_eq!(
            run(&argv(&format!(
                "batch --jobs {} --gpu A100 --workers 2 --epoch-size 1 \
                 --trajectories 1 --steps 2 --epoch-policies auto \
                 --staged --memo {}",
                jobs.to_str().unwrap(),
                memo.display()
            ))),
            0
        );
        assert!(memo.exists(), "staged batch must persist the memo");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kb_init_and_inspect_roundtrip() {
        let dir = std::env::temp_dir().join("kb_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("kb.json");
        let path_s = path.to_str().unwrap();
        assert_eq!(run(&argv(&format!("kb init --path {path_s}"))), 0);
        assert_eq!(run(&argv(&format!("kb inspect --path {path_s}"))), 0);
        assert_eq!(run(&argv("kb inspect --path /nonexistent/x.json")), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kb_lifecycle_subcommands_end_to_end() {
        let dir = std::env::temp_dir().join("kb_cli_lifecycle_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = |n: &str| dir.join(n).to_str().unwrap().to_string();
        let (a, b) = (p("a.json"), p("b.json"));
        let (merged, moved) = (p("merged.json"), p("h100.json"));
        assert_eq!(run(&argv(&format!("kb init --path {a}"))), 0);
        assert_eq!(run(&argv(&format!("kb init --path {b}"))), 0);
        assert_eq!(run(&argv(&format!("kb merge {a} {b} --out {merged}"))), 0);
        assert_eq!(run(&argv(&format!("kb stats --path {merged}"))), 0);
        // No recorded arch and no --from: transfer must refuse.
        assert_eq!(
            run(&argv(&format!("kb transfer --path {merged} --to H100"))),
            2
        );
        assert_eq!(
            run(&argv(&format!(
                "kb transfer --path {merged} --from A6000 --to H100 --out {moved}"
            ))),
            0
        );
        // Transferred KB records its arch: --from is now optional.
        assert_eq!(
            run(&argv(&format!("kb transfer --path {moved} --to L40S --out {moved}"))),
            0
        );
        assert_eq!(
            run(&argv(&format!("kb compact --path {moved} --max-notes 0"))),
            0
        );
        assert_eq!(run(&argv(&format!("kb inspect --path {moved}"))), 0);
        assert_eq!(run(&argv(&format!("kb stats --path {moved}"))), 0);
        // Error paths.
        assert_eq!(
            run(&argv(&format!(
                "kb transfer --path {moved} --to H100 --decay 2.0"
            ))),
            2
        );
        assert_eq!(run(&argv(&format!("kb merge {a} --out {merged}"))), 2);
        assert_eq!(run(&argv("kb stats --path /nonexistent/x.json")), 1);
        assert_eq!(run(&argv("kb frobnicate --path x.json")), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn optimize_warm_start_flag_seeds_run() {
        let dir = std::env::temp_dir().join("kb_cli_warmstart_test");
        std::fs::create_dir_all(&dir).unwrap();
        let prior = dir.join("prior.json").to_str().unwrap().to_string();
        let out = dir.join("out.json").to_str().unwrap().to_string();
        assert_eq!(run(&argv(&format!("kb init --path {prior}"))), 0);
        assert_eq!(
            run(&argv(&format!(
                "optimize --task L1/15_relu --gpu H100 --trajectories 1 --steps 2 \
                 --warm-start {prior} --save-kb {out}"
            ))),
            0
        );
        let kb = persist::load(Path::new(&out)).unwrap();
        assert_eq!(kb.arch.as_deref(), Some("H100"));
        assert!(kb.lineage.iter().any(|l| l.starts_with("warm_start")));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_end_to_end_streams_checkpoints_and_saves() {
        let dir = std::env::temp_dir().join("kb_cli_batch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = dir.join("jobs.txt");
        std::fs::write(
            &jobs,
            "# smoke batch\nL1/12_softmax\n\nL1/15_relu\nL1/01_matmul_square\n",
        )
        .unwrap();
        let out = dir.join("kb.json");
        let (jobs_s, out_s) = (jobs.to_str().unwrap(), out.to_str().unwrap());
        assert_eq!(
            run(&argv(&format!(
                "batch --jobs {jobs_s} --gpu A100 --workers 2 --epoch-size 2 \
                 --trajectories 1 --steps 2 --checkpoint-every 1 --save-kb {out_s}"
            ))),
            0
        );
        let kb = persist::load(&out).unwrap();
        assert!(kb.total_attempts() > 0, "batch must grow the shared KB");
        assert_eq!(kb.arch.as_deref(), Some("A100"));
        assert!(!dir.join("kb.json.tmp").exists(), "tmp renamed away");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_rejects_bad_inputs() {
        let dir = std::env::temp_dir().join("kb_cli_batch_errs_test");
        std::fs::create_dir_all(&dir).unwrap();
        // No job source at all.
        assert_eq!(run(&argv("batch")), 2);
        // Unreadable job file.
        assert_eq!(run(&argv("batch --jobs /nonexistent/jobs.txt")), 1);
        // Unknown task id in the list.
        let bogus = dir.join("bogus.txt");
        std::fs::write(&bogus, "L1/99_not_a_task\n").unwrap();
        let bogus_s = bogus.to_str().unwrap();
        assert_eq!(run(&argv(&format!("batch --jobs {bogus_s}"))), 2);
        // Empty list / bad fleet shape / bad GPU.
        let empty = dir.join("empty.txt");
        std::fs::write(&empty, "# nothing\n").unwrap();
        let empty_s = empty.to_str().unwrap();
        assert_eq!(run(&argv(&format!("batch --jobs {empty_s}"))), 2);
        let good = dir.join("good.txt");
        std::fs::write(&good, "L1/15_relu\n").unwrap();
        let good_s = good.to_str().unwrap();
        assert_eq!(run(&argv(&format!("batch --jobs {good_s} --workers 0"))), 2);
        assert_eq!(run(&argv(&format!("batch --jobs {good_s} --shards 0"))), 2);
        assert_eq!(
            run(&argv(&format!("batch --jobs {good_s} --commit-queue 0"))),
            2
        );
        assert_eq!(run(&argv(&format!("batch --jobs {good_s} --gpu V100"))), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_sharded_commits_match_the_single_committer() {
        let dir = std::env::temp_dir().join("kb_cli_batch_shards_test");
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = dir.join("jobs.txt");
        std::fs::write(&jobs, "L1/12_softmax\nL1/15_relu\nL1/01_matmul_square\n").unwrap();
        let jobs_s = jobs.to_str().unwrap();
        let saved = |shards: usize| {
            let out = dir.join(format!("kb_s{shards}.json"));
            assert_eq!(
                run(&argv(&format!(
                    "batch --jobs {jobs_s} --gpu A100 --workers 2 --epoch-size 2 \
                     --trajectories 1 --steps 2 --shards {shards} --save-kb {}",
                    out.to_str().unwrap()
                ))),
                0,
                "--shards {shards} batch failed"
            );
            std::fs::read_to_string(&out).unwrap()
        };
        let single = saved(1);
        assert_eq!(saved(2), single, "sharded KB bytes must match shards=1");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn screen_margin_auto_falls_back_and_reads_artifacts() {
        // No artifact on disk: auto must fall back to 1.5x and still run.
        assert_eq!(
            run(&argv(
                "optimize --task L1/15_relu --gpu A100 --trajectories 1 --steps 2 \
                 --staged --screen-margin auto --verify-bench /nonexistent/BENCH_verify.json"
            )),
            0
        );
        // A measured artifact resolves to its suggested margin.
        let dir = std::env::temp_dir().join("cli_screen_margin_auto_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bench = dir.join("BENCH_verify.json");
        std::fs::write(
            &bench,
            r#"{"format":"kernelblaster-bench-verify-v1",
                "screen_error":{"samples":12,"p95_ratio":1.62,"suggested_margin":1.62}}"#,
        )
        .unwrap();
        assert_eq!(read_suggested_margin(&bench), Ok(1.62));
        assert_eq!(
            run(&argv(&format!(
                "optimize --task L1/15_relu --gpu A100 --trajectories 1 --steps 2 \
                 --staged --screen-margin auto --verify-bench {}",
                bench.to_str().unwrap()
            ))),
            0
        );
        // Wrong format and missing section are fall-back errors, not panics.
        let wrong = dir.join("wrong.json");
        std::fs::write(&wrong, r#"{"format":"something-else"}"#).unwrap();
        assert!(read_suggested_margin(&wrong).is_err());
        let old = dir.join("old.json");
        std::fs::write(&old, r#"{"format":"kernelblaster-bench-verify-v1"}"#).unwrap();
        assert!(read_suggested_margin(&old).is_err());
        // Out-of-range margins (screen must never tighten below 1.0x).
        let low = dir.join("low.json");
        std::fs::write(
            &low,
            r#"{"format":"kernelblaster-bench-verify-v1","screen_error":{"suggested_margin":0.8}}"#,
        )
        .unwrap();
        assert!(read_suggested_margin(&low).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_experiment_rejected() {
        assert_eq!(run(&argv("experiment nope")), 2);
        assert_eq!(run(&argv("experiment")), 2);
    }

    #[test]
    fn optimize_skills_flags_select_and_validate() {
        assert_eq!(
            run(&argv(
                "optimize --task L1/15_relu --gpu A100 --trajectories 1 --steps 2 --skills"
            )),
            0
        );
        // Degenerate knob values are usage errors.
        assert_eq!(
            run(&argv("optimize --task L1/15_relu --skills --skill-max-len 1")),
            2
        );
        assert_eq!(
            run(&argv(
                "optimize --task L1/15_relu --skills --skill-min-support 0"
            )),
            2
        );
        assert_eq!(
            run(&argv(
                "optimize --task L1/15_relu --skills --skill-max-per-state 0"
            )),
            2
        );
    }

    #[test]
    fn kb_mine_end_to_end() {
        let dir = std::env::temp_dir().join("kb_cli_mine_test");
        std::fs::create_dir_all(&dir).unwrap();
        let kb_path = dir.join("kb.json").to_str().unwrap().to_string();
        assert_eq!(run(&argv(&format!("kb init --path {kb_path}"))), 0);
        assert_eq!(
            run(&argv(&format!(
                "kb mine --path {kb_path} --gpu A100 --tasks L1/12_softmax,L1/15_relu \
                 --trajectories 2 --steps 3 --skill-min-support 1 --skill-min-gain 1.0"
            ))),
            0
        );
        // The mined KB still loads, reports, and drives a skills-on run.
        assert_eq!(run(&argv(&format!("kb stats --path {kb_path}"))), 0);
        assert_eq!(
            run(&argv(&format!(
                "optimize --task L1/12_softmax --gpu A100 --trajectories 1 --steps 2 \
                 --kb {kb_path} --skills"
            ))),
            0
        );
        // Error paths.
        assert_eq!(run(&argv("kb mine")), 2);
        assert_eq!(
            run(&argv(&format!("kb mine --path {kb_path} --tasks L9/nope"))),
            2
        );
        assert_eq!(run(&argv("kb mine --path /nonexistent/x.json")), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn memo_compact_end_to_end() {
        let dir = std::env::temp_dir().join("kb_cli_memo_compact_test");
        std::fs::create_dir_all(&dir).unwrap();
        let memo_path = dir.join("memo.json");
        let memo_s = memo_path.to_str().unwrap();
        // Grow a memo with a staged run, then bound it.
        assert_eq!(
            run(&argv(&format!(
                "optimize --task L1/12_softmax --gpu A100 --trajectories 1 --steps 2 \
                 --staged --memo {memo_s}"
            ))),
            0
        );
        let grown = memo::load(&memo_path).unwrap();
        assert!(!grown.is_empty());
        assert_eq!(
            run(&argv(&format!(
                "memo compact --path {memo_s} --max-entries 1"
            ))),
            0
        );
        let bounded = memo::load(&memo_path).unwrap();
        assert!(bounded.len() <= 1, "bound not enforced: {}", bounded.len());
        assert_eq!(bounded.epoch(), grown.epoch() + 1, "compaction closes an era");
        // Error paths.
        assert_eq!(run(&argv("memo compact")), 2);
        assert_eq!(run(&argv(&format!("memo compact --path {memo_s}"))), 2);
        assert_eq!(
            run(&argv("memo compact --path /nonexistent/m.json --max-entries 5")),
            1
        );
        assert_eq!(run(&argv("memo frobnicate")), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn memo_compact_takes_bound_from_config_file() {
        let dir = std::env::temp_dir().join("kb_cli_memo_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let memo_path = dir.join("memo.json");
        let memo_s = memo_path.to_str().unwrap();
        assert_eq!(
            run(&argv(&format!(
                "optimize --task L1/12_softmax --gpu A100 --trajectories 1 --steps 2 \
                 --staged --memo {memo_s}"
            ))),
            0
        );
        let cfg = dir.join("run.json");
        std::fs::write(
            &cfg,
            r#"{"verify":{"staged":true,"memo_max_entries":1}}"#,
        )
        .unwrap();
        assert_eq!(
            run(&argv(&format!(
                "memo compact --path {memo_s} --config {}",
                cfg.display()
            ))),
            0
        );
        assert!(memo::load(&memo_path).unwrap().len() <= 1);
        // A config without the knob is not a bound.
        let empty_cfg = dir.join("empty.json");
        std::fs::write(&empty_cfg, "{}").unwrap();
        assert_eq!(
            run(&argv(&format!(
                "memo compact --path {memo_s} --config {}",
                empty_cfg.display()
            ))),
            2
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_rejects_bad_inputs() {
        // All of these fail validation before any socket is bound.
        assert_eq!(run(&argv("serve --gpu V100")), 2);
        assert_eq!(run(&argv("serve --workers 0")), 2);
        assert_eq!(run(&argv("serve --epoch-size 0")), 2);
        assert_eq!(run(&argv("serve --policy annealing")), 2);
        assert_eq!(run(&argv("serve --kb /nonexistent/kb.json")), 1);
        // Tenancy flags: malformed specs are usage errors, a missing
        // base KB file is a load failure.
        assert_eq!(run(&argv("serve --tenant-quota bad")), 2);
        assert_eq!(run(&argv("serve --tenant-quota acme=0")), 2);
        assert_eq!(run(&argv("serve --tenant-quota a/b=2")), 2);
        assert_eq!(run(&argv("serve --tenant-quota acme=three")), 2);
        assert_eq!(run(&argv("serve --base-kb /nonexistent/base.json")), 1);
    }

    #[test]
    fn tenant_quota_specs_parse_and_reject() {
        assert_eq!(
            parse_tenant_quotas("acme=3,zeta=1").unwrap(),
            vec![("acme".to_string(), 3), ("zeta".to_string(), 1)]
        );
        // A trailing comma is tolerated; empty spec parses to nothing.
        assert_eq!(parse_tenant_quotas("acme=2,").unwrap().len(), 1);
        assert!(parse_tenant_quotas("").unwrap().is_empty());
        for bad in ["acme", "acme=", "acme=0", "acme=-1", "a/b=2", "=3"] {
            assert!(parse_tenant_quotas(bad).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn policy_auto_picks_best_paired_arm() {
        let dir = std::env::temp_dir().join("kb_cli_policy_auto_test");
        std::fs::create_dir_all(&dir).unwrap();
        let sweep = dir.join("BENCH_sweep.json");
        // The unpaired arm scores highest but is ineligible; the unknown
        // policy must be skipped, not an error; ucb@1.2 beats greedy.
        std::fs::write(
            &sweep,
            r#"{"format":"kernelblaster-bench-sweep-v1","gpu":"A100","arms":[
                {"label":"greedy","policy":"greedy_topk","epsilon":0.15,"ucb_c":0.5,
                 "beam_width":3,"schedule":"constant","schedule_rate":0.0,
                 "vs_greedy_paired":1.0,"paired_cells":4},
                {"label":"ucb@1.2","policy":"ucb_bandit","epsilon":0.15,"ucb_c":1.2,
                 "beam_width":3,"schedule":"harmonic","schedule_rate":0.5,
                 "vs_greedy_paired":1.08,"paired_cells":4},
                {"label":"unpaired","policy":"beam_search","epsilon":0.15,"ucb_c":0.5,
                 "beam_width":2,"schedule":"constant","schedule_rate":0.0,
                 "vs_greedy_paired":9.99,"paired_cells":0},
                {"label":"future","policy":"quantum_anneal","epsilon":0.15,"ucb_c":0.5,
                 "beam_width":3,"schedule":"constant","schedule_rate":0.0,
                 "vs_greedy_paired":2.0,"paired_cells":4}
            ]}"#,
        )
        .unwrap();
        let (label, score, policy) = read_sweep_best(&sweep).unwrap();
        assert_eq!(label, "ucb@1.2");
        assert!((score - 1.08).abs() < 1e-12);
        assert_eq!(policy.kind, PolicyKind::UcbBandit);
        assert!((policy.ucb_c - 1.2).abs() < 1e-12);
        assert_eq!(policy.schedule, Schedule::Harmonic { rate: 0.5 });

        // Fallback paths: missing file and artifact with no eligible arm.
        let base = PolicyConfig::of_kind(PolicyKind::Thompson);
        let fb = policy_from_sweep(Path::new("/nonexistent/sweep.json"), &base);
        assert_eq!(fb.kind, PolicyKind::GreedyTopK, "fallback is greedy");
        let bad = dir.join("bad.json");
        std::fs::write(&bad, r#"{"format":"something-else","arms":[]}"#).unwrap();
        assert!(read_sweep_best(&bad).is_err());

        // End-to-end: auto resolves from the artifact; a missing
        // artifact is a notice + greedy, never a refusal to run.
        assert_eq!(
            run(&argv(&format!(
                "optimize --task L1/15_relu --gpu A100 --trajectories 1 --steps 2 \
                 --policy auto --sweep {}",
                sweep.display()
            ))),
            0
        );
        assert_eq!(
            run(&argv(
                "optimize --task L1/15_relu --gpu A100 --trajectories 1 --steps 2 \
                 --policy auto --sweep /nonexistent/sweep.json"
            )),
            0
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
