//! Agentic comparison systems: the AI CUDA Engineer analog, the
//! Kernelsseum-style zero-shot baseline, and the §6.4 minimal agent.
//!
//! All three share KernelBlaster's harness and lowering substrate but
//! differ in policy:
//! - **AI CUDA Engineer**: evolutionary archive search — generations of
//!   prior-weighted proposals, elitist selection, embedding-style
//!   retrieval of past kernels, *no* profile-conditioned states and no
//!   textual-gradient updates (Table 2: 10 generations; 8 proposals
//!   sampled per generation; top 4 evaluated).
//! - **Zero-shot**: a single unguided generation pass.
//! - **Minimal agent**: reads code + full NCU report, rewrites the whole
//!   kernel each turn (full-source completions — the 2.4× token cost of
//!   §6.4), no knowledge base, no state abstraction.

use crate::agents::lowering;
use crate::agents::{tokens, AgentConfig, TokenMeter};
use crate::gpu::GpuArch;
use crate::harness::{self, HarnessConfig, Outcome};
use crate::kir::render;
use crate::opts::{Candidate, Technique};
use crate::tasks::Task;
use crate::util::rng::Rng;

/// Outcome of an agentic baseline on one task.
#[derive(Debug, Clone)]
pub struct AgenticRun {
    pub task_id: String,
    pub valid: bool,
    pub naive_time_s: f64,
    pub best_time_s: f64,
    pub tokens: TokenMeter,
}

impl AgenticRun {
    pub fn speedup_vs_naive(&self) -> f64 {
        self.naive_time_s / self.best_time_s
    }
}

/// Sample a technique from prior weights over the applicable set (no
/// state conditioning — the key difference from KernelBlaster).
fn sample_prior_weighted(cand: &Candidate, rng: &mut Rng, allow_vendor: bool) -> Option<(Technique, usize)> {
    let apps: Vec<(Technique, usize)> = Technique::all()
        .iter()
        .filter(|t| allow_vendor || **t != Technique::VendorLibraryDispatch)
        .filter_map(|t| t.applicable_anywhere(cand).map(|gi| (*t, gi)))
        .collect();
    if apps.is_empty() {
        return None;
    }
    let weights: Vec<f64> = apps.iter().map(|(t, _)| t.prior_gain() - 0.9).collect();
    Some(apps[rng.weighted_index(&weights)])
}

/// AI CUDA Engineer analog: `generations` rounds; each samples
/// `proposals` mutations of the current elite, evaluates the top
/// `evaluated` by prior score, keeps the best. The paper's published
/// system shows ~82% valid rate; invalidity here emerges from the same
/// lowering failure model KernelBlaster faces, plus a stricter one-shot
/// initial translation (no retry on the first lowering).
pub fn cuda_engineer(
    task: &Task,
    arch: &GpuArch,
    hcfg: &HarnessConfig,
    seed: u64,
) -> AgenticRun {
    let mut rng = Rng::new(seed).derive(&format!("cuda-eng/{}", task.id));
    let mut meter = TokenMeter::new();
    let agent = AgentConfig {
        // No profile feedback loop → lowering errors are likelier and are
        // not retried with feedback.
        lowering_bug_rate: 0.12,
        lowering_fail_rate: 0.08,
        reward_hack_rate: 0.03,
        retry_limit: 0,
        ..AgentConfig::default()
    };
    let naive = Candidate::naive(task);
    let naive_rep = harness::profile_naive(task, arch, hcfg, &mut rng);
    let naive_time = naive_rep.total_time_s;
    // §Perf: baselines share the memoized-oracle discipline — the task
    // reference is computed once per run, not once per candidate.
    let mut cache = harness::VerifyCache::new();
    let _ = cache.warm(task, hcfg);

    // One-shot initial translation: ~15% of tasks never produce a valid
    // starting kernel (drives the 82% ValidRate).
    if rng.chance(0.15) {
        return AgenticRun {
            task_id: task.id.clone(),
            valid: false,
            naive_time_s: naive_time,
            best_time_s: naive_time,
            tokens: meter,
        };
    }

    let generations = 10;
    let proposals = 8;
    let evaluated = 4;
    let mut elite = naive.clone();
    let mut elite_time = naive_time;
    let mut any_valid = true;

    for _gen in 0..generations {
        // Propose mutations (embedding retrieval = prior-weighted sampling
        // over the archive's technique distribution).
        let mut cands: Vec<(Technique, usize)> = Vec::new();
        for _ in 0..proposals {
            if let Some(pick) = sample_prior_weighted(&elite, &mut rng, hcfg.allow_vendor) {
                cands.push(pick);
            }
            // Proposal cost: archive exemplars + code context.
            meter.add(600, 120);
        }
        cands.truncate(evaluated);
        for (tech, gi) in cands {
            let lowered = lowering::lower(tech, &elite, gi, &agent, 0, &mut meter, &mut rng);
            if let Some(c) = lowered.candidate() {
                let out = harness::run_cached(task, c, arch, hcfg, Some(&cache), &mut rng);
                if let Outcome::Ok(rep) = out {
                    if rep.total_time_s < elite_time {
                        elite_time = rep.total_time_s;
                        elite = c.clone();
                    }
                }
                // Harness-rejected candidates (semantic bugs, reward
                // hacks) are simply discarded — no feedback/retry loop.
            }
        }
        let _ = &mut any_valid;
    }
    AgenticRun {
        task_id: task.id.clone(),
        valid: any_valid,
        naive_time_s: naive_time,
        best_time_s: elite_time,
        tokens: meter,
    }
}

/// Kernelsseum-style zero-shot: one generation, no iteration, no
/// profiling feedback. Often the naive kernel with one cheap tweak.
pub fn zero_shot(task: &Task, arch: &GpuArch, hcfg: &HarnessConfig, seed: u64) -> AgenticRun {
    let mut rng = Rng::new(seed).derive(&format!("zero-shot/{}", task.id));
    let mut meter = TokenMeter::new();
    let naive = Candidate::naive(task);
    let naive_rep = harness::profile_naive(task, arch, hcfg, &mut rng);
    let naive_time = naive_rep.total_time_s;
    meter.add(tokens::text_tokens(&render::render(&naive.full, &naive.schedule)) + 300, 500);
    // ~30% of zero-shot generations are invalid (no feedback loop at all).
    if rng.chance(0.30) {
        return AgenticRun {
            task_id: task.id.clone(),
            valid: false,
            naive_time_s: naive_time,
            best_time_s: naive_time,
            tokens: meter,
        };
    }
    // The model "knows" common good practice: coalescing, maybe fusion.
    let mut cache = harness::VerifyCache::new();
    let _ = cache.warm(task, hcfg);
    let mut cand = naive;
    let mut time = naive_time;
    for tech in [Technique::MemoryCoalescing, Technique::KernelFusion] {
        if let Some(gi) = tech.applicable_anywhere(&cand) {
            if let Ok(c) = crate::opts::apply::apply(tech, &cand, gi) {
                let out = harness::run_cached(task, &c, arch, hcfg, Some(&cache), &mut rng);
                if let Outcome::Ok(rep) = out {
                    cand = c;
                    time = rep.total_time_s;
                }
            }
        }
    }
    AgenticRun {
        task_id: task.id.clone(),
        valid: true,
        naive_time_s: naive_time,
        best_time_s: time,
        tokens: meter,
    }
}

/// §6.4 minimal agent: at each iteration it "directly takes in CUDA code
/// and NCU profiling data and outputs optimized code" — whole-source
/// completions, uniform technique choice, no knowledge base. Run shape
/// matches the paper's comparison (10 trajectories × length 10).
pub fn minimal_agent(
    task: &Task,
    arch: &GpuArch,
    hcfg: &HarnessConfig,
    trajectories: usize,
    steps: usize,
    seed: u64,
) -> AgenticRun {
    let mut rng = Rng::new(seed).derive(&format!("minimal/{}", task.id));
    let mut meter = TokenMeter::new();
    let agent = AgentConfig {
        // No guided reasoning → more correction retries needed (§6.4
        // cause 2: "requires more retrievals for correctness").
        lowering_bug_rate: 0.16,
        lowering_fail_rate: 0.10,
        reward_hack_rate: 0.02,
        retry_limit: 2,
        state_misclassify_rate: 0.0, // no state abstraction at all
    };
    let naive = Candidate::naive(task);
    let naive_rep = harness::profile_naive(task, arch, hcfg, &mut rng);
    let naive_time = naive_rep.total_time_s;
    // §Perf: memoized oracle, as in the driver and the other baselines.
    let mut cache = harness::VerifyCache::new();
    let _ = cache.warm(task, hcfg);
    let mut best = naive.clone();
    let mut best_time = naive_time;
    let mut any_valid = false;

    for _traj in 0..trajectories {
        let mut cand = naive.clone();
        let mut cur_time = naive_time;
        let mut cur_rep = naive_rep.clone();
        for step in 0..steps {
            // Prompt: full source + full NCU details (no KB to focus it),
            // PLUS the growing chat history — a minimal loop is one long
            // conversation, so every turn re-reads all prior attempts.
            let src = render::render(&cand.full, &cand.schedule);
            let details = cur_rep.render_details();
            let history = step * 450;
            // Completion: the agent rewrites the WHOLE kernel source, plus
            // up-front unguided reasoning (§6.4 cause 1).
            let reasoning = 1600;
            meter.add(
                tokens::text_tokens(&src) + tokens::text_tokens(&details) + 200 + history,
                tokens::text_tokens(&src) + reasoning,
            );
            // Uniform choice over applicable techniques.
            let apps: Vec<(Technique, usize)> = Technique::all()
                .iter()
                .filter(|t| hcfg.allow_vendor || **t != Technique::VendorLibraryDispatch)
                .filter_map(|t| t.applicable_anywhere(&cand).map(|gi| (*t, gi)))
                .collect();
            let Some(&(tech, gi)) = (if apps.is_empty() {
                None
            } else {
                Some(&apps[rng.index(apps.len())])
            }) else {
                break;
            };
            let mut stepped = false;
            for attempt in 0..=agent.retry_limit {
                let lowered = lowering::lower(tech, &cand, gi, &agent, attempt, &mut meter, &mut rng);
                if let Some(c) = lowered.candidate() {
                    let out = harness::run_cached(task, c, arch, hcfg, Some(&cache), &mut rng);
                    if let Outcome::Ok(rep) = out {
                        any_valid = true;
                        if rep.total_time_s < best_time {
                            best_time = rep.total_time_s;
                            best = c.clone();
                        }
                        cur_time = rep.total_time_s;
                        cur_rep = rep;
                        cand = c.clone();
                        stepped = true;
                        break;
                    }
                }
            }
            if !stepped {
                // Keep state; burned tokens.
                let _ = cur_time;
            }
        }
    }
    let _ = best;
    AgenticRun {
        task_id: task.id.clone(),
        valid: any_valid,
        naive_time_s: naive_time,
        best_time_s: best_time,
        tokens: meter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Suite;

    fn hcfg() -> HarnessConfig {
        HarnessConfig {
            noise_sigma: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn cuda_engineer_improves_but_stochastically() {
        let suite = Suite::full();
        let task = suite.by_id("L2/01_gemm_bias_relu").unwrap();
        let arch = GpuArch::l40s();
        let run = cuda_engineer(task, &arch, &hcfg(), 3);
        if run.valid {
            assert!(run.speedup_vs_naive() >= 1.0);
        }
        assert!(run.tokens.total() > 1000);
    }

    #[test]
    fn cuda_engineer_valid_rate_near_82pct() {
        let suite = Suite::full();
        let arch = GpuArch::l40s();
        let mut valid = 0;
        let mut total = 0;
        for task in suite.of_level(crate::tasks::Level::L1) {
            for seed in 0..3 {
                total += 1;
                if cuda_engineer(task, &arch, &hcfg(), seed).valid {
                    valid += 1;
                }
            }
        }
        let rate = valid as f64 / total as f64;
        assert!((0.70..=0.95).contains(&rate), "valid rate {rate:.2}");
    }

    #[test]
    fn zero_shot_is_cheap_and_weak() {
        let suite = Suite::full();
        let task = suite.by_id("L2/09_mlp_block").unwrap();
        let arch = GpuArch::h100();
        let zs = zero_shot(task, &arch, &hcfg(), 1);
        let ce = cuda_engineer(task, &arch, &hcfg(), 1);
        assert!(zs.tokens.total() < ce.tokens.total() / 2);
    }

    #[test]
    fn minimal_agent_token_heavy() {
        let suite = Suite::full();
        let task = suite.by_id("L2/01_gemm_bias_relu").unwrap();
        let arch = GpuArch::h100();
        let run = minimal_agent(task, &arch, &hcfg(), 2, 3, 5);
        // Whole-source completions: completion tokens rival prompt tokens.
        assert!(run.tokens.completion * 3 > run.tokens.prompt);
        assert!(run.tokens.total() > 5_000);
    }

    #[test]
    fn deterministic_across_calls() {
        let suite = Suite::full();
        let task = suite.by_id("L1/12_softmax").unwrap();
        let arch = GpuArch::a100();
        let a = cuda_engineer(task, &arch, &hcfg(), 9);
        let b = cuda_engineer(task, &arch, &hcfg(), 9);
        assert_eq!(a.best_time_s, b.best_time_s);
        assert_eq!(a.tokens, b.tokens);
    }
}
