//! Comparator systems (paper §4.1): PyTorch eager, torch.compile, the
//! IREE ML compiler, the AI CUDA Engineer, the Kernelsseum zero-shot
//! baseline, and the §6.4 minimal agent.
//!
//! Every baseline is evaluated "under equivalent execution and profiling
//! conditions": the same task graphs ([`crate::tasks`]), the same GPU
//! performance model ([`crate::gpu`]), the same harness
//! ([`crate::harness`]). They differ only in optimization policy —
//! exactly the axis the paper varies. [`crate::experiments`] and
//! [`crate::metrics`] consume the resulting times alongside
//! [`crate::icrl`]'s runs.

pub mod agentic;

use crate::gpu::{estimate_schedule, GpuArch};
use crate::kir::schedule::{MemLayout, Schedule, Tiling};
use crate::opts::{apply, Candidate, Technique};
use crate::tasks::Task;

/// Reference execution times for one task on one architecture.
#[derive(Debug, Clone, Copy)]
pub struct BaselineTimes {
    /// PyTorch eager: one vendor-library kernel per op.
    pub eager_s: f64,
    /// torch.compile: eager + elementwise fusion + dead-code elimination.
    pub compiled_s: f64,
}

impl BaselineTimes {
    /// The paper's 1.0× reference: "the best performance among PyTorch
    /// Eager and torch.compile" (§4.2).
    pub fn best_s(&self) -> f64 {
        self.eager_s.min(self.compiled_s)
    }
}

/// PyTorch eager analog: each op runs as a separate, well-engineered
/// vendor kernel (cuBLAS/cuDNN for contractions, tuned elementwise
/// kernels) — strong per-kernel performance, no cross-op fusion.
pub fn pytorch_eager(task: &Task, arch: &GpuArch) -> f64 {
    let mut schedule = Schedule::naive(&task.graph);
    for g in &mut schedule.groups {
        let has_contraction = g
            .nodes
            .iter()
            .any(|n| task.graph.nodes[*n].kind.is_contraction());
        if has_contraction {
            g.opts.vendor_lib = true;
        } else {
            // PyTorch's handwritten elementwise/reduction kernels are
            // memory-tuned: coalesced, vectorized, occupancy-friendly.
            g.opts.layout = MemLayout::Coalesced;
            g.opts.vector_width = 4;
            g.opts.warp_shuffle_reduction = true;
            g.opts.regs_per_thread = 32;
        }
    }
    estimate_schedule(arch, &task.graph, &schedule).total_time_s
}

/// torch.compile analog: eager's per-kernel quality plus elementwise
/// fusion and dead-code elimination (no algebraic rewrites — the Q18
/// double-logsumexp survives, which is why the paper's agent beats it
/// there by 20×).
pub fn torch_compile(task: &Task, arch: &GpuArch) -> f64 {
    let mut cand = Candidate::naive(task);
    // DCE only (no algebraic simplification).
    while let Some(gi) = Technique::DeadCodeElimination.applicable_anywhere(&cand) {
        match apply::apply(Technique::DeadCodeElimination, &cand, gi) {
            Ok(c) => cand = c,
            Err(_) => break,
        }
    }
    // Fuse maximal elementwise chains (not across contractions — inductor
    // epilogue fusion is modeled conservatively).
    loop {
        let mut fused_any = false;
        let mut a = 0;
        while a + 1 < cand.schedule.groups.len() {
            let all_ew = |gi: usize| {
                cand.schedule.groups[gi]
                    .nodes
                    .iter()
                    .all(|n| cand.full.nodes[*n].kind.is_elementwise())
            };
            if all_ew(a) && all_ew(a + 1) && cand.schedule.can_fuse(&cand.full, a, a + 1) {
                cand.schedule.fuse(a, a + 1);
                fused_any = true;
            } else {
                a += 1;
            }
        }
        if !fused_any {
            break;
        }
    }
    for g in &mut cand.schedule.groups {
        let has_contraction = g
            .nodes
            .iter()
            .any(|n| cand.full.nodes[*n].kind.is_contraction());
        if has_contraction {
            g.opts.vendor_lib = true;
        } else {
            g.opts.layout = MemLayout::Coalesced;
            g.opts.vector_width = 4;
            g.opts.warp_shuffle_reduction = true;
            g.opts.regs_per_thread = 32;
        }
    }
    estimate_schedule(arch, &cand.full, &cand.schedule).total_time_s
}

/// Both references at once.
pub fn baseline_times(task: &Task, arch: &GpuArch) -> BaselineTimes {
    BaselineTimes {
        eager_s: pytorch_eager(task, arch),
        compiled_s: torch_compile(task, arch),
    }
}

/// IREE analog (§4.8): a static ML compiler with (a) frontend op-coverage
/// failures (the paper hit 42/400 torch-mlir lowering failures ≈10.5%)
/// and (b) no access to NVIDIA vendor libraries — decent generic tiling,
/// but well behind cuBLAS/cuDNN on this hardware.
///
/// Returns `None` on a (deterministic, task-keyed) compilation failure.
pub fn iree(task: &Task, arch: &GpuArch) -> Option<f64> {
    // Deterministic ~10% failure, keyed by task id (stable across runs,
    // like a fixed unimplemented-op list).
    let mut h: u64 = 0xcbf29ce484222325;
    for b in task.id.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    if h % 10 == 0 {
        return None; // torch.aten.<something> lowering unimplemented
    }
    let mut cand = Candidate::naive(task);
    // Generic LLVMGPU pipeline: fuse elementwise consumers, tile
    // contractions modestly, coalesce. No vendor libs, no tensor cores
    // (the paper notes IREE's NVIDIA path is not its optimization focus).
    while let Some(gi) = Technique::DeadCodeElimination.applicable_anywhere(&cand) {
        match apply::apply(Technique::DeadCodeElimination, &cand, gi) {
            Ok(c) => cand = c,
            Err(_) => break,
        }
    }
    let mut a = 0;
    while a + 1 < cand.schedule.groups.len() {
        if cand.schedule.can_fuse(&cand.full, a, a + 1) {
            let next_is_ew = cand.schedule.groups[a + 1]
                .nodes
                .iter()
                .all(|n| cand.full.nodes[*n].kind.is_elementwise());
            if next_is_ew {
                cand.schedule.fuse(a, a + 1);
                continue;
            }
        }
        a += 1;
    }
    for g in &mut cand.schedule.groups {
        let has_contraction = g
            .nodes
            .iter()
            .any(|n| cand.full.nodes[*n].kind.is_contraction());
        g.opts.layout = MemLayout::Coalesced;
        if has_contraction {
            g.opts.tiling = Tiling::Shared { tile: 32 };
            g.opts.unroll = 4;
        }
        g.launch.block = 128; // generic pick, not NVIDIA-tuned
        let total: usize = g
            .nodes
            .iter()
            .map(|n| cand.full.nodes[*n].shape.numel())
            .max()
            .unwrap_or(1);
        g.launch.grid = total.div_ceil(g.launch.block).max(1);
    }
    Some(estimate_schedule(arch, &cand.full, &cand.schedule).total_time_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Suite;

    #[test]
    fn eager_beats_naive_cuda_heavily_on_gemm() {
        let suite = Suite::full();
        let task = suite.by_id("L1/02_matmul_large").unwrap();
        let arch = GpuArch::h100();
        let naive = estimate_schedule(
            &arch,
            &task.graph,
            &Schedule::naive(&task.graph),
        )
        .total_time_s;
        let eager = pytorch_eager(task, &arch);
        assert!(
            naive / eager > 10.0,
            "naive/eager = {:.1} (paper: naive CUDA up to 100x slower)",
            naive / eager
        );
    }

    #[test]
    fn compile_at_least_as_good_as_eager_on_chains() {
        let suite = Suite::full();
        let arch = GpuArch::a100();
        for id in ["L2/12_scale_tanh_clip_chain", "L2/01_gemm_bias_relu", "L3/01_lenet5"] {
            let task = suite.by_id(id).unwrap();
            let t = baseline_times(task, &arch);
            assert!(
                t.compiled_s <= t.eager_s * 1.001,
                "{id}: compile {:.2e} vs eager {:.2e}",
                t.compiled_s,
                t.eager_s
            );
        }
        // And strictly better where fusion matters.
        let chain = suite.by_id("L2/12_scale_tanh_clip_chain").unwrap();
        let t = baseline_times(chain, &arch);
        assert!(t.compiled_s < t.eager_s * 0.7);
    }

    #[test]
    fn iree_much_slower_than_pytorch_on_average() {
        // Paper Table 3: IREE geomean ≈ 0.27x of the PyTorch baseline.
        let suite = Suite::full();
        let arch = GpuArch::a100();
        let mut ratios = Vec::new();
        for task in suite.of_level(crate::tasks::Level::L1) {
            if let Some(t_iree) = iree(task, &arch) {
                let base = baseline_times(task, &arch).best_s();
                ratios.push(base / t_iree);
            }
        }
        let gm = crate::util::stats::geomean(&ratios);
        assert!(gm < 0.8, "IREE relative perf {gm:.2} should be well below 1");
        assert!(gm > 0.02, "IREE relative perf {gm:.2} implausibly low");
    }

    #[test]
    fn iree_fails_deterministically_on_some_tasks() {
        let suite = Suite::full();
        let arch = GpuArch::a6000();
        let fails: Vec<&str> = suite
            .tasks
            .iter()
            .filter(|t| iree(t, &arch).is_none())
            .map(|t| t.id.as_str())
            .collect();
        assert!(!fails.is_empty(), "some tasks must fail to compile");
        assert!(fails.len() < suite.tasks.len() / 4, "too many failures");
        // Determinism.
        let fails2: Vec<&str> = suite
            .tasks
            .iter()
            .filter(|t| iree(t, &arch).is_none())
            .map(|t| t.id.as_str())
            .collect();
        assert_eq!(fails, fails2);
    }

    #[test]
    fn q18_survives_torch_compile_unsimplified() {
        // torch.compile must NOT remove the double logsumexp — that gap is
        // the paper's 20x headline on Q18.
        let suite = Suite::full();
        let task = suite.by_id("L2/18_linear_sum_logsumexp2").unwrap();
        let arch = GpuArch::h100();
        let t = baseline_times(task, &arch);
        // Simplified+optimized agent kernel: strictly faster than both.
        let mut cand = Candidate::naive(task);
        cand = apply::simplify_fixpoint(&cand);
        for g in &mut cand.schedule.groups {
            g.opts.vendor_lib = true;
        }
        let agent = estimate_schedule(&arch, &cand.full, &cand.schedule).total_time_s;
        assert!(agent < t.best_s(), "agent {:.2e} vs best {:.2e}", agent, t.best_s());
    }
}
