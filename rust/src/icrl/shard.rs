//! Sharded pipelined fleet committer: parallel KB commits behind the
//! classic fleet's exact determinism contract.
//!
//! [`crate::icrl::fleet`]'s single committer folds every task delta
//! serially, so commit latency caps batch throughput no matter how many
//! workers explore. This module is the scale-out path
//! ([`FleetConfig::shards`] > 1): the same epoch/snapshot protocol, with
//! the commit side restructured as a pipeline of bounded stages and the
//! KB partitioned into shards that commit in parallel.
//!
//! # Dataflow (per epoch)
//!
//! ```text
//!   shared KB ──split_kb──► fragment 0 … fragment S-1   (+ canonical
//!       │                                                state order)
//!       └──► read-only snapshot
//!                │
//!        worker 0 … worker W-1          (pull tasks, run the driver,
//!                │                       extract a KbDelta)
//!                ▼  bounded channel (results, cap = commit_queue)
//!            sequencer                  (reorder to task order, strip
//!                │                       epoch-duplicate lineage,
//!                │                       split_delta by StateSig hash)
//!      ┌─────────┼─────────┐  bounded channels (cap = commit_queue)
//!      ▼         ▼         ▼
//!  committer 0  committer 1 … committer S-1
//!  (apply_delta on its fragment; append the part to its own
//!   journal segment when the store is segmented)
//!      └─────────┴─────────┘
//!                ▼ (scope ends)
//!   assemble_kb: fragments + canonical order ──► shared KB
//! ```
//!
//! Full queues block the sender — backpressure, counted in
//! [`ShardMetrics::commit_waits`] — so a slow committer throttles the
//! pipeline instead of letting it buffer unboundedly.
//!
//! # Why the result is byte-identical
//!
//! [`lifecycle::apply_delta`] is **per-state independent**: folding a
//! [`lifecycle::StateDelta`] reads and writes only that state's entry,
//! and the global fields (updates counter, arch stamp, lineage) fold by
//! plain addition/append. Partitioning states by a deterministic hash of
//! [`StateSig`] ([`shard_of`]) therefore commutes with commit order
//! *across* shards as long as each shard folds **its own** parts in task
//! order — which the per-shard FIFO channels guarantee. Three
//! order-sensitive residues are handled explicitly:
//!
//! - **state discovery order** (`kb.states` is insertion-ordered, and
//!   the saved artifact serializes it): the sequencer tracks the
//!   canonical order — snapshot order plus newly discovered sigs in
//!   task-then-delta order, exactly where the single committer's
//!   `insert_state` would have appended them — and [`assemble_kb`]
//!   rebuilds `kb.states` in that order;
//! - **globals** (updates / arch / lineage): routed exclusively with
//!   shard 0's part, so committer 0 folds them serially in task order,
//!   exactly like the single committer;
//! - **epoch lineage dedup**: done in the sequencer, before splitting,
//!   on the full delta — identical to the classic path.
//!
//! Hence `shards = S` reproduces the `shards = 1` KB — and its saved
//! bytes — exactly, for any worker count. `tests/fleet.rs` pins the
//! workers × shards byte-equality matrix.
//!
//! # Durability
//!
//! A segmented store ([`crate::kb::store::LogStore`] created with a
//! matching shard count) hands each committer its own
//! [`ShardSegment`]; parts are journaled concurrently, tagged with
//! `(seq, shard, parts, pos)` so recovery can reassemble each logical
//! commit and replay the **longest prefix of complete commits** (see
//! the store docs §Sharded journals). Stores without matching segments
//! fall back to epoch-boundary whole-delta appends
//! ([`Store::commit_unsegmented`]) — slower, never less correct. A
//! store error aborts the batch after the epoch; the in-memory KB is
//! left at the last epoch boundary (the classic path leaves it at the
//! last committed task — the one contract difference, documented on
//! [`Store::end_epoch`]).
//!
//! Tenancy sits strictly **above** this module: the serving daemon
//! ([`crate::serve`] §Tenancy) picks the tenant's KB and store before
//! any sharded batch starts, so [`shard_of`] only ever partitions one
//! tenant's states and each tenant's journal segments carry their own
//! independent `seq` space. Two tenants' stores never share a file,
//! which keeps the workers × shards byte-equality matrix a per-tenant
//! property.

use super::driver::{IcrlConfig, KbMode, TaskRun};
use super::fleet::{
    auto_epoch_policy, serve_epoch_task, EpochJob, FleetConfig, FleetObserver, FleetOutcome, Store,
    TaskResult,
};
use crate::gpu::GpuArch;
use crate::harness::memo::{MemoDelta, VerifyMemo};
use crate::harness::staged::TierStats;
use crate::harness::VerifyCache;
use crate::kb::lifecycle::{self, KbDelta};
use crate::kb::persist::PersistError;
use crate::kb::store::ShardSegment;
use crate::kb::{KnowledgeBase, StateSig};
use crate::tasks::Task;
use crate::util::hash::fnv1a64;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};

/// Counters the sharded pipeline reports in [`FleetOutcome::shard`].
/// Only the `shards` field affects nothing downstream; the rest are
/// observability (BENCH_fleet's queue/commit-wait columns) — results
/// never depend on them.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardMetrics {
    /// Shard count the batch ran with (1 = classic single committer).
    pub shards: usize,
    /// Delta parts routed to shard committers (one logical commit
    /// splits into ≤ `shards` parts).
    pub sub_commits: usize,
    /// Times a bounded pipeline queue was full and the sender had to
    /// block (backpressure events).
    pub commit_waits: usize,
    /// High-water mark of in-flight messages on any single committer
    /// queue.
    pub queue_peak: usize,
}

/// The shard a state commits through: a deterministic FNV-1a hash of
/// the sig's stable id, mod `shards`. Pure function of the sig — never
/// of discovery order, worker, or epoch — so the partition is stable
/// across runs, processes, and recovery.
pub fn shard_of(sig: StateSig, shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    (fnv1a64(&sig.id()) % shards as u64) as usize
}

/// One shard's slice of a [`KbDelta`]: the states [`shard_of`] routed
/// here, plus (shard 0 only) the delta's global fields. `pos[k]` is the
/// index `sub.states[k]` held in the full delta's state list — what
/// lets journal recovery and [`assemble_kb`]'s canonical order rebuild
/// the exact single-committer state ordering.
pub(crate) struct DeltaPart {
    /// Destination shard.
    pub(crate) shard: usize,
    /// The sub-delta: this shard's states; globals iff `shard == 0`.
    pub(crate) sub: KbDelta,
    /// Original index in the full delta of each `sub.states` entry.
    pub(crate) pos: Vec<usize>,
}

/// What the sequencer sends a shard committer for one logical commit.
pub(crate) struct ShardMsg {
    /// Journal sequence number — `None` when the epoch is unsegmented
    /// (the store journals at the epoch boundary instead).
    pub(crate) seq: Option<u64>,
    /// How many parts this logical commit split into (recovery's
    /// completeness count).
    pub(crate) parts: usize,
    /// This shard's part.
    pub(crate) part: DeltaPart,
}

/// Partition the epoch-start KB into per-shard fragments, and record
/// the canonical state order (`canon`) plus its membership set. Each
/// state entry lives in exactly one fragment ([`shard_of`]); fragment 0
/// additionally carries the KB's globals (updates / arch / lineage).
pub(crate) fn split_kb(
    kb: &KnowledgeBase,
    shards: usize,
) -> (Vec<KnowledgeBase>, Vec<StateSig>, HashSet<StateSig>) {
    let mut fragments: Vec<KnowledgeBase> = (0..shards).map(|_| KnowledgeBase::empty()).collect();
    fragments[0].updates = kb.updates;
    fragments[0].arch = kb.arch.clone();
    fragments[0].lineage = kb.lineage.clone();
    let mut canon = Vec::with_capacity(kb.states.len());
    let mut known = HashSet::with_capacity(kb.states.len());
    for entry in &kb.states {
        canon.push(entry.sig);
        known.insert(entry.sig);
        fragments[shard_of(entry.sig, shards)].insert_state(entry.clone());
    }
    (fragments, canon, known)
}

/// Split one committed delta into per-shard parts. Returns one slot per
/// shard; `None` slots get no message. For a non-empty delta, shard 0's
/// part always exists (it carries the globals and anchors recovery's
/// completeness check) even when no state hashed there.
pub(crate) fn split_delta(delta: &KbDelta, shards: usize) -> Vec<Option<DeltaPart>> {
    let mut parts: Vec<Option<DeltaPart>> = (0..shards).map(|_| None).collect();
    if delta.is_empty() {
        return parts;
    }
    parts[0] = Some(DeltaPart {
        shard: 0,
        sub: KbDelta {
            arch: delta.arch.clone(),
            lineage_added: delta.lineage_added.clone(),
            updates_added: delta.updates_added,
            states: Vec::new(),
        },
        pos: Vec::new(),
    });
    for (i, sd) in delta.states.iter().enumerate() {
        let s = shard_of(sd.sig, shards);
        let slot = parts[s].get_or_insert_with(|| DeltaPart {
            shard: s,
            sub: KbDelta::empty(),
            pos: Vec::new(),
        });
        slot.sub.states.push(sd.clone());
        slot.pos.push(i);
    }
    parts
}

/// Reassemble the shared KB from the epoch's committed fragments:
/// states in canonical order (each pulled from the one fragment that
/// owns its shard), globals from fragment 0. Inverse of [`split_kb`]
/// modulo the committed deltas — byte-identical to what the single
/// committer would have produced (see the module docs).
pub(crate) fn assemble_kb(
    fragments: Vec<KnowledgeBase>,
    canon: &[StateSig],
) -> KnowledgeBase {
    let mut kb = KnowledgeBase::empty();
    let mut entries = HashMap::with_capacity(canon.len());
    for (s, frag) in fragments.into_iter().enumerate() {
        if s == 0 {
            kb.updates = frag.updates;
            kb.arch = frag.arch;
            kb.lineage = frag.lineage;
        }
        for entry in frag.states {
            entries.insert(entry.sig, entry);
        }
    }
    for sig in canon {
        let entry = entries
            .remove(sig)
            .expect("every canonical sig lives in exactly one fragment");
        kb.insert_state(entry);
    }
    kb
}

/// Route one message to a committer queue, counting backpressure: a
/// fast-path `try_send`, and on a full queue one `commit_waits` tick
/// followed by the blocking send. A disconnected receiver is ignored —
/// it means the committer panicked, which the epoch's scope join
/// surfaces as the real error.
pub(crate) fn send_routed(
    tx: &SyncSender<ShardMsg>,
    msg: ShardMsg,
    metrics: &mut ShardMetrics,
) {
    match tx.try_send(msg) {
        Ok(()) => {}
        Err(TrySendError::Full(msg)) => {
            metrics.commit_waits += 1;
            let _ = tx.send(msg);
        }
        Err(TrySendError::Disconnected(_)) => {}
    }
}

/// One shard committer: fold every part routed here into this shard's
/// fragment, in arrival (= task) order, journaling each part to the
/// shard's segment when the epoch is segmented. On a journal error the
/// committer stops folding but keeps draining its queue — the
/// sequencer's sends must never deadlock — and returns the error for
/// the epoch to surface.
fn committer_loop(
    fragment: &mut KnowledgeBase,
    mut segment: Option<&mut ShardSegment>,
    rx: Receiver<ShardMsg>,
    done: &AtomicUsize,
) -> Result<(), PersistError> {
    let mut err: Option<PersistError> = None;
    while let Ok(msg) = rx.recv() {
        if err.is_none() {
            lifecycle::apply_delta(fragment, &msg.part.sub);
            if let (Some(seq), Some(seg)) = (msg.seq, segment.as_deref_mut()) {
                if let Err(e) = seg.append_part(seq, msg.parts, &msg.part.sub, &msg.part.pos) {
                    err = Some(e);
                }
            }
        }
        done.fetch_add(1, Ordering::Relaxed);
    }
    match err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// The sharded pipelined fleet (dispatched from the classic
/// [`crate::icrl::fleet`] entry points when [`FleetConfig::shards`] > 1).
/// Same inputs, same outputs, same determinism contract — see the
/// module docs for the dataflow and the byte-identity argument.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_fleet_sharded(
    tasks: &[&Task],
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    fleet: &FleetConfig,
    mut memo: Option<&mut VerifyMemo>,
    store: &mut dyn Store,
    obs: &mut dyn FleetObserver,
) -> Result<FleetOutcome, PersistError> {
    let shards = fleet.shards.max(1);
    let epoch_size = fleet.epoch_size.max(1);
    let workers = fleet.workers.max(1);
    let queue = fleet.commit_queue.max(1);
    let ephemeral = cfg.kb_mode == KbMode::EphemeralPerTask;
    let mut runs: Vec<TaskRun> = Vec::with_capacity(tasks.len());
    let mut epochs = 0usize;
    let mut commits = 0usize;
    let mut tiers = TierStats::default();
    let mut metrics = ShardMetrics {
        shards,
        ..Default::default()
    };
    let mut offset = 0usize;
    for (epoch_idx, chunk) in tasks.chunks(epoch_size).enumerate() {
        // Identical policy scheduling to the classic path: pure
        // functions of the epoch-start KB / epoch index.
        let epoch_policy = if fleet.auto_epoch_policies {
            auto_epoch_policy(kb, &cfg.policy)
        } else {
            fleet.policy_for_epoch(epoch_idx, &cfg.policy)
        };
        let epoch_cfg = IcrlConfig {
            policy: epoch_policy,
            ..cfg.clone()
        };
        let (mut fragments, mut canon, mut known) = split_kb(kb, shards);
        // Segment handout: borrows `store` until the scope below ends,
        // which is why the unsegmented path buffers deltas and replays
        // them through the store only after the borrow is gone.
        let (seg_slots, seq_base): (Vec<Option<&mut ShardSegment>>, u64) = if ephemeral {
            ((0..shards).map(|_| None).collect(), 0)
        } else {
            match store.begin_epoch(shards) {
                Some((slice, base)) => (slice.iter_mut().map(Some).collect(), base),
                None => ((0..shards).map(|_| None).collect(), 0),
            }
        };
        let segmented = seg_slots.iter().any(|s| s.is_some());
        let n = chunk.len();
        let job = EpochJob {
            chunk,
            offset,
            arch,
            snapshot: kb,
            cfg: &epoch_cfg,
            workers,
            ephemeral,
            memo: memo.as_deref(),
        };
        // Per-task tails the sequencer defers past the scope (memo and
        // observer mutation can't happen while workers borrow them).
        let mut tails: Vec<(TaskRun, MemoDelta, TierStats)> = Vec::with_capacity(n);
        let mut buffered: Vec<KbDelta> = Vec::new();
        let mut epoch_commits = 0usize;
        let mut journaled = 0u64;
        let mut epoch_lines: Vec<String> = Vec::new();
        let done_counts: Vec<AtomicUsize> = (0..shards).map(|_| AtomicUsize::new(0)).collect();
        let epoch_err: Option<PersistError> = std::thread::scope(|scope| {
            // Stage 3: per-shard committers.
            let mut committer_txs: Vec<SyncSender<ShardMsg>> = Vec::with_capacity(shards);
            let committer_handles: Vec<_> = fragments
                .iter_mut()
                .zip(seg_slots)
                .enumerate()
                .map(|(s, (fragment, segment))| {
                    let (tx, rx) = std::sync::mpsc::sync_channel::<ShardMsg>(queue);
                    committer_txs.push(tx);
                    let done = &done_counts[s];
                    scope.spawn(move || committer_loop(fragment, segment, rx, done))
                })
                .collect();
            // Stage 1: workers stream finished tasks to the sequencer.
            let (result_tx, result_rx) =
                std::sync::mpsc::sync_channel::<(usize, TaskResult)>(queue);
            let next = AtomicUsize::new(0);
            let job_ref = &job;
            let next_ref = &next;
            for _ in 0..workers.min(n.max(1)) {
                let tx = result_tx.clone();
                scope.spawn(move || {
                    let mut cache = VerifyCache::new();
                    loop {
                        let i = next_ref.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let out = serve_epoch_task(job_ref, i, &mut cache);
                        if tx.send((i, out)).is_err() {
                            break; // sequencer gone: epoch is unwinding
                        }
                    }
                });
            }
            drop(result_tx);
            // Stage 2: the sequencer (this thread) — reorder to task
            // order, dedup epoch lineage, route split parts.
            let mut pending: BTreeMap<usize, TaskResult> = BTreeMap::new();
            let mut next_commit = 0usize;
            let mut sent: Vec<usize> = vec![0; shards];
            while next_commit < n {
                let (i, res) = result_rx
                    .recv()
                    .expect("fleet workers ended before finishing the epoch");
                pending.insert(i, res);
                while let Some(res) = pending.remove(&next_commit) {
                    let TaskResult {
                        run,
                        mut delta,
                        memo: mdelta,
                        tiers: t,
                    } = res;
                    if !ephemeral {
                        delta.lineage_added.retain(|l| !epoch_lines.contains(l));
                        epoch_lines.extend(delta.lineage_added.iter().cloned());
                        epoch_commits += 1;
                        if !delta.is_empty() {
                            // Canonical order: newly discovered sigs land
                            // exactly where the single committer's
                            // insert_state would have appended them.
                            for sd in &delta.states {
                                if known.insert(sd.sig) {
                                    canon.push(sd.sig);
                                }
                            }
                            let seq = if segmented {
                                journaled += 1;
                                Some(seq_base + journaled - 1)
                            } else {
                                None
                            };
                            let parts = split_delta(&delta, shards);
                            let emitted = parts.iter().filter(|p| p.is_some()).count();
                            for (s, part) in parts.into_iter().enumerate() {
                                let Some(part) = part else { continue };
                                metrics.sub_commits += 1;
                                send_routed(
                                    &committer_txs[s],
                                    ShardMsg {
                                        seq,
                                        parts: emitted,
                                        part,
                                    },
                                    &mut metrics,
                                );
                                sent[s] += 1;
                                let depth =
                                    sent[s].saturating_sub(done_counts[s].load(Ordering::Relaxed));
                                metrics.queue_peak = metrics.queue_peak.max(depth);
                            }
                            if !segmented {
                                buffered.push(delta);
                            }
                        }
                    }
                    tails.push((run, mdelta, t));
                    next_commit += 1;
                }
            }
            drop(committer_txs); // committers drain and exit
            let mut first_err = None;
            for h in committer_handles {
                if let Err(e) = h.join().expect("shard committer panicked") {
                    first_err.get_or_insert(e);
                }
            }
            first_err
        });
        if let Some(e) = epoch_err {
            // The epoch's fragments are inconsistent (a committer froze
            // mid-stream); leave the shared KB at the epoch boundary.
            return Err(e);
        }
        if !ephemeral {
            *kb = assemble_kb(fragments, &canon);
            for delta in &buffered {
                store.commit_unsegmented(delta)?;
            }
            store.end_epoch(kb, epoch_commits, journaled)?;
        }
        commits += epoch_commits;
        // Deferred per-task tails, in task order — the classic path's
        // post-barrier timing exactly.
        for (i, (run, mdelta, t)) in tails.into_iter().enumerate() {
            if let Some(m) = memo.as_deref_mut() {
                m.apply_delta(&mdelta);
            }
            tiers.add(&t);
            obs.task_done(offset + i, &run);
            runs.push(run);
        }
        epochs += 1;
        obs.epoch_committed(epochs, commits, kb);
        offset += chunk.len();
    }
    Ok(FleetOutcome {
        runs,
        epochs,
        commits,
        tiers,
        shard: metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Bottleneck;
    use crate::harness::HarnessConfig;
    use crate::kb::WorkloadClass;
    use crate::opts::Technique;
    use crate::tasks::Suite;

    fn quick_cfg() -> IcrlConfig {
        IcrlConfig {
            trajectories: 2,
            rollout_steps: 3,
            top_k: 2,
            harness: HarnessConfig {
                noise_sigma: 0.0,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// Every (primary, secondary, workload) sig used below.
    fn some_sigs() -> Vec<StateSig> {
        let b = [
            Bottleneck::MemoryBandwidth,
            Bottleneck::ComputeThroughput,
            Bottleneck::Occupancy,
            Bottleneck::LaunchOverhead,
        ];
        let w = [WorkloadClass::ContractionHeavy, WorkloadClass::ReductionHeavy];
        let mut sigs = Vec::new();
        for p in b {
            for s in b {
                for wl in w {
                    sigs.push(StateSig {
                        primary: p,
                        secondary: s,
                        workload: wl,
                    });
                }
            }
        }
        sigs
    }

    #[test]
    fn shard_of_is_deterministic_in_range_and_spreads() {
        let sigs = some_sigs();
        for shards in [1usize, 2, 4, 7] {
            let mut hit = vec![false; shards];
            for &sig in &sigs {
                let s = shard_of(sig, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(sig, shards), "must be deterministic");
                hit[s] = true;
            }
            if shards <= 4 {
                assert!(hit.iter().all(|&h| h), "32 sigs must reach all {shards} shards");
            }
        }
        for &sig in &sigs {
            assert_eq!(shard_of(sig, 1), 0);
        }
    }

    #[test]
    fn split_then_assemble_roundtrips_the_kb() {
        let mut kb = KnowledgeBase::seed_priors();
        kb.arch = Some("H100".into());
        kb.lineage.push("merge(2 inputs, 3 states)".into());
        kb.updates = 7;
        for shards in [1usize, 2, 3, 4] {
            let (fragments, canon, known) = split_kb(&kb, shards);
            assert_eq!(canon.len(), kb.states.len());
            assert_eq!(known.len(), kb.states.len());
            assert_eq!(
                fragments.iter().map(|f| f.states.len()).sum::<usize>(),
                kb.states.len()
            );
            let back = assemble_kb(fragments, &canon);
            assert_eq!(back, kb, "split ∘ assemble must be the identity");
        }
    }

    #[test]
    fn split_delta_partitions_states_and_keeps_globals_on_shard_zero() {
        // Grow a KB across enough sigs to hit several shards.
        let base = KnowledgeBase::empty();
        let mut grown = base.clone();
        for (k, sig) in some_sigs().into_iter().take(6).enumerate() {
            let m = grown.match_state(sig);
            grown.update_score(
                m.index(),
                Technique::SharedMemoryTiling,
                1.0 + k as f64 / 3.0,
                Some(format!("n{k}")),
            );
        }
        grown.updates = 3;
        grown.arch = Some("A100".into());
        grown.lineage.push("audit line".into());
        let delta = lifecycle::extract_delta(&base, &grown);
        assert_eq!(delta.states.len(), 6);
        let shards = 3;
        let parts = split_delta(&delta, shards);
        let p0 = parts[0].as_ref().expect("shard 0 part always exists");
        assert_eq!(p0.sub.updates_added, 3);
        assert_eq!(p0.sub.arch.as_deref(), Some("A100"));
        assert_eq!(p0.sub.lineage_added, vec!["audit line".to_string()]);
        let mut seen = vec![false; delta.states.len()];
        for part in parts.iter().flatten() {
            assert_eq!(part.sub.states.len(), part.pos.len());
            if part.shard != 0 {
                assert!(part.sub.arch.is_none() && part.sub.updates_added == 0);
            }
            for (sd, &p) in part.sub.states.iter().zip(&part.pos) {
                assert_eq!(shard_of(sd.sig, shards), part.shard);
                assert_eq!(delta.states[p], *sd, "pos must index the full delta");
                assert!(!seen[p], "each state routed exactly once");
                seen[p] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "no state may be dropped");
        // An empty delta splits into nothing.
        assert!(split_delta(&KbDelta::empty(), shards).iter().all(|p| p.is_none()));
    }

    #[test]
    fn send_routed_counts_backpressure_on_a_full_queue() {
        let msg = || ShardMsg {
            seq: None,
            parts: 1,
            part: DeltaPart {
                shard: 0,
                sub: KbDelta::empty(),
                pos: Vec::new(),
            },
        };
        let (tx, rx) = std::sync::mpsc::sync_channel::<ShardMsg>(1);
        let mut metrics = ShardMetrics::default();
        // Space available: fast path, no wait recorded.
        send_routed(&tx, msg(), &mut metrics);
        assert_eq!(metrics.commit_waits, 0);
        // Queue now full. The next routed send must record exactly one
        // wait and then block until the committer drains a slot.
        let started = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let started2 = started.clone();
        let sender = std::thread::spawn(move || {
            let mut m = ShardMetrics::default();
            started2.store(true, Ordering::SeqCst);
            send_routed(&tx, msg(), &mut m);
            m
        });
        while !started.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        // Give the sender time to travel the few straight-line
        // instructions from the flag to its try_send before draining.
        std::thread::sleep(std::time::Duration::from_millis(100));
        let _ = rx.recv().expect("first message");
        let _ = rx.recv().expect("blocked message must still arrive");
        let m = sender.join().expect("sender thread");
        assert_eq!(m.commit_waits, 1, "full queue must count one wait");
        // Disconnected receiver: no panic, no wait.
        let (tx2, rx2) = std::sync::mpsc::sync_channel::<ShardMsg>(1);
        drop(rx2);
        let mut m2 = ShardMetrics::default();
        send_routed(&tx2, msg(), &mut m2);
        assert_eq!(m2.commit_waits, 0);
    }

    #[test]
    fn sharded_fleet_matches_single_committer_bit_for_bit() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/01_matmul_square").unwrap(),
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
            suite.by_id("L2/01_gemm_bias_relu").unwrap(),
        ];
        let arch = GpuArch::h100();
        let cfg = quick_cfg();
        let single = FleetConfig {
            workers: 2,
            epoch_size: 2,
            ..Default::default()
        };
        let mut kb_single = KnowledgeBase::empty();
        let out_single =
            super::super::fleet::run_fleet(&tasks, &arch, &mut kb_single, &cfg, &single);
        for shards in [2usize, 4] {
            let sharded = FleetConfig {
                shards,
                ..single.clone()
            };
            let mut kb_sharded = KnowledgeBase::empty();
            let out_sharded =
                super::super::fleet::run_fleet(&tasks, &arch, &mut kb_sharded, &cfg, &sharded);
            assert_eq!(out_single.runs, out_sharded.runs, "shards={shards}");
            assert_eq!(out_single.commits, out_sharded.commits);
            assert_eq!(out_single.epochs, out_sharded.epochs);
            assert_eq!(kb_single, kb_sharded, "shards={shards} diverged the KB");
            assert_eq!(
                crate::kb::persist::to_json(&kb_single).to_string_pretty(),
                crate::kb::persist::to_json(&kb_sharded).to_string_pretty(),
                "saved bytes must be invariant (shards={shards})"
            );
            assert_eq!(out_sharded.shard.shards, shards);
            assert!(out_sharded.shard.sub_commits > 0);
        }
        assert_eq!(out_single.shard.shards, 1);
        assert_eq!(out_single.shard.sub_commits, 0);
    }

    #[test]
    fn sharded_fleet_ephemeral_mode_leaves_kb_untouched() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![suite.by_id("L1/15_relu").unwrap()];
        let arch = GpuArch::a100();
        let cfg = IcrlConfig {
            kb_mode: KbMode::EphemeralPerTask,
            ..quick_cfg()
        };
        let fleet = FleetConfig {
            workers: 2,
            shards: 2,
            ..Default::default()
        };
        let mut kb = KnowledgeBase::empty();
        let out = super::super::fleet::run_fleet(&tasks, &arch, &mut kb, &cfg, &fleet);
        assert_eq!(out.commits, 0);
        assert!(kb.states.is_empty());
        assert!(out.runs[0].valid);
    }
}
