//! The rollout/trajectory driver (Algorithm 2 implementation).
//!
//! # Performance architecture (§Perf)
//!
//! The per-step inner loop (profile → state-extract → KB match → lower →
//! verify) is the throughput bound of the whole system, so it is built
//! around three invariants:
//!
//! - **Memoized oracle** — the driver owns a [`harness::VerifyCache`] per
//!   task, warmed once; every candidate verification reads the cached
//!   reference outputs instead of re-executing the unchanged task graph.
//! - **Move, don't clone** — lowered candidates and their profiles are
//!   moved through `PickEval` into the step log; the only full
//!   candidate clone left on the hot path is "new global best".
//! - **Deterministic parallel exploration** — the top-k picks of a step
//!   are independent: each gets its own RNG stream derived from the step
//!   state (`Rng::derive`, keyed by trajectory/step/pick index), its own
//!   token meter, and its own interpreter arena, then results are merged
//!   in pick order. Because nothing about the evaluation depends on
//!   execution order, the parallel (`IcrlConfig::parallel_explore`) and
//!   sequential paths produce **bit-identical** `TaskRun`s — asserted by
//!   the `hotpath` integration tests.
//!
//! Note on reproducibility across versions: adopting per-pick derived
//! streams restructured RNG consumption (pick evaluation no longer
//! advances the step's main stream), so fixed-seed results differ from
//! pre-overhaul builds. Determinism holds *within* this structure — same
//! seed, same results, regardless of `parallel_explore` — and the stream
//! layout is now stable under future changes to pick-evaluation
//! internals, which is what lets experiments stay reproducible from this
//! version onward.
//!
//! # Search policies (§policy)
//!
//! The step loop is parameterized over a [`super::policy::SearchPolicy`]
//! ([`IcrlConfig::policy`]): the driver maintains a **frontier** of
//! `beam_width()` candidates (one for the greedy family); per step it
//! asks the policy which of the state's scored KB candidates to explore
//! for each frontier node, evaluates every pick, then keeps the best
//! `beam_width()` distinct valid outcomes (by step gain, evaluation
//! order breaking ties — the pre-policy max-gain scan at width 1; the
//! run's global best additionally tracks every valid outcome, kept or
//! pruned) as the next frontier. The default
//! `greedy_topk` policy reproduces the pre-policy-subsystem driver
//! **bit-identically**: frontier node 0 uses the historical
//! `explore-t{traj}-s{step}` stream label and its selection is the
//! unchanged `kb::weighted_top_k` draw (in its index-returning form,
//! same RNG stream), so RNG consumption is byte-for-byte the same
//! (asserted by `tests/policy.rs` against a reference reimplementation
//! of the pre-refactor loop).
//!
//! # Mined skills (§skills)
//!
//! With [`IcrlConfig::skills`] enabled, each state's mined chains
//! ([`crate::kb::skills`]) join the selection pool as composite
//! candidates appended after the plain opts; a policy that picks one
//! triggers the multi-link apply path ([`evaluate_skill_pick`]): every
//! link is lowered in sequence on the evolving candidate and the end
//! state is verified once, so a whole §5 prep→compute sequence costs
//! one step. Skill evidence lands on the KB's composite entries (in
//! pick order, preserving parallel/sequential bit-identity) and skill
//! samples are excluded from the single-technique replay buffer. Off —
//! the default — the pool is exactly the scored enumeration and the
//! driver is bit-identical to the pre-skills build (`tests/skills.rs`).

use super::policy::PolicyConfig;
use crate::agents::lowering;
use crate::agents::textgrad::{self, Sample};
use crate::agents::{state_extractor, AgentConfig, TokenMeter};
use crate::gpu::{Bottleneck, GpuArch, NcuReport};
use crate::harness::memo::{MemoDelta, MemoVerdict, VerifyMemo};
use crate::harness::staged::{self, StagedRequest, TierStats, VerifyConfig};
use crate::harness::{self, HarnessConfig, Outcome, VerifyCache};
use crate::kb::lifecycle::{self, KbDelta, TransferPolicy};
use crate::kb::skills::SkillsConfig;
use crate::kb::{self, KnowledgeBase, ScoredCandidate, StateSig, WorkloadClass};
use crate::kir::interp;
use crate::opts::{Candidate, Technique};
use crate::tasks::Task;
use crate::util::rng::Rng;

/// How the Knowledge Base persists across tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KbMode {
    /// Normal MAIC-RL: one KB across all tasks (cross-task learning).
    Persistent,
    /// §6.1 no_mem ablation: full profiling, but the KB is reset for
    /// every task — no cross-task reuse.
    EphemeralPerTask,
}

/// Driver configuration (Table 2 defaults: 10 iterations × 10 rollout
/// steps per iteration).
#[derive(Debug, Clone)]
pub struct IcrlConfig {
    /// Rollouts per task (search breadth, Fig. 17).
    pub trajectories: usize,
    /// Steps per rollout (search depth, Fig. 18).
    pub rollout_steps: usize,
    /// Candidate optimizations sampled per step (top-k).
    pub top_k: usize,
    /// Failure model of the simulated LLM agents.
    pub agent: AgentConfig,
    /// Verification/profiling policy.
    pub harness: HarnessConfig,
    /// Cross-task KB persistence mode.
    pub kb_mode: KbMode,
    /// §6.3 ablation: the agent sees only elapsed cycles — profile detail
    /// is withheld, collapsing every state signature.
    pub cycles_only: bool,
    /// Evaluate the top-k picks of each step on scoped worker threads.
    /// Bit-identical results either way (see module docs §Perf); disable
    /// for single-core environments or flame-graph profiling.
    pub parallel_explore: bool,
    /// Search policy driving per-step candidate selection and the step
    /// transition (see module docs §policy). The default (`greedy_topk`)
    /// is bit-identical to the pre-policy-subsystem driver.
    pub policy: PolicyConfig,
    /// Base RNG seed (combined with the per-task run seed).
    pub seed: u64,
    /// Tiered-verification staging ([`crate::harness::staged`]). Off by
    /// default: the classic four-stage harness runs for every candidate,
    /// bit-identical to the pre-staging driver (asserted by
    /// `tests/staged.rs`).
    pub verify: VerifyConfig,
    /// Mined-skill drawing ([`crate::kb::skills`]). Off by default: the
    /// candidate pool is exactly the KB's scored enumeration and the
    /// driver is bit-identical to the pre-skills build (asserted by
    /// `tests/skills.rs`). When enabled, the state's mined skills join
    /// the pool as composite candidates and a pick may apply a whole
    /// chain in one step.
    pub skills: SkillsConfig,
}

impl Default for IcrlConfig {
    fn default() -> Self {
        Self {
            trajectories: 10,
            rollout_steps: 10,
            top_k: 3,
            agent: AgentConfig::default(),
            harness: HarnessConfig::default(),
            kb_mode: KbMode::Persistent,
            cycles_only: false,
            parallel_explore: true,
            policy: PolicyConfig::default(),
            seed: 42,
            verify: VerifyConfig::default(),
            skills: SkillsConfig::default(),
        }
    }
}

/// Per-step trace record (feeds the §5 / Figs. 12–14 analyses).
#[derive(Debug, Clone, PartialEq)]
pub struct StepLog {
    /// Rollout index within the task.
    pub trajectory: usize,
    /// Step index within the rollout.
    pub step: usize,
    /// Extracted performance state at this step.
    pub state: StateSig,
    /// True when this step discovered a brand-new KB state.
    pub new_state_discovered: bool,
    /// The technique evaluated by this sample.
    pub technique: Technique,
    /// Whether the lowered candidate passed the harness.
    pub valid: bool,
    /// Step gain (old time / new time); 0.0 for invalid attempts.
    pub gain: f64,
    /// Retries consumed by the lowering agent.
    pub retries: usize,
    /// Whether this sample was the one the trajectory stepped to (the
    /// chosen action — the others were explored and discarded). The §5
    /// transition analysis follows chosen actions only.
    pub chosen: bool,
    /// `Some(chain)` when this sample drew a mined skill and applied the
    /// whole chain in one step ([`crate::kb::skills`]); `technique` then
    /// holds the chain's first link and `gain` the end-to-end chain
    /// gain. `None` for every single-technique sample (and always when
    /// `IcrlConfig::skills` is off). The miner skips skill-draw samples
    /// so skills never re-mine their own output.
    pub skill: Option<Vec<Technique>>,
}

/// Result of optimizing one task.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskRun {
    /// The optimized task's suite id.
    pub task_id: String,
    /// Naive-CUDA starting time (§4.6 baseline), seconds.
    pub naive_time_s: f64,
    /// Best validated time found.
    pub best_time_s: f64,
    /// The best validated candidate program.
    pub best: Candidate,
    /// Token usage across all agent calls of the run.
    pub tokens: TokenMeter,
    /// Per-sample trace, in evaluation order.
    pub steps: Vec<StepLog>,
    /// Distinct states visited (paper reports ≈5.5 per kernel).
    pub states_visited: usize,
    /// True if the task produced at least one valid optimized kernel.
    pub valid: bool,
    /// 1-based index (into `steps`, evaluation order) of the sample that
    /// set the run's final best time; 0 when no sample beat the naive
    /// baseline. The `experiment skills` time-to-solution metric: mined
    /// skills should reach the run's best in fewer samples.
    pub steps_to_best: usize,
}

impl TaskRun {
    /// Speedup over the naive starting point.
    pub fn speedup_vs_naive(&self) -> f64 {
        self.naive_time_s / self.best_time_s
    }
}

/// The degenerate signature used by the cycles-only ablation: with no
/// profile detail every kernel looks alike.
fn cycles_only_sig(graph: &crate::kir::KernelGraph) -> StateSig {
    StateSig {
        primary: Bottleneck::ComputeThroughput,
        secondary: Bottleneck::ComputeThroughput,
        workload: WorkloadClass::of_graph(graph),
    }
}

/// One pick's fixed evaluation context, decided at selection time:
/// the technique, the KB expectation recorded into the replay buffer,
/// the fusion group the lowering targets, and the frontier node's
/// profiled time (the tier-0 screen's dominance reference).
#[derive(Clone)]
struct PickPlan {
    tech: Technique,
    expected: f64,
    group: usize,
    /// The frontier node's `report.total_time_s` — what the staged
    /// pipeline's static screen compares candidate estimates against.
    node_time: f64,
    /// `Some(chain)` when this pick draws a mined skill: the full
    /// technique chain to apply in one step (`tech` is its first link).
    /// `None` for every single-technique pick.
    chain: Option<Vec<Technique>>,
}

/// One pick's evaluation result, produced by [`evaluate_pick`] on either
/// the sequential or the parallel path and merged in pick order.
struct PickEval {
    tech: Technique,
    /// KB expectation at selection time (recorded in the replay buffer).
    expected: f64,
    /// The lowered candidate and its harness outcome (None = every
    /// attempt failed to compile).
    outcome: Option<(Candidate, Outcome)>,
    retries: usize,
    meter: TokenMeter,
    /// New memo verdicts this pick produced (staged mode only), in
    /// attempt order; the step loop merges them in pick order so the
    /// parallel and sequential paths stay bit-identical.
    memo_records: Vec<(String, MemoVerdict)>,
    /// Tier activity of this pick (all-zero when staging is off).
    tiers: TierStats,
    /// The mined-skill chain this pick applied (`None` for plain picks).
    /// Carried so the merge loop can log it, record skill evidence, and
    /// keep the sample out of the single-technique replay buffer.
    chain: Option<Vec<Technique>>,
}

/// Read-only inputs shared by every pick evaluation of a step: the task,
/// the architecture, the config, the warmed reference cache, and (staged
/// mode) the working-memo snapshot. Bundled so [`evaluate_pick`] stays
/// under a sane argument count while remaining a plain `Copy` capture
/// for the scoped-thread closures.
#[derive(Clone, Copy)]
struct EvalCtx<'a> {
    task: &'a Task,
    arch: &'a GpuArch,
    cfg: &'a IcrlConfig,
    cache: &'a VerifyCache,
    /// Verify-memo snapshot at node-evaluation start; `None` when
    /// staging is off. Reads only — new verdicts travel back through
    /// [`PickEval::memo_records`] and are merged after the evaluations.
    memo: Option<&'a VerifyMemo>,
}

/// One frontier element the step loop carries across steps: a candidate
/// with its latest profile. The greedy family runs a frontier of one;
/// beam search carries `beam_width()` of these.
struct BeamNode {
    cand: Candidate,
    report: NcuReport,
    /// `report.total_time_s`, cached (the step's gain denominator).
    time: f64,
}

/// A valid evaluated pick, as a transition candidate for the step.
struct StepOutcome {
    cand: Candidate,
    report: NcuReport,
    time: f64,
    /// Step gain relative to the frontier node that produced it — the
    /// transition ranking key (identical to the pre-policy driver's
    /// max-gain comparison for a width-1 frontier, including its
    /// floating-point tie behavior).
    gain: f64,
    /// Index of this pick's [`StepLog`] in the task's trace; `chosen` is
    /// set there if the outcome survives the transition.
    log_index: usize,
}

/// Lower the planned technique onto `cand` (with retries on failure
/// feedback) and run the harness — staged
/// ([`staged::run_staged_in`]) when `cfg.verify.staged`, the classic
/// four-stage pipeline otherwise. Self-contained: owns its RNG stream
/// and token meter so picks can run concurrently yet merge
/// deterministically.
fn evaluate_pick(ctx: &EvalCtx<'_>, cand: &Candidate, plan: &PickPlan, mut rng: Rng) -> PickEval {
    if let Some(chain) = plan.chain.as_deref() {
        return evaluate_skill_pick(ctx, cand, plan, chain, rng);
    }
    let cfg = ctx.cfg;
    let mut meter = TokenMeter::new();
    let mut outcome: Option<(Candidate, Outcome)> = None;
    let mut retries = 0;
    let mut memo_records: Vec<(String, MemoVerdict)> = Vec::new();
    let mut tiers = TierStats::default();
    // One interpreter arena for the whole pick: buffer pools and the
    // per-graph plan amortize across lowering retries × verify seeds.
    let mut interp_ctx = interp::ExecContext::new();
    for attempt in 0..=cfg.agent.retry_limit {
        retries = attempt;
        let lowered = lowering::lower(
            plan.tech, cand, plan.group, &cfg.agent, attempt, &mut meter, &mut rng,
        );
        match lowered.into_candidate() {
            None => continue, // compile fail → retry
            Some(c) => {
                let res = if cfg.verify.staged {
                    let staged_out = staged::run_staged_in(
                        &StagedRequest {
                            task: ctx.task,
                            cand: &c,
                            arch: ctx.arch,
                            cfg: &cfg.harness,
                            verify: &cfg.verify,
                            best_time_s: plan.node_time,
                            cache: Some(ctx.cache),
                            memo: ctx.memo,
                        },
                        &mut interp_ctx,
                        &mut rng,
                    );
                    tiers.add(&staged_out.stats);
                    if let Some(rec) = staged_out.memo_record {
                        memo_records.push(rec);
                    }
                    staged_out.outcome
                } else {
                    harness::run_cached_in(
                        ctx.task,
                        &c,
                        ctx.arch,
                        &cfg.harness,
                        Some(ctx.cache),
                        &mut interp_ctx,
                        &mut rng,
                    )
                };
                let ok = res.is_ok();
                outcome = Some((c, res));
                if ok {
                    break;
                }
            }
        }
    }
    PickEval {
        tech: plan.tech,
        expected: plan.expected,
        outcome,
        retries,
        meter,
        memo_records,
        tiers,
        chain: None,
    }
}

/// Apply a mined-skill chain as one pick: lower every link in sequence
/// on the evolving candidate, then verify the **end state** once. The
/// chain's realized gain is an end-to-end measurement against the
/// frontier node (exactly how the miner scored it — a product of
/// per-link gains telescopes to end-over-start), so intermediate links
/// are lowering-only: verifying them would multiply the oracle cost of
/// a pick by the chain length for verdicts nothing consumes. Each link
/// retries compile failures on its own slice of the retry budget; a
/// link whose technique stops being applicable on the evolved candidate
/// (or exhausts its retries) fails the whole pick (`outcome: None`),
/// mirroring a plain pick that never lowered. Self-contained like
/// [`evaluate_pick`]: own RNG stream, own meter, deterministic merge.
fn evaluate_skill_pick(
    ctx: &EvalCtx<'_>,
    cand: &Candidate,
    plan: &PickPlan,
    chain: &[Technique],
    mut rng: Rng,
) -> PickEval {
    let cfg = ctx.cfg;
    let mut meter = TokenMeter::new();
    let mut outcome: Option<(Candidate, Outcome)> = None;
    let mut retries = 0;
    let mut memo_records: Vec<(String, MemoVerdict)> = Vec::new();
    let mut tiers = TierStats::default();
    let mut interp_ctx = interp::ExecContext::new();
    let mut current = cand.clone();
    'links: for (li, &tech) in chain.iter().enumerate() {
        // Link 0 targets the group planned at selection time (the
        // node's dominant kernel where applicable); later links re-site
        // on the evolved candidate — there is no profile for the
        // intermediate program, so applicability is the only signal.
        let group = if li == 0 {
            plan.group
        } else {
            match tech.applicable_anywhere(&current) {
                Some(g) => g,
                None => break 'links, // chain no longer applies here
            }
        };
        let last = li + 1 == chain.len();
        let mut advanced = false;
        for attempt in 0..=cfg.agent.retry_limit {
            retries += if attempt > 0 { 1 } else { 0 };
            let lowered = lowering::lower(
                tech, &current, group, &cfg.agent, attempt, &mut meter, &mut rng,
            );
            let Some(c) = lowered.into_candidate() else {
                continue; // compile fail → retry this link
            };
            if !last {
                current = c;
                advanced = true;
                break;
            }
            // Final link: the one harness run of the whole pick.
            let res = if cfg.verify.staged {
                let staged_out = staged::run_staged_in(
                    &StagedRequest {
                        task: ctx.task,
                        cand: &c,
                        arch: ctx.arch,
                        cfg: &cfg.harness,
                        verify: &cfg.verify,
                        best_time_s: plan.node_time,
                        cache: Some(ctx.cache),
                        memo: ctx.memo,
                    },
                    &mut interp_ctx,
                    &mut rng,
                );
                tiers.add(&staged_out.stats);
                if let Some(rec) = staged_out.memo_record {
                    memo_records.push(rec);
                }
                staged_out.outcome
            } else {
                harness::run_cached_in(
                    ctx.task,
                    &c,
                    ctx.arch,
                    &cfg.harness,
                    Some(ctx.cache),
                    &mut interp_ctx,
                    &mut rng,
                )
            };
            let ok = res.is_ok();
            outcome = Some((c, res));
            advanced = true;
            if ok {
                break;
            }
        }
        if !advanced {
            break; // link exhausted its retries without lowering
        }
    }
    PickEval {
        tech: plan.tech,
        expected: plan.expected,
        outcome,
        retries,
        meter,
        memo_records,
        tiers,
        chain: Some(chain.to_vec()),
    }
}

/// Build a warm-start θ₀ for a run on `arch` from one or more prior KBs:
/// each prior grown on a different architecture is transferred through
/// the arch scaling model first (its entries become decayed-confidence
/// priors the textual-gradient step cites by source), then everything is
/// merged by evidence. Thin driver-side entry over
/// [`lifecycle::warm_start`] — the CLI's `--warm-start` flag and the
/// config file's `warm_start` list both land here.
pub fn warm_start_kb(
    priors: &[KnowledgeBase],
    arch: &GpuArch,
    policy: &TransferPolicy,
) -> KnowledgeBase {
    lifecycle::warm_start(priors, arch, policy)
}

/// Optimize one task (Algorithm 2 inner loops). Mutates `kb` in place,
/// stamping it with `arch` (the KB records where its native evidence
/// was measured — the transfer step reads this on the next lifecycle
/// hop). Running over a KB recorded on a *different* arch without
/// transferring it first mixes evidence populations; the relabeling is
/// flagged in the KB's lineage so `kb stats` and later transfers can see
/// it.
pub fn optimize_task(
    task: &Task,
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    run_seed: u64,
) -> TaskRun {
    let mut cache = VerifyCache::new();
    optimize_task_in(task, arch, kb, cfg, run_seed, &mut cache)
}

/// [`optimize_task`] with a caller-owned [`VerifyCache`]. The cache is
/// keyed by task id and warming is idempotent, so a long-lived cache can
/// be reused across many tasks — each fleet worker owns one for all the
/// tasks it processes ([`crate::icrl::fleet`]), amortizing the reference
/// oracle across a batch. Semantically invisible: results are identical
/// to a fresh cache (the §Perf contract of [`crate::harness`]).
pub fn optimize_task_in(
    task: &Task,
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    run_seed: u64,
    cache: &mut VerifyCache,
) -> TaskRun {
    optimize_task_core(task, arch, kb, cfg, run_seed, cache, None).0
}

/// [`optimize_task_in`] plus the staged-verification outputs: the
/// [`MemoDelta`] of verdicts this run added over the caller's memo
/// snapshot (empty when `cfg.verify.staged` is off) and the run's
/// [`TierStats`] (all-zero likewise). `memo` is the snapshot-in side of
/// the fleet's snapshot-in/delta-out memo contract; `None` starts the
/// run's working memo cold. Memo contents never change a `TaskRun` when
/// the tier-0 screen is off — verification consumes no RNG, so a
/// memo-verified pass re-profiles on the identical stream (asserted by
/// `tests/staged.rs`).
pub fn optimize_task_verified(
    task: &Task,
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    run_seed: u64,
    cache: &mut VerifyCache,
    memo: Option<&VerifyMemo>,
) -> (TaskRun, MemoDelta, TierStats) {
    optimize_task_core(task, arch, kb, cfg, run_seed, cache, memo)
}

/// The driver core behind every entry point. Maintains a working verify
/// memo when staging is on (seeded from `memo_snapshot`, grown in pick
/// order) and reports the delta relative to the snapshot; with staging
/// off the memo machinery is inert and the body is the pre-staging
/// driver, byte for byte.
fn optimize_task_core(
    task: &Task,
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    run_seed: u64,
    cache: &mut VerifyCache,
    memo_snapshot: Option<&VerifyMemo>,
) -> (TaskRun, MemoDelta, TierStats) {
    if let Some(prev) = &kb.arch {
        if prev != arch.name {
            kb.lineage.push(format!(
                "mixed-arch evidence: ran on {} over a {prev} KB without transfer",
                arch.name
            ));
        }
    }
    kb.arch = Some(arch.name.to_string());
    let mut rng = Rng::new(cfg.seed ^ run_seed).derive(&task.id);
    let mut tokens = TokenMeter::new();
    let mut steps: Vec<StepLog> = Vec::new();
    let mut visited: Vec<StateSig> = Vec::new();

    // §Perf: the reference oracle runs once per (task, seed) — here —
    // instead of once per candidate per seed. On warm failure (a task
    // graph that cannot execute; unreachable for suite tasks) the cache
    // stays cold and run_cached falls back to inline references.
    let _ = cache.warm(task, &cfg.harness);

    let naive = Candidate::naive(task);
    let naive_report = harness::profile_naive(task, arch, &cfg.harness, &mut rng);
    let naive_time = naive_report.total_time_s;

    let mut best = naive.clone();
    let mut best_time = naive_time;
    let mut any_valid = false;
    // 1-based log index of the sample that set the final best (0 =
    // never improved). A pure function of data the run already
    // produces, so tracking it is invisible to every existing output.
    let mut steps_to_best = 0usize;

    // Staged verification: the run's working memo (snapshot + everything
    // learned so far this run) and the delta going back to the caller.
    // `None` when staging is off — zero additional work on that path.
    let mut working_memo: Option<VerifyMemo> = if cfg.verify.staged {
        Some(memo_snapshot.cloned().unwrap_or_default())
    } else {
        None
    };
    let mut memo_delta = MemoDelta::empty();
    let mut tier_stats = TierStats::default();

    // The search policy (§policy in the module docs). Built once per
    // task; the frontier width is its declared transition rule.
    let policy = cfg.policy.build();
    let beam_width = policy.beam_width().max(1);

    for traj in 0..cfg.trajectories {
        let mut frontier: Vec<BeamNode> = vec![BeamNode {
            cand: naive.clone(),
            report: naive_report.clone(),
            time: naive_time,
        }];
        let mut replay: Vec<Sample> = Vec::new();

        for step in 0..cfg.rollout_steps {
            // Valid outcomes of this step across the whole frontier, in
            // evaluation order (frontier node order, then pick order) —
            // the transition pool.
            let mut outcomes: Vec<StepOutcome> = Vec::new();
            let mut any_applicable = false;

            for (node_idx, node) in frontier.iter().enumerate() {
                // --- state extraction & matching ---
                let sig = if cfg.cycles_only {
                    tokens.add(60, 20); // the agent still reads the cycle count
                    cycles_only_sig(&node.cand.full)
                } else {
                    state_extractor::extract(
                        &node.report,
                        &node.cand.full,
                        &cfg.agent,
                        &mut tokens,
                        &mut rng,
                    )
                };
                let matched = kb.match_state(sig);
                let discovered = matched.is_discovery();
                let state_idx = matched.index();
                if !visited.contains(&sig) {
                    visited.push(sig);
                }

                // --- candidate retrieval / proposal ---
                let applicable: Vec<Technique> = Technique::all()
                    .iter()
                    .copied()
                    .filter(|t| {
                        (cfg.harness.allow_vendor || *t != Technique::VendorLibraryDispatch)
                            && t.applicable_anywhere(&node.cand).is_some()
                    })
                    .collect();
                if applicable.is_empty() {
                    continue; // this frontier node is exhausted
                }
                any_applicable = true;
                kb.ensure_candidates(state_idx, &applicable);
                let mut pool = kb.scored_candidates(state_idx, |t| applicable.contains(&t));
                // Skills on: the state's mined chains join the pool as
                // composite candidates (appended after the plain opts,
                // so the opt indices — and the skills-off pool — are
                // untouched). A chain is drawn only when its first link
                // is applicable here; later links re-check applicability
                // on the evolving candidate inside the pick.
                if cfg.skills.enabled {
                    for (si, sk) in kb.states[state_idx].skills.iter().enumerate() {
                        let Some(&lead) = sk.techniques.first() else {
                            continue; // defensive: empty chains never mine
                        };
                        if !applicable.contains(&lead) {
                            continue;
                        }
                        pool.push(ScoredCandidate {
                            technique: lead,
                            expected_gain: sk.expected_gain,
                            attempts: sk.attempts,
                            successes: sk.successes,
                            weight: kb::selection_weight(sk.expected_gain),
                            skill: Some(si),
                        });
                    }
                }
                let picks = policy.select_indices(&pool, cfg.top_k, &mut rng);

                // --- explore each pick ---
                // Per-pick context is fixed up front: KB expectation and
                // the targeted fusion group. The dominant (slowest)
                // kernel's group is preferred where the technique
                // applies; the cycles-only ablation has no per-kernel
                // breakdown, so it cannot target the dominant kernel
                // (§6.3: "scalar latency alone is insufficient to infer
                // … which optimization direction to optimize next").
                let dominant_group = node
                    .report
                    .kernels
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.time_us.total_cmp(&b.1.time_us))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                let pick_info: Vec<PickPlan> = picks
                    .iter()
                    .map(|&pi| {
                        let tech = pool[pi].technique;
                        let group = if cfg.cycles_only {
                            tech.applicable_anywhere(&node.cand).unwrap_or(0)
                        } else if tech.applicable(&node.cand, dominant_group) {
                            dominant_group
                        } else {
                            tech.applicable_anywhere(&node.cand).unwrap_or(0)
                        };
                        if let Some(si) = pool[pi].skill {
                            // A mined chain: the KB's composite entry is
                            // the expectation; the plan sites link 0 on
                            // the dominant group like a plain pick.
                            let sk = &kb.states[state_idx].skills[si];
                            return PickPlan {
                                tech,
                                expected: sk.expected_gain,
                                group,
                                node_time: node.time,
                                chain: Some(sk.techniques.clone()),
                            };
                        }
                        let expected = kb.states[state_idx]
                            .opt_index(tech)
                            .map(|i| kb.states[state_idx].opts[i].expected_gain)
                            .unwrap_or(tech.prior_gain());
                        PickPlan {
                            tech,
                            expected,
                            group,
                            node_time: node.time,
                            chain: None,
                        }
                    })
                    .collect();

                // Independent per-pick RNG streams, derived from the
                // current step state. Frontier node 0 keeps the
                // historical `explore-t{traj}-s{step}` label (the
                // GreedyTopK bit-identity anchor); extra beam nodes get
                // their own `-b{n}` streams. Streams and the evaluation
                // call are built in exactly one place so the parallel
                // and sequential paths cannot drift apart (their
                // bit-identity is the §Perf contract).
                let label = if node_idx == 0 {
                    format!("explore-t{traj}-s{step}")
                } else {
                    format!("explore-t{traj}-s{step}-b{node_idx}")
                };
                let step_rng = rng.derive(&label);
                let pick_rngs: Vec<Rng> = (0..pick_info.len())
                    .map(|i| step_rng.derive(&format!("pick-{i}")))
                    .collect();
                let ectx = EvalCtx {
                    task,
                    arch,
                    cfg,
                    cache: &*cache,
                    memo: working_memo.as_ref(),
                };
                let cand_ref = &node.cand;
                let eval_one = move |plan: &PickPlan, pick_rng: Rng| {
                    evaluate_pick(&ectx, cand_ref, plan, pick_rng)
                };
                let evals: Vec<PickEval> = if cfg.parallel_explore && pick_info.len() > 1 {
                    std::thread::scope(|scope| {
                        let handles: Vec<_> = pick_info
                            .iter()
                            .zip(pick_rngs)
                            .map(|(plan, pick_rng)| scope.spawn(move || eval_one(plan, pick_rng)))
                            .collect();
                        handles
                            .into_iter()
                            .map(|h| h.join().expect("pick worker panicked"))
                            .collect()
                    })
                } else {
                    pick_info
                        .iter()
                        .zip(pick_rngs)
                        .map(|(plan, pick_rng)| eval_one(plan, pick_rng))
                        .collect()
                };

                // --- merge in pick order (the canonical sequential order) ---
                for eval in evals {
                    let PickEval {
                        tech,
                        expected,
                        outcome,
                        retries,
                        meter,
                        memo_records,
                        tiers,
                        chain,
                    } = eval;
                    tokens.merge(&meter);
                    tier_stats.add(&tiers);
                    // Grow the working memo in pick order; only verdicts
                    // the snapshot didn't already hold enter the delta
                    // (insert-or-ignore — verdicts are deterministic per
                    // key, so first-write-wins loses nothing).
                    if let Some(wm) = working_memo.as_mut() {
                        for (key, verdict) in memo_records {
                            if wm.insert(key.clone(), verdict.clone()) {
                                memo_delta.added.push((key, verdict));
                            }
                        }
                    }
                    let (valid, gain, occ, util, new_primary) = match outcome {
                        Some((c, Outcome::Ok(rep))) => {
                            any_valid = true;
                            let time = rep.total_time_s;
                            let gain = node.time / time;
                            let (occ, util) = rep
                                .kernels
                                .first()
                                .map(|k| (k.occupancy, k.utilization))
                                .unwrap_or((1.0, 1.0));
                            let np = rep.dominant_bottleneck();
                            outcomes.push(StepOutcome {
                                cand: c,
                                report: rep,
                                time,
                                gain,
                                log_index: steps.len(),
                            });
                            (true, gain, occ, util, np)
                        }
                        _ => (false, 0.0, 1.0, 1.0, sig.primary),
                    };
                    match &chain {
                        // Skill picks stay out of the single-technique
                        // replay buffer — a chain's end-to-end gain
                        // credited to its first link would corrupt that
                        // opt's EMA. Their evidence lands on the KB's
                        // composite entry instead, in pick order (the
                        // canonical merge order, so parallel and
                        // sequential exploration stay bit-identical).
                        Some(c) => kb.update_skill(state_idx, c, gain),
                        None => replay.push(Sample {
                            state: sig,
                            technique: tech,
                            expected_gain: expected,
                            measured_gain: gain,
                            valid,
                            occupancy: occ,
                            utilization: util,
                            new_primary,
                        }),
                    }
                    steps.push(StepLog {
                        trajectory: traj,
                        step,
                        state: sig,
                        new_state_discovered: discovered && step == 0,
                        technique: tech,
                        valid,
                        gain,
                        retries,
                        chosen: false,
                        skill: chain,
                    });
                }
            }

            if !any_applicable {
                break; // optimization space exhausted (Fig. 18's plateau)
            }

            // --- move (the policy's transition rule) ---
            // Keep the best `beam_width` *distinct* valid outcomes as
            // the next frontier, ranked by step gain with evaluation
            // order breaking ties — width 1 is exactly the classic
            // greedy step-to-best (the pre-policy driver's strict
            // max-gain scan). A step with no valid outcome keeps
            // exploring from the same frontier next step (fresh samples,
            // different picks).
            if !outcomes.is_empty() {
                // Global-best bookkeeping considers EVERY valid outcome,
                // kept or pruned: the transition ranks by *relative*
                // step gain, so with a multi-node frontier the
                // absolutely fastest kernel of a step may lose its
                // frontier slot — it must still be recorded as the run's
                // best. One min-scan, at most one clone (§Perf: move,
                // don't clone). Width-1 unchanged: the step winner IS
                // the first time-minimum, the candidate the old
                // winner-only update cloned.
                let fastest = outcomes
                    .iter()
                    .min_by(|a, b| a.time.total_cmp(&b.time))
                    .expect("outcomes is non-empty");
                if fastest.time < best_time {
                    best_time = fastest.time;
                    best = fastest.cand.clone();
                    steps_to_best = fastest.log_index + 1;
                }
                let mut order: Vec<usize> = (0..outcomes.len()).collect();
                order.sort_by(|&a, &b| {
                    outcomes[b].gain.total_cmp(&outcomes[a].gain).then(a.cmp(&b))
                });
                let mut slots: Vec<Option<StepOutcome>> =
                    outcomes.into_iter().map(Some).collect();
                let mut next_frontier: Vec<BeamNode> =
                    Vec::with_capacity(beam_width.min(order.len()));
                let dedup_distance = cfg.policy.dedup_distance;
                for &oi in &order {
                    if next_frontier.len() >= beam_width {
                        break;
                    }
                    // Dedup: two beam nodes that picked the same
                    // technique from the same state converge to equal
                    // candidates; duplicates would waste frontier width.
                    // Identity is the *candidate program* — measured
                    // times carry per-pick noise and must not decide
                    // duplication. With `policy.dedup_distance > 0`,
                    // near-duplicates are pruned too: an outcome within
                    // that schedule-distance of an already-kept node
                    // (same graph, nearly identical execution plan)
                    // yields its slot to a genuinely different plan. At
                    // the default 0.0 the similarity check is skipped
                    // outright — exact-equality behavior, byte for byte.
                    let is_dup = {
                        let o = slots[oi].as_ref().expect("order indexes are unique");
                        next_frontier.iter().any(|n| {
                            n.cand == o.cand
                                || (dedup_distance > 0.0
                                    && n.cand.schedule_distance(&o.cand) <= dedup_distance)
                        })
                    };
                    if is_dup {
                        continue;
                    }
                    let o = slots[oi].take().expect("order indexes are unique");
                    steps[o.log_index].chosen = true;
                    next_frontier.push(BeamNode {
                        cand: o.cand,
                        report: o.report,
                        time: o.time,
                    });
                }
                frontier = next_frontier;
            }
        }

        // --- textual-gradient update (per trajectory) ---
        // Runs in every KB mode: EphemeralPerTask still learns *within*
        // a task (run_suite hands it a fresh KB per task, which is what
        // makes the ablation "no cross-task memory" rather than "no
        // learning"). The old mode guard here was tautological and has
        // been removed.
        let g = textgrad::policy_evaluation(&replay, &mut tokens);
        let p = textgrad::perf_gap_analysis(&g, &mut tokens);
        textgrad::parameter_update(kb, &p, &mut tokens);
    }

    let run = TaskRun {
        task_id: task.id.clone(),
        naive_time_s: naive_time,
        best_time_s: best_time,
        best,
        tokens,
        steps,
        states_visited: visited.len(),
        valid: any_valid,
        steps_to_best,
    };
    (run, memo_delta, tier_stats)
}

/// Snapshot-in / delta-out entry point — the fleet worker's unit of work
/// ([`crate::icrl::fleet`]). Runs the driver over a *clone* of
/// `snapshot`, leaving the snapshot untouched, and returns the
/// [`TaskRun`] plus the [`KbDelta`] of evidence the run added. Applying
/// the delta back onto the snapshot
/// ([`lifecycle::apply_delta`]) reproduces the sequential
/// [`optimize_task`] mutation bit-identically.
pub fn optimize_task_delta(
    task: &Task,
    arch: &GpuArch,
    snapshot: &KnowledgeBase,
    cfg: &IcrlConfig,
    run_seed: u64,
    cache: &mut VerifyCache,
) -> (TaskRun, KbDelta) {
    let mut grown = snapshot.clone();
    let run = optimize_task_in(task, arch, &mut grown, cfg, run_seed, cache);
    let delta = lifecycle::extract_delta(snapshot, &grown);
    (run, delta)
}

/// [`optimize_task_delta`] plus the verify-memo side of the fleet
/// contract: the run reads `memo` as its snapshot-in and returns the
/// [`MemoDelta`] of new verdicts as its delta-out, mirroring the KB's
/// snapshot/delta discipline exactly. Verdicts are deterministic per
/// key, so commit order across workers cannot change merged contents —
/// the root of the fleet's worker-count-invariant saved memos.
pub fn optimize_task_delta_verified(
    task: &Task,
    arch: &GpuArch,
    snapshot: &KnowledgeBase,
    cfg: &IcrlConfig,
    run_seed: u64,
    cache: &mut VerifyCache,
    memo: Option<&VerifyMemo>,
) -> (TaskRun, KbDelta, MemoDelta, TierStats) {
    let mut grown = snapshot.clone();
    let (run, mdelta, tiers) =
        optimize_task_core(task, arch, &mut grown, cfg, run_seed, cache, memo);
    let delta = lifecycle::extract_delta(snapshot, &grown);
    (run, delta, mdelta, tiers)
}

/// Run the driver over a task list. Returns per-task runs; `kb` carries
/// cross-task experience when `KbMode::Persistent`.
pub fn run_suite(
    tasks: &[&Task],
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
) -> Vec<TaskRun> {
    let mut out = Vec::with_capacity(tasks.len());
    for (i, task) in tasks.iter().enumerate() {
        let run = match cfg.kb_mode {
            KbMode::Persistent => optimize_task(task, arch, kb, cfg, i as u64),
            KbMode::EphemeralPerTask => {
                let mut fresh = KnowledgeBase::empty();
                optimize_task(task, arch, &mut fresh, cfg, i as u64)
            }
        };
        out.push(run);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Suite;

    fn quick_cfg() -> IcrlConfig {
        IcrlConfig {
            trajectories: 2,
            rollout_steps: 4,
            top_k: 2,
            agent: AgentConfig::default(),
            harness: HarnessConfig {
                noise_sigma: 0.0,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn driver_improves_q18() {
        let suite = Suite::full();
        let task = suite.by_id("L2/18_linear_sum_logsumexp2").unwrap();
        let arch = GpuArch::h100();
        let mut kb = KnowledgeBase::empty();
        let cfg = IcrlConfig {
            trajectories: 4,
            rollout_steps: 6,
            ..quick_cfg()
        };
        let run = optimize_task(task, &arch, &mut kb, &cfg, 0);
        assert!(run.valid);
        assert!(
            run.speedup_vs_naive() > 1.5,
            "speedup {:.2}",
            run.speedup_vs_naive()
        );
        assert!(run.tokens.total() > 1000);
        assert!(!run.steps.is_empty());
        assert!(kb.total_attempts() > 0);
    }

    #[test]
    fn driver_deterministic_for_seed() {
        let suite = Suite::full();
        let task = suite.by_id("L2/01_gemm_bias_relu").unwrap();
        let arch = GpuArch::a100();
        let cfg = quick_cfg();
        let mut kb1 = KnowledgeBase::empty();
        let r1 = optimize_task(task, &arch, &mut kb1, &cfg, 0);
        let mut kb2 = KnowledgeBase::empty();
        let r2 = optimize_task(task, &arch, &mut kb2, &cfg, 0);
        assert_eq!(r1.best_time_s, r2.best_time_s);
        assert_eq!(r1.tokens, r2.tokens);
        assert_eq!(r1.steps.len(), r2.steps.len());
        assert_eq!(kb1, kb2);
    }

    #[test]
    fn parallel_and_sequential_exploration_agree_exactly() {
        // The module-doc §Perf invariant: same derived RNG streams, same
        // merge order → bit-identical TaskRuns and KBs. Fast in-module
        // guard on one task; tests/hotpath.rs sweeps more tasks and
        // top_k/noise configurations.
        let suite = Suite::full();
        let arch = GpuArch::h100();
        let task = suite.by_id("L2/01_gemm_bias_relu").unwrap();
        let seq_cfg = IcrlConfig {
            parallel_explore: false,
            ..quick_cfg()
        };
        let par_cfg = IcrlConfig {
            parallel_explore: true,
            ..quick_cfg()
        };
        let mut kb_seq = KnowledgeBase::empty();
        let r_seq = optimize_task(task, &arch, &mut kb_seq, &seq_cfg, 3);
        let mut kb_par = KnowledgeBase::empty();
        let r_par = optimize_task(task, &arch, &mut kb_par, &par_cfg, 3);
        assert_eq!(r_seq, r_par, "TaskRun diverged");
        assert_eq!(kb_seq, kb_par, "KB diverged");
    }

    #[test]
    fn best_candidate_always_validates() {
        let suite = Suite::full();
        let arch = GpuArch::l40s();
        let cfg = quick_cfg();
        let mut kb = KnowledgeBase::empty();
        for id in ["L1/12_softmax", "L2/09_mlp_block"] {
            let task = suite.by_id(id).unwrap();
            let run = optimize_task(task, &arch, &mut kb, &cfg, 7);
            // The returned best candidate must still pass the harness.
            let mut rng = Rng::new(0);
            let out = harness::run(task, &run.best, &arch, &cfg.harness, &mut rng);
            assert!(out.is_ok(), "{id}: {}", out.feedback());
            assert!(run.best_time_s <= run.naive_time_s * 1.0001);
        }
    }

    #[test]
    fn delta_entry_point_replays_sequential_mutation() {
        // optimize_task_delta over a snapshot + apply_delta must equal
        // the in-place optimize_task mutation, bit for bit — the fleet's
        // one-task-epoch exactness anchor.
        let suite = Suite::full();
        let task = suite.by_id("L1/12_softmax").unwrap();
        let arch = GpuArch::h100();
        let cfg = quick_cfg();
        let mut kb_seq = KnowledgeBase::empty();
        let _ = optimize_task(task, &arch, &mut kb_seq, &cfg, 0);
        let snapshot = kb_seq.clone();
        let r_seq = optimize_task(task, &arch, &mut kb_seq, &cfg, 1);
        let mut cache = VerifyCache::new();
        let (r_delta, delta) =
            optimize_task_delta(task, &arch, &snapshot, &cfg, 1, &mut cache);
        assert_eq!(r_seq, r_delta, "TaskRun diverged");
        let mut committed = snapshot.clone();
        lifecycle::apply_delta(&mut committed, &delta);
        assert_eq!(committed, kb_seq, "committed KB diverged");
        // The cache is reusable: a second delta run over the same task
        // hits the warmed fixtures and still agrees.
        let (r_again, _) = optimize_task_delta(task, &arch, &snapshot, &cfg, 1, &mut cache);
        assert_eq!(r_again, r_seq);
    }

    #[test]
    fn kb_accumulates_across_tasks_in_persistent_mode() {
        let suite = Suite::full();
        let arch = GpuArch::a6000();
        let cfg = quick_cfg();
        let mut kb = KnowledgeBase::empty();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/01_matmul_square").unwrap(),
            suite.by_id("L1/02_matmul_large").unwrap(),
        ];
        let runs = run_suite(&tasks, &arch, &mut kb, &cfg);
        assert_eq!(runs.len(), 2);
        assert!(kb.total_attempts() > 0);
        assert!(!kb.states.is_empty());
    }

    #[test]
    fn ephemeral_mode_leaves_shared_kb_untouched() {
        let suite = Suite::full();
        let arch = GpuArch::a6000();
        let cfg = IcrlConfig {
            kb_mode: KbMode::EphemeralPerTask,
            ..quick_cfg()
        };
        let mut kb = KnowledgeBase::empty();
        let tasks: Vec<&Task> = vec![suite.by_id("L1/01_matmul_square").unwrap()];
        let _ = run_suite(&tasks, &arch, &mut kb, &cfg);
        assert_eq!(kb.total_attempts(), 0);
        assert!(kb.states.is_empty());
    }

    #[test]
    fn cycles_only_collapses_states() {
        let suite = Suite::full();
        let arch = GpuArch::h100();
        let cfg = IcrlConfig {
            cycles_only: true,
            ..quick_cfg()
        };
        let mut kb = KnowledgeBase::empty();
        let task = suite.by_id("L2/01_gemm_bias_relu").unwrap();
        let run = optimize_task(task, &arch, &mut kb, &cfg, 0);
        // Only the degenerate state may appear.
        assert_eq!(run.states_visited, 1);
        for s in &run.steps {
            assert_eq!(s.state.primary, s.state.secondary);
        }
    }

    #[test]
    fn warm_start_kb_transfers_grown_evidence() {
        let suite = Suite::full();
        let task = suite.by_id("L1/01_matmul_square").unwrap();
        let cfg = quick_cfg();
        // Grow native evidence on an A6000…
        let src = GpuArch::a6000();
        let mut grown = KnowledgeBase::empty();
        let _ = optimize_task(task, &src, &mut grown, &cfg, 0);
        assert_eq!(grown.arch.as_deref(), Some("A6000"));
        assert!(grown.total_attempts() > 0);
        // …and prepare an H100 warm start: every entry becomes a
        // decayed-confidence prior whose provenance names the source.
        let dst = GpuArch::h100();
        let mut warm = warm_start_kb(
            &[grown],
            &dst,
            &crate::kb::lifecycle::TransferPolicy::default(),
        );
        assert_eq!(warm.arch.as_deref(), Some("H100"));
        let st = crate::kb::lifecycle::stats(&warm);
        assert!(st.states > 0);
        assert_eq!(st.attempts, 0);
        assert!(st.transferred > 0 && st.transferred == st.entries);
        // The warm KB drives a valid run.
        let run = optimize_task(task, &dst, &mut warm, &cfg, 1);
        assert!(run.valid);
        assert_eq!(warm.arch.as_deref(), Some("H100"));
    }

    #[test]
    fn cross_arch_reuse_without_transfer_is_flagged_in_lineage() {
        let suite = Suite::full();
        let task = suite.by_id("L1/15_relu").unwrap();
        let cfg = quick_cfg();
        let mut kb = KnowledgeBase::empty();
        let _ = optimize_task(task, &GpuArch::a6000(), &mut kb, &cfg, 0);
        assert!(kb.lineage.is_empty());
        // Reusing the A6000 KB on H100 without a lifecycle transfer mixes
        // evidence populations — the relabeling is audit-trailed.
        let _ = optimize_task(task, &GpuArch::h100(), &mut kb, &cfg, 1);
        assert_eq!(kb.arch.as_deref(), Some("H100"));
        assert!(kb.lineage.iter().any(|l| l.contains("mixed-arch")));
        // Same-arch continuation doesn't re-flag.
        let n = kb.lineage.len();
        let _ = optimize_task(task, &GpuArch::h100(), &mut kb, &cfg, 2);
        assert_eq!(kb.lineage.len(), n);
    }

    #[test]
    fn textual_gradient_cites_priors_the_run_actually_touches() {
        // Deterministic prior-citation check: discover which states this
        // exact (task, arch, seed) run visits, re-label that KB's entries
        // as transferred priors (scores untouched, so the RNG-driven
        // trajectory is unchanged), and re-run — the first parameter
        // update must integrate notes citing the prior's source arch.
        let suite = Suite::full();
        let task = suite.by_id("L1/12_softmax").unwrap();
        let arch = GpuArch::h100();
        let cfg = quick_cfg();
        let mut cold = KnowledgeBase::empty();
        let _ = optimize_task(task, &arch, &mut cold, &cfg, 5);
        let mut warm = cold.clone();
        warm.updates = 0;
        for s in &mut warm.states {
            s.visits = 0;
            for o in &mut s.opts {
                o.attempts = 0;
                o.successes = 0;
                o.last_gain = 1.0;
                o.notes.clear();
                o.origin = Some("A6000".into());
            }
        }
        let _ = optimize_task(task, &arch, &mut warm, &cfg, 5);
        let cited = warm
            .states
            .iter()
            .flat_map(|s| &s.opts)
            .flat_map(|o| &o.notes)
            .any(|n| n.starts_with("prior from A6000:"));
        assert!(cited, "no transferred prior was cited");
    }

    #[test]
    fn pretrained_kb_converges_faster_in_tokens() {
        // Fig. 15's mechanism: with a trained KB the selector goes
        // straight to what works; verify the trained-KB run reaches at
        // least the same best time without more tokens than the empty-KB
        // run (looser: its speedup is >= 90% of the empty run's).
        let suite = Suite::full();
        let arch = GpuArch::l40s();
        let task = suite.by_id("L2/63_gemm_bias_relu_div_f16").unwrap();
        let cfg = IcrlConfig {
            trajectories: 3,
            rollout_steps: 5,
            ..quick_cfg()
        };
        // Train on a related task first.
        let mut trained = KnowledgeBase::empty();
        let t0 = suite.by_id("L2/01_gemm_bias_relu").unwrap();
        let _ = optimize_task(t0, &arch, &mut trained, &cfg, 1);
        let r_trained = optimize_task(task, &arch, &mut trained.clone(), &cfg, 2);
        let mut empty = KnowledgeBase::empty();
        let r_empty = optimize_task(task, &arch, &mut empty, &cfg, 2);
        // At this tiny scale the comparison is noisy; the strong claim
        // (faster coverage) is exercised statistically by the Fig. 15/16
        // experiment — here we only require the trained run stays in the
        // same ballpark rather than collapsing.
        assert!(
            r_trained.speedup_vs_naive() >= 0.5 * r_empty.speedup_vs_naive(),
            "trained {:.2} vs empty {:.2}",
            r_trained.speedup_vs_naive(),
            r_empty.speedup_vs_naive()
        );
    }

    #[test]
    fn every_policy_runs_deterministically() {
        use crate::icrl::policy::{PolicyConfig, PolicyKind};
        let suite = Suite::full();
        let task = suite.by_id("L2/01_gemm_bias_relu").unwrap();
        let arch = GpuArch::h100();
        for kind in PolicyKind::all() {
            let cfg = IcrlConfig {
                policy: PolicyConfig::of_kind(*kind),
                ..quick_cfg()
            };
            let mut kb1 = KnowledgeBase::empty();
            let r1 = optimize_task(task, &arch, &mut kb1, &cfg, 3);
            let mut kb2 = KnowledgeBase::empty();
            let r2 = optimize_task(task, &arch, &mut kb2, &cfg, 3);
            assert_eq!(r1, r2, "{}: TaskRun not reproducible", kind.name());
            assert_eq!(kb1, kb2, "{}: KB not reproducible", kind.name());
            assert!(r1.valid, "{}: no valid kernel found", kind.name());
            assert!(
                r1.best_time_s <= r1.naive_time_s * 1.0001,
                "{}: best worse than naive",
                kind.name()
            );
        }
    }

    #[test]
    fn beam_search_parallel_and_sequential_agree_exactly() {
        // The §Perf bit-identity contract must survive a frontier wider
        // than one: per-node derived streams + pick-order merge make the
        // parallel path invisible for beam search too.
        use crate::icrl::policy::{PolicyConfig, PolicyKind};
        let suite = Suite::full();
        let task = suite.by_id("L1/12_softmax").unwrap();
        let arch = GpuArch::a100();
        let base = IcrlConfig {
            policy: PolicyConfig {
                kind: PolicyKind::BeamSearch,
                beam_width: 3,
                ..Default::default()
            },
            ..quick_cfg()
        };
        let mut kb_seq = KnowledgeBase::empty();
        let r_seq = optimize_task(
            task,
            &arch,
            &mut kb_seq,
            &IcrlConfig {
                parallel_explore: false,
                ..base.clone()
            },
            5,
        );
        let mut kb_par = KnowledgeBase::empty();
        let r_par = optimize_task(
            task,
            &arch,
            &mut kb_par,
            &IcrlConfig {
                parallel_explore: true,
                ..base
            },
            5,
        );
        assert_eq!(r_seq, r_par, "beam TaskRun diverged");
        assert_eq!(kb_seq, kb_par, "beam KB diverged");
    }

    #[test]
    fn similarity_dedup_is_off_by_default_and_deterministic_when_on() {
        use crate::icrl::policy::{PolicyConfig, PolicyKind};
        let suite = Suite::full();
        let task = suite.by_id("L2/09_mlp_block").unwrap();
        let arch = GpuArch::h100();
        let beam = |dedup_distance: f64| IcrlConfig {
            policy: PolicyConfig {
                kind: PolicyKind::BeamSearch,
                beam_width: 3,
                dedup_distance,
                ..Default::default()
            },
            ..quick_cfg()
        };
        // Default 0.0 IS the exact-equality driver: an explicit 0.0 and
        // the default config field are the same code path.
        assert_eq!(PolicyConfig::default().dedup_distance, 0.0);
        let mut kb_a = KnowledgeBase::empty();
        let r_a = optimize_task(task, &arch, &mut kb_a, &beam(0.0), 2);
        // Similarity dedup on: still deterministic, still valid, and the
        // per-step chosen count stays within the frontier width.
        let threshold = 1.5;
        let mut kb_b1 = KnowledgeBase::empty();
        let r_b1 = optimize_task(task, &arch, &mut kb_b1, &beam(threshold), 2);
        let mut kb_b2 = KnowledgeBase::empty();
        let r_b2 = optimize_task(task, &arch, &mut kb_b2, &beam(threshold), 2);
        assert_eq!(r_b1, r_b2, "dedup run not reproducible");
        assert_eq!(kb_b1, kb_b2);
        assert!(r_b1.valid && r_a.valid);
        let mut chosen = std::collections::BTreeMap::new();
        for s in &r_b1.steps {
            if s.chosen {
                *chosen.entry((s.trajectory, s.step)).or_insert(0usize) += 1;
            }
        }
        assert!(chosen.values().all(|&n| n <= 3));
        assert!(
            r_b1.best_time_s <= r_b1.naive_time_s * 1.0001,
            "dedup run regressed past naive"
        );
    }

    #[test]
    fn beam_search_explores_a_wider_frontier() {
        // With width B > 1 a step evaluates more samples than the greedy
        // frontier of one, and at most B logs per step are chosen.
        use crate::icrl::policy::{PolicyConfig, PolicyKind};
        let suite = Suite::full();
        let task = suite.by_id("L2/09_mlp_block").unwrap();
        let arch = GpuArch::h100();
        let greedy_cfg = quick_cfg();
        let beam_cfg = IcrlConfig {
            policy: PolicyConfig {
                kind: PolicyKind::BeamSearch,
                beam_width: 2,
                ..Default::default()
            },
            ..quick_cfg()
        };
        let mut kb_g = KnowledgeBase::empty();
        let r_greedy = optimize_task(task, &arch, &mut kb_g, &greedy_cfg, 0);
        let mut kb_b = KnowledgeBase::empty();
        let r_beam = optimize_task(task, &arch, &mut kb_b, &beam_cfg, 0);
        assert!(
            r_beam.steps.len() > r_greedy.steps.len(),
            "beam {} vs greedy {} samples",
            r_beam.steps.len(),
            r_greedy.steps.len()
        );
        // Per (trajectory, step), chosen count is bounded by the width.
        let mut chosen_per_step = std::collections::BTreeMap::new();
        for s in &r_beam.steps {
            if s.chosen {
                *chosen_per_step.entry((s.trajectory, s.step)).or_insert(0usize) += 1;
            }
        }
        assert!(chosen_per_step.values().all(|&n| n <= 2));
        // The wider frontier actually materializes: some step chose two.
        assert!(
            chosen_per_step.values().any(|&n| n == 2),
            "beam never carried two survivors"
        );
        assert!(r_beam.valid);
    }

    #[test]
    fn staged_with_screen_off_is_bit_identical_to_unstaged() {
        // Probe + remainder is the full oracle and verification draws no
        // RNG, so staging with the heuristic screen disabled must
        // reproduce the unstaged driver exactly — including when the
        // in-run working memo replays repeat candidates.
        let suite = Suite::full();
        let task = suite.by_id("L2/01_gemm_bias_relu").unwrap();
        let arch = GpuArch::h100();
        let base = quick_cfg();
        let staged_cfg = IcrlConfig {
            verify: VerifyConfig {
                staged: true,
                screen: false,
                ..Default::default()
            },
            ..base.clone()
        };
        let mut kb_a = KnowledgeBase::empty();
        let r_a = optimize_task(task, &arch, &mut kb_a, &base, 4);
        let mut kb_b = KnowledgeBase::empty();
        let mut cache = VerifyCache::new();
        let (r_b, delta, tiers) =
            optimize_task_verified(task, &arch, &mut kb_b, &staged_cfg, 4, &mut cache, None);
        assert_eq!(r_a, r_b, "staged (screen off) TaskRun diverged");
        assert_eq!(kb_a, kb_b, "staged (screen off) KB diverged");
        assert!(tiers.full_verifications > 0);
        assert!(!delta.is_empty(), "a grown run must memoize verdicts");
    }

    #[test]
    fn staged_off_keeps_verified_outputs_inert() {
        // The default config through the verified entry point is the
        // plain driver: same TaskRun, empty delta, zero tier activity.
        let suite = Suite::full();
        let task = suite.by_id("L1/12_softmax").unwrap();
        let arch = GpuArch::a100();
        let cfg = quick_cfg();
        assert!(!cfg.verify.staged, "default must be off");
        let mut kb_a = KnowledgeBase::empty();
        let r_a = optimize_task(task, &arch, &mut kb_a, &cfg, 2);
        let mut kb_b = KnowledgeBase::empty();
        let mut cache = VerifyCache::new();
        let (r_b, delta, tiers) =
            optimize_task_verified(task, &arch, &mut kb_b, &cfg, 2, &mut cache, None);
        assert_eq!(r_a, r_b);
        assert_eq!(kb_a, kb_b);
        assert!(delta.is_empty());
        assert_eq!(tiers, TierStats::default());
    }

    #[test]
    fn skill_draws_apply_whole_chains_and_record_composite_evidence() {
        use crate::kb::SkillEntry;
        let suite = Suite::full();
        let task = suite.by_id("L2/01_gemm_bias_relu").unwrap();
        let arch = GpuArch::h100();
        // Grow states cold, then hand a high-expectation mined chain to
        // every state so the weighted draw is all but certain to pull
        // it at least once across the run.
        let mut kb = KnowledgeBase::empty();
        let _ = optimize_task(task, &arch, &mut kb, &quick_cfg(), 0);
        for s in &mut kb.states {
            s.skills.push(SkillEntry {
                techniques: vec![
                    Technique::SharedMemoryTiling,
                    Technique::VectorizedAccess,
                ],
                expected_gain: 6.0,
                support: 3,
                attempts: 0,
                successes: 0,
                last_gain: 1.0,
                origin: Some(crate::kb::MINED_ORIGIN.to_string()),
            });
        }
        let cfg_on = IcrlConfig {
            skills: SkillsConfig {
                enabled: true,
                ..Default::default()
            },
            ..quick_cfg()
        };
        let mut kb1 = kb.clone();
        let r1 = optimize_task(task, &arch, &mut kb1, &cfg_on, 1);
        let mut kb2 = kb.clone();
        let r2 = optimize_task(task, &arch, &mut kb2, &cfg_on, 1);
        assert_eq!(r1, r2, "skills-on run not reproducible");
        assert_eq!(kb1, kb2);
        assert!(r1.valid);
        let skill_draws: Vec<_> = r1.steps.iter().filter(|s| s.skill.is_some()).collect();
        assert!(
            !skill_draws.is_empty(),
            "a 6x-expectation chain was never drawn"
        );
        for s in &skill_draws {
            let chain = s.skill.as_ref().unwrap();
            assert_eq!(s.technique, chain[0], "log carries the lead link");
            assert!(chain.len() >= 2);
        }
        // Evidence landed on the composite entries, not the lead opts'
        // replay buffer: every skill attempt in the KB came from a draw.
        let skill_attempts: usize = kb1
            .states
            .iter()
            .flat_map(|s| &s.skills)
            .map(|k| k.attempts)
            .sum();
        assert_eq!(skill_attempts, skill_draws.len());
        // steps_to_best points at a real sample that set the best time.
        if r1.steps_to_best > 0 {
            let s = &r1.steps[r1.steps_to_best - 1];
            assert!(s.valid && s.chosen);
        }
    }

    #[test]
    fn fully_staged_driver_is_deterministic_and_best_passes_the_oracle() {
        // Screen + probe + memo all on: the run stays reproducible, and
        // the returned best still passes the full unstaged harness — the
        // "full oracle is the only committing gate" invariant, end to
        // end.
        let suite = Suite::full();
        let task = suite.by_id("L2/09_mlp_block").unwrap();
        let arch = GpuArch::h100();
        let cfg = IcrlConfig {
            verify: VerifyConfig {
                staged: true,
                ..Default::default()
            },
            ..quick_cfg()
        };
        let mut kb1 = KnowledgeBase::empty();
        let mut cache1 = VerifyCache::new();
        let (r1, d1, t1) =
            optimize_task_verified(task, &arch, &mut kb1, &cfg, 6, &mut cache1, None);
        let mut kb2 = KnowledgeBase::empty();
        let mut cache2 = VerifyCache::new();
        let (r2, d2, t2) =
            optimize_task_verified(task, &arch, &mut kb2, &cfg, 6, &mut cache2, None);
        assert_eq!(r1, r2, "staged run not reproducible");
        assert_eq!(kb1, kb2);
        assert_eq!(d1, d2);
        assert_eq!(t1, t2);
        assert!(r1.valid);
        let mut rng = Rng::new(0);
        let out = harness::run(task, &r1.best, &arch, &cfg.harness, &mut rng);
        assert!(out.is_ok(), "{}", out.feedback());
        assert!(r1.best_time_s <= r1.naive_time_s * 1.0001);
    }
}
