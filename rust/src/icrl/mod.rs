//! The MAIC-RL driver — Algorithm 2 of the paper.
//!
//! Outer loop: for each task, run `trajectories` rollouts of
//! `rollout_steps` optimization steps. Each step:
//! 1. profile the current kernel (NCU analog),
//! 2. extract its performance state (StateExtractor),
//! 3. match/discover the state in the Knowledge Base,
//! 4. retrieve + weighted-sample the top-k candidate optimizations,
//! 5. lower each candidate (LoweringAgent, with retries on feedback),
//! 6. validate + profile (harness), record rewards in the replay buffer,
//! 7. step to the best valid candidate.
//!
//! After every trajectory the textual-gradient trio (PolicyEvaluation →
//! PerfGapAnalysis → ParameterUpdate) integrates the replay buffer into
//! the Knowledge Base — the in-context policy-gradient step.

pub mod driver;

pub use driver::{optimize_task, run_suite, IcrlConfig, KbMode, StepLog, TaskRun};
