//! The MAIC-RL driver — Algorithm 2 of the paper.
//!
//! Outer loop: for each task, run `trajectories` rollouts of
//! `rollout_steps` optimization steps. Each step:
//! 1. profile the current kernel (NCU analog),
//! 2. extract its performance state (StateExtractor),
//! 3. match/discover the state in the Knowledge Base,
//! 4. retrieve + weighted-sample the top-k candidate optimizations,
//! 5. lower each candidate (LoweringAgent, with retries on feedback),
//! 6. validate + profile (harness), record rewards in the replay buffer,
//! 7. step to the best valid candidate.
//!
//! After every trajectory the textual-gradient trio (PolicyEvaluation →
//! PerfGapAnalysis → ParameterUpdate) integrates the replay buffer into
//! the Knowledge Base — the in-context policy-gradient step.
//!
//! Neighbors in the loop: profiles come from [`crate::gpu`], state
//! extraction and lowering from [`crate::agents`], state matching and
//! scores from [`crate::kb`], validation from [`crate::harness`], and
//! tasks from [`crate::tasks`]. A run no longer has to start cold:
//! [`warm_start_kb`] seeds θ₀ from prior KBs via the
//! [`crate::kb::lifecycle`] merge/transfer pipeline, and the driver
//! stamps the KB with the [`crate::gpu::GpuArch`] it ran on so later
//! lifecycle hops know where the evidence came from.
//!
//! Batches of tasks no longer run strictly one at a time either: the
//! [`fleet`] scheduler serves many optimization requests concurrently
//! over a bounded worker pool (snapshot → worker → delta →
//! epoch-ordered commit), bit-identical to the sequential driver — see
//! its module docs for the determinism contract. With
//! [`FleetConfig::shards`] > 1 the commit side itself parallelizes: the
//! [`shard`] pipeline partitions the KB by `StateSig` hash across
//! per-shard committer threads without changing a byte of output.
//!
//! Step 4's selection rule is no longer hard-wired: the driver is
//! parameterized over a [`policy::SearchPolicy`]
//! ([`IcrlConfig::policy`], CLI `--policy`) — weighted top-k
//! (`greedy_topk`, the default, bit-identical to the previous driver),
//! ε-greedy, a UCB bandit over KB evidence, beam search carrying B
//! candidates across steps, or the contrastive [`policy::Portfolio`]
//! that arbitrates an explore/exploit pair per state from replay
//! statistics. ε and UCB-c can anneal per state as evidence accumulates
//! ([`policy::Schedule`]); `experiment policy` compares the arms over
//! paired seeds and `experiment sweep` grids their hyperparameters.
//!
//! Step 6's verification can run **staged**
//! ([`IcrlConfig::verify`], CLI `--staged`): a static cost-model screen
//! and a one-seed probe triage candidates before the full oracle, and a
//! persistent cross-run memo ([`crate::harness::memo`]) replays verdicts
//! for candidates any earlier run already verified. The full oracle
//! remains the only committing gate — see [`crate::harness::staged`].
//!
//! Step 4 can also draw **mined skills** ([`IcrlConfig::skills`], CLI
//! `--skills`): composite technique chains the [`crate::kb::skills`]
//! miner compressed out of earlier runs' replay logs join the candidate
//! pool, and a single pick applies the whole chain (lowering every link,
//! verifying once at the end) — see the driver's §skills docs. Off by
//! default and bit-identical off.

#![deny(missing_docs)]

pub mod driver;
pub mod fleet;
pub mod policy;
pub mod shard;

pub use driver::{
    optimize_task, optimize_task_delta, optimize_task_delta_verified, optimize_task_in,
    optimize_task_verified, run_suite, warm_start_kb, IcrlConfig, KbMode, StepLog, TaskRun,
};
pub use fleet::{
    auto_epoch_policy, run_fleet, run_fleet_memo, run_fleet_observed, run_fleet_store,
    FleetConfig, FleetOutcome, NullStore, Store, WholeFileStore,
};
pub use shard::{shard_of, ShardMetrics};
pub use policy::{
    BeamSearch, EpsilonGreedy, GreedyTopK, PolicyConfig, PolicyKind, Portfolio, Schedule,
    SearchPolicy, Thompson, UcbBandit,
};

pub use crate::kb::skills::SkillsConfig;
