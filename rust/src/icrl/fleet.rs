//! Fleet scheduler: concurrent multi-task serving over a shared KB.
//!
//! The paper amortizes exploration across tasks through one Persistent
//! CUDA Knowledge Base; this module amortizes it across *time* as well —
//! a batch of optimization requests is served by a bounded worker pool
//! instead of strictly one task at a time.
//!
//! # Dataflow (snapshot → worker → delta → epoch-ordered commit)
//!
//! ```text
//!   task list ──► epochs of `epoch_size` tasks
//!                     │
//!        ┌── epoch ───┴──────────────────────────────────────────┐
//!        │  shared KB ──clone──► read-only snapshot              │
//!        │      ▲                    │ (same snapshot for every  │
//!        │      │                    │  task of the epoch)       │
//!        │      │        ┌───────────┼───────────┐               │
//!        │      │     worker 0    worker 1 …  worker W-1         │
//!        │      │     (own VerifyCache, own RNG streams, own     │
//!        │      │      interpreter arenas — no shared mutable    │
//!        │      │      state; tasks pulled from a shared queue)  │
//!        │      │        │           │           │               │
//!        │      │     optimize_task_delta: clone snapshot, run   │
//!        │      │     the unmodified driver loop, extract a      │
//!        │      │     KbDelta of the evidence the run added      │
//!        │      │        └───────────┼───────────┘               │
//!        │      │                    ▼                           │
//!        │      └── committer: lifecycle::apply_delta in TASK    │
//!        │          ORDER (epoch order), one delta at a time     │
//!        └───────────────────────────────────────────────────────┘
//! ```
//!
//! # Determinism contract
//!
//! `fleet(batch)` is bit-identical to `sequential(batch)` — the same
//! epoch/snapshot/commit pipeline executed serially — for **any** worker
//! count, the same contract the driver's `parallel_explore` established
//! for in-step exploration (see [`crate::icrl::driver`] §Perf):
//!
//! - each task's [`TaskRun`] is a pure function of (task, arch, config,
//!   global task index, epoch snapshot) — never of which worker ran it
//!   or in what order workers finished;
//! - deltas commit in task order, and [`lifecycle::apply_delta`] is
//!   deterministic, so the shared KB after every epoch is worker-count
//!   invariant;
//! - with `epoch_size == 1` the pipeline degenerates to the sequential
//!   driver exactly: one delta per epoch applies to its own base, which
//!   [`lifecycle::apply_delta`] replays bit-identically — the final KB
//!   and every `TaskRun` equal [`crate::icrl::run_suite`]'s.
//!
//! `tests/fleet.rs` asserts all three (workers ∈ {1, 2, 8}; serialized
//! KB bytes compared).
//!
//! `epoch_size` trades shared-knowledge freshness for parallelism: tasks
//! within an epoch cannot see each other's discoveries (they all read
//! the epoch snapshot), so larger epochs mean more concurrency but
//! staler retrieval. Worker count never changes results — only wall
//! clock. `experiments/fleet.rs` measures the throughput side
//! (tasks/min) and the KB-quality parity, emitting `BENCH_fleet.json`.
//!
//! The search policy rides per-batch: every worker runs the batch's
//! [`IcrlConfig::policy`] (`kernelblaster batch --policy`, or the
//! config file's `policy` section), so the shared KB accumulates
//! evidence gathered under one selection rule — mixing policies within
//! a batch would make its delta evidence populations incomparable. The
//! determinism contract is policy-independent (each `TaskRun` is still
//! a pure function of task, arch, config, global task index, and the
//! epoch snapshot); `tests/policy.rs` anchors the default-policy fleet
//! against the pre-policy sequential driver bit-for-bit.
//!
//! # Checkpointing
//!
//! Long batches checkpoint the shared KB every
//! [`FleetConfig::checkpoint_every`] commits (a commit = one task's
//! delta folded in). [`checkpoint_atomic`] writes the full
//! `kernelblaster-kb-v1` document to `<file>.tmp` in the target
//! directory and atomically renames it over the destination, so a crash
//! mid-write can never leave a torn KB — readers observe either the
//! previous checkpoint or the new one, nothing in between.

use super::driver::{optimize_task_delta, optimize_task_in, IcrlConfig, KbMode, TaskRun};
use crate::gpu::GpuArch;
use crate::harness::VerifyCache;
use crate::kb::lifecycle::{self, KbDelta};
use crate::kb::{persist, KnowledgeBase};
use crate::tasks::Task;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Fleet scheduling knobs ([`crate::config::RunConfig`] plumbs these
/// from the `fleet` section of a run config; `kernelblaster batch`
/// exposes them as flags).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetConfig {
    /// Worker threads serving each epoch (≥ 1). Never affects results —
    /// only throughput.
    pub workers: usize,
    /// Tasks per epoch (≥ 1): every task of an epoch reads the same
    /// shared-KB snapshot, so this bounds both the available concurrency
    /// and the staleness of retrieval. `1` reproduces the sequential
    /// driver exactly.
    pub epoch_size: usize,
    /// Checkpoint the shared KB every N commits (0 = never). A commit is
    /// one task's delta folded into the shared KB.
    pub checkpoint_every: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            epoch_size: 8,
            checkpoint_every: 0,
        }
    }
}

/// What a fleet run produced, beyond the shared KB mutation.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetOutcome {
    /// Per-task results, in task-list order (same order as
    /// [`crate::icrl::run_suite`]).
    pub runs: Vec<TaskRun>,
    /// Epochs executed.
    pub epochs: usize,
    /// Deltas committed into the shared KB (0 in
    /// [`KbMode::EphemeralPerTask`]).
    pub commits: usize,
}

/// Progress hooks for streaming consumers (the `batch` CLI command
/// streams JSON-lines and checkpoints from these). Default
/// implementations do nothing.
pub trait FleetObserver {
    /// Task `index` (position in the task list) finished and — in
    /// persistent mode — its delta has been committed.
    fn task_done(&mut self, _index: usize, _run: &TaskRun) {}

    /// An epoch's deltas have all been folded in. `commits` is the
    /// running total; `kb` is the shared KB after the fold.
    fn epoch_committed(&mut self, _epoch: usize, _commits: usize, _kb: &KnowledgeBase) {}
}

/// The do-nothing observer for callers that only want [`FleetOutcome`].
pub struct NullObserver;

impl FleetObserver for NullObserver {}

/// Run a batch through the fleet pipeline. See the module docs for the
/// dataflow and the determinism contract; per-task `run_seed`s are the
/// global task indices, matching [`crate::icrl::run_suite`].
pub fn run_fleet(
    tasks: &[&Task],
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    fleet: &FleetConfig,
) -> FleetOutcome {
    run_fleet_observed(tasks, arch, kb, cfg, fleet, &mut NullObserver)
}

/// [`run_fleet`] with progress hooks.
pub fn run_fleet_observed(
    tasks: &[&Task],
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    fleet: &FleetConfig,
    obs: &mut dyn FleetObserver,
) -> FleetOutcome {
    let epoch_size = fleet.epoch_size.max(1);
    let workers = fleet.workers.max(1);
    let ephemeral = cfg.kb_mode == KbMode::EphemeralPerTask;
    let mut runs: Vec<TaskRun> = Vec::with_capacity(tasks.len());
    let mut epochs = 0usize;
    let mut commits = 0usize;
    let mut offset = 0usize;
    for chunk in tasks.chunks(epoch_size) {
        let results = epoch_results(chunk, offset, arch, kb, cfg, workers, ephemeral);
        // Lineage lines observed on this epoch's shared snapshot: every
        // worker of the epoch sees the same snapshot, so a condition
        // (e.g. the mixed-arch audit flag) is reported once per epoch,
        // matching the once-per-transition behavior of the sequential
        // driver. With one task per epoch nothing is stripped — deltas
        // replay verbatim.
        let mut epoch_lines: Vec<String> = Vec::new();
        for (i, (run, mut delta)) in results.into_iter().enumerate() {
            if !ephemeral {
                delta.lineage_added.retain(|l| !epoch_lines.contains(l));
                epoch_lines.extend(delta.lineage_added.iter().cloned());
                lifecycle::apply_delta(kb, &delta);
                commits += 1;
            }
            obs.task_done(offset + i, &run);
            runs.push(run);
        }
        epochs += 1;
        obs.epoch_committed(epochs, commits, kb);
        offset += chunk.len();
    }
    FleetOutcome {
        runs,
        epochs,
        commits,
    }
}

/// Serve one epoch: the chunk's tasks against a single snapshot, over a
/// pool of `workers` threads pulling from a shared queue. Results come
/// back in task order regardless of completion order.
fn epoch_results(
    chunk: &[&Task],
    offset: usize,
    arch: &GpuArch,
    snapshot: &KnowledgeBase,
    cfg: &IcrlConfig,
    workers: usize,
    ephemeral: bool,
) -> Vec<(TaskRun, KbDelta)> {
    let n = chunk.len();
    let serve_one = |i: usize, cache: &mut VerifyCache| {
        let run_seed = (offset + i) as u64;
        if ephemeral {
            // The ablation arm starts every task cold and discards the
            // KB, exactly as run_suite's EphemeralPerTask does — no
            // delta to extract, nothing to commit.
            let mut scratch = KnowledgeBase::empty();
            let run = optimize_task_in(chunk[i], arch, &mut scratch, cfg, run_seed, cache);
            (run, KbDelta::empty())
        } else {
            optimize_task_delta(chunk[i], arch, snapshot, cfg, run_seed, cache)
        }
    };
    if workers <= 1 || n <= 1 {
        // Thread-free serial path (also the profiling-friendly mode).
        let mut cache = VerifyCache::new();
        return (0..n).map(|i| serve_one(i, &mut cache)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<(TaskRun, KbDelta)>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers.min(n))
            .map(|_| {
                scope.spawn(|| {
                    // §Perf: one verification cache per worker, reused
                    // across every task this worker serves (idempotent
                    // warm, keyed by task id) — see harness docs.
                    let mut cache = VerifyCache::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let out = serve_one(i, &mut cache);
                        *slots[i].lock().expect("slot lock") = Some(out);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("fleet worker panicked");
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("slot lock")
                .expect("every epoch slot is filled before the scope ends")
        })
        .collect()
}

/// Crash-safe KB checkpoint: write the serialized document to a `.tmp`
/// sibling, then atomically rename it over `path`. On any error the
/// previous checkpoint (if one exists) is left untouched.
pub fn checkpoint_atomic(kb: &KnowledgeBase, path: &Path) -> Result<(), String> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).map_err(|e| format!("mkdir: {e}"))?;
        }
    }
    let mut tmp_name = path.file_name().map(|f| f.to_os_string()).ok_or_else(|| {
        format!("checkpoint path has no file name: {}", path.display())
    })?;
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    std::fs::write(&tmp, persist::to_json(kb).to_string_pretty())
        .map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::HarnessConfig;
    use crate::tasks::Suite;

    fn quick_cfg() -> IcrlConfig {
        IcrlConfig {
            trajectories: 2,
            rollout_steps: 3,
            top_k: 2,
            harness: HarnessConfig {
                noise_sigma: 0.0,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn fleet_runs_batch_in_task_order() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/01_matmul_square").unwrap(),
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
        ];
        let arch = GpuArch::h100();
        let mut kb = KnowledgeBase::empty();
        let fleet = FleetConfig {
            workers: 2,
            epoch_size: 2,
            checkpoint_every: 0,
        };
        let out = run_fleet(&tasks, &arch, &mut kb, &quick_cfg(), &fleet);
        assert_eq!(out.runs.len(), 3);
        assert_eq!(out.epochs, 2);
        assert_eq!(out.commits, 3);
        for (t, r) in tasks.iter().zip(&out.runs) {
            assert_eq!(t.id, r.task_id);
        }
        assert!(kb.total_attempts() > 0);
        assert_eq!(kb.arch.as_deref(), Some("H100"));
    }

    #[test]
    fn ephemeral_mode_leaves_shared_kb_untouched() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![suite.by_id("L1/15_relu").unwrap()];
        let arch = GpuArch::a100();
        let mut kb = KnowledgeBase::empty();
        let cfg = IcrlConfig {
            kb_mode: KbMode::EphemeralPerTask,
            ..quick_cfg()
        };
        let out = run_fleet(&tasks, &arch, &mut kb, &cfg, &FleetConfig::default());
        assert_eq!(out.commits, 0);
        assert!(kb.states.is_empty());
        assert_eq!(kb.total_attempts(), 0);
        assert!(out.runs[0].valid);
    }

    #[test]
    fn observer_sees_every_task_and_epoch() {
        struct Spy {
            tasks: Vec<usize>,
            epochs: Vec<(usize, usize)>,
        }
        impl FleetObserver for Spy {
            fn task_done(&mut self, index: usize, _run: &TaskRun) {
                self.tasks.push(index);
            }
            fn epoch_committed(&mut self, epoch: usize, commits: usize, _kb: &KnowledgeBase) {
                self.epochs.push((epoch, commits));
            }
        }
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/01_matmul_square").unwrap(),
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
        ];
        let arch = GpuArch::l40s();
        let mut kb = KnowledgeBase::empty();
        let mut spy = Spy {
            tasks: vec![],
            epochs: vec![],
        };
        let fleet = FleetConfig {
            workers: 2,
            epoch_size: 2,
            checkpoint_every: 0,
        };
        let _ = run_fleet_observed(&tasks, &arch, &mut kb, &quick_cfg(), &fleet, &mut spy);
        assert_eq!(spy.tasks, vec![0, 1, 2]);
        assert_eq!(spy.epochs, vec![(1, 2), (2, 3)]);
    }

    #[test]
    fn checkpoint_atomic_writes_loadable_kb_and_cleans_tmp() {
        let dir = std::env::temp_dir().join("kb_fleet_checkpoint_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("kb.json");
        let kb = KnowledgeBase::seed_priors();
        checkpoint_atomic(&kb, &path).unwrap();
        let back = persist::load(&path).unwrap();
        assert_eq!(back.states.len(), kb.states.len());
        assert!(
            !dir.join("kb.json.tmp").exists(),
            "tmp file must be renamed away"
        );
        // Overwrite is atomic too (same path, new content).
        let kb2 = KnowledgeBase::empty();
        checkpoint_atomic(&kb2, &path).unwrap();
        assert!(persist::load(&path).unwrap().states.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
