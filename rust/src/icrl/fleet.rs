//! Fleet scheduler: concurrent multi-task serving over a shared KB.
//!
//! The paper amortizes exploration across tasks through one Persistent
//! CUDA Knowledge Base; this module amortizes it across *time* as well —
//! a batch of optimization requests is served by a bounded worker pool
//! instead of strictly one task at a time.
//!
//! # Dataflow (snapshot → worker → delta → epoch-ordered commit)
//!
//! ```text
//!   task list ──► epochs of `epoch_size` tasks
//!                     │
//!        ┌── epoch ───┴──────────────────────────────────────────┐
//!        │  shared KB ──clone──► read-only snapshot              │
//!        │      ▲                    │ (same snapshot for every  │
//!        │      │                    │  task of the epoch)       │
//!        │      │        ┌───────────┼───────────┐               │
//!        │      │     worker 0    worker 1 …  worker W-1         │
//!        │      │     (own VerifyCache, own RNG streams, own     │
//!        │      │      interpreter arenas — no shared mutable    │
//!        │      │      state; tasks pulled from a shared queue)  │
//!        │      │        │           │           │               │
//!        │      │     optimize_task_delta: clone snapshot, run   │
//!        │      │     the unmodified driver loop, extract a      │
//!        │      │     KbDelta of the evidence the run added      │
//!        │      │        └───────────┼───────────┘               │
//!        │      │                    ▼                           │
//!        │      └── committer: lifecycle::apply_delta in TASK    │
//!        │          ORDER (epoch order), one delta at a time     │
//!        └───────────────────────────────────────────────────────┘
//! ```
//!
//! # Determinism contract
//!
//! `fleet(batch)` is bit-identical to `sequential(batch)` — the same
//! epoch/snapshot/commit pipeline executed serially — for **any** worker
//! count, the same contract the driver's `parallel_explore` established
//! for in-step exploration (see [`crate::icrl::driver`] §Perf):
//!
//! - each task's [`TaskRun`] is a pure function of (task, arch, config,
//!   global task index, epoch snapshot) — never of which worker ran it
//!   or in what order workers finished;
//! - deltas commit in task order, and [`lifecycle::apply_delta`] is
//!   deterministic, so the shared KB after every epoch is worker-count
//!   invariant;
//! - with `epoch_size == 1` the pipeline degenerates to the sequential
//!   driver exactly: one delta per epoch applies to its own base, which
//!   [`lifecycle::apply_delta`] replays bit-identically — the final KB
//!   and every `TaskRun` equal [`crate::icrl::run_suite`]'s.
//!
//! `tests/fleet.rs` asserts all three (workers ∈ {1, 2, 8}; serialized
//! KB bytes compared).
//!
//! `epoch_size` trades shared-knowledge freshness for parallelism: tasks
//! within an epoch cannot see each other's discoveries (they all read
//! the epoch snapshot), so larger epochs mean more concurrency but
//! staler retrieval. Worker count never changes results — only wall
//! clock. `experiments/fleet.rs` measures the throughput side
//! (tasks/min) and the KB-quality parity, emitting `BENCH_fleet.json`.
//!
//! The search policy rides per-**epoch**: by default every epoch runs
//! the batch's [`IcrlConfig::policy`] (`kernelblaster batch --policy`,
//! or the config file's `policy` section), and
//! [`FleetConfig::epoch_policies`] can schedule a *mix* across epochs —
//! explore-heavy policies while the shared KB is cold, exploit-heavy
//! ones once it has evidence (`--epoch-policies`, saturating at the
//! last entry). Within one epoch every task runs the same policy:
//! mixing *within* an epoch would make its deltas' evidence populations
//! incomparable. The determinism contract is policy-independent (each
//! `TaskRun` is still a pure function of task, arch, epoch config,
//! global task index, and the epoch snapshot, and the epoch's policy is
//! a pure function of the epoch index); `tests/policy.rs` anchors the
//! default-policy fleet against the pre-policy sequential driver
//! bit-for-bit, and `tests/fleet.rs` pins the epoch mix's worker-count
//! invariance.
//!
//! # Sharded pipelined committer ([`FleetConfig::shards`] > 1)
//!
//! The single committer above serializes every KB commit. With
//! `shards > 1` the commit side runs as a pipeline instead
//! ([`crate::icrl::shard`]): workers stream finished tasks to a
//! sequencer over a bounded channel, the sequencer splits each delta by
//! a deterministic [`crate::kb::StateSig`] hash and routes the parts to
//! per-shard committer threads, and each committer folds its shard's
//! parts (and journals them to its own [`ShardSegment`]) in task order.
//! Because [`lifecycle::apply_delta`] treats states independently, the
//! per-shard folds compose back into the single-committer KB
//! byte-for-byte — `shards = 1` runs this module's classic path
//! unchanged, and `tests/fleet.rs` pins saved-KB-bytes invariance
//! across workers × shards. Counters land in [`FleetOutcome::shard`].
//!
//! # Durability (the [`Store`] trait)
//!
//! The committer persists through a [`Store`]: after each delta is
//! folded into the shared KB, `store.commit(&delta, kb)` runs — still
//! in task order, so durability inherits the determinism contract.
//! (On the sharded path the same backends persist through the trait's
//! epoch hooks — [`Store::begin_epoch`] / [`Store::commit_unsegmented`]
//! / [`Store::end_epoch`] — with cadence work landing on epoch
//! boundaries; a store failure there surfaces after the epoch, leaving
//! the in-memory KB at the last epoch boundary rather than the last
//! committed task.) Three backends:
//!
//! - [`NullStore`] — no persistence (the default for `run_fleet` /
//!   `run_fleet_observed` / `run_fleet_memo`, preserving their exact
//!   pre-trait behavior);
//! - [`WholeFileStore`] — the classic batch discipline: rewrite the
//!   full `kernelblaster-kb-v1` document via [`checkpoint_atomic`]
//!   every `every` commits (`kernelblaster batch --checkpoint-every`);
//! - [`crate::kb::store::LogStore`] — the log-structured serving
//!   engine: O(delta) journal appends plus periodic compacted
//!   snapshots (`kernelblaster serve`).
//!
//! [`checkpoint_atomic`] writes the full document to `<file>.tmp` in
//! the target directory and atomically renames it over the
//! destination, so a crash mid-write can never leave a torn KB —
//! readers observe either the previous checkpoint or the new one,
//! nothing in between. All persistence failures surface as one type,
//! [`PersistError`].
//!
//! # Tenancy (who calls this, with what)
//!
//! The fleet is **tenant-blind**: one call = one KB = one store. The
//! serving daemon's multi-tenant layer ([`crate::serve`] §Tenancy)
//! routes each admitted request to a per-tenant KB and per-tenant
//! [`Store`] *before* invoking the fleet, so everything above — the
//! determinism contract, commit order, store cadence — holds per
//! tenant independently. Nothing here ever sees two tenants' evidence
//! in one batch, which is precisely what makes a tenant's KB bytes
//! identical to a solo run's (`tests/serve.rs` pins this).

use super::driver::{
    optimize_task_delta_verified, optimize_task_verified, IcrlConfig, KbMode, TaskRun,
};
use super::policy::{PolicyConfig, PolicyKind};
use super::shard::{self, ShardMetrics};
use crate::gpu::GpuArch;
use crate::harness::memo::{MemoDelta, VerifyMemo};
use crate::harness::staged::TierStats;
use crate::harness::VerifyCache;
use crate::kb::lifecycle::{self, KbDelta};
use crate::kb::persist::PersistError;
use crate::kb::store::{LogStore, ShardSegment};
use crate::kb::{persist, KnowledgeBase};
use crate::tasks::Task;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Fleet scheduling knobs ([`crate::config::RunConfig`] plumbs these
/// from the `fleet` section of a run config; `kernelblaster batch`
/// exposes them as flags).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Worker threads serving each epoch (≥ 1). Never affects results —
    /// only throughput.
    pub workers: usize,
    /// Tasks per epoch (≥ 1): every task of an epoch reads the same
    /// shared-KB snapshot, so this bounds both the available concurrency
    /// and the staleness of retrieval. `1` reproduces the sequential
    /// driver exactly.
    pub epoch_size: usize,
    /// Checkpoint the shared KB every N commits (0 = never). A commit is
    /// one task's delta folded into the shared KB.
    pub checkpoint_every: usize,
    /// Per-epoch search-policy mix: epoch `e` (0-based) runs
    /// `epoch_policies[e]`, saturating at the last entry — so
    /// `[explore, explore, exploit]` means two explore-heavy epochs and
    /// then exploit for the rest of the batch. Empty (the default) runs
    /// the batch's [`IcrlConfig::policy`] in every epoch, byte-identical
    /// to the pre-mix fleet. Within one epoch every task still runs the
    /// same policy (mixing *within* an epoch would make its deltas'
    /// evidence populations incomparable), and the worker-count
    /// determinism contract is untouched: the epoch's policy is a pure
    /// function of the epoch index, never of worker scheduling.
    pub epoch_policies: Vec<PolicyConfig>,
    /// Auto-tune the per-epoch policy from KB maturity instead of a
    /// hand-written mix (`fleet.epoch_policies: "auto"` in a run config):
    /// each epoch reads the shared KB's untried-entry ratio
    /// ([`lifecycle::stats`]) at commit-boundary time and picks
    /// explore-heavy policies while most entries are unexplored,
    /// settling on the batch's base policy once evidence has
    /// accumulated (see [`auto_epoch_policy`]). Takes precedence over
    /// `epoch_policies` when both are set. The choice is a pure function
    /// of the epoch-start KB, so worker-count invariance is untouched.
    pub auto_epoch_policies: bool,
    /// KB shards (≥ 1): partition the shared KB by a deterministic hash
    /// of [`crate::kb::StateSig`] into this many shards, each with its
    /// own committer thread, so commits to different shards proceed in
    /// parallel (see [`crate::icrl::shard`]). `1` (the default) runs the
    /// classic single-committer pipeline; any value is bit-identical in
    /// results and saved-KB bytes — like `workers`, the knob only moves
    /// wall clock.
    pub shards: usize,
    /// Bound of each pipeline queue in the sharded path (≥ 1): the
    /// worker → sequencer results channel and every sequencer →
    /// committer channel hold at most this many in-flight messages; a
    /// full queue blocks the sender (backpressure, counted in
    /// [`ShardMetrics::commit_waits`]). Ignored when `shards == 1`.
    pub commit_queue: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            epoch_size: 8,
            checkpoint_every: 0,
            epoch_policies: Vec::new(),
            auto_epoch_policies: false,
            shards: 1,
            commit_queue: 8,
        }
    }
}

impl FleetConfig {
    /// The search policy epoch `epoch` (0-based) runs: the epoch-mix
    /// entry for that index, saturating at the last configured entry, or
    /// `base` (the batch's [`IcrlConfig::policy`]) when no mix is set.
    pub fn policy_for_epoch(&self, epoch: usize, base: &PolicyConfig) -> PolicyConfig {
        match self.epoch_policies.last() {
            None => base.clone(),
            Some(last) => self.epoch_policies.get(epoch).unwrap_or(last).clone(),
        }
    }
}

/// The maturity-driven epoch policy (`fleet.epoch_policies: "auto"`):
/// derive the next epoch's search policy from how much of the shared KB
/// is still unexplored. A mostly-untried KB (> 50% entries without
/// attempts — including the empty cold-start KB) explores with
/// ε-greedy; a partially-explored one (> 20% untried) balances with the
/// UCB bandit; a mature KB runs the batch's base policy (exploit what
/// the evidence says). Pure function of the KB passed in, so calling it
/// at epoch-commit boundaries keeps the fleet's worker-count-invariance
/// contract intact.
pub fn auto_epoch_policy(kb: &KnowledgeBase, base: &PolicyConfig) -> PolicyConfig {
    let st = lifecycle::stats(kb);
    let untried_ratio = if st.entries == 0 {
        1.0
    } else {
        st.untried as f64 / st.entries as f64
    };
    if untried_ratio > 0.5 {
        PolicyConfig::of_kind(PolicyKind::EpsilonGreedy)
    } else if untried_ratio > 0.2 {
        PolicyConfig::of_kind(PolicyKind::UcbBandit)
    } else {
        base.clone()
    }
}

/// What a fleet run produced, beyond the shared KB mutation.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetOutcome {
    /// Per-task results, in task-list order (same order as
    /// [`crate::icrl::run_suite`]).
    pub runs: Vec<TaskRun>,
    /// Epochs executed.
    pub epochs: usize,
    /// Deltas committed into the shared KB (0 in
    /// [`KbMode::EphemeralPerTask`]).
    pub commits: usize,
    /// Aggregated staged-verification activity across every task of the
    /// batch (all-zero when `verify.staged` is off).
    pub tiers: TierStats,
    /// Sharded-pipeline counters ([`crate::icrl::shard`]): sub-commits
    /// routed, backpressure waits, and queue high-water. On the classic
    /// single-committer path (`FleetConfig::shards == 1`) this is
    /// `ShardMetrics { shards: 1, .. }` with zero counters.
    pub shard: ShardMetrics,
}

/// Progress hooks for streaming consumers (the `batch` CLI command
/// streams JSON-lines and checkpoints from these). Default
/// implementations do nothing.
pub trait FleetObserver {
    /// Task `index` (position in the task list) finished and — in
    /// persistent mode — its delta has been committed.
    fn task_done(&mut self, _index: usize, _run: &TaskRun) {}

    /// An epoch's deltas have all been folded in. `commits` is the
    /// running total; `kb` is the shared KB after the fold.
    fn epoch_committed(&mut self, _epoch: usize, _commits: usize, _kb: &KnowledgeBase) {}
}

/// The do-nothing observer for callers that only want [`FleetOutcome`].
pub struct NullObserver;

impl FleetObserver for NullObserver {}

/// Durability backend for the committer (see the module docs
/// §Durability). `commit` runs after every task delta is folded into
/// the shared KB — in task order, so whatever a backend persists is
/// worker-count invariant; `flush` is the end-of-run / shutdown hook.
pub trait Store {
    /// Persist one committed delta. `kb_after` is the shared KB with
    /// the delta already folded in (what a snapshotting backend saves).
    fn commit(&mut self, delta: &KbDelta, kb_after: &KnowledgeBase) -> Result<(), PersistError>;

    /// Persist the full KB unconditionally (end of run, shutdown).
    fn flush(&mut self, kb: &KnowledgeBase) -> Result<(), PersistError>;

    /// Sharded-committer hook ([`crate::icrl::shard`]): hand out one
    /// journal segment per shard for the epoch about to run, plus the
    /// first sequence number the epoch's journaled commits will use.
    /// Committer threads append delta *parts* to their segment
    /// concurrently; the fleet calls [`Store::end_epoch`] once the
    /// epoch's borrow ends. The default (`None`, every backend without
    /// per-shard segments — and a [`LogStore`] whose on-disk layout
    /// doesn't match `shards`) makes the sharded fleet journal nothing
    /// during the epoch and replay each committed delta through
    /// [`Store::commit_unsegmented`] at the epoch boundary instead.
    fn begin_epoch(&mut self, _shards: usize) -> Option<(&mut [ShardSegment], u64)> {
        None
    }

    /// Epoch-boundary fallback commit for backends that returned `None`
    /// from [`Store::begin_epoch`]: called once per non-empty committed
    /// delta, in task order, after the epoch's KB is assembled. The
    /// default does nothing ([`NullStore`]); [`LogStore`] appends a
    /// classic whole-delta journal record; [`WholeFileStore`] counts the
    /// commit toward its checkpoint cadence.
    fn commit_unsegmented(&mut self, _delta: &KbDelta) -> Result<(), PersistError> {
        Ok(())
    }

    /// Sharded-committer hook: the epoch is fully committed and `kb` is
    /// the assembled shared KB. `commits` is this epoch's committed-delta
    /// count; `journaled` is how many of them consumed a journal
    /// sequence number through segments (0 on the
    /// [`Store::commit_unsegmented`] path, where appends count
    /// themselves). Backends fold segment counters and run their
    /// cadence work (checkpoint / snapshot) here — which is why, on the
    /// sharded path, durability cadences land on epoch boundaries
    /// rather than mid-epoch.
    fn end_epoch(
        &mut self,
        _kb: &KnowledgeBase,
        _commits: usize,
        _journaled: u64,
    ) -> Result<(), PersistError> {
        Ok(())
    }
}

/// The no-persistence backend: callers that save the KB themselves
/// afterwards (or not at all). Never fails.
pub struct NullStore;

impl Store for NullStore {
    fn commit(&mut self, _delta: &KbDelta, _kb: &KnowledgeBase) -> Result<(), PersistError> {
        Ok(())
    }

    fn flush(&mut self, _kb: &KnowledgeBase) -> Result<(), PersistError> {
        Ok(())
    }
}

/// The whole-file backend: rewrite the full `kernelblaster-kb-v1`
/// document ([`checkpoint_atomic`]) every `every` commits — the batch
/// CLI's historical checkpoint discipline, now expressed as a
/// [`Store`]. O(KB) per checkpoint, which is exactly why the serving
/// path uses [`LogStore`] instead.
pub struct WholeFileStore {
    /// Checkpoint destination.
    pub path: PathBuf,
    /// Checkpoint cadence in commits (0 = only on [`Store::flush`]).
    pub every: usize,
    /// Degrade checkpoint failures to a stderr warning instead of
    /// aborting the batch (the CLI's resilience contract: a full disk
    /// mid-batch loses a checkpoint, not the run). `flush` still
    /// fails hard.
    pub fail_soft: bool,
    /// Announce successful checkpoints on stderr (the CLI's
    /// `checkpointed KB at …` progress lines).
    pub verbose: bool,
    commits: usize,
    last_ckpt: usize,
    checkpoints: usize,
}

impl WholeFileStore {
    /// Backend writing to `path` every `every` commits, quiet and
    /// fail-hard.
    pub fn new(path: impl Into<PathBuf>, every: usize) -> Self {
        WholeFileStore {
            path: path.into(),
            every,
            fail_soft: false,
            verbose: false,
            commits: 0,
            last_ckpt: 0,
            checkpoints: 0,
        }
    }

    /// Checkpoints written so far (cadence + flushes).
    pub fn checkpoints(&self) -> usize {
        self.checkpoints
    }
}

impl Store for WholeFileStore {
    fn commit(&mut self, _delta: &KbDelta, kb_after: &KnowledgeBase) -> Result<(), PersistError> {
        self.commits += 1;
        if self.every == 0 || self.commits - self.last_ckpt < self.every {
            return Ok(());
        }
        match checkpoint_atomic(kb_after, &self.path) {
            Ok(()) => {
                self.last_ckpt = self.commits;
                self.checkpoints += 1;
                if self.verbose {
                    eprintln!(
                        "checkpointed KB at {} ({} commits)",
                        self.path.display(),
                        self.commits
                    );
                }
                Ok(())
            }
            Err(e) if self.fail_soft => {
                eprintln!("warning: checkpoint failed: {e}");
                Ok(())
            }
            Err(e) => Err(e),
        }
    }

    fn flush(&mut self, kb: &KnowledgeBase) -> Result<(), PersistError> {
        checkpoint_atomic(kb, &self.path)?;
        self.last_ckpt = self.commits;
        self.checkpoints += 1;
        Ok(())
    }

    /// Sharded path: fold the epoch's full commit count (the classic
    /// `commit` counts every commit, empty deltas included, so cadence
    /// parity needs the epoch total — [`Store::commit_unsegmented`]
    /// only sees non-empty deltas) and run the cadence checkpoint
    /// against the assembled KB.
    fn end_epoch(
        &mut self,
        kb: &KnowledgeBase,
        commits: usize,
        _journaled: u64,
    ) -> Result<(), PersistError> {
        self.commits += commits;
        if self.every == 0 || self.commits - self.last_ckpt < self.every {
            return Ok(());
        }
        match checkpoint_atomic(kb, &self.path) {
            Ok(()) => {
                self.last_ckpt = self.commits;
                self.checkpoints += 1;
                if self.verbose {
                    eprintln!(
                        "checkpointed KB at {} ({} commits)",
                        self.path.display(),
                        self.commits
                    );
                }
                Ok(())
            }
            Err(e) if self.fail_soft => {
                eprintln!("warning: checkpoint failed: {e}");
                Ok(())
            }
            Err(e) => Err(e),
        }
    }
}

impl Store for LogStore {
    /// Journal the delta (skipping empty ones — nothing to replay) and
    /// compact on the store's snapshot cadence.
    fn commit(&mut self, delta: &KbDelta, kb_after: &KnowledgeBase) -> Result<(), PersistError> {
        if delta.is_empty() {
            return Ok(());
        }
        self.append(delta)?;
        self.maybe_snapshot(kb_after)?;
        Ok(())
    }

    fn flush(&mut self, kb: &KnowledgeBase) -> Result<(), PersistError> {
        self.snapshot(kb)
    }

    /// Hand out the per-shard journal segments when the on-disk layout
    /// matches the fleet's shard count (see
    /// [`LogStore::epoch_segments`]); otherwise fall back to
    /// epoch-boundary whole-delta appends.
    fn begin_epoch(&mut self, shards: usize) -> Option<(&mut [ShardSegment], u64)> {
        self.epoch_segments(shards)
    }

    fn commit_unsegmented(&mut self, delta: &KbDelta) -> Result<(), PersistError> {
        if delta.is_empty() {
            return Ok(());
        }
        self.append(delta)?;
        Ok(())
    }

    fn end_epoch(
        &mut self,
        kb: &KnowledgeBase,
        _commits: usize,
        journaled: u64,
    ) -> Result<(), PersistError> {
        self.fold_epoch(journaled);
        self.maybe_snapshot(kb)?;
        Ok(())
    }
}

/// Run a batch through the fleet pipeline. See the module docs for the
/// dataflow and the determinism contract; per-task `run_seed`s are the
/// global task indices, matching [`crate::icrl::run_suite`].
pub fn run_fleet(
    tasks: &[&Task],
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    fleet: &FleetConfig,
) -> FleetOutcome {
    run_fleet_observed(tasks, arch, kb, cfg, fleet, &mut NullObserver)
}

/// [`run_fleet`] with progress hooks.
pub fn run_fleet_observed(
    tasks: &[&Task],
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    fleet: &FleetConfig,
    obs: &mut dyn FleetObserver,
) -> FleetOutcome {
    run_fleet_core(tasks, arch, kb, cfg, fleet, None, &mut NullStore, obs)
        .expect("null store never fails")
}

/// [`run_fleet_observed`] plus the persistent verify memo
/// ([`crate::harness::staged`]): `memo` is read as each epoch's
/// snapshot-in and grown by task-ordered delta commits — exactly the
/// shared KB's discipline, so saved memo bytes are worker-count
/// invariant (`tests/staged.rs`). With `verify.staged` off the memo is
/// left untouched.
pub fn run_fleet_memo(
    tasks: &[&Task],
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    fleet: &FleetConfig,
    memo: &mut VerifyMemo,
    obs: &mut dyn FleetObserver,
) -> FleetOutcome {
    run_fleet_core(tasks, arch, kb, cfg, fleet, Some(memo), &mut NullStore, obs)
        .expect("null store never fails")
}

/// The full committer: [`run_fleet_memo`]'s pipeline persisting through
/// an arbitrary [`Store`] backend. `store.commit` runs after each delta
/// is folded in (task order — durability inherits the determinism
/// contract); a store failure aborts the batch with the error, leaving
/// the in-memory KB at the last committed task. The store is *not*
/// flushed — callers own the end-of-run flush (the batch CLI's final
/// save, the serve daemon's shutdown snapshot).
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_store(
    tasks: &[&Task],
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    fleet: &FleetConfig,
    memo: Option<&mut VerifyMemo>,
    store: &mut dyn Store,
    obs: &mut dyn FleetObserver,
) -> Result<FleetOutcome, PersistError> {
    run_fleet_core(tasks, arch, kb, cfg, fleet, memo, store, obs)
}

#[allow(clippy::too_many_arguments)]
fn run_fleet_core(
    tasks: &[&Task],
    arch: &GpuArch,
    kb: &mut KnowledgeBase,
    cfg: &IcrlConfig,
    fleet: &FleetConfig,
    mut memo: Option<&mut VerifyMemo>,
    store: &mut dyn Store,
    obs: &mut dyn FleetObserver,
) -> Result<FleetOutcome, PersistError> {
    if fleet.shards > 1 {
        // The sharded pipelined committer: same epoch/snapshot/commit
        // protocol, with deltas split by StateSig hash across per-shard
        // committer threads. Bit-identical by the associativity argument
        // in its module docs; `shards <= 1` stays on this path so the
        // classic fleet is untouched code, not just untouched behavior.
        return shard::run_fleet_sharded(tasks, arch, kb, cfg, fleet, memo, store, obs);
    }
    let epoch_size = fleet.epoch_size.max(1);
    let workers = fleet.workers.max(1);
    let ephemeral = cfg.kb_mode == KbMode::EphemeralPerTask;
    let mut runs: Vec<TaskRun> = Vec::with_capacity(tasks.len());
    let mut epochs = 0usize;
    let mut commits = 0usize;
    let mut offset = 0usize;
    let mut tiers = TierStats::default();
    for (epoch_idx, chunk) in tasks.chunks(epoch_size).enumerate() {
        // Policy-aware scheduling: the epoch's policy comes from the
        // KB-maturity autotuner or the per-epoch mix (pure functions of
        // the epoch-start KB / the epoch index — results stay
        // worker-count invariant). With neither configured this clones
        // the batch config unchanged.
        let epoch_policy = if fleet.auto_epoch_policies {
            auto_epoch_policy(kb, &cfg.policy)
        } else {
            fleet.policy_for_epoch(epoch_idx, &cfg.policy)
        };
        let epoch_cfg = IcrlConfig {
            policy: epoch_policy,
            ..cfg.clone()
        };
        let results = epoch_results(&EpochJob {
            chunk,
            offset,
            arch,
            snapshot: kb,
            cfg: &epoch_cfg,
            workers,
            ephemeral,
            memo: memo.as_deref(),
        });
        // Lineage lines observed on this epoch's shared snapshot: every
        // worker of the epoch sees the same snapshot, so a condition
        // (e.g. the mixed-arch audit flag) is reported once per epoch,
        // matching the once-per-transition behavior of the sequential
        // driver. With one task per epoch nothing is stripped — deltas
        // replay verbatim.
        let mut epoch_lines: Vec<String> = Vec::new();
        for (i, res) in results.into_iter().enumerate() {
            let TaskResult {
                run,
                mut delta,
                memo: mdelta,
                tiers: t,
            } = res;
            if !ephemeral {
                delta.lineage_added.retain(|l| !epoch_lines.contains(l));
                epoch_lines.extend(delta.lineage_added.iter().cloned());
                lifecycle::apply_delta(kb, &delta);
                commits += 1;
                // Persist the exact delta that was folded in (after the
                // lineage strip), so a journaling backend's replay
                // repeats this commit verbatim.
                store.commit(&delta, kb)?;
            }
            // Memo verdicts commit in task order regardless of KB mode —
            // verification truths are mode-independent. Insert-or-ignore
            // over deterministic verdicts makes the merged contents
            // independent of epoch partitioning and worker count.
            if let Some(m) = memo.as_deref_mut() {
                m.apply_delta(&mdelta);
            }
            tiers.add(&t);
            obs.task_done(offset + i, &run);
            runs.push(run);
        }
        epochs += 1;
        obs.epoch_committed(epochs, commits, kb);
        offset += chunk.len();
    }
    Ok(FleetOutcome {
        runs,
        epochs,
        commits,
        tiers,
        shard: ShardMetrics {
            shards: 1,
            ..Default::default()
        },
    })
}

/// One epoch's inputs, bundled: the task chunk, its global offset, the
/// epoch-shared snapshots (KB and verify memo), and the serving knobs.
pub(crate) struct EpochJob<'a> {
    pub(crate) chunk: &'a [&'a Task],
    pub(crate) offset: usize,
    pub(crate) arch: &'a GpuArch,
    pub(crate) snapshot: &'a KnowledgeBase,
    pub(crate) cfg: &'a IcrlConfig,
    pub(crate) workers: usize,
    pub(crate) ephemeral: bool,
    /// Verify-memo snapshot shared by every task of the epoch (same
    /// staleness contract as the KB snapshot).
    pub(crate) memo: Option<&'a VerifyMemo>,
}

/// What one task's serving produced: the run, the KB evidence delta, the
/// verify-memo delta, and the tier counters.
pub(crate) struct TaskResult {
    pub(crate) run: TaskRun,
    pub(crate) delta: KbDelta,
    pub(crate) memo: MemoDelta,
    pub(crate) tiers: TierStats,
}

/// Serve task `i` of an epoch — the one per-task function both fleet
/// paths run (the classic pool here, the sharded pipeline in
/// [`crate::icrl::shard`]), so their results are identical by
/// construction. Pure in everything but `cache` (a per-worker memo).
pub(crate) fn serve_epoch_task(
    job: &EpochJob<'_>,
    i: usize,
    cache: &mut VerifyCache,
) -> TaskResult {
    let run_seed = (job.offset + i) as u64;
    if job.ephemeral {
        // The ablation arm starts every task cold and discards the
        // KB, exactly as run_suite's EphemeralPerTask does — no
        // delta to extract, nothing to commit.
        let mut scratch = KnowledgeBase::empty();
        let (run, mdelta, tiers) = optimize_task_verified(
            job.chunk[i],
            job.arch,
            &mut scratch,
            job.cfg,
            run_seed,
            cache,
            job.memo,
        );
        TaskResult {
            run,
            delta: KbDelta::empty(),
            memo: mdelta,
            tiers,
        }
    } else {
        let (run, delta, mdelta, tiers) = optimize_task_delta_verified(
            job.chunk[i],
            job.arch,
            job.snapshot,
            job.cfg,
            run_seed,
            cache,
            job.memo,
        );
        TaskResult {
            run,
            delta,
            memo: mdelta,
            tiers,
        }
    }
}

/// Serve one epoch: the chunk's tasks against a single snapshot, over a
/// pool of `workers` threads pulling from a shared queue. Results come
/// back in task order regardless of completion order.
fn epoch_results(job: &EpochJob<'_>) -> Vec<TaskResult> {
    let n = job.chunk.len();
    let serve_one = |i: usize, cache: &mut VerifyCache| serve_epoch_task(job, i, cache);
    if job.workers <= 1 || n <= 1 {
        // Thread-free serial path (also the profiling-friendly mode).
        let mut cache = VerifyCache::new();
        return (0..n).map(|i| serve_one(i, &mut cache)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<TaskResult>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..job.workers.min(n))
            .map(|_| {
                scope.spawn(|| {
                    // §Perf: one verification cache per worker, reused
                    // across every task this worker serves (idempotent
                    // warm, keyed by task id) — see harness docs.
                    let mut cache = VerifyCache::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let out = serve_one(i, &mut cache);
                        *slots[i].lock().expect("slot lock") = Some(out);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("fleet worker panicked");
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("slot lock")
                .expect("every epoch slot is filled before the scope ends")
        })
        .collect()
}

/// Crash-safe KB checkpoint: write the serialized document to a `.tmp`
/// sibling, then atomically rename it over `path`. On any error the
/// previous checkpoint (if one exists) is left untouched. Errors carry
/// their step context as [`PersistError::Store`] — the unified
/// persistence error surface (see [`crate::kb::persist`]).
pub fn checkpoint_atomic(kb: &KnowledgeBase, path: &Path) -> Result<(), PersistError> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| PersistError::Store(format!("mkdir: {e}")))?;
        }
    }
    let mut tmp_name = path.file_name().map(|f| f.to_os_string()).ok_or_else(|| {
        PersistError::Store(format!("checkpoint path has no file name: {}", path.display()))
    })?;
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    std::fs::write(&tmp, persist::to_json(kb).to_string_pretty())
        .map_err(|e| PersistError::Store(format!("write {}: {e}", tmp.display())))?;
    std::fs::rename(&tmp, path).map_err(|e| {
        PersistError::Store(format!("rename {} -> {}: {e}", tmp.display(), path.display()))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::HarnessConfig;
    use crate::tasks::Suite;

    fn quick_cfg() -> IcrlConfig {
        IcrlConfig {
            trajectories: 2,
            rollout_steps: 3,
            top_k: 2,
            harness: HarnessConfig {
                noise_sigma: 0.0,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn fleet_runs_batch_in_task_order() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/01_matmul_square").unwrap(),
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
        ];
        let arch = GpuArch::h100();
        let mut kb = KnowledgeBase::empty();
        let fleet = FleetConfig {
            workers: 2,
            epoch_size: 2,
            checkpoint_every: 0,
            ..Default::default()
        };
        let out = run_fleet(&tasks, &arch, &mut kb, &quick_cfg(), &fleet);
        assert_eq!(out.runs.len(), 3);
        assert_eq!(out.epochs, 2);
        assert_eq!(out.commits, 3);
        for (t, r) in tasks.iter().zip(&out.runs) {
            assert_eq!(t.id, r.task_id);
        }
        assert!(kb.total_attempts() > 0);
        assert_eq!(kb.arch.as_deref(), Some("H100"));
    }

    #[test]
    fn ephemeral_mode_leaves_shared_kb_untouched() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![suite.by_id("L1/15_relu").unwrap()];
        let arch = GpuArch::a100();
        let mut kb = KnowledgeBase::empty();
        let cfg = IcrlConfig {
            kb_mode: KbMode::EphemeralPerTask,
            ..quick_cfg()
        };
        let out = run_fleet(&tasks, &arch, &mut kb, &cfg, &FleetConfig::default());
        assert_eq!(out.commits, 0);
        assert!(kb.states.is_empty());
        assert_eq!(kb.total_attempts(), 0);
        assert!(out.runs[0].valid);
    }

    #[test]
    fn observer_sees_every_task_and_epoch() {
        struct Spy {
            tasks: Vec<usize>,
            epochs: Vec<(usize, usize)>,
        }
        impl FleetObserver for Spy {
            fn task_done(&mut self, index: usize, _run: &TaskRun) {
                self.tasks.push(index);
            }
            fn epoch_committed(&mut self, epoch: usize, commits: usize, _kb: &KnowledgeBase) {
                self.epochs.push((epoch, commits));
            }
        }
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/01_matmul_square").unwrap(),
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
        ];
        let arch = GpuArch::l40s();
        let mut kb = KnowledgeBase::empty();
        let mut spy = Spy {
            tasks: vec![],
            epochs: vec![],
        };
        let fleet = FleetConfig {
            workers: 2,
            epoch_size: 2,
            checkpoint_every: 0,
            ..Default::default()
        };
        let _ = run_fleet_observed(&tasks, &arch, &mut kb, &quick_cfg(), &fleet, &mut spy);
        assert_eq!(spy.tasks, vec![0, 1, 2]);
        assert_eq!(spy.epochs, vec![(1, 2), (2, 3)]);
    }

    #[test]
    fn policy_for_epoch_saturates_at_the_last_mix_entry() {
        use crate::icrl::policy::PolicyKind;
        let base = PolicyConfig::default();
        // No mix: every epoch runs the batch policy.
        let plain = FleetConfig::default();
        for e in 0..4 {
            assert_eq!(plain.policy_for_epoch(e, &base), base);
        }
        // Mix: explore-heavy first, then exploit for the rest.
        let explore = PolicyConfig::of_kind(PolicyKind::EpsilonGreedy);
        let exploit = PolicyConfig::of_kind(PolicyKind::UcbBandit);
        let mixed = FleetConfig {
            epoch_policies: vec![explore.clone(), explore.clone(), exploit.clone()],
            ..Default::default()
        };
        assert_eq!(mixed.policy_for_epoch(0, &base), explore);
        assert_eq!(mixed.policy_for_epoch(1, &base), explore);
        assert_eq!(mixed.policy_for_epoch(2, &base), exploit);
        assert_eq!(mixed.policy_for_epoch(99, &base), exploit, "saturates");
    }

    #[test]
    fn epoch_mix_runs_each_epoch_under_its_scheduled_policy() {
        use crate::icrl::policy::PolicyKind;
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/01_matmul_square").unwrap(),
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
            suite.by_id("L2/01_gemm_bias_relu").unwrap(),
        ];
        let arch = GpuArch::h100();
        let cfg = quick_cfg();
        // Epochs of 2 → epoch 0 explores (ε-greedy), epoch 1 exploits
        // (UCB). Reproducibility first, then the exactness anchor: with
        // epoch_size = 1 the mix degenerates to the sequential driver
        // run task-by-task under the matching per-epoch policy.
        let mix = vec![
            PolicyConfig::of_kind(PolicyKind::EpsilonGreedy),
            PolicyConfig::of_kind(PolicyKind::UcbBandit),
        ];
        let fleet_cfg = FleetConfig {
            workers: 2,
            epoch_size: 2,
            checkpoint_every: 0,
            epoch_policies: mix.clone(),
            ..Default::default()
        };
        let mut kb1 = KnowledgeBase::empty();
        let out1 = run_fleet(&tasks, &arch, &mut kb1, &cfg, &fleet_cfg);
        let mut kb2 = KnowledgeBase::empty();
        let out2 = run_fleet(&tasks, &arch, &mut kb2, &cfg, &fleet_cfg);
        assert_eq!(out1.runs, out2.runs, "mixed-epoch fleet not reproducible");
        assert_eq!(kb1, kb2);
        assert_eq!(out1.epochs, 2);
        // epoch_size=1 mix == the sequential driver run with the same
        // per-epoch (here per-task) policy schedule, bit for bit.
        let e1 = FleetConfig {
            workers: 2,
            epoch_size: 1,
            checkpoint_every: 0,
            epoch_policies: mix.clone(),
            ..Default::default()
        };
        let mut kb_fleet = KnowledgeBase::empty();
        let out_e1 = run_fleet(&tasks, &arch, &mut kb_fleet, &cfg, &e1);
        let mut kb_seq = KnowledgeBase::empty();
        let mut seq_runs = Vec::new();
        for (i, task) in tasks.iter().enumerate() {
            let task_cfg = IcrlConfig {
                policy: e1.policy_for_epoch(i, &cfg.policy),
                ..cfg.clone()
            };
            seq_runs.push(crate::icrl::optimize_task(
                task, &arch, &mut kb_seq, &task_cfg, i as u64,
            ));
        }
        assert_eq!(out_e1.runs, seq_runs, "epoch=1 mix diverged from sequential");
        assert_eq!(kb_fleet, kb_seq);
    }

    #[test]
    fn auto_epoch_policy_tracks_kb_maturity() {
        let base = PolicyConfig::default();
        // A cold KB (no entries at all) must explore.
        assert_eq!(
            auto_epoch_policy(&KnowledgeBase::empty(), &base).kind,
            PolicyKind::EpsilonGreedy
        );
        // Grown evidence: the choice must agree with the stats ratio.
        let suite = Suite::full();
        let task = suite.by_id("L2/01_gemm_bias_relu").unwrap();
        let arch = GpuArch::h100();
        let mut kb = KnowledgeBase::empty();
        let _ = crate::icrl::optimize_task(task, &arch, &mut kb, &quick_cfg(), 0);
        let st = lifecycle::stats(&kb);
        assert!(st.entries > 0);
        let ratio = st.untried as f64 / st.entries as f64;
        let got = auto_epoch_policy(&kb, &base).kind;
        if ratio > 0.5 {
            assert_eq!(got, PolicyKind::EpsilonGreedy);
        } else if ratio > 0.2 {
            assert_eq!(got, PolicyKind::UcbBandit);
        } else {
            assert_eq!(got, base.kind);
        }
        // A fully-attempted KB exploits with the base policy.
        let mut mature = kb.clone();
        for s in &mut mature.states {
            for o in &mut s.opts {
                o.attempts = o.attempts.max(1);
            }
        }
        assert_eq!(auto_epoch_policy(&mature, &base).kind, base.kind);
    }

    #[test]
    fn auto_epoch_fleet_is_reproducible() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/01_matmul_square").unwrap(),
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
        ];
        let arch = GpuArch::h100();
        let fleet = FleetConfig {
            workers: 2,
            epoch_size: 1,
            auto_epoch_policies: true,
            ..Default::default()
        };
        let mut kb1 = KnowledgeBase::empty();
        let out1 = run_fleet(&tasks, &arch, &mut kb1, &quick_cfg(), &fleet);
        let mut kb2 = KnowledgeBase::empty();
        let out2 = run_fleet(&tasks, &arch, &mut kb2, &quick_cfg(), &fleet);
        assert_eq!(out1.runs, out2.runs, "auto-epoch fleet not reproducible");
        assert_eq!(kb1, kb2);
        assert!(out1.runs.iter().all(|r| r.valid));
    }

    #[test]
    fn memo_fleet_grows_a_memo_and_stays_reproducible() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
        ];
        let arch = GpuArch::a100();
        let cfg = IcrlConfig {
            verify: crate::harness::staged::VerifyConfig {
                staged: true,
                ..Default::default()
            },
            ..quick_cfg()
        };
        let fleet = FleetConfig {
            workers: 2,
            epoch_size: 2,
            ..Default::default()
        };
        let mut kb1 = KnowledgeBase::empty();
        let mut memo1 = VerifyMemo::new();
        let out1 = run_fleet_memo(&tasks, &arch, &mut kb1, &cfg, &fleet, &mut memo1, &mut NullObserver);
        assert!(!memo1.is_empty(), "staged fleet must memoize verdicts");
        assert!(out1.tiers.full_verifications > 0);
        let mut kb2 = KnowledgeBase::empty();
        let mut memo2 = VerifyMemo::new();
        let out2 = run_fleet_memo(&tasks, &arch, &mut kb2, &cfg, &fleet, &mut memo2, &mut NullObserver);
        assert_eq!(out1.runs, out2.runs);
        assert_eq!(memo1, memo2);
        // Staging off leaves a provided memo untouched.
        let mut memo3 = VerifyMemo::new();
        let mut kb3 = KnowledgeBase::empty();
        let _ = run_fleet_memo(
            &tasks,
            &arch,
            &mut kb3,
            &quick_cfg(),
            &fleet,
            &mut memo3,
            &mut NullObserver,
        );
        assert!(memo3.is_empty());
    }

    #[test]
    fn store_backends_do_not_perturb_results_and_checkpoint_on_cadence() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/01_matmul_square").unwrap(),
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
        ];
        let arch = GpuArch::h100();
        let fleet = FleetConfig {
            workers: 2,
            epoch_size: 2,
            ..Default::default()
        };
        let mut kb_null = KnowledgeBase::empty();
        let out_null = run_fleet(&tasks, &arch, &mut kb_null, &quick_cfg(), &fleet);
        let dir = std::env::temp_dir().join("kb_fleet_store_backend_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = dir.join("kb.json");
        let mut wf = WholeFileStore::new(&ckpt, 2);
        let mut kb_wf = KnowledgeBase::empty();
        let out_wf = run_fleet_store(
            &tasks,
            &arch,
            &mut kb_wf,
            &quick_cfg(),
            &fleet,
            None,
            &mut wf,
            &mut NullObserver,
        )
        .unwrap();
        assert_eq!(out_null.runs, out_wf.runs, "store must not perturb results");
        assert_eq!(kb_null, kb_wf);
        assert_eq!(wf.checkpoints(), 1, "cadence of 2 over 3 commits");
        assert!(persist::load(&ckpt).is_ok());
        // A LogStore backend journals every commit and recovers the
        // exact shared KB.
        let sdir = dir.join("store");
        let mut ls = LogStore::create(&sdir, &KnowledgeBase::empty()).unwrap();
        let mut kb_ls = KnowledgeBase::empty();
        let out_ls = run_fleet_store(
            &tasks,
            &arch,
            &mut kb_ls,
            &quick_cfg(),
            &fleet,
            None,
            &mut ls,
            &mut NullObserver,
        )
        .unwrap();
        assert_eq!(out_null.runs, out_ls.runs);
        assert_eq!(kb_null, kb_ls);
        let (recovered, _) = LogStore::recover(&sdir).unwrap();
        assert_eq!(recovered, kb_ls, "journal replay must be bit-exact");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_atomic_writes_loadable_kb_and_cleans_tmp() {
        let dir = std::env::temp_dir().join("kb_fleet_checkpoint_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("kb.json");
        let kb = KnowledgeBase::seed_priors();
        checkpoint_atomic(&kb, &path).unwrap();
        let back = persist::load(&path).unwrap();
        assert_eq!(back.states.len(), kb.states.len());
        assert!(
            !dir.join("kb.json.tmp").exists(),
            "tmp file must be renamed away"
        );
        // Overwrite is atomic too (same path, new content).
        let kb2 = KnowledgeBase::empty();
        checkpoint_atomic(&kb2, &path).unwrap();
        assert!(persist::load(&path).unwrap().states.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
