//! Pluggable search policies: how a rollout step turns the KB's scored
//! candidate set into the picks it explores and the transition it takes.
//!
//! The paper's claim is that KERNELBLASTER "systematically explores
//! high-potential optimization strategies beyond naive rewrites" — but
//! the *search policy* itself is a lever the related work pulls hard
//! (STARK's strategic refinement, CUDA-L1's contrastive selection). This
//! module extracts that lever from the driver: [`SearchPolicy`] is the
//! contract, the driver ([`crate::icrl::driver`]) is parameterized over
//! it, and adding a strategy is a one-file change instead of driver
//! surgery.
//!
//! # The contract
//!
//! Per rollout step, for each frontier node, the driver hands the policy
//! the KB's **scored candidate enumeration** for the node's current
//! state ([`crate::kb::KnowledgeBase::scored_candidates`] — deterministic,
//! insertion-ordered, RNG-free) plus the step's pick budget `k` and the
//! task's main RNG stream. The policy returns up to `k` **distinct**
//! techniques to explore ([`SearchPolicy::select`]). The transition rule
//! is declared by [`SearchPolicy::beam_width`]: after every pick of
//! every frontier node is evaluated, the driver keeps the best
//! `beam_width` *distinct* valid outcomes (ranked by step gain relative
//! to the node that produced each, evaluation order breaking ties) as
//! the next frontier — width 1 is the classic greedy step-to-best,
//! width B > 1 carries B candidates across steps. The run's global best
//! considers every valid outcome, kept or pruned, so a fast kernel that
//! loses its frontier slot is still recorded.
//!
//! # Determinism / RNG-stream rules
//!
//! - `select` draws only from the `rng` it is handed (the task's main
//!   stream) — never from ambient state. A policy may consume any number
//!   of draws, including zero ([`UcbBandit`] is fully deterministic);
//!   what matters is that the consumption is a pure function of
//!   (candidates, k, rng state), which keeps every run replayable from
//!   its seed.
//! - Pick *evaluation* never touches the main stream: each pick gets a
//!   stream derived from the step state (`explore-t{traj}-s{step}` for
//!   frontier node 0, `…-b{node}` for the rest, then `pick-{i}`), so the
//!   parallel and sequential evaluation paths stay bit-identical and the
//!   stream layout is stable under pick-internals changes.
//! - [`GreedyTopK`] is defined as exactly the pre-policy-subsystem draw
//!   ([`crate::kb::weighted_top_k`] over the scored enumeration), which
//!   makes the default driver **bit-identical** to the pre-refactor
//!   hard-wired loop — asserted draw-for-draw and run-for-run in
//!   `tests/policy.rs`.
//!
//! # Adding a policy
//!
//! Implement [`SearchPolicy`] (selection + optional beam width), add a
//! [`PolicyKind`] variant with its `name`/`from_name` strings, extend
//! [`PolicyConfig::build`] and `validate`, and it is reachable from the
//! CLI (`--policy`), config files (`[policy]` section), the fleet, and
//! `experiment policy` with no driver changes.

use crate::kb::{self, ScoredCandidate};
use crate::opts::Technique;
use crate::util::rng::Rng;

/// A search policy: candidate selection plus the step transition rule.
/// See the module docs for the full contract.
pub trait SearchPolicy {
    /// Stable name (CLI/config/report identifier).
    fn name(&self) -> &'static str;

    /// Frontier size the driver carries across steps — the transition
    /// rule. `1` (the default) is greedy step-to-best; `B > 1` keeps the
    /// best B distinct valid outcomes of the step as the next frontier.
    fn beam_width(&self) -> usize {
        1
    }

    /// Choose up to `k` distinct techniques to explore from the state's
    /// scored candidate enumeration. `candidates` is never empty when the
    /// driver calls this; order is KB insertion order.
    fn select(&self, candidates: &[ScoredCandidate], k: usize, rng: &mut Rng) -> Vec<Technique>;
}

/// The paper's §3 rule and the crate's default: weighted draw without
/// replacement, mass proportional to expected gain above parity with an
/// exploration floor ([`crate::kb::selection_weight`]). Bit-identical to
/// the pre-policy-subsystem driver.
#[derive(Debug, Clone, Copy, Default)]
pub struct GreedyTopK;

impl SearchPolicy for GreedyTopK {
    fn name(&self) -> &'static str {
        "greedy_topk"
    }

    fn select(&self, candidates: &[ScoredCandidate], k: usize, rng: &mut Rng) -> Vec<Technique> {
        kb::weighted_top_k(candidates, k, rng)
    }
}

/// Greedy weighted draw with a uniform exploration floor: each slot
/// flips an ε-coin; heads picks uniformly among the still-unpicked
/// **untried** candidates (zero native attempts — the entries the
/// weighted draw structurally starves once a few techniques accumulate
/// evidence), tails falls back to the weighted draw. With no untried
/// candidates left the slot is always a weighted draw.
#[derive(Debug, Clone, Copy)]
pub struct EpsilonGreedy {
    /// Probability of the uniform-over-untried draw per slot, in [0, 1].
    pub epsilon: f64,
}

impl SearchPolicy for EpsilonGreedy {
    fn name(&self) -> &'static str {
        "epsilon_greedy"
    }

    fn select(&self, candidates: &[ScoredCandidate], k: usize, rng: &mut Rng) -> Vec<Technique> {
        let mut remaining: Vec<usize> = (0..candidates.len()).collect();
        let mut picked = Vec::new();
        while picked.len() < k && !remaining.is_empty() {
            // Positions (into `remaining`) of still-untried candidates.
            let untried: Vec<usize> = remaining
                .iter()
                .enumerate()
                .filter(|(_, &ci)| candidates[ci].attempts == 0)
                .map(|(pos, _)| pos)
                .collect();
            let pos = if !untried.is_empty() && rng.chance(self.epsilon) {
                untried[rng.index(untried.len())]
            } else {
                let weights: Vec<f64> =
                    remaining.iter().map(|&ci| candidates[ci].weight).collect();
                rng.weighted_index(&weights)
            };
            picked.push(candidates[remaining[pos]].technique);
            remaining.remove(pos);
        }
        picked
    }
}

/// UCB1 over the KB's replay statistics: rank by
/// `expected_gain + c·sqrt(ln(T+1)/(attempts+1))` where `T` is the total
/// attempts across the candidate set, and take the top k
/// deterministically (enumeration order breaks ties). Turns the KB's
/// attempt counts into a principled exploration bonus — an entry's
/// uncertainty, not just its mean, earns it picks. Consumes no RNG.
#[derive(Debug, Clone, Copy)]
pub struct UcbBandit {
    /// Exploration coefficient (≥ 0; 0 degenerates to deterministic
    /// exploit-by-expected-gain).
    pub c: f64,
}

impl UcbBandit {
    /// The UCB score of one candidate given the pool's total attempts.
    fn score(&self, cand: &ScoredCandidate, total_attempts: usize) -> f64 {
        let base = if cand.expected_gain.is_finite() {
            cand.expected_gain
        } else {
            0.0
        };
        let ln_t = ((total_attempts + 1) as f64).ln();
        base + self.c * (ln_t / (cand.attempts as f64 + 1.0)).sqrt()
    }
}

impl SearchPolicy for UcbBandit {
    fn name(&self) -> &'static str {
        "ucb_bandit"
    }

    fn select(&self, candidates: &[ScoredCandidate], k: usize, _rng: &mut Rng) -> Vec<Technique> {
        let total: usize = candidates.iter().map(|c| c.attempts).sum();
        let mut idx: Vec<usize> = (0..candidates.len()).collect();
        idx.sort_by(|&a, &b| {
            self.score(&candidates[b], total)
                .total_cmp(&self.score(&candidates[a], total))
                .then(a.cmp(&b))
        });
        idx.truncate(k);
        idx.into_iter().map(|i| candidates[i].technique).collect()
    }
}

/// Beam search: the same weighted draw as [`GreedyTopK`] per frontier
/// node, but the driver carries the best `width` distinct valid outcomes
/// across steps instead of stepping to the single best — a slower step
/// that is much harder to trap in a local minimum (the §5 prep→compute
/// sequences survive even when the preparatory step alone looks like a
/// loss).
#[derive(Debug, Clone, Copy)]
pub struct BeamSearch {
    /// Frontier size carried across steps (≥ 1; 1 degenerates to
    /// [`GreedyTopK`]).
    pub width: usize,
}

impl SearchPolicy for BeamSearch {
    fn name(&self) -> &'static str {
        "beam_search"
    }

    fn beam_width(&self) -> usize {
        self.width.max(1)
    }

    fn select(&self, candidates: &[ScoredCandidate], k: usize, rng: &mut Rng) -> Vec<Technique> {
        kb::weighted_top_k(candidates, k, rng)
    }
}

/// The four built-in policies, as a closed nameable set (CLI/config/
/// experiment surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// [`GreedyTopK`] — the default; bit-identical to the pre-refactor
    /// driver.
    GreedyTopK,
    /// [`EpsilonGreedy`] — uniform exploration floor over untried
    /// techniques.
    EpsilonGreedy,
    /// [`UcbBandit`] — UCB over KB attempt counts.
    UcbBandit,
    /// [`BeamSearch`] — carry B candidates across steps.
    BeamSearch,
}

impl PolicyKind {
    /// Every kind, stable order (the `experiment policy` arm order).
    pub fn all() -> &'static [PolicyKind] {
        &[
            PolicyKind::GreedyTopK,
            PolicyKind::EpsilonGreedy,
            PolicyKind::UcbBandit,
            PolicyKind::BeamSearch,
        ]
    }

    /// Stable lowercase name used by `--policy`, the `[policy]` config
    /// section, and reports.
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::GreedyTopK => "greedy_topk",
            PolicyKind::EpsilonGreedy => "epsilon_greedy",
            PolicyKind::UcbBandit => "ucb_bandit",
            PolicyKind::BeamSearch => "beam_search",
        }
    }

    /// Inverse of [`Self::name`]; `None` for unknown names.
    pub fn from_name(s: &str) -> Option<PolicyKind> {
        PolicyKind::all().iter().copied().find(|k| k.name() == s)
    }

    /// Space-separated list of every policy name — the single source of
    /// truth for "unknown policy" error messages (CLI and config loader).
    pub fn known_names() -> String {
        PolicyKind::all()
            .iter()
            .map(|k| k.name())
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Declarative policy selection + hyperparameters — the form that lives
/// in [`crate::icrl::IcrlConfig`] (and therefore in config files and
/// CLI flags). [`Self::build`] turns it into the trait object the driver
/// runs; keeping the config plain data keeps `IcrlConfig: Clone` and the
/// wire format trivial.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyConfig {
    /// Which policy to run.
    pub kind: PolicyKind,
    /// [`EpsilonGreedy`]'s ε (ignored by the other kinds).
    pub epsilon: f64,
    /// [`UcbBandit`]'s exploration coefficient (ignored by the others).
    pub ucb_c: f64,
    /// [`BeamSearch`]'s frontier width (ignored by the others).
    pub beam_width: usize,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            kind: PolicyKind::GreedyTopK,
            epsilon: 0.15,
            ucb_c: 0.5,
            beam_width: 3,
        }
    }
}

impl PolicyConfig {
    /// A config running `kind` with the default hyperparameters — the
    /// `experiment policy` arms.
    pub fn of_kind(kind: PolicyKind) -> Self {
        Self {
            kind,
            ..Self::default()
        }
    }

    /// Hyperparameter sanity: ε ∈ [0, 1], finite c ≥ 0, width ≥ 1. The
    /// config-file loader and the CLI flags both enforce this before a
    /// run starts.
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.epsilon) {
            return Err(format!("policy.epsilon must be in [0, 1], got {}", self.epsilon));
        }
        if !self.ucb_c.is_finite() || self.ucb_c < 0.0 {
            return Err(format!("policy.ucb_c must be finite and >= 0, got {}", self.ucb_c));
        }
        if self.beam_width == 0 {
            return Err("policy.beam_width must be >= 1".to_string());
        }
        Ok(())
    }

    /// Instantiate the configured policy.
    pub fn build(&self) -> Box<dyn SearchPolicy> {
        match self.kind {
            PolicyKind::GreedyTopK => Box::new(GreedyTopK),
            PolicyKind::EpsilonGreedy => Box::new(EpsilonGreedy {
                epsilon: self.epsilon,
            }),
            PolicyKind::UcbBandit => Box::new(UcbBandit { c: self.ucb_c }),
            PolicyKind::BeamSearch => Box::new(BeamSearch {
                width: self.beam_width,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Bottleneck;
    use crate::kb::{KnowledgeBase, StateSig, WorkloadClass};

    fn pool() -> (KnowledgeBase, usize) {
        let mut kbase = KnowledgeBase::empty();
        let m = kbase.match_state(StateSig {
            primary: Bottleneck::MemoryLatency,
            secondary: Bottleneck::ComputeThroughput,
            workload: WorkloadClass::ContractionHeavy,
        });
        kbase.ensure_candidates(m.index(), Technique::all());
        // Give a couple of techniques evidence so "untried" is a strict
        // subset and the UCB bonus differentiates.
        for _ in 0..4 {
            kbase.update_score(0, Technique::SharedMemoryTiling, 2.5, None);
        }
        kbase.update_score(0, Technique::LoopUnrolling, 0.4, None);
        (kbase, m.index())
    }

    #[test]
    fn greedy_matches_legacy_select_top_k_draw_for_draw() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        for seed in 0..20u64 {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let a = GreedyTopK.select(&scored, 3, &mut r1);
            let b = kbase.select_top_k(state, 3, |_| true, &mut r2);
            assert_eq!(a, b, "seed {seed}");
            // Identical RNG consumption, not just identical picks.
            assert_eq!(r1, r2, "seed {seed}: rng streams diverged");
        }
    }

    #[test]
    fn every_policy_returns_distinct_picks_within_budget() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        for kind in PolicyKind::all() {
            let policy = PolicyConfig::of_kind(*kind).build();
            let mut rng = Rng::new(7);
            for k in [1usize, 3, 5, 100] {
                let picks = policy.select(&scored, k, &mut rng);
                assert_eq!(picks.len(), k.min(scored.len()), "{}", policy.name());
                let mut d = picks.clone();
                d.sort();
                d.dedup();
                assert_eq!(d.len(), picks.len(), "{}: duplicate picks", policy.name());
            }
        }
    }

    #[test]
    fn epsilon_greedy_floors_untried_candidates() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        // ε = 1: slot 0 must always be an untried candidate while any
        // remain untried.
        let always = EpsilonGreedy { epsilon: 1.0 };
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let picks = always.select(&scored, 2, &mut rng);
            let first = scored.iter().find(|c| c.technique == picks[0]).unwrap();
            assert_eq!(first.attempts, 0, "ε=1 must pick untried first");
        }
        // ε = 0 degenerates to the greedy weighted draw, same rng stream.
        let never = EpsilonGreedy { epsilon: 0.0 };
        let mut r1 = Rng::new(11);
        let mut r2 = Rng::new(11);
        // ε=0 still consumes the coin flip, so streams differ from pure
        // greedy — but the *distribution shape* is the weighted draw;
        // spot-check determinism instead.
        assert_eq!(
            never.select(&scored, 3, &mut r1),
            never.select(&scored, 3, &mut r2)
        );
    }

    #[test]
    fn ucb_is_deterministic_and_rewards_uncertainty() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        let ucb = UcbBandit { c: 5.0 };
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(999);
        let a = ucb.select(&scored, 4, &mut r1);
        let b = ucb.select(&scored, 4, &mut r2);
        assert_eq!(a, b, "UCB must not depend on the rng");
        assert_eq!(r1, Rng::new(1), "UCB must consume no draws");
        // With a huge exploration coefficient, the heavily-tried
        // technique loses its slot to untried ones.
        assert!(
            !a.contains(&Technique::SharedMemoryTiling),
            "c=5 should crowd out the 4-attempt arm: {a:?}"
        );
        // With c = 0 it is pure exploitation: best expected gain first.
        let exploit = UcbBandit { c: 0.0 };
        let picks = exploit.select(&scored, 1, &mut Rng::new(0));
        let best = scored
            .iter()
            .max_by(|x, y| x.expected_gain.total_cmp(&y.expected_gain))
            .unwrap();
        assert_eq!(picks[0], best.technique);
    }

    #[test]
    fn beam_width_and_names_roundtrip() {
        assert_eq!(BeamSearch { width: 4 }.beam_width(), 4);
        assert_eq!(BeamSearch { width: 0 }.beam_width(), 1);
        assert_eq!(GreedyTopK.beam_width(), 1);
        for kind in PolicyKind::all() {
            assert_eq!(PolicyKind::from_name(kind.name()), Some(*kind));
            let built = PolicyConfig::of_kind(*kind).build();
            assert_eq!(built.name(), kind.name());
        }
        assert_eq!(PolicyKind::from_name("simulated_annealing"), None);
        let known = PolicyKind::known_names();
        for kind in PolicyKind::all() {
            assert!(known.contains(kind.name()), "{known}");
        }
    }

    #[test]
    fn config_validation_rejects_bad_hyperparameters() {
        assert!(PolicyConfig::default().validate().is_ok());
        let bad = [
            PolicyConfig {
                epsilon: 1.5,
                ..Default::default()
            },
            PolicyConfig {
                epsilon: -0.01,
                ..Default::default()
            },
            PolicyConfig {
                ucb_c: -0.1,
                ..Default::default()
            },
            PolicyConfig {
                ucb_c: f64::NAN,
                ..Default::default()
            },
            PolicyConfig {
                beam_width: 0,
                ..Default::default()
            },
        ];
        for c in bad {
            assert!(c.validate().is_err(), "{c:?} must be rejected");
        }
    }
}
