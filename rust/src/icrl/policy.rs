//! Pluggable search policies: how a rollout step turns the KB's scored
//! candidate set into the picks it explores and the transition it takes.
//!
//! The paper's claim is that KERNELBLASTER "systematically explores
//! high-potential optimization strategies beyond naive rewrites" — but
//! the *search policy* itself is a lever the related work pulls hard
//! (STARK's strategic refinement, CUDA-L1's contrastive selection). This
//! module extracts that lever from the driver: [`SearchPolicy`] is the
//! contract, the driver ([`crate::icrl::driver`]) is parameterized over
//! it, and adding a strategy is a one-file change instead of driver
//! surgery.
//!
//! # The contract
//!
//! Per rollout step, for each frontier node, the driver hands the policy
//! the KB's **scored candidate enumeration** for the node's current
//! state ([`crate::kb::KnowledgeBase::scored_candidates`] — deterministic,
//! insertion-ordered, RNG-free; with skills enabled the driver appends
//! mined-skill candidates after the plain opts) plus the step's pick
//! budget `k` and the task's main RNG stream. The policy returns up to
//! `k` **distinct** candidate indices to explore
//! ([`SearchPolicy::select_indices`]; [`SearchPolicy::select`] is the
//! technique-level view of the same draw). The transition rule
//! is declared by [`SearchPolicy::beam_width`]: after every pick of
//! every frontier node is evaluated, the driver keeps the best
//! `beam_width` *distinct* valid outcomes (ranked by step gain relative
//! to the node that produced each, evaluation order breaking ties) as
//! the next frontier — width 1 is the classic greedy step-to-best,
//! width B > 1 carries B candidates across steps. The run's global best
//! considers every valid outcome, kept or pruned, so a fast kernel that
//! loses its frontier slot is still recorded.
//!
//! # Determinism / RNG-stream rules
//!
//! - `select` draws only from the `rng` it is handed (the task's main
//!   stream) — never from ambient state. A policy may consume any number
//!   of draws, including zero ([`UcbBandit`] is fully deterministic);
//!   what matters is that the consumption is a pure function of
//!   (candidates, k, rng state), which keeps every run replayable from
//!   its seed.
//! - Pick *evaluation* never touches the main stream: each pick gets a
//!   stream derived from the step state (`explore-t{traj}-s{step}` for
//!   frontier node 0, `…-b{node}` for the rest, then `pick-{i}`), so the
//!   parallel and sequential evaluation paths stay bit-identical and the
//!   stream layout is stable under pick-internals changes.
//! - [`GreedyTopK`] is defined as exactly the pre-policy-subsystem draw
//!   ([`crate::kb::weighted_top_k`] over the scored enumeration), which
//!   makes the default driver **bit-identical** to the pre-refactor
//!   hard-wired loop — asserted draw-for-draw and run-for-run in
//!   `tests/policy.rs`.
//!
//! # Adaptive exploration (§anneal)
//!
//! The KB accumulates per-state evidence precisely so that later
//! decisions stop paying run-constant exploration costs — yet a fixed ε
//! or UCB-c charges the same exploration tax on a state with 40 recorded
//! attempts as on one with none. Two mechanisms close that gap:
//!
//! - **Annealed schedules** ([`Schedule`]): [`EpsilonGreedy`] and
//!   [`UcbBandit`] decay their exploration hyperparameter *per state*, as
//!   a function of the candidate pool's total recorded attempts
//!   ([`ScoredCandidate::attempts`]). [`Schedule::Constant`] (the
//!   default) applies the configured value verbatim — bit-identical to
//!   the fixed-hyperparameter policies it replaced (asserted in
//!   `tests/policy.rs`).
//! - **The [`Portfolio`] contrastive policy**: runs an exploring member
//!   (ε-greedy) and an exploiting member (UCB) side by side each step
//!   and arbitrates between their pick sets using the state's replay
//!   statistics (CUDA-L1-style contrastive selection) — fresh states
//!   follow the explorer, evidence-heavy states follow the exploiter,
//!   and both members always contribute picks.
//!
//! # Adding a policy
//!
//! Implement [`SearchPolicy`] (selection + optional beam width), add a
//! [`PolicyKind`] variant with its `name`/`from_name` strings, extend
//! [`PolicyConfig::build`] and `validate`, and it is reachable from the
//! CLI (`--policy`), config files (`[policy]` section), the fleet, and
//! `experiment policy` with no driver changes.

use crate::kb::{self, ScoredCandidate};
use crate::opts::Technique;
use crate::util::rng::Rng;

/// Annealing schedule for an exploration hyperparameter (ε or UCB-c):
/// how the configured base value decays as evidence accumulates. `n` is
/// the *(state, technique)* attempt count ([`ScoredCandidate::attempts`]
/// of the entry the decision concerns), not the state's pooled total:
/// each technique's exploration decays with its **own** evidence, so one
/// saturated technique cannot freeze its untried siblings' exploration.
/// Fresh entries explore at full strength; well-evidenced ones exploit.
///
/// [`Schedule::Constant`] returns the base value verbatim (no arithmetic
/// touches it), which makes the default configuration bit-identical to
/// the pre-schedule fixed-hyperparameter policies — the regression
/// anchor `tests/policy.rs` pins. A rate of `0.0` also degenerates to
/// the constant schedule exactly (`base / 1.0` and `base · e⁰` are
/// IEEE-identity operations).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// No decay: the configured value applies at every evidence level.
    Constant,
    /// `base / (1 + rate·n)` — heavy-tailed decay; exploration never
    /// quite reaches zero (the classic 1/t bandit annealing).
    Harmonic {
        /// Decay per recorded attempt (finite, ≥ 0).
        rate: f64,
    },
    /// `base · exp(−rate·n)` — aggressive decay; exploration is
    /// effectively off once a state is well evidenced.
    Exponential {
        /// Decay per recorded attempt (finite, ≥ 0).
        rate: f64,
    },
}

impl Schedule {
    /// Default decay rate for the non-constant schedules (the CLI's
    /// `--schedule-rate` fallback): halves ε after 4 attempts under
    /// [`Schedule::Harmonic`], reaches `e⁻¹` after 4 under
    /// [`Schedule::Exponential`].
    pub const DEFAULT_RATE: f64 = 0.25;

    /// The annealed value of `base` after `attempts` recorded attempts.
    pub fn apply(&self, base: f64, attempts: usize) -> f64 {
        match self {
            Schedule::Constant => base,
            Schedule::Harmonic { rate } => base / (1.0 + rate * attempts as f64),
            Schedule::Exponential { rate } => base * (-rate * attempts as f64).exp(),
        }
    }

    /// Stable lowercase name (CLI `--schedule`, config `schedule` key,
    /// report labels).
    pub fn name(&self) -> &'static str {
        match self {
            Schedule::Constant => "constant",
            Schedule::Harmonic { .. } => "harmonic",
            Schedule::Exponential { .. } => "exponential",
        }
    }

    /// The decay rate (0.0 for [`Schedule::Constant`], which has none).
    pub fn rate(&self) -> f64 {
        match self {
            Schedule::Constant => 0.0,
            Schedule::Harmonic { rate } | Schedule::Exponential { rate } => *rate,
        }
    }

    /// Build a schedule from its name and rate; `None` for unknown
    /// names. `rate` is ignored by `constant`.
    pub fn from_parts(name: &str, rate: f64) -> Option<Schedule> {
        match name {
            "constant" => Some(Schedule::Constant),
            "harmonic" => Some(Schedule::Harmonic { rate }),
            "exponential" => Some(Schedule::Exponential { rate }),
            _ => None,
        }
    }

    /// Space-separated list of the schedule names — the single source of
    /// truth for "unknown schedule" error messages.
    pub fn known_names() -> &'static str {
        "constant harmonic exponential"
    }

    /// Rate sanity: finite and ≥ 0 (a negative rate would *grow*
    /// exploration with evidence — never meaningful).
    pub fn validate(&self) -> Result<(), String> {
        match self {
            Schedule::Constant => Ok(()),
            Schedule::Harmonic { rate } | Schedule::Exponential { rate } => {
                if rate.is_finite() && *rate >= 0.0 {
                    Ok(())
                } else {
                    Err(format!(
                        "policy.schedule_rate must be finite and >= 0, got {rate}"
                    ))
                }
            }
        }
    }
}

/// A search policy: candidate selection plus the step transition rule.
/// See the module docs for the full contract.
///
/// Policies select **indices** into the candidate slice
/// ([`Self::select_indices`]) rather than techniques, because with
/// skills enabled the driver's pool can hold two candidates sharing a
/// lead technique (a plain opt and a mined chain starting with it —
/// [`ScoredCandidate::skill`]); an index names a candidate
/// unambiguously where a technique no longer does. [`Self::select`] is
/// the technique-level view of the same draw, kept for callers that
/// work over plain `scored_candidates` enumerations (where techniques
/// are distinct and the two views are interchangeable).
pub trait SearchPolicy {
    /// Stable name (CLI/config/report identifier).
    fn name(&self) -> &'static str;

    /// Frontier size the driver carries across steps — the transition
    /// rule. `1` (the default) is greedy step-to-best; `B > 1` keeps the
    /// best B distinct valid outcomes of the step as the next frontier.
    fn beam_width(&self) -> usize {
        1
    }

    /// Choose up to `k` distinct candidate indices to explore from the
    /// state's scored candidate enumeration. `candidates` is never empty
    /// when the driver calls this; order is KB insertion order (with any
    /// skill candidates appended by the driver after the plain opts).
    /// RNG consumption is a pure function of (candidates, k, rng state).
    fn select_indices(&self, candidates: &[ScoredCandidate], k: usize, rng: &mut Rng)
        -> Vec<usize>;

    /// [`Self::select_indices`] mapped to techniques — same draw, same
    /// RNG consumption, technique-level result.
    fn select(&self, candidates: &[ScoredCandidate], k: usize, rng: &mut Rng) -> Vec<Technique> {
        self.select_indices(candidates, k, rng)
            .into_iter()
            .map(|i| candidates[i].technique)
            .collect()
    }
}

/// The paper's §3 rule and the crate's default: weighted draw without
/// replacement, mass proportional to expected gain above parity with an
/// exploration floor ([`crate::kb::selection_weight`]). Bit-identical to
/// the pre-policy-subsystem driver.
#[derive(Debug, Clone, Copy, Default)]
pub struct GreedyTopK;

impl SearchPolicy for GreedyTopK {
    fn name(&self) -> &'static str {
        "greedy_topk"
    }

    fn select_indices(
        &self,
        candidates: &[ScoredCandidate],
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        kb::weighted_top_k_indices(candidates, k, rng)
    }
}

/// Greedy weighted draw with a uniform exploration floor: each slot
/// flips an ε-coin; heads picks uniformly among the still-unpicked
/// **untried** candidates (zero native attempts — the entries the
/// weighted draw structurally starves once a few techniques accumulate
/// evidence), tails falls back to the weighted draw. With no untried
/// candidates left the slot is always a weighted draw.
///
/// The effective ε is annealed by `schedule` over the least-evidenced
/// remaining candidate's own (state, technique) attempt count — while
/// any candidate is still untried that count is zero, so the floor
/// holds at full strength no matter how saturated its siblings are
/// (pooled-attempt keying used to let one hot technique anneal the
/// whole state's floor away and starve the rest). Once every technique
/// carries evidence the uniform branch is unreachable and the schedule
/// is moot — exploration decays structurally, by the untried set
/// emptying, rather than by ε shrinking.
/// [`Schedule::Constant`] keeps ε fixed — bit-identical to the
/// pre-schedule policy (the coin consumes the same stream draw with the
/// same probability).
#[derive(Debug, Clone, Copy)]
pub struct EpsilonGreedy {
    /// Base probability of the uniform-over-untried draw per slot, in
    /// [0, 1].
    pub epsilon: f64,
    /// Annealing of ε over the least-evidenced remaining candidate's
    /// own attempts (per-technique keying).
    pub schedule: Schedule,
}

impl SearchPolicy for EpsilonGreedy {
    fn name(&self) -> &'static str {
        "epsilon_greedy"
    }

    fn select_indices(
        &self,
        candidates: &[ScoredCandidate],
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let mut remaining: Vec<usize> = (0..candidates.len()).collect();
        let mut picked = Vec::new();
        while picked.len() < k && !remaining.is_empty() {
            // Positions (into `remaining`) of still-untried candidates.
            let untried: Vec<usize> = remaining
                .iter()
                .enumerate()
                .filter(|(_, &ci)| candidates[ci].attempts == 0)
                .map(|(pos, _)| pos)
                .collect();
            // Per-technique keying: the floor decays with the evidence
            // of the most-starved remaining candidate (zero while any
            // untried entry exists), never with siblings' saturation.
            let floor_evidence = remaining
                .iter()
                .map(|&ci| candidates[ci].attempts)
                .min()
                .unwrap_or(0);
            let epsilon = self.schedule.apply(self.epsilon, floor_evidence);
            let pos = if !untried.is_empty() && rng.chance(epsilon) {
                untried[rng.index(untried.len())]
            } else {
                let weights: Vec<f64> =
                    remaining.iter().map(|&ci| candidates[ci].weight).collect();
                rng.weighted_index(&weights)
            };
            picked.push(remaining[pos]);
            remaining.remove(pos);
        }
        picked
    }
}

/// UCB1 over the KB's replay statistics: rank by
/// `expected_gain + c·sqrt(ln(T+1)/(attempts+1))` where `T` is the total
/// attempts across the candidate set, and take the top k
/// deterministically (enumeration order breaks ties). Turns the KB's
/// attempt counts into a principled exploration bonus — an entry's
/// uncertainty, not just its mean, earns it picks. Consumes no RNG.
///
/// The effective c is annealed per candidate by `schedule` over that
/// candidate's own (state, technique) attempts (on top of UCB's own
/// `1/√attempts` per-entry decay — the schedule shrinks each *entry's*
/// bonus as its own evidence matures, so a saturated technique's bonus
/// collapses while an untried sibling keeps the full-strength c it was
/// configured with). [`Schedule::Constant`] keeps c fixed —
/// bit-identical to the pre-schedule policy.
#[derive(Debug, Clone, Copy)]
pub struct UcbBandit {
    /// Base exploration coefficient (≥ 0; 0 degenerates to deterministic
    /// exploit-by-expected-gain).
    pub c: f64,
    /// Per-candidate annealing of c over each entry's own attempts.
    pub schedule: Schedule,
}

impl UcbBandit {
    /// The UCB score of one candidate: the exploration coefficient is
    /// annealed over the candidate's **own** attempts, the `ln` term
    /// keeps the pool's total (classic UCB1 shape).
    fn score(&self, cand: &ScoredCandidate, total_attempts: usize) -> f64 {
        let base = if cand.expected_gain.is_finite() {
            cand.expected_gain
        } else {
            0.0
        };
        let c = self.schedule.apply(self.c, cand.attempts);
        let ln_t = ((total_attempts + 1) as f64).ln();
        base + c * (ln_t / (cand.attempts as f64 + 1.0)).sqrt()
    }
}

impl SearchPolicy for UcbBandit {
    fn name(&self) -> &'static str {
        "ucb_bandit"
    }

    fn select_indices(
        &self,
        candidates: &[ScoredCandidate],
        k: usize,
        _rng: &mut Rng,
    ) -> Vec<usize> {
        let total: usize = candidates.iter().map(|c| c.attempts).sum();
        let mut idx: Vec<usize> = (0..candidates.len()).collect();
        idx.sort_by(|&a, &b| {
            self.score(&candidates[b], total)
                .total_cmp(&self.score(&candidates[a], total))
                .then(a.cmp(&b))
        });
        idx.truncate(k);
        idx
    }
}

/// Thompson sampling over the KB's replay statistics: each candidate's
/// success probability gets a Beta posterior — `Beta(successes + 1,
/// failures + 1)` under a uniform prior — and each selection slot ranks
/// candidates by `θ · expected_gain` where `θ` is one posterior draw.
/// Exploration emerges from posterior width instead of an explicit ε or
/// bonus term: an entry with 1/1 successes still draws θ anywhere in
/// (0, 1), while 40/40 concentrates near 1 — so uncertainty earns picks
/// exactly in proportion to how unresolved the entry is, and the policy
/// anneals itself as evidence accumulates (no [`Schedule`] needed).
///
/// Draws consume only the handed stream (one Beta = two Gamma draws per
/// candidate, via Marsaglia–Tsang), keeping the selection a pure
/// function of (candidates, k, rng state) like every other policy.
#[derive(Debug, Clone, Copy, Default)]
pub struct Thompson;

impl Thompson {
    /// Gamma(shape, 1) via Marsaglia–Tsang. Shapes here are always
    /// ≥ 1 (count + 1), the regime where the squeeze-free rejection
    /// loop applies directly.
    fn gamma(shape: f64, rng: &mut Rng) -> f64 {
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = rng.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = rng.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Beta(a, b) as the Gamma ratio Gₐ/(Gₐ+G_b).
    fn beta(a: f64, b: f64, rng: &mut Rng) -> f64 {
        let x = Self::gamma(a, rng);
        let y = Self::gamma(b, rng);
        if x + y <= 0.0 {
            return 0.5;
        }
        x / (x + y)
    }
}

impl SearchPolicy for Thompson {
    fn name(&self) -> &'static str {
        "thompson"
    }

    fn select_indices(
        &self,
        candidates: &[ScoredCandidate],
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let mut scored: Vec<(usize, f64)> = candidates
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let a = c.successes as f64 + 1.0;
                let b = c.attempts.saturating_sub(c.successes) as f64 + 1.0;
                let theta = Self::beta(a, b, rng);
                let gain = if c.expected_gain.is_finite() {
                    c.expected_gain
                } else {
                    0.0
                };
                (i, theta * gain)
            })
            .collect();
        scored.sort_by(|x, y| y.1.total_cmp(&x.1).then(x.0.cmp(&y.0)));
        scored.truncate(k);
        scored.into_iter().map(|(i, _)| i).collect()
    }
}

/// Beam search: the same weighted draw as [`GreedyTopK`] per frontier
/// node, but the driver carries the best `width` distinct valid outcomes
/// across steps instead of stepping to the single best — a slower step
/// that is much harder to trap in a local minimum (the §5 prep→compute
/// sequences survive even when the preparatory step alone looks like a
/// loss).
#[derive(Debug, Clone, Copy)]
pub struct BeamSearch {
    /// Frontier size carried across steps (≥ 1; 1 degenerates to
    /// [`GreedyTopK`]).
    pub width: usize,
}

impl SearchPolicy for BeamSearch {
    fn name(&self) -> &'static str {
        "beam_search"
    }

    fn beam_width(&self) -> usize {
        self.width.max(1)
    }

    fn select_indices(
        &self,
        candidates: &[ScoredCandidate],
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        kb::weighted_top_k_indices(candidates, k, rng)
    }
}

/// Contrastive two-member portfolio (CUDA-L1-style contrastive selection
/// over the KB's replay statistics): every step runs an *exploring*
/// member ([`EpsilonGreedy`]) and an *exploiting* member ([`UcbBandit`])
/// side by side on the same scored enumeration, then arbitrates between
/// their pick sets using the state's recorded evidence. The trust signal
/// is learned **per `StateSig`** the ICRL way — it is read from the KB
/// each step rather than held in mutable policy state, so the policy
/// stays a pure function and the KB remains the only memory.
///
/// Arbitration: each pick set is scored by its evidence-backed expected
/// advantage (mean over picks of `confidence · (expected_gain − 1)`,
/// where `confidence = attempts/(attempts+1)`). The higher-scoring
/// member *leads*; picks interleave lead-first (lead[0], other[0],
/// lead[1], …, duplicates skipped) so **both** members always contribute
/// to the explored set. A fresh state scores every set 0, so ties break
/// toward the explorer — exploration-first on unknown states,
/// exploitation-first once confident positive evidence accumulates.
///
/// # RNG-stream rule (the two-member draw)
///
/// The members must not race each other for main-stream draws (their
/// consumption counts differ: UCB draws nothing). `select` therefore
/// derives one child stream per member from the main stream
/// (`portfolio-explore` / `portfolio-exploit`) and advances the parent
/// by **exactly one draw** — so consumption is a fixed one-draw cost
/// regardless of member internals, successive selections (and multiple
/// frontier nodes within one step) get fresh member streams, and the
/// whole selection stays a pure function of (candidates, k, rng state).
#[derive(Debug, Clone, Copy)]
pub struct Portfolio {
    /// The exploring member (runs on the `portfolio-explore` stream).
    pub explore: EpsilonGreedy,
    /// The exploiting member (consumes no draws from its
    /// `portfolio-exploit` stream).
    pub exploit: UcbBandit,
}

impl Portfolio {
    /// Evidence-backed score of a pick set (candidate indices): mean
    /// confidence-weighted expected advantage over parity. 0.0 for an
    /// empty set or a fully untried state.
    fn trust(picks: &[usize], candidates: &[ScoredCandidate]) -> f64 {
        if picks.is_empty() {
            return 0.0;
        }
        let mut sum = 0.0;
        for &i in picks {
            let c = &candidates[i];
            if c.expected_gain.is_finite() {
                let confidence = c.attempts as f64 / (c.attempts as f64 + 1.0);
                sum += confidence * (c.expected_gain - 1.0);
            }
        }
        sum / picks.len() as f64
    }
}

impl SearchPolicy for Portfolio {
    fn name(&self) -> &'static str {
        "portfolio"
    }

    fn select_indices(
        &self,
        candidates: &[ScoredCandidate],
        k: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let mut explore_rng = rng.derive("portfolio-explore");
        let mut exploit_rng = rng.derive("portfolio-exploit");
        let _ = rng.next_u64(); // fixed one-draw parent cost (see docs)
        let explore_picks = self.explore.select_indices(candidates, k, &mut explore_rng);
        let exploit_picks = self.exploit.select_indices(candidates, k, &mut exploit_rng);
        let exploit_leads = Self::trust(&exploit_picks, candidates)
            > Self::trust(&explore_picks, candidates);
        let (lead, other) = if exploit_leads {
            (exploit_picks, explore_picks)
        } else {
            (explore_picks, exploit_picks)
        };
        // Interleave lead-first, skipping duplicates: both members'
        // proposals compete for slots every step, the trusted one with
        // first-pick priority at each rank.
        let queues = [lead.as_slice(), other.as_slice()];
        let mut pos = [0usize; 2];
        let mut picked: Vec<usize> = Vec::with_capacity(k.min(candidates.len()));
        while picked.len() < k {
            let mut advanced = false;
            for (m, queue) in queues.iter().enumerate() {
                if picked.len() >= k {
                    break;
                }
                while pos[m] < queue.len() {
                    let i = queue[pos[m]];
                    pos[m] += 1;
                    if !picked.contains(&i) {
                        picked.push(i);
                        advanced = true;
                        break;
                    }
                }
            }
            if !advanced {
                break; // both members exhausted
            }
        }
        picked
    }
}

/// The six built-in policies, as a closed nameable set (CLI/config/
/// experiment surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// [`GreedyTopK`] — the default; bit-identical to the pre-refactor
    /// driver.
    GreedyTopK,
    /// [`EpsilonGreedy`] — uniform exploration floor over untried
    /// techniques.
    EpsilonGreedy,
    /// [`UcbBandit`] — UCB over KB attempt counts.
    UcbBandit,
    /// [`BeamSearch`] — carry B candidates across steps.
    BeamSearch,
    /// [`Portfolio`] — contrastive ε-greedy/UCB mix arbitrated per state
    /// by replay statistics.
    Portfolio,
    /// [`Thompson`] — Beta-posterior sampling over per-entry
    /// success/attempt counts.
    Thompson,
}

impl PolicyKind {
    /// Every kind, stable order (the `experiment policy` arm order).
    pub fn all() -> &'static [PolicyKind] {
        &[
            PolicyKind::GreedyTopK,
            PolicyKind::EpsilonGreedy,
            PolicyKind::UcbBandit,
            PolicyKind::BeamSearch,
            PolicyKind::Portfolio,
            PolicyKind::Thompson,
        ]
    }

    /// Stable lowercase name used by `--policy`, the `[policy]` config
    /// section, and reports.
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::GreedyTopK => "greedy_topk",
            PolicyKind::EpsilonGreedy => "epsilon_greedy",
            PolicyKind::UcbBandit => "ucb_bandit",
            PolicyKind::BeamSearch => "beam_search",
            PolicyKind::Portfolio => "portfolio",
            PolicyKind::Thompson => "thompson",
        }
    }

    /// Inverse of [`Self::name`]; `None` for unknown names.
    pub fn from_name(s: &str) -> Option<PolicyKind> {
        PolicyKind::all().iter().copied().find(|k| k.name() == s)
    }

    /// Space-separated list of every policy name — the single source of
    /// truth for "unknown policy" error messages (CLI and config loader).
    pub fn known_names() -> String {
        PolicyKind::all()
            .iter()
            .map(|k| k.name())
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Declarative policy selection + hyperparameters — the form that lives
/// in [`crate::icrl::IcrlConfig`] (and therefore in config files and
/// CLI flags). [`Self::build`] turns it into the trait object the driver
/// runs; keeping the config plain data keeps `IcrlConfig: Clone` and the
/// wire format trivial.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyConfig {
    /// Which policy to run.
    pub kind: PolicyKind,
    /// [`EpsilonGreedy`]'s ε (ignored by the other kinds).
    pub epsilon: f64,
    /// [`UcbBandit`]'s exploration coefficient (ignored by the others).
    pub ucb_c: f64,
    /// [`BeamSearch`]'s frontier width (ignored by the others).
    pub beam_width: usize,
    /// Annealing schedule for ε / UCB-c (used by [`EpsilonGreedy`],
    /// [`UcbBandit`], and both [`Portfolio`] members; ignored by the
    /// RNG-weighted draws). [`Schedule::Constant`] (the default)
    /// reproduces the fixed-hyperparameter policies bit-for-bit.
    pub schedule: Schedule,
    /// Beam-frontier similarity-dedup threshold, in schedule-distance
    /// units ([`crate::opts::Candidate::schedule_distance`]): two step
    /// outcomes within this distance are treated as duplicates when
    /// filling the next frontier, so near-identical candidates stop
    /// wasting beam width. `0.0` (the default) disables the similarity
    /// check entirely — dedup falls back to exact candidate equality,
    /// byte-identical to the pre-threshold driver. Only meaningful for
    /// frontiers wider than one.
    pub dedup_distance: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            kind: PolicyKind::GreedyTopK,
            epsilon: 0.15,
            ucb_c: 0.5,
            beam_width: 3,
            schedule: Schedule::Constant,
            dedup_distance: 0.0,
        }
    }
}

impl PolicyConfig {
    /// A config running `kind` with the default hyperparameters — the
    /// `experiment policy` arms.
    pub fn of_kind(kind: PolicyKind) -> Self {
        Self {
            kind,
            ..Self::default()
        }
    }

    /// Hyperparameter sanity: ε ∈ [0, 1], finite c ≥ 0, width ≥ 1, a
    /// finite non-negative schedule rate, and a finite non-negative
    /// dedup threshold. The config-file loader and the CLI flags both
    /// enforce this before a run starts.
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.epsilon) {
            return Err(format!("policy.epsilon must be in [0, 1], got {}", self.epsilon));
        }
        if !self.ucb_c.is_finite() || self.ucb_c < 0.0 {
            return Err(format!("policy.ucb_c must be finite and >= 0, got {}", self.ucb_c));
        }
        if self.beam_width == 0 {
            return Err("policy.beam_width must be >= 1".to_string());
        }
        self.schedule.validate()?;
        if !self.dedup_distance.is_finite() || self.dedup_distance < 0.0 {
            return Err(format!(
                "policy.dedup_distance must be finite and >= 0, got {}",
                self.dedup_distance
            ));
        }
        Ok(())
    }

    /// Instantiate the configured policy.
    pub fn build(&self) -> Box<dyn SearchPolicy> {
        match self.kind {
            PolicyKind::GreedyTopK => Box::new(GreedyTopK),
            PolicyKind::EpsilonGreedy => Box::new(EpsilonGreedy {
                epsilon: self.epsilon,
                schedule: self.schedule,
            }),
            PolicyKind::UcbBandit => Box::new(UcbBandit {
                c: self.ucb_c,
                schedule: self.schedule,
            }),
            PolicyKind::BeamSearch => Box::new(BeamSearch {
                width: self.beam_width,
            }),
            PolicyKind::Portfolio => Box::new(Portfolio {
                explore: EpsilonGreedy {
                    epsilon: self.epsilon,
                    schedule: self.schedule,
                },
                exploit: UcbBandit {
                    c: self.ucb_c,
                    schedule: self.schedule,
                },
            }),
            PolicyKind::Thompson => Box::new(Thompson),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Bottleneck;
    use crate::kb::{KnowledgeBase, StateSig, WorkloadClass};

    fn pool() -> (KnowledgeBase, usize) {
        let mut kbase = KnowledgeBase::empty();
        let m = kbase.match_state(StateSig {
            primary: Bottleneck::MemoryLatency,
            secondary: Bottleneck::ComputeThroughput,
            workload: WorkloadClass::ContractionHeavy,
        });
        kbase.ensure_candidates(m.index(), Technique::all());
        // Give a couple of techniques evidence so "untried" is a strict
        // subset and the UCB bonus differentiates.
        for _ in 0..4 {
            kbase.update_score(0, Technique::SharedMemoryTiling, 2.5, None);
        }
        kbase.update_score(0, Technique::LoopUnrolling, 0.4, None);
        (kbase, m.index())
    }

    #[test]
    fn greedy_matches_legacy_select_top_k_draw_for_draw() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        for seed in 0..20u64 {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let a = GreedyTopK.select(&scored, 3, &mut r1);
            let b = kbase.select_top_k(state, 3, |_| true, &mut r2);
            assert_eq!(a, b, "seed {seed}");
            // Identical RNG consumption, not just identical picks.
            assert_eq!(r1, r2, "seed {seed}: rng streams diverged");
        }
    }

    #[test]
    fn select_and_select_indices_agree_draw_for_draw() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        for kind in PolicyKind::all() {
            let policy = PolicyConfig::of_kind(*kind).build();
            for seed in 0..10u64 {
                let mut r1 = Rng::new(seed);
                let mut r2 = Rng::new(seed);
                let idx = policy.select_indices(&scored, 3, &mut r1);
                let techs = policy.select(&scored, 3, &mut r2);
                assert_eq!(
                    idx.iter().map(|&i| scored[i].technique).collect::<Vec<_>>(),
                    techs,
                    "{}: index and technique views diverged",
                    policy.name()
                );
                assert_eq!(r1, r2, "{}: rng streams diverged", policy.name());
                let mut d = idx.clone();
                d.sort_unstable();
                d.dedup();
                assert_eq!(d.len(), idx.len(), "{}: duplicate indices", policy.name());
            }
        }
    }

    #[test]
    fn every_policy_returns_distinct_picks_within_budget() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        for kind in PolicyKind::all() {
            let policy = PolicyConfig::of_kind(*kind).build();
            let mut rng = Rng::new(7);
            for k in [1usize, 3, 5, 100] {
                let picks = policy.select(&scored, k, &mut rng);
                assert_eq!(picks.len(), k.min(scored.len()), "{}", policy.name());
                let mut d = picks.clone();
                d.sort();
                d.dedup();
                assert_eq!(d.len(), picks.len(), "{}: duplicate picks", policy.name());
            }
        }
    }

    #[test]
    fn epsilon_greedy_floors_untried_candidates() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        // ε = 1: slot 0 must always be an untried candidate while any
        // remain untried.
        let always = EpsilonGreedy {
            epsilon: 1.0,
            schedule: Schedule::Constant,
        };
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let picks = always.select(&scored, 2, &mut rng);
            let first = scored.iter().find(|c| c.technique == picks[0]).unwrap();
            assert_eq!(first.attempts, 0, "ε=1 must pick untried first");
        }
        // ε = 0 degenerates to the greedy weighted draw, same rng stream.
        let never = EpsilonGreedy {
            epsilon: 0.0,
            schedule: Schedule::Constant,
        };
        let mut r1 = Rng::new(11);
        let mut r2 = Rng::new(11);
        // ε=0 still consumes the coin flip, so streams differ from pure
        // greedy — but the *distribution shape* is the weighted draw;
        // spot-check determinism instead.
        assert_eq!(
            never.select(&scored, 3, &mut r1),
            never.select(&scored, 3, &mut r2)
        );
    }

    #[test]
    fn ucb_is_deterministic_and_rewards_uncertainty() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        let ucb = UcbBandit {
            c: 5.0,
            schedule: Schedule::Constant,
        };
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(999);
        let a = ucb.select(&scored, 4, &mut r1);
        let b = ucb.select(&scored, 4, &mut r2);
        assert_eq!(a, b, "UCB must not depend on the rng");
        assert_eq!(r1, Rng::new(1), "UCB must consume no draws");
        // With a huge exploration coefficient, the heavily-tried
        // technique loses its slot to untried ones.
        assert!(
            !a.contains(&Technique::SharedMemoryTiling),
            "c=5 should crowd out the 4-attempt arm: {a:?}"
        );
        // With c = 0 it is pure exploitation: best expected gain first.
        let exploit = UcbBandit {
            c: 0.0,
            schedule: Schedule::Constant,
        };
        let picks = exploit.select(&scored, 1, &mut Rng::new(0));
        let best = scored
            .iter()
            .max_by(|x, y| x.expected_gain.total_cmp(&y.expected_gain))
            .unwrap();
        assert_eq!(picks[0], best.technique);
    }

    #[test]
    fn beam_width_and_names_roundtrip() {
        assert_eq!(BeamSearch { width: 4 }.beam_width(), 4);
        assert_eq!(BeamSearch { width: 0 }.beam_width(), 1);
        assert_eq!(GreedyTopK.beam_width(), 1);
        for kind in PolicyKind::all() {
            assert_eq!(PolicyKind::from_name(kind.name()), Some(*kind));
            let built = PolicyConfig::of_kind(*kind).build();
            assert_eq!(built.name(), kind.name());
        }
        assert_eq!(PolicyKind::from_name("simulated_annealing"), None);
        let known = PolicyKind::known_names();
        for kind in PolicyKind::all() {
            assert!(known.contains(kind.name()), "{known}");
        }
    }

    #[test]
    fn config_validation_rejects_bad_hyperparameters() {
        assert!(PolicyConfig::default().validate().is_ok());
        let bad = [
            PolicyConfig {
                epsilon: 1.5,
                ..Default::default()
            },
            PolicyConfig {
                epsilon: -0.01,
                ..Default::default()
            },
            PolicyConfig {
                ucb_c: -0.1,
                ..Default::default()
            },
            PolicyConfig {
                ucb_c: f64::NAN,
                ..Default::default()
            },
            PolicyConfig {
                beam_width: 0,
                ..Default::default()
            },
            PolicyConfig {
                schedule: Schedule::Harmonic { rate: -0.1 },
                ..Default::default()
            },
            PolicyConfig {
                schedule: Schedule::Exponential { rate: f64::NAN },
                ..Default::default()
            },
            PolicyConfig {
                dedup_distance: -1.0,
                ..Default::default()
            },
            PolicyConfig {
                dedup_distance: f64::INFINITY,
                ..Default::default()
            },
        ];
        for c in bad {
            assert!(c.validate().is_err(), "{c:?} must be rejected");
        }
    }

    #[test]
    fn schedules_decay_monotonically_and_constant_is_exact() {
        for base in [0.15f64, 0.5, 1.0] {
            for n in [0usize, 1, 4, 40, 400] {
                // Constant returns the base verbatim — the bit-identity
                // anchor (no arithmetic may touch the value).
                assert_eq!(Schedule::Constant.apply(base, n).to_bits(), base.to_bits());
                // Rate 0 degenerates to constant exactly.
                assert_eq!(
                    Schedule::Harmonic { rate: 0.0 }.apply(base, n).to_bits(),
                    base.to_bits()
                );
                assert_eq!(
                    Schedule::Exponential { rate: 0.0 }.apply(base, n).to_bits(),
                    base.to_bits()
                );
            }
            // Monotone non-increasing in evidence, never negative.
            for sched in [
                Schedule::Harmonic { rate: 0.25 },
                Schedule::Exponential { rate: 0.25 },
            ] {
                let mut prev = sched.apply(base, 0);
                assert_eq!(prev, base, "{}: no evidence = full strength", sched.name());
                for n in 1..50usize {
                    let v = sched.apply(base, n);
                    assert!(v <= prev && v >= 0.0, "{}: not decaying at {n}", sched.name());
                    prev = v;
                }
                // Exponential outruns harmonic at matched rates.
                assert!(
                    Schedule::Exponential { rate: 0.25 }.apply(base, 40)
                        < Schedule::Harmonic { rate: 0.25 }.apply(base, 40)
                );
            }
        }
    }

    #[test]
    fn schedule_names_and_parts_roundtrip() {
        for sched in [
            Schedule::Constant,
            Schedule::Harmonic { rate: 0.5 },
            Schedule::Exponential { rate: 0.5 },
        ] {
            let back = Schedule::from_parts(sched.name(), sched.rate()).unwrap();
            assert_eq!(back, sched);
            assert!(Schedule::known_names().contains(sched.name()));
            assert!(sched.validate().is_ok());
        }
        assert_eq!(Schedule::from_parts("cosine", 0.5), None);
        // constant ignores the rate it is handed.
        assert_eq!(Schedule::from_parts("constant", 9.0), Some(Schedule::Constant));
        assert!(Schedule::Harmonic { rate: -1.0 }.validate().is_err());
    }

    #[test]
    fn annealed_epsilon_keys_on_the_starved_technique_not_the_pool() {
        // Per-technique keying: the fixture pool carries one saturated
        // technique (4 attempts) amid untried siblings. Under pooled
        // keying an aggressive schedule would have collapsed ε and
        // starved the untried entries; under per-technique keying the
        // floor anneals over the most-starved candidate's own evidence
        // (zero), so with ε = 1 every slot with an untried candidate
        // left MUST pick an untried one.
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        let evidence: usize = scored.iter().map(|c| c.attempts).sum();
        assert!(evidence >= 5, "fixture must carry pooled evidence");
        assert!(
            scored.iter().any(|c| c.attempts == 0),
            "fixture must carry untried siblings"
        );
        // The saturated sibling's pooled evidence no longer reaches the
        // floor: the effective ε at the untried entries' own count (0)
        // is the full base value under every schedule.
        let sched = Schedule::Exponential { rate: 2.0 };
        assert_eq!(sched.apply(1.0, 0).to_bits(), 1.0f64.to_bits());
        assert!(sched.apply(1.0, evidence) < 1e-4, "pooled keying would collapse");
        let policy = EpsilonGreedy {
            epsilon: 1.0,
            schedule: sched,
        };
        let picks = policy.select_indices(&scored, 3, &mut Rng::new(5));
        for &i in &picks {
            assert_eq!(
                scored[i].attempts, 0,
                "ε = 1 with untried candidates left must pick untried ones"
            );
        }
        let a = policy.select(&scored, 3, &mut Rng::new(5));
        let b = policy.select(&scored, 3, &mut Rng::new(5));
        assert_eq!(a, b, "annealed selection must stay deterministic");
    }

    #[test]
    fn annealed_ucb_decays_each_entry_by_its_own_evidence() {
        // The saturated entry's bonus must collapse under an aggressive
        // schedule while an untried sibling keeps the full-strength c:
        // the untried entry outranks the evidence-heavy winner once the
        // winner's own attempts anneal its bonus away — exactly the
        // sibling-starvation fix. (Pooled keying shrank both bonuses
        // together, so relative order never changed with the schedule.)
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        let winner = scored
            .iter()
            .position(|c| c.attempts > 0)
            .expect("fixture carries an evidenced entry");
        let flat = UcbBandit {
            c: 50.0,
            schedule: Schedule::Constant,
        };
        let sharp = UcbBandit {
            c: 50.0,
            schedule: Schedule::Exponential { rate: 4.0 },
        };
        let mut rng = Rng::new(7);
        // A huge constant c makes the (attempts+1)⁻¹ᐟ² spread dominate:
        // every policy puts untried entries first either way; the
        // per-candidate anneal must preserve that and additionally push
        // the evidenced entry's rank DOWN, never up.
        let rank = |p: &UcbBandit, r: &mut Rng| {
            p.select_indices(&scored, scored.len(), r)
                .iter()
                .position(|&i| i == winner)
                .unwrap()
        };
        let flat_rank = rank(&flat, &mut rng);
        let sharp_rank = rank(&sharp, &mut rng);
        assert!(
            sharp_rank >= flat_rank,
            "annealing an entry's own bonus must not improve its rank \
             (flat {flat_rank}, annealed {sharp_rank})"
        );
        // Determinism: zero RNG consumed either way.
        let mut r1 = Rng::new(9);
        let before = r1.clone();
        let _ = sharp.select_indices(&scored, 3, &mut r1);
        assert_eq!(r1, before, "UCB must consume no stream draws");
    }

    #[test]
    fn portfolio_is_deterministic_and_advances_parent_one_draw() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        let portfolio = PolicyConfig::of_kind(PolicyKind::Portfolio).build();
        // Deterministic for a fixed stream, distinct, within budget.
        for k in [1usize, 2, 4, 100] {
            let mut r1 = Rng::new(31);
            let mut r2 = Rng::new(31);
            let a = portfolio.select(&scored, k, &mut r1);
            let b = portfolio.select(&scored, k, &mut r2);
            assert_eq!(a, b);
            assert_eq!(r1, r2, "stream consumption must be deterministic");
            assert_eq!(a.len(), k.min(scored.len()));
            let mut d = a.clone();
            d.sort();
            d.dedup();
            assert_eq!(d.len(), a.len(), "duplicate picks");
        }
        // The parent stream advances by exactly one u64 (the documented
        // fixed cost), independent of member internals.
        let mut used = Rng::new(31);
        let _ = portfolio.select(&scored, 3, &mut used);
        let mut reference = Rng::new(31);
        let _ = reference.next_u64();
        assert_eq!(used, reference, "parent must advance exactly one draw");
    }

    #[test]
    fn thompson_is_deterministic_and_posterior_sharpens_with_evidence() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        let policy = PolicyConfig::of_kind(PolicyKind::Thompson).build();
        assert_eq!(policy.name(), "thompson");
        for k in [1usize, 3, 100] {
            let mut r1 = Rng::new(13);
            let mut r2 = Rng::new(13);
            let a = policy.select(&scored, k, &mut r1);
            let b = policy.select(&scored, k, &mut r2);
            assert_eq!(a, b, "same stream must reproduce the draw");
            assert_eq!(r1, r2, "stream consumption must be deterministic");
            assert_eq!(a.len(), k.min(scored.len()));
            let mut d = a.clone();
            d.sort();
            d.dedup();
            assert_eq!(d.len(), a.len(), "duplicate picks");
        }
        // Posterior draws live in (0, 1) and concentrate with evidence:
        // Beta(41, 1) sits far above Beta(1, 1)'s typical spread.
        let mut rng = Rng::new(21);
        let mut lo = 1.0f64;
        for _ in 0..200 {
            let sharp = Thompson::beta(41.0, 1.0, &mut rng);
            assert!((0.0..=1.0).contains(&sharp));
            lo = lo.min(sharp);
        }
        assert!(lo > 0.8, "Beta(41,1) draws must concentrate near 1: {lo}");
        // A 4/4-success entry at measured gain ≈ 2.4 must win the top
        // slot far above the 1/25 uniform rate — posterior mass follows
        // the evidence (exact rate depends on the 24 untried priors).
        let mut wins = 0;
        for seed in 0..100u64 {
            let picks = Thompson.select(&scored, 1, &mut Rng::new(seed));
            if picks[0] == Technique::SharedMemoryTiling {
                wins += 1;
            }
        }
        assert!(wins > 30, "evidence-backed winner picked only {wins}/100");
    }

    #[test]
    fn portfolio_trust_follows_replay_statistics() {
        let (kbase, state) = pool();
        let scored = kbase.scored_candidates(state, |_| true);
        // The evidence-backed winner (4 attempts at gain ≈ 2.5) trusts
        // higher than any untried set.
        let winner = scored
            .iter()
            .position(|c| c.technique == Technique::SharedMemoryTiling)
            .unwrap();
        let confident = Portfolio::trust(&[winner], &scored);
        let untried: Vec<usize> = scored
            .iter()
            .enumerate()
            .filter(|(_, c)| c.attempts == 0)
            .map(|(i, _)| i)
            .take(2)
            .collect();
        assert!(!untried.is_empty());
        assert_eq!(Portfolio::trust(&untried, &scored), 0.0, "untried = no trust");
        assert!(confident > 0.0, "confident positive evidence must score > 0");
        assert_eq!(Portfolio::trust(&[], &scored), 0.0);
        // On an all-untried (fresh) pool the explorer leads: with ε = 1
        // the first pick of the portfolio must be an untried technique.
        let mut fresh = KnowledgeBase::empty();
        let m = fresh.match_state(StateSig {
            primary: Bottleneck::MemoryLatency,
            secondary: Bottleneck::ComputeThroughput,
            workload: WorkloadClass::ContractionHeavy,
        });
        fresh.ensure_candidates(m.index(), Technique::all());
        let fresh_scored = fresh.scored_candidates(m.index(), |_| true);
        let p = Portfolio {
            explore: EpsilonGreedy {
                epsilon: 1.0,
                schedule: Schedule::Constant,
            },
            exploit: UcbBandit {
                c: 0.5,
                schedule: Schedule::Constant,
            },
        };
        let picks = p.select(&fresh_scored, 3, &mut Rng::new(2));
        assert_eq!(picks.len(), 3);
    }
}
