//! KernelBlaster leader entrypoint. All behavior lives in
//! [`kernelblaster::cli`]; see `kernelblaster --help`/USAGE.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(kernelblaster::cli::run(&argv));
}
