//! Per-op and per-group cost queries: FLOPs, bytes moved, arithmetic
//! intensity. These are the raw inputs to the GPU roofline model.

use super::schedule::{FusionGroup, Schedule, Tiling};
use super::{KernelGraph, OpKind, Shape, ValueRef};

/// Cost of a single op at its shapes.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCost {
    /// Floating-point operations performed.
    pub flops: f64,
    /// Bytes read from memory.
    pub bytes_in: f64,
    /// Bytes written to memory.
    pub bytes_out: f64,
    /// Fraction of flops that are transcendental (exp/tanh/…): they run on
    /// the SFU at lower throughput.
    pub transcendental_frac: f64,
}

impl OpCost {
    /// Total bytes moved.
    pub fn bytes_total(&self) -> f64 {
        self.bytes_in + self.bytes_out
    }

    /// Arithmetic intensity (FLOP/byte).
    pub fn intensity(&self) -> f64 {
        if self.bytes_total() <= 0.0 {
            return 0.0;
        }
        self.flops / self.bytes_total()
    }
}

/// Cost of one node in a graph.
pub fn node_cost(graph: &KernelGraph, node_idx: usize) -> OpCost {
    let node = &graph.nodes[node_idx];
    let in_shapes: Vec<&Shape> = node.deps.iter().map(|d| graph.shape_of(*d)).collect();
    let elem_in = node.dtype.size_bytes() as f64;
    let bytes_in: f64 = node
        .deps
        .iter()
        .map(|d| graph.shape_of(*d).numel() as f64 * graph.dtype_of(*d).size_bytes() as f64)
        .sum();
    let out_n = node.shape.numel() as f64;
    let bytes_out = out_n * elem_in;
    let (flops, trans) = match &node.kind {
        OpKind::Matmul => {
            let m = in_shapes[0].dim(0) as f64;
            let k = in_shapes[0].dim(1) as f64;
            let n = in_shapes[1].dim(1) as f64;
            (2.0 * m * n * k, 0.0)
        }
        OpKind::Conv2d { .. } => {
            let w = in_shapes[1];
            let per_out = 2.0 * (w.dim(1) * w.dim(2) * w.dim(3)) as f64;
            (out_n * per_out, 0.0)
        }
        OpKind::MaxPool2d { k, .. } | OpKind::AvgPool2d { k, .. } => {
            (out_n * (k * k) as f64, 0.0)
        }
        OpKind::BiasAdd { .. } | OpKind::Add | OpKind::Sub | OpKind::Mul => (out_n, 0.0),
        OpKind::Relu | OpKind::Scale { .. } | OpKind::AddConst { .. } | OpKind::DivConst { .. } => {
            (out_n, 0.0)
        }
        OpKind::Gelu => (out_n * 10.0, 0.5),
        OpKind::Sigmoid | OpKind::Tanh | OpKind::Exp => (out_n * 4.0, 1.0),
        OpKind::Softmax { axis } => {
            let axis_len = in_shapes[0].dim(*axis) as f64;
            // max + exp + sum + div per row element
            (in_shapes[0].numel() as f64 * 4.0 + axis_len, 0.4)
        }
        OpKind::LogSumExp { .. } => (in_shapes[0].numel() as f64 * 4.0, 0.4),
        OpKind::ReduceSum { .. } | OpKind::ReduceMax { .. } | OpKind::ReduceMean { .. } => {
            (in_shapes[0].numel() as f64, 0.0)
        }
        OpKind::LayerNorm => (in_shapes[0].numel() as f64 * 6.0, 0.15),
        OpKind::Transpose | OpKind::Reshape { .. } | OpKind::Identity | OpKind::Concat { .. } => {
            (0.0, 0.0)
        }
    };
    OpCost {
        flops,
        bytes_in,
        bytes_out,
        transcendental_frac: trans,
    }
}

/// Cost of a fusion group: flops add; *interior* tensors (produced and
/// consumed entirely inside the group) do not touch HBM, which is the whole
/// point of fusion. Exterior inputs are read once, group outputs written
/// once. Tiling additionally deduplicates repeated reads of the same
/// operand (modeled in the GPU layer via an efficiency factor, not here).
pub fn group_cost(graph: &KernelGraph, group: &FusionGroup) -> OpCost {
    let in_group = |r: &ValueRef| match r {
        ValueRef::Node(i) => group.nodes.contains(i),
        ValueRef::Input(_) => false,
    };
    let mut total = OpCost::default();
    let mut trans_flops = 0.0;
    for &ni in &group.nodes {
        let c = node_cost(graph, ni);
        total.flops += c.flops;
        trans_flops += c.flops * c.transcendental_frac;
        // Inputs: count only group-external reads.
        for dep in &graph.nodes[ni].deps {
            if !in_group(dep) {
                total.bytes_in += graph.shape_of(*dep).numel() as f64
                    * graph.dtype_of(*dep).size_bytes() as f64;
            }
        }
        // Outputs: count only values escaping the group.
        let users = graph.users_of(ValueRef::Node(ni));
        let escapes = users.iter().any(|u| !group.nodes.contains(u))
            || graph.outputs.contains(&ValueRef::Node(ni))
            || users.is_empty();
        if escapes {
            total.bytes_out += graph.nodes[ni].shape.numel() as f64
                * graph.nodes[ni].dtype.size_bytes() as f64;
        }
    }
    // Split-K materializes a workspace (partial accumulators) round-trip.
    if group.opts.split_k > 1 {
        total.bytes_out += total.bytes_out.max(1.0) * (group.opts.split_k as f64 - 1.0) * 0.5;
    }
    total.transcendental_frac = if total.flops > 0.0 {
        trans_flops / total.flops
    } else {
        0.0
    };
    total
}

/// Whole-schedule cost (sum over groups).
pub fn schedule_cost(graph: &KernelGraph, schedule: &Schedule) -> OpCost {
    let mut total = OpCost::default();
    let mut trans = 0.0;
    for g in &schedule.groups {
        let c = group_cost(graph, g);
        total.flops += c.flops;
        total.bytes_in += c.bytes_in;
        total.bytes_out += c.bytes_out;
        trans += c.flops * c.transcendental_frac;
    }
    total.transcendental_frac = if total.flops > 0.0 { trans / total.flops } else { 0.0 };
    total
}

/// Estimated scratch (shared-memory analog) bytes a group needs under its
/// current tiling — occupancy input for the GPU model.
pub fn group_scratch_bytes(graph: &KernelGraph, group: &FusionGroup) -> usize {
    match group.opts.tiling {
        Tiling::None => 0,
        Tiling::Shared { tile } => {
            // Two staged operand tiles (A-tile and B-tile) of `tile` width,
            // times the block's row count (approximated by 32 lanes), at
            // the group's widest dtype.
            let elem = group
                .nodes
                .iter()
                .map(|n| graph.nodes[*n].dtype.size_bytes())
                .max()
                .unwrap_or(4);
            let factor = if group.opts.double_buffer { 2 } else { 1 };
            2 * tile * 32 * elem * factor
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::schedule::Schedule;
    use crate::kir::{GraphBuilder, OpKind};

    fn mm_chain() -> KernelGraph {
        let mut b = GraphBuilder::new("mm");
        let x = b.input("x", &[64, 128]);
        let w = b.input("w", &[128, 32]);
        let mm = b.op(OpKind::Matmul, &[x, w]);
        let r = b.op(OpKind::Relu, &[mm]);
        b.output(r);
        b.finish()
    }

    #[test]
    fn matmul_flops() {
        let g = mm_chain();
        let c = node_cost(&g, 0);
        assert_eq!(c.flops, 2.0 * 64.0 * 128.0 * 32.0);
        assert_eq!(c.bytes_in, (64.0 * 128.0 + 128.0 * 32.0) * 4.0);
        assert_eq!(c.bytes_out, 64.0 * 32.0 * 4.0);
        assert!(c.intensity() > 5.0);
    }

    #[test]
    fn elementwise_low_intensity() {
        let g = mm_chain();
        let c = node_cost(&g, 1);
        assert!(c.intensity() < 0.5);
        assert_eq!(c.flops, 64.0 * 32.0);
    }

    #[test]
    fn fusion_removes_interior_traffic() {
        let g = mm_chain();
        let naive = Schedule::naive(&g);
        let naive_cost = schedule_cost(&g, &naive);
        let mut fused = naive.clone();
        fused.fuse(0, 1);
        let fused_cost = schedule_cost(&g, &fused);
        assert_eq!(naive_cost.flops, fused_cost.flops);
        // Interior tensor (matmul output) no longer written+read:
        let interior = 64.0 * 32.0 * 4.0;
        assert!(
            (naive_cost.bytes_total() - fused_cost.bytes_total() - 2.0 * interior).abs() < 1.0,
            "naive={} fused={}",
            naive_cost.bytes_total(),
            fused_cost.bytes_total()
        );
    }

    #[test]
    fn split_k_adds_workspace_traffic() {
        let g = mm_chain();
        let s = Schedule::naive(&g);
        let base = group_cost(&g, &s.groups[0]);
        let mut g2 = s.groups[0].clone();
        g2.opts.split_k = 4;
        let with_split = group_cost(&g, &g2);
        assert!(with_split.bytes_out > base.bytes_out);
        assert_eq!(with_split.flops, base.flops);
    }

    #[test]
    fn conv_cost_counts_macs() {
        let mut b = GraphBuilder::new("c");
        let x = b.input("x", &[1, 3, 8, 8]);
        let w = b.input("w", &[4, 3, 3, 3]);
        let c = b.op(OpKind::Conv2d { stride: 1, pad: 1 }, &[x, w]);
        b.output(c);
        let g = b.finish();
        let cost = node_cost(&g, 0);
        // out = 1*4*8*8 = 256 elems, per-out = 2*3*3*3 = 54
        assert_eq!(cost.flops, 256.0 * 54.0);
    }

    #[test]
    fn transcendental_fraction_propagates() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[32, 32]);
        let e = b.op(OpKind::Exp, &[x]);
        b.output(e);
        let g = b.finish();
        let c = node_cost(&g, 0);
        assert_eq!(c.transcendental_frac, 1.0);
        let s = Schedule::naive(&g);
        assert_eq!(schedule_cost(&g, &s).transcendental_frac, 1.0);
    }

    #[test]
    fn scratch_bytes_reflect_tiling() {
        let g = mm_chain();
        let s = Schedule::naive(&g);
        assert_eq!(group_scratch_bytes(&g, &s.groups[0]), 0);
        let mut tiled = s.groups[0].clone();
        tiled.opts.tiling = Tiling::Shared { tile: 64 };
        let sb = group_scratch_bytes(&g, &tiled);
        assert_eq!(sb, 2 * 64 * 32 * 4);
        tiled.opts.double_buffer = true;
        assert_eq!(group_scratch_bytes(&g, &tiled), 2 * sb);
    }
}
