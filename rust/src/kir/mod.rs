//! KIR — the Kernel Intermediate Representation.
//!
//! The paper's agents transform CUDA source; our reproduction substitutes a
//! structured IR that every optimization technique in Figs. 12–14 can act on
//! *as a real transformation with checkable semantics*:
//!
//! - a dataflow graph of tensor ops ([`KernelGraph`]) — the "what",
//! - a [`schedule::Schedule`] partitioning the graph into kernel launches
//!   with per-launch execution attributes (tiling, vectorization, ILP, …)
//!   — the "how",
//! - a reference interpreter ([`interp`]) — the numeric oracle used by the
//!   validation harness,
//! - a CUDA-like source renderer ([`render`]) — used for token accounting
//!   and the soft-verification pass,
//! - per-op cost queries ([`cost`]) — consumed by the GPU performance model.
//!
//! Position in the MAIC-RL loop (profile → state-extract → KB-match →
//! **lower** → **verify**): the optimization catalog ([`crate::opts`])
//! rewrites (graph, schedule) pairs, the harness ([`crate::harness`])
//! checks them against [`interp`], the GPU model ([`crate::gpu`])
//! profiles them through [`cost`], and the task suite ([`crate::tasks`])
//! is built from [`GraphBuilder`] graphs.

#![deny(missing_docs)]

pub mod cost;
pub mod interp;
pub mod render;
pub mod schedule;

use std::fmt;

/// Element type. The simulator models fp32 as the default; fp16/bf16 enable
/// tensor-core (MXU-analog) execution and halve memory traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit IEEE float (the default; CUDA-core path).
    F32,
    /// 16-bit IEEE float (tensor-core eligible).
    F16,
    /// bfloat16 (tensor-core eligible).
    BF16,
}

impl DType {
    /// Bytes per element.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 | DType::BF16 => 2,
        }
    }

    /// Lowercase type name used in rendering.
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
        }
    }
}

/// Tensor shape, up to 4-D (N, C, H, W) conventions where relevant.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    /// The rank-0 shape.
    pub fn scalar() -> Self {
        Shape(vec![])
    }

    /// Shape from a dimension list.
    pub fn of(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Size of dimension `i` (panics out of range).
    pub fn dim(&self, i: usize) -> usize {
        self.0[i]
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

/// Reference to a value in the graph: either a graph input or a node output.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ValueRef {
    /// Index into `KernelGraph::inputs`.
    Input(usize),
    /// Index into `KernelGraph::nodes`.
    Node(usize),
}

/// A named graph input (parameter or activation).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Input name (rendered into kernel signatures).
    pub name: String,
    /// Input shape.
    pub shape: Shape,
    /// Element type.
    pub dtype: DType,
}

/// Tensor operations. Arity and shape rules are enforced by the builder.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// C[m,n] = A[m,k] @ B[k,n]
    Matmul,
    /// NCHW conv; weight is [c_out, c_in, kh, kw].
    Conv2d {
        stride: usize,
        pad: usize,
    },
    /// NCHW max pool, no padding.
    MaxPool2d {
        k: usize,
        stride: usize,
    },
    /// NCHW average pool, no padding.
    AvgPool2d {
        k: usize,
        stride: usize,
    },
    /// Add a bias vector along the given axis (broadcast elsewhere).
    BiasAdd {
        axis: usize,
    },
    /// max(x, 0).
    Relu,
    /// Gaussian-error linear unit (tanh approximation).
    Gelu,
    /// Logistic sigmoid.
    Sigmoid,
    /// Hyperbolic tangent.
    Tanh,
    /// Elementwise exponential.
    Exp,
    /// x * c
    Scale {
        c: f32,
    },
    /// x + c
    AddConst {
        c: f32,
    },
    /// Elementwise addition over same-shape operands.
    Add,
    /// Elementwise subtraction over same-shape operands.
    Sub,
    /// Elementwise multiplication over same-shape operands.
    Mul,
    /// x / c (the paper's "division by scalar" epilogues).
    DivConst {
        c: f32,
    },
    /// Softmax along an axis.
    Softmax {
        axis: usize,
    },
    /// logsumexp along an axis, keepdim (shape keeps a 1 there) — the
    /// Level-2 Q18 op the paper's algebraic simplification eliminates.
    LogSumExp {
        axis: usize,
    },
    /// Sum-reduce along an axis, keepdim.
    ReduceSum {
        axis: usize,
    },
    /// Max-reduce along an axis, keepdim.
    ReduceMax {
        axis: usize,
    },
    /// Mean-reduce along an axis, keepdim.
    ReduceMean {
        axis: usize,
    },
    /// 2-D transpose.
    Transpose,
    /// Reshape to a target shape (same numel).
    Reshape {
        shape: Shape,
    },
    /// LayerNorm over the last axis.
    LayerNorm,
    /// Concatenate two tensors along an axis (SqueezeNet Fire expand).
    Concat {
        axis: usize,
    },
    /// Identity / copy. Appears when a lowering bug stubs out work, and as a
    /// reward-hacking vector the soft verifier must catch.
    Identity,
}

impl OpKind {
    /// Number of tensor operands this op consumes.
    pub fn arity(&self) -> usize {
        match self {
            OpKind::Matmul | OpKind::Add | OpKind::Sub | OpKind::Mul => 2,
            OpKind::Conv2d { .. } | OpKind::BiasAdd { .. } | OpKind::Concat { .. } => 2,
            _ => 1,
        }
    }

    /// Short mnemonic used in rendering, reports, and state signatures.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Matmul => "matmul",
            OpKind::Conv2d { .. } => "conv2d",
            OpKind::MaxPool2d { .. } => "maxpool2d",
            OpKind::AvgPool2d { .. } => "avgpool2d",
            OpKind::BiasAdd { .. } => "bias_add",
            OpKind::Relu => "relu",
            OpKind::Gelu => "gelu",
            OpKind::Sigmoid => "sigmoid",
            OpKind::Tanh => "tanh",
            OpKind::Exp => "exp",
            OpKind::Scale { .. } => "scale",
            OpKind::AddConst { .. } => "add_const",
            OpKind::Add => "add",
            OpKind::Sub => "sub",
            OpKind::Mul => "mul",
            OpKind::DivConst { .. } => "div_const",
            OpKind::Softmax { .. } => "softmax",
            OpKind::LogSumExp { .. } => "logsumexp",
            OpKind::ReduceSum { .. } => "reduce_sum",
            OpKind::ReduceMax { .. } => "reduce_max",
            OpKind::ReduceMean { .. } => "reduce_mean",
            OpKind::Transpose => "transpose",
            OpKind::Reshape { .. } => "reshape",
            OpKind::LayerNorm => "layer_norm",
            OpKind::Concat { .. } => "concat",
            OpKind::Identity => "identity",
        }
    }

    /// True for ops that are dominated by a contraction (matmul-like inner
    /// product) — the tensor-core-eligible class.
    pub fn is_contraction(&self) -> bool {
        matches!(self, OpKind::Matmul | OpKind::Conv2d { .. })
    }

    /// True for cheap elementwise ops (fusion epilogue candidates).
    pub fn is_elementwise(&self) -> bool {
        matches!(
            self,
            OpKind::Relu
                | OpKind::Gelu
                | OpKind::Sigmoid
                | OpKind::Tanh
                | OpKind::Exp
                | OpKind::Scale { .. }
                | OpKind::AddConst { .. }
                | OpKind::Add
                | OpKind::Sub
                | OpKind::Mul
                | OpKind::DivConst { .. }
                | OpKind::BiasAdd { .. }
                | OpKind::Identity
        )
    }

    /// True for reduction-style ops.
    pub fn is_reduction(&self) -> bool {
        matches!(
            self,
            OpKind::Softmax { .. }
                | OpKind::LogSumExp { .. }
                | OpKind::ReduceSum { .. }
                | OpKind::ReduceMax { .. }
                | OpKind::ReduceMean { .. }
                | OpKind::LayerNorm
                | OpKind::MaxPool2d { .. }
                | OpKind::AvgPool2d { .. }
        )
    }
}

/// One node in the dataflow graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    /// The operation computed.
    pub kind: OpKind,
    /// Operands (inputs or earlier nodes only — topological invariant).
    pub deps: Vec<ValueRef>,
    /// Output shape (validated against shape inference).
    pub shape: Shape,
    /// Output element type.
    pub dtype: DType,
}

/// The kernel dataflow graph. Nodes are in topological order by
/// construction (deps may only reference inputs or earlier nodes).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelGraph {
    /// Graph name (task ids derive kernel names from it).
    pub name: String,
    /// Named graph inputs.
    pub inputs: Vec<TensorSpec>,
    /// Operation nodes, topologically ordered.
    pub nodes: Vec<Node>,
    /// Graph outputs (usually one).
    pub outputs: Vec<ValueRef>,
}

/// Errors from graph construction / validation.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum KirError {
    /// Wrong operand count for an op.
    #[error("op {op} expects {expected} operands, got {got}")]
    Arity {
        /// Op mnemonic.
        op: String,
        /// Operands the op requires.
        expected: usize,
        /// Operands actually supplied.
        got: usize,
    },
    /// Operand/result shapes are inconsistent.
    #[error("shape mismatch at {context}: {a} vs {b}")]
    ShapeMismatch {
        /// Where the mismatch was found.
        context: String,
        /// First shape (rendered).
        a: String,
        /// Second shape (rendered).
        b: String,
    },
    /// A value reference is out of range or forward-referencing.
    #[error("invalid reference {0:?}")]
    BadRef(ValueRef),
    /// An axis argument exceeds the operand's rank.
    #[error("axis {axis} out of range for rank {rank}")]
    BadAxis {
        /// The offending axis.
        axis: usize,
        /// The operand's rank.
        rank: usize,
    },
    /// Any other structural violation.
    #[error("{0}")]
    Invalid(String),
}

impl KernelGraph {
    /// Shape of a referenced value.
    pub fn shape_of(&self, r: ValueRef) -> &Shape {
        match r {
            ValueRef::Input(i) => &self.inputs[i].shape,
            ValueRef::Node(i) => &self.nodes[i].shape,
        }
    }

    /// Element type of a referenced value.
    pub fn dtype_of(&self, r: ValueRef) -> DType {
        match r {
            ValueRef::Input(i) => self.inputs[i].dtype,
            ValueRef::Node(i) => self.nodes[i].dtype,
        }
    }

    /// Users (node indices) of each value, useful for fusion legality.
    pub fn users_of(&self, r: ValueRef) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.deps.contains(&r))
            .map(|(i, _)| i)
            .collect()
    }

    /// Validate internal consistency: refs in range and topological,
    /// arities and shapes consistent. This is the "compile check" of the
    /// execution harness.
    pub fn validate(&self) -> Result<(), KirError> {
        for (idx, node) in self.nodes.iter().enumerate() {
            if node.deps.len() != node.kind.arity() {
                return Err(KirError::Arity {
                    op: node.kind.mnemonic().to_string(),
                    expected: node.kind.arity(),
                    got: node.deps.len(),
                });
            }
            for dep in &node.deps {
                match dep {
                    ValueRef::Input(i) if *i >= self.inputs.len() => {
                        return Err(KirError::BadRef(*dep))
                    }
                    ValueRef::Node(i) if *i >= idx => return Err(KirError::BadRef(*dep)),
                    _ => {}
                }
            }
            let expected = infer_shape(
                &node.kind,
                &node
                    .deps
                    .iter()
                    .map(|d| self.shape_of(*d).clone())
                    .collect::<Vec<_>>(),
            )?;
            if expected != node.shape {
                return Err(KirError::ShapeMismatch {
                    context: format!("node {idx} ({})", node.kind.mnemonic()),
                    a: format!("{expected}"),
                    b: format!("{}", node.shape),
                });
            }
        }
        for out in &self.outputs {
            match out {
                ValueRef::Input(i) if *i >= self.inputs.len() => {
                    return Err(KirError::BadRef(*out))
                }
                ValueRef::Node(i) if *i >= self.nodes.len() => {
                    return Err(KirError::BadRef(*out))
                }
                _ => {}
            }
        }
        if self.outputs.is_empty() {
            return Err(KirError::Invalid("graph has no outputs".to_string()));
        }
        Ok(())
    }

    /// Replace every use of `old` (in node deps and graph outputs) with
    /// `new`. Used by graph rewrites before removing a node.
    pub fn replace_value(&mut self, old: ValueRef, new: ValueRef) {
        for node in &mut self.nodes {
            for dep in &mut node.deps {
                if *dep == old {
                    *dep = new;
                }
            }
        }
        for out in &mut self.outputs {
            if *out == old {
                *out = new;
            }
        }
    }

    /// Remove node `idx`. The node must have no remaining users (call
    /// [`Self::replace_value`] first). All later node references shift
    /// down by one. Returns an error if the node still has users.
    pub fn remove_node(&mut self, idx: usize) -> Result<(), KirError> {
        let r = ValueRef::Node(idx);
        if !self.users_of(r).is_empty() || self.outputs.contains(&r) {
            return Err(KirError::Invalid(format!(
                "node {idx} still has users; rewire before removal"
            )));
        }
        self.nodes.remove(idx);
        let shift = |v: &mut ValueRef| {
            if let ValueRef::Node(i) = v {
                if *i > idx {
                    *i -= 1;
                }
            }
        };
        for node in &mut self.nodes {
            for dep in &mut node.deps {
                shift(dep);
            }
        }
        for out in &mut self.outputs {
            shift(out);
        }
        Ok(())
    }

    /// Node indices that are dead: not outputs and (transitively) unused.
    /// Returned in descending order so they can be removed one by one.
    pub fn dead_nodes(&self) -> Vec<usize> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack: Vec<usize> = self
            .outputs
            .iter()
            .filter_map(|o| match o {
                ValueRef::Node(i) => Some(*i),
                _ => None,
            })
            .collect();
        while let Some(i) = stack.pop() {
            if live[i] {
                continue;
            }
            live[i] = true;
            for dep in &self.nodes[i].deps {
                if let ValueRef::Node(d) = dep {
                    stack.push(*d);
                }
            }
        }
        (0..self.nodes.len()).rev().filter(|i| !live[*i]).collect()
    }

    /// Count of nodes of each coarse class — part of the state signature.
    pub fn op_census(&self) -> OpCensus {
        let mut c = OpCensus::default();
        for n in &self.nodes {
            if n.kind.is_contraction() {
                c.contractions += 1;
            } else if n.kind.is_reduction() {
                c.reductions += 1;
            } else if n.kind.is_elementwise() {
                c.elementwise += 1;
            } else {
                c.other += 1;
            }
        }
        c
    }
}

/// Node counts by coarse op class — the workload axis of the KB's state
/// signature and the soft verifier's functionality-elimination guard.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCensus {
    /// Matmul/conv nodes.
    pub contractions: usize,
    /// Reduction-style nodes.
    pub reductions: usize,
    /// Cheap elementwise nodes.
    pub elementwise: usize,
    /// Everything else (transpose, reshape, …).
    pub other: usize,
}

impl OpCensus {
    /// Total node count.
    pub fn total(&self) -> usize {
        self.contractions + self.reductions + self.elementwise + self.other
    }
}

/// Shape inference for an op applied to operand shapes.
pub fn infer_shape(kind: &OpKind, operands: &[Shape]) -> Result<Shape, KirError> {
    let need = |n: usize| -> Result<(), KirError> {
        if operands.len() != n {
            Err(KirError::Arity {
                op: kind.mnemonic().to_string(),
                expected: n,
                got: operands.len(),
            })
        } else {
            Ok(())
        }
    };
    match kind {
        OpKind::Matmul => {
            need(2)?;
            let (a, b) = (&operands[0], &operands[1]);
            if a.rank() != 2 || b.rank() != 2 || a.dim(1) != b.dim(0) {
                return Err(KirError::ShapeMismatch {
                    context: "matmul".to_string(),
                    a: format!("{a}"),
                    b: format!("{b}"),
                });
            }
            Ok(Shape(vec![a.dim(0), b.dim(1)]))
        }
        OpKind::Conv2d { stride, pad } => {
            need(2)?;
            let (x, w) = (&operands[0], &operands[1]);
            if x.rank() != 4 || w.rank() != 4 || x.dim(1) != w.dim(1) {
                return Err(KirError::ShapeMismatch {
                    context: "conv2d".to_string(),
                    a: format!("{x}"),
                    b: format!("{w}"),
                });
            }
            let oh = (x.dim(2) + 2 * pad).checked_sub(w.dim(2)).map(|v| v / stride + 1);
            let ow = (x.dim(3) + 2 * pad).checked_sub(w.dim(3)).map(|v| v / stride + 1);
            match (oh, ow) {
                (Some(oh), Some(ow)) if oh > 0 && ow > 0 => {
                    Ok(Shape(vec![x.dim(0), w.dim(0), oh, ow]))
                }
                _ => Err(KirError::Invalid(format!(
                    "conv2d kernel {w} too large for input {x}"
                ))),
            }
        }
        OpKind::MaxPool2d { k, stride } | OpKind::AvgPool2d { k, stride } => {
            need(1)?;
            let x = &operands[0];
            if x.rank() != 4 || x.dim(2) < *k || x.dim(3) < *k {
                return Err(KirError::Invalid(format!("pool2d on {x} with k={k}")));
            }
            let oh = (x.dim(2) - k) / stride + 1;
            let ow = (x.dim(3) - k) / stride + 1;
            Ok(Shape(vec![x.dim(0), x.dim(1), oh, ow]))
        }
        OpKind::BiasAdd { axis } => {
            need(2)?;
            let (x, b) = (&operands[0], &operands[1]);
            if *axis >= x.rank() {
                return Err(KirError::BadAxis {
                    axis: *axis,
                    rank: x.rank(),
                });
            }
            if b.rank() != 1 || b.dim(0) != x.dim(*axis) {
                return Err(KirError::ShapeMismatch {
                    context: format!("bias_add axis {axis}"),
                    a: format!("{x}"),
                    b: format!("{b}"),
                });
            }
            Ok(x.clone())
        }
        OpKind::Add | OpKind::Sub | OpKind::Mul => {
            need(2)?;
            if operands[0] != operands[1] {
                return Err(KirError::ShapeMismatch {
                    context: kind.mnemonic().to_string(),
                    a: format!("{}", operands[0]),
                    b: format!("{}", operands[1]),
                });
            }
            Ok(operands[0].clone())
        }
        OpKind::Relu
        | OpKind::Gelu
        | OpKind::Sigmoid
        | OpKind::Tanh
        | OpKind::Exp
        | OpKind::Scale { .. }
        | OpKind::AddConst { .. }
        | OpKind::DivConst { .. }
        | OpKind::Identity
        | OpKind::LayerNorm => {
            need(1)?;
            Ok(operands[0].clone())
        }
        OpKind::Softmax { axis } => {
            need(1)?;
            if *axis >= operands[0].rank() {
                return Err(KirError::BadAxis {
                    axis: *axis,
                    rank: operands[0].rank(),
                });
            }
            Ok(operands[0].clone())
        }
        OpKind::LogSumExp { axis }
        | OpKind::ReduceSum { axis }
        | OpKind::ReduceMax { axis }
        | OpKind::ReduceMean { axis } => {
            need(1)?;
            let x = &operands[0];
            if *axis >= x.rank() {
                return Err(KirError::BadAxis {
                    axis: *axis,
                    rank: x.rank(),
                });
            }
            let mut dims = x.0.clone();
            dims[*axis] = 1;
            Ok(Shape(dims))
        }
        OpKind::Transpose => {
            need(1)?;
            let x = &operands[0];
            if x.rank() != 2 {
                return Err(KirError::Invalid(format!("transpose needs rank-2, got {x}")));
            }
            Ok(Shape(vec![x.dim(1), x.dim(0)]))
        }
        OpKind::Concat { axis } => {
            need(2)?;
            let (a, b) = (&operands[0], &operands[1]);
            if a.rank() != b.rank() || *axis >= a.rank() {
                return Err(KirError::ShapeMismatch {
                    context: format!("concat axis {axis}"),
                    a: format!("{a}"),
                    b: format!("{b}"),
                });
            }
            for d in 0..a.rank() {
                if d != *axis && a.dim(d) != b.dim(d) {
                    return Err(KirError::ShapeMismatch {
                        context: format!("concat axis {axis} (dim {d})"),
                        a: format!("{a}"),
                        b: format!("{b}"),
                    });
                }
            }
            let mut dims = a.0.clone();
            dims[*axis] += b.dim(*axis);
            Ok(Shape(dims))
        }
        OpKind::Reshape { shape } => {
            need(1)?;
            if shape.numel() != operands[0].numel() {
                return Err(KirError::ShapeMismatch {
                    context: "reshape".to_string(),
                    a: format!("{}", operands[0]),
                    b: format!("{shape}"),
                });
            }
            Ok(shape.clone())
        }
    }
}

/// Fluent builder that maintains the topological invariant and infers
/// shapes, so constructing an invalid graph is hard.
pub struct GraphBuilder {
    graph: KernelGraph,
}

impl GraphBuilder {
    /// Start a named, empty graph.
    pub fn new(name: &str) -> Self {
        Self {
            graph: KernelGraph {
                name: name.to_string(),
                inputs: Vec::new(),
                nodes: Vec::new(),
                outputs: Vec::new(),
            },
        }
    }

    /// Declare an f32 graph input.
    pub fn input(&mut self, name: &str, dims: &[usize]) -> ValueRef {
        self.input_typed(name, dims, DType::F32)
    }

    /// Declare a graph input with an explicit element type.
    pub fn input_typed(&mut self, name: &str, dims: &[usize], dtype: DType) -> ValueRef {
        self.graph.inputs.push(TensorSpec {
            name: name.to_string(),
            shape: Shape::of(dims),
            dtype,
        });
        ValueRef::Input(self.graph.inputs.len() - 1)
    }

    /// Append an op node; its shape is inferred (panics on illegal
    /// construction — builder misuse is a programming error).
    pub fn op(&mut self, kind: OpKind, deps: &[ValueRef]) -> ValueRef {
        let operand_shapes: Vec<Shape> =
            deps.iter().map(|d| self.graph.shape_of(*d).clone()).collect();
        let shape = infer_shape(&kind, &operand_shapes)
            .unwrap_or_else(|e| panic!("graph '{}': {e}", self.graph.name));
        let dtype = deps
            .first()
            .map(|d| self.graph.dtype_of(*d))
            .unwrap_or(DType::F32);
        self.graph.nodes.push(Node {
            kind,
            deps: deps.to_vec(),
            shape,
            dtype,
        });
        ValueRef::Node(self.graph.nodes.len() - 1)
    }

    /// Mark a value as a graph output.
    pub fn output(&mut self, r: ValueRef) -> &mut Self {
        self.graph.outputs.push(r);
        self
    }

    /// Validate and return the finished graph (panics if invalid).
    pub fn finish(self) -> KernelGraph {
        let g = self.graph;
        g.validate()
            .unwrap_or_else(|e| panic!("graph '{}' failed validation: {e}", g.name));
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linear_graph() -> KernelGraph {
        let mut b = GraphBuilder::new("linear");
        let x = b.input("x", &[8, 16]);
        let w = b.input("w", &[16, 4]);
        let bias = b.input("b", &[4]);
        let mm = b.op(OpKind::Matmul, &[x, w]);
        let biased = b.op(OpKind::BiasAdd { axis: 1 }, &[mm, bias]);
        let act = b.op(OpKind::Relu, &[biased]);
        b.output(act);
        b.finish()
    }

    #[test]
    fn builder_infers_shapes() {
        let g = linear_graph();
        assert_eq!(g.nodes[0].shape, Shape::of(&[8, 4]));
        assert_eq!(g.nodes[2].shape, Shape::of(&[8, 4]));
        assert!(g.validate().is_ok());
    }

    #[test]
    fn census_classifies() {
        let g = linear_graph();
        let c = g.op_census();
        assert_eq!(c.contractions, 1);
        assert_eq!(c.elementwise, 2);
        assert_eq!(c.total(), 3);
    }

    #[test]
    fn conv_shape_inference() {
        // LeNet conv1: 1x1x28x28, 6x1x5x5, pad 2 → 1x6x28x28
        let s = infer_shape(
            &OpKind::Conv2d { stride: 1, pad: 2 },
            &[Shape::of(&[1, 1, 28, 28]), Shape::of(&[6, 1, 5, 5])],
        )
        .unwrap();
        assert_eq!(s, Shape::of(&[1, 6, 28, 28]));
        // no pad → 24x24
        let s = infer_shape(
            &OpKind::Conv2d { stride: 1, pad: 0 },
            &[Shape::of(&[1, 1, 28, 28]), Shape::of(&[6, 1, 5, 5])],
        )
        .unwrap();
        assert_eq!(s, Shape::of(&[1, 6, 24, 24]));
    }

    #[test]
    fn pool_shape_inference() {
        let s = infer_shape(
            &OpKind::MaxPool2d { k: 2, stride: 2 },
            &[Shape::of(&[1, 6, 28, 28])],
        )
        .unwrap();
        assert_eq!(s, Shape::of(&[1, 6, 14, 14]));
    }

    #[test]
    fn reduce_keepdim() {
        let s = infer_shape(&OpKind::LogSumExp { axis: 1 }, &[Shape::of(&[32, 10])]).unwrap();
        assert_eq!(s, Shape::of(&[32, 1]));
    }

    #[test]
    fn matmul_mismatch_rejected() {
        let e = infer_shape(
            &OpKind::Matmul,
            &[Shape::of(&[2, 3]), Shape::of(&[4, 5])],
        );
        assert!(matches!(e, Err(KirError::ShapeMismatch { .. })));
    }

    #[test]
    fn bad_axis_rejected() {
        let e = infer_shape(&OpKind::ReduceSum { axis: 3 }, &[Shape::of(&[2, 3])]);
        assert!(matches!(e, Err(KirError::BadAxis { .. })));
    }

    #[test]
    fn validate_catches_forward_ref() {
        let mut g = linear_graph();
        // Corrupt: node 0 depends on node 2 (forward reference).
        g.nodes[0].deps[0] = ValueRef::Node(2);
        assert!(matches!(g.validate(), Err(KirError::BadRef(_))));
    }

    #[test]
    fn validate_catches_shape_corruption() {
        let mut g = linear_graph();
        g.nodes[1].shape = Shape::of(&[9, 9]);
        assert!(matches!(g.validate(), Err(KirError::ShapeMismatch { .. })));
    }

    #[test]
    fn users_of_finds_consumers() {
        let g = linear_graph();
        assert_eq!(g.users_of(ValueRef::Node(0)), vec![1]);
        assert_eq!(g.users_of(ValueRef::Input(0)), vec![0]);
        assert!(g.users_of(ValueRef::Node(2)).is_empty());
    }

    #[test]
    fn reshape_checks_numel() {
        assert!(infer_shape(
            &OpKind::Reshape {
                shape: Shape::of(&[4, 4])
            },
            &[Shape::of(&[2, 8])]
        )
        .is_ok());
        assert!(infer_shape(
            &OpKind::Reshape {
                shape: Shape::of(&[4, 5])
            },
            &[Shape::of(&[2, 8])]
        )
        .is_err());
    }
}
