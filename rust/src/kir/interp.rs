//! Reference interpreter for KIR graphs.
//!
//! This is the numeric oracle of the validation harness: after an agent
//! transforms a kernel graph, the harness executes both the original task
//! graph and the transformed graph on identical random inputs (multiple
//! seeds, per the paper's §4.4 "multiple randomized seeds" rule) and
//! compares outputs. Lowering bugs that change semantics — dropped ops,
//! wrong reduction axes, stubbed work — are caught here, exactly as the
//! paper's harness catches miscompiled CUDA.
//!
//! All arithmetic is f32 (matching the CUDA kernels' accumulate-in-f32
//! convention); comparisons use a relative+absolute tolerance.
//!
//! # Performance architecture (§Perf)
//!
//! The interpreter is the dominant cost of the driver's inner loop: every
//! candidate at every rollout step is executed against `verify_seeds`
//! randomized inputs. Two invariants make that hot path allocation-free:
//!
//! - **Arena-backed execution** — [`ExecContext`] owns one output
//!   [`Tensor`] per graph node plus a buffer pool of retired `Vec<f32>`s.
//!   Repeated `execute` calls re-use those buffers in place; a graph with
//!   different per-node shapes triggers a plan rebuild that recycles the
//!   old buffers through the pool instead of freeing them.
//! - **Cached evaluation plan** — per-node output shapes and row-major
//!   strides are derived once per (context, graph-shape) pair. The node
//!   order itself is already topological by construction, so the plan is
//!   exactly the per-node layout metadata. Every op kernel writes each
//!   output element (ops that accumulate, like matmul, zero their buffer
//!   first), so stale pool contents can never leak into results.
//!
//! The free function [`execute`] remains the convenience entry point (a
//! fresh context per call) and is bitwise-identical to pooled execution —
//! asserted by the `hotpath` property tests across the whole task suite.

use super::{DType, KernelGraph, OpKind, Shape, ValueRef};
use crate::util::rng::Rng;

/// A dense f32 tensor in row-major layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Logical shape of the buffer.
    pub shape: Shape,
    /// Elements in row-major order (`shape.numel()` of them).
    pub data: Vec<f32>,
}

impl Tensor {
    /// Wrap a buffer (panics unless `data.len() == shape.numel()`).
    pub fn new(shape: Shape, data: Vec<f32>) -> Self {
        assert_eq!(shape.numel(), data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    /// All-zeros tensor of a shape.
    pub fn zeros(shape: Shape) -> Self {
        let n = shape.numel();
        Self {
            shape,
            data: vec![0.0; n],
        }
    }

    /// Uniform random tensor in [-1, 1) — verification inputs.
    pub fn random(shape: Shape, rng: &mut Rng) -> Self {
        let n = shape.numel();
        Self {
            shape,
            data: (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect(),
        }
    }
}

/// Row-major strides for a shape.
fn row_major_strides(shape: &Shape) -> Vec<usize> {
    let dims = &shape.0;
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Execution failures (the oracle's analog of a CUDA launch failure).
#[derive(Debug, thiserror::Error)]
pub enum InterpError {
    /// Too few input tensors supplied (the count that was supplied).
    #[error("missing input {0}")]
    MissingInput(usize),
    /// An input tensor's shape disagrees with the graph's spec.
    #[error("input {index} shape mismatch: expected {expected}, got {got}")]
    InputShape {
        /// Which input.
        index: usize,
        /// Shape the graph declares.
        expected: String,
        /// Shape actually supplied.
        got: String,
    },
}

fn check_inputs(graph: &KernelGraph, inputs: &[Tensor]) -> Result<(), InterpError> {
    if inputs.len() != graph.inputs.len() {
        return Err(InterpError::MissingInput(inputs.len()));
    }
    for (i, (spec, t)) in graph.inputs.iter().zip(inputs).enumerate() {
        if spec.shape != t.shape {
            return Err(InterpError::InputShape {
                index: i,
                expected: format!("{}", spec.shape),
                got: format!("{}", t.shape),
            });
        }
    }
    Ok(())
}

/// Reusable execution arena: per-node output tensors, their precomputed
/// strides (the cached evaluation plan), and a pool of retired buffers.
///
/// One context serves any sequence of graphs; buffers are recycled across
/// plan rebuilds. Not `Sync` by design — concurrent evaluators (the
/// driver's parallel top-k exploration) each own a private context.
#[derive(Debug, Default)]
pub struct ExecContext {
    /// One output tensor per node; shapes double as the plan fingerprint.
    values: Vec<Tensor>,
    /// Row-major strides per node output (plan metadata).
    strides: Vec<Vec<usize>>,
    /// Retired `Vec<f32>` buffers awaiting reuse (kept across rebuilds).
    pool: Vec<Vec<f32>>,
}

impl ExecContext {
    /// A fresh arena with an empty plan and buffer pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of pooled (idle) buffers — observability for tests/benches.
    pub fn pooled_buffers(&self) -> usize {
        self.pool.len()
    }

    /// Take a zeroed buffer of length `n`, preferring the smallest pooled
    /// buffer whose capacity suffices.
    fn take_buffer(&mut self, n: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.pool.iter().enumerate() {
            if b.capacity() >= n {
                match best {
                    Some(j) if self.pool[j].capacity() <= b.capacity() => {}
                    _ => best = Some(i),
                }
            }
        }
        match best {
            Some(i) => {
                let mut b = self.pool.swap_remove(i);
                b.clear();
                b.resize(n, 0.0);
                b
            }
            None => vec![0.0; n],
        }
    }

    /// (Re)build the evaluation plan if the graph's per-node shapes differ
    /// from the cached ones. Old buffers are recycled through the pool.
    fn ensure_plan(&mut self, graph: &KernelGraph) {
        let reusable = self.values.len() == graph.nodes.len()
            && self
                .values
                .iter()
                .zip(&graph.nodes)
                .all(|(v, n)| v.shape == n.shape);
        if reusable {
            return;
        }
        // The buffer-reuse design leans on each node's recorded shape
        // being the op's true output shape (plan == node.shape ==
        // inference result). Re-derive it once per plan build in debug
        // builds — the check the allocating eval_op did per node eval.
        // (Harness-path graphs are additionally shape-checked up front by
        // `Candidate::validate`.)
        #[cfg(debug_assertions)]
        for (idx, node) in graph.nodes.iter().enumerate() {
            let operand_shapes: Vec<Shape> = node
                .deps
                .iter()
                .map(|d| graph.shape_of(*d).clone())
                .collect();
            match super::infer_shape(&node.kind, &operand_shapes) {
                Ok(expected) => debug_assert_eq!(
                    expected, node.shape,
                    "node {idx} ({:?}) has wrong recorded shape",
                    node.kind
                ),
                Err(e) => debug_assert!(false, "shape inference failed at node {idx}: {e}"),
            }
        }
        for t in self.values.drain(..) {
            self.pool.push(t.data);
        }
        self.strides.clear();
        for node in &graph.nodes {
            let n = node.shape.numel();
            let data = self.take_buffer(n);
            self.values.push(Tensor {
                shape: node.shape.clone(),
                data,
            });
            self.strides.push(row_major_strides(&node.shape));
        }
    }

    /// Execute the graph, returning borrowed output tensors (no clones).
    /// The borrows keep the context frozen until dropped.
    pub fn execute<'a>(
        &'a mut self,
        graph: &KernelGraph,
        inputs: &'a [Tensor],
    ) -> Result<Vec<&'a Tensor>, InterpError> {
        check_inputs(graph, inputs)?;
        self.ensure_plan(graph);
        for i in 0..graph.nodes.len() {
            let node = &graph.nodes[i];
            // Split so node i's buffer is writable while earlier outputs
            // stay readable (values are topologically ordered).
            let (done, rest) = self.values.split_at_mut(i);
            let out = &mut rest[0];
            let operands: Vec<&Tensor> = node
                .deps
                .iter()
                .map(|d| match d {
                    ValueRef::Input(j) => &inputs[*j],
                    ValueRef::Node(j) => &done[*j],
                })
                .collect();
            eval_op_into(&node.kind, &operands, &self.strides[i], out);
            // Model reduced-precision storage: rounding through f16/bf16
            // between kernels keeps the oracle honest about mixed
            // precision.
            if node.dtype != DType::F32 {
                for v in &mut out.data {
                    *v = round_to(*v, node.dtype);
                }
            }
        }
        Ok(graph
            .outputs
            .iter()
            .map(|o| match o {
                ValueRef::Input(i) => &inputs[*i],
                ValueRef::Node(i) => &self.values[*i],
            })
            .collect())
    }

    /// Execute and clone the outputs out of the arena (for callers that
    /// need owned tensors, e.g. the verification cache).
    pub fn execute_owned(
        &mut self,
        graph: &KernelGraph,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>, InterpError> {
        let outs = self.execute(graph, inputs)?;
        Ok(outs.into_iter().cloned().collect())
    }
}

/// Execute the graph on the given inputs (indexed as graph.inputs) with a
/// fresh single-use arena. Hot paths that execute repeatedly should hold
/// an [`ExecContext`] instead (§Perf above).
pub fn execute(graph: &KernelGraph, inputs: &[Tensor]) -> Result<Vec<Tensor>, InterpError> {
    let mut ctx = ExecContext::new();
    ctx.execute_owned(graph, inputs)
}

/// Generate random inputs for a graph with a given seed.
pub fn random_inputs(graph: &KernelGraph, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed).derive("interp-inputs");
    graph
        .inputs
        .iter()
        .map(|spec| Tensor::random(spec.shape.clone(), &mut rng))
        .collect()
}

/// Numeric comparison: max |a-b| / (atol + rtol*|b|) <= 1.
pub fn allclose(a: &Tensor, b: &Tensor, rtol: f32, atol: f32) -> bool {
    if a.shape != b.shape {
        return false;
    }
    a.data
        .iter()
        .zip(&b.data)
        .all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs())
}

/// Largest elementwise absolute difference (reported in harness feedback).
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    if a.shape != b.shape {
        return f32::INFINITY;
    }
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Evaluate one op into a preallocated output tensor whose shape is the
/// node's inferred shape. Every kernel writes all of `out` (accumulating
/// kernels zero it first), so buffer reuse is safe.
fn eval_op_into(kind: &OpKind, operands: &[&Tensor], strides: &[usize], out: &mut Tensor) {
    debug_assert_eq!(out.shape.numel(), out.data.len());
    match kind {
        OpKind::Matmul => matmul_into(operands[0], operands[1], &mut out.data),
        OpKind::Conv2d { stride, pad } => {
            conv2d_into(operands[0], operands[1], *stride, *pad, &mut out.data)
        }
        OpKind::MaxPool2d { k, stride } => {
            pool2d_into(operands[0], *k, *stride, PoolKind::Max, &mut out.data)
        }
        OpKind::AvgPool2d { k, stride } => {
            pool2d_into(operands[0], *k, *stride, PoolKind::Avg, &mut out.data)
        }
        OpKind::BiasAdd { axis } => {
            bias_add_into(operands[0], operands[1], *axis, strides, &mut out.data)
        }
        OpKind::Relu => map1_into(operands[0], &mut out.data, |x| x.max(0.0)),
        OpKind::Gelu => map1_into(operands[0], &mut out.data, |x| {
            // tanh approximation, matching jax.nn.gelu(approximate=True)
            0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f32).tanh())
        }),
        OpKind::Sigmoid => map1_into(operands[0], &mut out.data, |x| 1.0 / (1.0 + (-x).exp())),
        OpKind::Tanh => map1_into(operands[0], &mut out.data, f32::tanh),
        OpKind::Exp => map1_into(operands[0], &mut out.data, f32::exp),
        OpKind::Scale { c } => {
            let c = *c;
            map1_into(operands[0], &mut out.data, move |x| x * c)
        }
        OpKind::AddConst { c } => {
            let c = *c;
            map1_into(operands[0], &mut out.data, move |x| x + c)
        }
        OpKind::DivConst { c } => {
            let c = *c;
            map1_into(operands[0], &mut out.data, move |x| x / c)
        }
        OpKind::Add => map2_into(operands[0], operands[1], &mut out.data, |a, b| a + b),
        OpKind::Sub => map2_into(operands[0], operands[1], &mut out.data, |a, b| a - b),
        OpKind::Mul => map2_into(operands[0], operands[1], &mut out.data, |a, b| a * b),
        OpKind::Softmax { axis } => softmax_into(operands[0], *axis, &mut out.data),
        OpKind::LogSumExp { axis } => {
            reduce_into(operands[0], *axis, ReduceKind::LogSumExp, &mut out.data)
        }
        OpKind::ReduceSum { axis } => reduce_into(operands[0], *axis, ReduceKind::Sum, &mut out.data),
        OpKind::ReduceMax { axis } => reduce_into(operands[0], *axis, ReduceKind::Max, &mut out.data),
        OpKind::ReduceMean { axis } => {
            reduce_into(operands[0], *axis, ReduceKind::Mean, &mut out.data)
        }
        OpKind::Transpose => transpose_into(operands[0], &mut out.data),
        OpKind::Reshape { .. } => out.data.copy_from_slice(&operands[0].data),
        OpKind::LayerNorm => layer_norm_into(operands[0], &mut out.data),
        OpKind::Concat { axis } => concat_into(operands[0], operands[1], *axis, &mut out.data),
        OpKind::Identity => out.data.copy_from_slice(&operands[0].data),
    }
}

fn round_to(x: f32, dtype: DType) -> f32 {
    match dtype {
        DType::F32 => x,
        DType::BF16 => f32::from_bits(x.to_bits() & 0xFFFF_0000),
        DType::F16 => {
            // Crude but monotone f16 rounding: clamp + truncate mantissa to
            // 10 bits. Adequate for tolerance-based comparisons.
            let clamped = x.clamp(-65504.0, 65504.0);
            let bits = clamped.to_bits();
            f32::from_bits(bits & 0xFFFF_E000)
        }
    }
}

fn map1_into(a: &Tensor, out: &mut [f32], f: impl Fn(f32) -> f32) {
    debug_assert_eq!(a.data.len(), out.len());
    for (o, x) in out.iter_mut().zip(&a.data) {
        *o = f(*x);
    }
}

fn map2_into(a: &Tensor, b: &Tensor, out: &mut [f32], f: impl Fn(f32, f32) -> f32) {
    assert_eq!(a.shape, b.shape);
    debug_assert_eq!(a.data.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(&a.data).zip(&b.data) {
        *o = f(*x, *y);
    }
}

fn matmul_into(a: &Tensor, b: &Tensor, out: &mut [f32]) {
    let (m, k) = (a.shape.dim(0), a.shape.dim(1));
    let n = b.shape.dim(1);
    assert_eq!(k, b.shape.dim(0));
    debug_assert_eq!(out.len(), m * n);
    // Accumulating kernel: zero the (possibly recycled) buffer first.
    out.fill(0.0);
    for i in 0..m {
        for kk in 0..k {
            let av = a.data[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

fn conv2d_into(x: &Tensor, w: &Tensor, stride: usize, pad: usize, out: &mut [f32]) {
    let (n, c_in, h, wd) = (
        x.shape.dim(0),
        x.shape.dim(1),
        x.shape.dim(2),
        x.shape.dim(3),
    );
    let (c_out, _, kh, kw) = (
        w.shape.dim(0),
        w.shape.dim(1),
        w.shape.dim(2),
        w.shape.dim(3),
    );
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (wd + 2 * pad - kw) / stride + 1;
    debug_assert_eq!(out.len(), n * c_out * oh * ow);
    // §Perf: slice-based inner loops (kx contiguous in both x and w)
    // avoid per-element index arithmetic and bounds checks; interior
    // output pixels (no padding clipping) take a branch-free fast path.
    for b in 0..n {
        for oc in 0..c_out {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ic in 0..c_in {
                        let x_base = (b * c_in + ic) * h;
                        let w_base = (oc * c_in + ic) * kh;
                        for ky in 0..kh {
                            let iy = oy * stride + ky;
                            if iy < pad || iy - pad >= h {
                                continue;
                            }
                            let iy = iy - pad;
                            let wrow = &w.data[(w_base + ky) * kw..(w_base + ky) * kw + kw];
                            let ix0 = ox * stride;
                            if ix0 >= pad && ix0 + kw - 1 < wd + pad {
                                // Interior along x: whole kw run in-bounds.
                                let xs = (x_base + iy) * wd + (ix0 - pad);
                                let xrow = &x.data[xs..xs + kw];
                                for (xv, wv) in xrow.iter().zip(wrow) {
                                    acc += xv * wv;
                                }
                            } else {
                                for (kx, wv) in wrow.iter().enumerate() {
                                    let ix = ix0 + kx;
                                    if ix < pad || ix - pad >= wd {
                                        continue;
                                    }
                                    acc += x.data[(x_base + iy) * wd + (ix - pad)] * wv;
                                }
                            }
                        }
                    }
                    out[((b * c_out + oc) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
}

enum PoolKind {
    Max,
    Avg,
}

fn pool2d_into(x: &Tensor, k: usize, stride: usize, kind: PoolKind, out: &mut [f32]) {
    let (n, c, h, w) = (
        x.shape.dim(0),
        x.shape.dim(1),
        x.shape.dim(2),
        x.shape.dim(3),
    );
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    debug_assert_eq!(out.len(), n * c * oh * ow);
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = match kind {
                        PoolKind::Max => f32::NEG_INFINITY,
                        PoolKind::Avg => 0.0,
                    };
                    for ky in 0..k {
                        for kx in 0..k {
                            let v = x.data
                                [((b * c + ch) * h + oy * stride + ky) * w + ox * stride + kx];
                            match kind {
                                PoolKind::Max => acc = acc.max(v),
                                PoolKind::Avg => acc += v,
                            }
                        }
                    }
                    if matches!(kind, PoolKind::Avg) {
                        acc /= (k * k) as f32;
                    }
                    out[((b * c + ch) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
}

fn bias_add_into(x: &Tensor, bias: &Tensor, axis: usize, strides: &[usize], out: &mut [f32]) {
    // `strides` is the plan's row-major strides of x's shape (== output
    // shape for bias_add).
    debug_assert_eq!(strides.len(), x.shape.rank());
    let dim = x.shape.dim(axis);
    let stride = strides[axis];
    debug_assert_eq!(out.len(), x.data.len());
    for (i, (o, v)) in out.iter_mut().zip(&x.data).enumerate() {
        *o = v + bias.data[(i / stride) % dim];
    }
}

enum ReduceKind {
    Sum,
    Max,
    Mean,
    LogSumExp,
}

/// Keepdim reduction along `axis`.
fn reduce_into(x: &Tensor, axis: usize, kind: ReduceKind, out: &mut [f32]) {
    let dims = &x.shape.0;
    let axis_len = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let outer: usize = dims[..axis].iter().product();
    debug_assert_eq!(out.len(), outer * inner);
    for o in 0..outer {
        for i in 0..inner {
            let at = |a: usize| x.data[o * axis_len * inner + a * inner + i];
            let v = match kind {
                ReduceKind::Sum => (0..axis_len).map(at).sum(),
                ReduceKind::Mean => (0..axis_len).map(at).sum::<f32>() / axis_len as f32,
                ReduceKind::Max => (0..axis_len).map(at).fold(f32::NEG_INFINITY, f32::max),
                ReduceKind::LogSumExp => {
                    let m = (0..axis_len).map(at).fold(f32::NEG_INFINITY, f32::max);
                    let s: f32 = (0..axis_len).map(|a| (at(a) - m).exp()).sum();
                    m + s.ln()
                }
            };
            out[o * inner + i] = v;
        }
    }
}

fn softmax_into(x: &Tensor, axis: usize, out: &mut [f32]) {
    let dims = &x.shape.0;
    let axis_len = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let outer: usize = dims[..axis].iter().product();
    debug_assert_eq!(out.len(), x.data.len());
    for o in 0..outer {
        for i in 0..inner {
            let idx = |a: usize| o * axis_len * inner + a * inner + i;
            let m = (0..axis_len)
                .map(|a| x.data[idx(a)])
                .fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for a in 0..axis_len {
                let e = (x.data[idx(a)] - m).exp();
                out[idx(a)] = e;
                denom += e;
            }
            for a in 0..axis_len {
                out[idx(a)] /= denom;
            }
        }
    }
}

fn transpose_into(x: &Tensor, out: &mut [f32]) {
    let (m, n) = (x.shape.dim(0), x.shape.dim(1));
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = x.data[i * n + j];
        }
    }
}

fn concat_into(a: &Tensor, b: &Tensor, axis: usize, out: &mut [f32]) {
    let a_dims = &a.shape.0;
    let b_dims = &b.shape.0;
    let outer: usize = a_dims[..axis].iter().product();
    let a_block: usize = a_dims[axis..].iter().product();
    let b_block: usize = b_dims[axis..].iter().product();
    debug_assert_eq!(out.len(), a.data.len() + b.data.len());
    let step = a_block + b_block;
    for o in 0..outer {
        out[o * step..o * step + a_block]
            .copy_from_slice(&a.data[o * a_block..(o + 1) * a_block]);
        out[o * step + a_block..(o + 1) * step]
            .copy_from_slice(&b.data[o * b_block..(o + 1) * b_block]);
    }
}

/// LayerNorm over the last axis, eps 1e-5, no affine params.
fn layer_norm_into(x: &Tensor, out: &mut [f32]) {
    let dims = &x.shape.0;
    let last = *dims.last().unwrap();
    let rows = x.data.len() / last;
    debug_assert_eq!(out.len(), x.data.len());
    for r in 0..rows {
        let row = &x.data[r * last..(r + 1) * last];
        let mean: f32 = row.iter().sum::<f32>() / last as f32;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / last as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (i, v) in row.iter().enumerate() {
            out[r * last + i] = (v - mean) * inv;
        }
    }
}

// ---- allocating wrappers (unit-test convenience only) ----

#[cfg(test)]
fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(Shape(vec![a.shape.dim(0), b.shape.dim(1)]));
    matmul_into(a, b, &mut out.data);
    out
}

#[cfg(test)]
fn conv2d(x: &Tensor, w: &Tensor, stride: usize, pad: usize) -> Tensor {
    let oh = (x.shape.dim(2) + 2 * pad - w.shape.dim(2)) / stride + 1;
    let ow = (x.shape.dim(3) + 2 * pad - w.shape.dim(3)) / stride + 1;
    let mut out = Tensor::zeros(Shape(vec![x.shape.dim(0), w.shape.dim(0), oh, ow]));
    conv2d_into(x, w, stride, pad, &mut out.data);
    out
}

#[cfg(test)]
fn pool2d(x: &Tensor, k: usize, stride: usize, kind: PoolKind) -> Tensor {
    let oh = (x.shape.dim(2) - k) / stride + 1;
    let ow = (x.shape.dim(3) - k) / stride + 1;
    let mut out = Tensor::zeros(Shape(vec![x.shape.dim(0), x.shape.dim(1), oh, ow]));
    pool2d_into(x, k, stride, kind, &mut out.data);
    out
}

#[cfg(test)]
fn bias_add(x: &Tensor, bias: &Tensor, axis: usize) -> Tensor {
    let mut out = Tensor::zeros(x.shape.clone());
    bias_add_into(x, bias, axis, &row_major_strides(&x.shape), &mut out.data);
    out
}

#[cfg(test)]
fn reduce(x: &Tensor, axis: usize, kind: ReduceKind) -> Tensor {
    let mut dims = x.shape.0.clone();
    dims[axis] = 1;
    let mut out = Tensor::zeros(Shape(dims));
    reduce_into(x, axis, kind, &mut out.data);
    out
}

#[cfg(test)]
fn softmax(x: &Tensor, axis: usize) -> Tensor {
    let mut out = Tensor::zeros(x.shape.clone());
    softmax_into(x, axis, &mut out.data);
    out
}

#[cfg(test)]
fn transpose(x: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(Shape(vec![x.shape.dim(1), x.shape.dim(0)]));
    transpose_into(x, &mut out.data);
    out
}

#[cfg(test)]
fn layer_norm(x: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(x.shape.clone());
    layer_norm_into(x, &mut out.data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::{GraphBuilder, OpKind};

    #[test]
    fn matmul_known() {
        let a = Tensor::new(Shape(vec![2, 2]), vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(Shape(vec![2, 2]), vec![1.0, 1.0, 1.0, 1.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with weight=1 is identity.
        let x = Tensor::new(Shape(vec![1, 1, 2, 2]), vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::new(Shape(vec![1, 1, 1, 1]), vec![1.0]);
        let y = conv2d(&x, &w, 1, 0);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_padding_sums() {
        // 3x3 all-ones kernel, pad 1, on all-ones 3x3 input: center = 9,
        // corners = 4, edges = 6.
        let x = Tensor::new(Shape(vec![1, 1, 3, 3]), vec![1.0; 9]);
        let w = Tensor::new(Shape(vec![1, 1, 3, 3]), vec![1.0; 9]);
        let y = conv2d(&x, &w, 1, 1);
        assert_eq!(y.shape, Shape(vec![1, 1, 3, 3]));
        assert_eq!(y.data[4], 9.0);
        assert_eq!(y.data[0], 4.0);
        assert_eq!(y.data[1], 6.0);
    }

    #[test]
    fn maxpool_known() {
        let x = Tensor::new(
            Shape(vec![1, 1, 2, 2]),
            vec![1.0, 5.0, 3.0, 2.0],
        );
        let y = pool2d(&x, 2, 2, PoolKind::Max);
        assert_eq!(y.data, vec![5.0]);
        let y = pool2d(&x, 2, 2, PoolKind::Avg);
        assert_eq!(y.data, vec![2.75]);
    }

    #[test]
    fn logsumexp_on_singleton_axis_is_identity() {
        // The Level-2 Q18 algebraic fact: logsumexp over a size-1 axis is x.
        let x = Tensor::new(Shape(vec![3, 1]), vec![0.5, -2.0, 7.0]);
        let y = reduce(&x, 1, ReduceKind::LogSumExp);
        assert!(allclose(&x, &y, 1e-6, 1e-6));
    }

    #[test]
    fn logsumexp_matches_manual() {
        let x = Tensor::new(Shape(vec![1, 3]), vec![1.0, 2.0, 3.0]);
        let y = reduce(&x, 1, ReduceKind::LogSumExp);
        let expected = ((1.0f32).exp() + (2.0f32).exp() + (3.0f32).exp()).ln();
        assert!((y.data[0] - expected).abs() < 1e-5);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(5);
        let x = Tensor::random(Shape(vec![4, 7]), &mut rng);
        let y = softmax(&x, 1);
        for r in 0..4 {
            let s: f32 = y.data[r * 7..(r + 1) * 7].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn bias_add_axis1() {
        let x = Tensor::new(Shape(vec![2, 3]), vec![0.0; 6]);
        let b = Tensor::new(Shape(vec![3]), vec![1.0, 2.0, 3.0]);
        let y = bias_add(&x, &b, 1);
        assert_eq!(y.data, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn bias_add_axis1_nchw() {
        // Channel bias on NCHW: axis=1 broadcast over H,W.
        let x = Tensor::new(Shape(vec![1, 2, 1, 2]), vec![0.0; 4]);
        let b = Tensor::new(Shape(vec![2]), vec![10.0, 20.0]);
        let y = bias_add(&x, &b, 1);
        assert_eq!(y.data, vec![10.0, 10.0, 20.0, 20.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(7);
        let x = Tensor::random(Shape(vec![3, 5]), &mut rng);
        let y = transpose(&transpose(&x));
        assert_eq!(x, y);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut rng = Rng::new(9);
        let x = Tensor::random(Shape(vec![2, 64]), &mut rng);
        let y = layer_norm(&x);
        for r in 0..2 {
            let row = &y.data[r * 64..(r + 1) * 64];
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn graph_execution_end_to_end() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 3]);
        let w = b.input("w", &[3, 2]);
        let mm = b.op(OpKind::Matmul, &[x, w]);
        let act = b.op(OpKind::Relu, &[mm]);
        b.output(act);
        let g = b.finish();
        let xs = vec![
            Tensor::new(Shape(vec![2, 3]), vec![1.0, 0.0, -1.0, 2.0, 2.0, 2.0]),
            Tensor::new(Shape(vec![3, 2]), vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0]),
        ];
        let out = execute(&g, &xs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].data, vec![0.0, 0.0, 6.0, 0.0]);
    }

    #[test]
    fn execute_rejects_wrong_shape_inputs() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 3]);
        let y = b.op(OpKind::Relu, &[x]);
        b.output(y);
        let g = b.finish();
        let bad = vec![Tensor::zeros(Shape(vec![3, 2]))];
        assert!(execute(&g, &bad).is_err());
    }

    #[test]
    fn random_inputs_deterministic() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 4]);
        let y = b.op(OpKind::Relu, &[x]);
        b.output(y);
        let g = b.finish();
        assert_eq!(random_inputs(&g, 1)[0], random_inputs(&g, 1)[0]);
        assert_ne!(random_inputs(&g, 1)[0], random_inputs(&g, 2)[0]);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::new(Shape(vec![2]), vec![1.0, 100.0]);
        let b = Tensor::new(Shape(vec![2]), vec![1.0 + 1e-6, 100.0 + 1e-4]);
        assert!(allclose(&a, &b, 1e-5, 1e-5));
        let c = Tensor::new(Shape(vec![2]), vec![1.1, 100.0]);
        assert!(!allclose(&a, &c, 1e-5, 1e-5));
        assert!(max_abs_diff(&a, &c) > 0.09);
    }

    #[test]
    fn bf16_rounding_monotone_and_close() {
        for x in [0.1f32, -3.75, 1000.0, 1e-3] {
            let r = round_to(x, DType::BF16);
            assert!((r - x).abs() / x.abs() < 0.01, "x={x} r={r}");
        }
        let r = round_to(70000.0, DType::F16);
        assert!(r <= 65504.0);
    }

    #[test]
    fn pooled_context_reuses_buffers_and_matches_fresh() {
        let mut b = GraphBuilder::new("mlp");
        let x = b.input("x", &[8, 16]);
        let w1 = b.input("w1", &[16, 16]);
        let w2 = b.input("w2", &[16, 4]);
        let h = b.op(OpKind::Matmul, &[x, w1]);
        let a = b.op(OpKind::Gelu, &[h]);
        let o = b.op(OpKind::Matmul, &[a, w2]);
        let s = b.op(OpKind::Softmax { axis: 1 }, &[o]);
        b.output(s);
        let g = b.finish();
        let mut ctx = ExecContext::new();
        for seed in 0..4u64 {
            let inputs = random_inputs(&g, seed);
            let fresh = execute(&g, &inputs).unwrap();
            let pooled = ctx.execute(&g, &inputs).unwrap();
            assert_eq!(pooled.len(), fresh.len());
            for (p, f) in pooled.iter().zip(&fresh) {
                assert_eq!(p.data, f.data, "seed {seed}: pooled != fresh");
            }
        }
    }

    #[test]
    fn context_rebuilds_plan_on_shape_change_and_recycles() {
        let make = |n: usize| {
            let mut b = GraphBuilder::new("r");
            let x = b.input("x", &[n, n]);
            let y = b.op(OpKind::Relu, &[x]);
            b.output(y);
            b.finish()
        };
        let g8 = make(8);
        let g4 = make(4);
        let mut ctx = ExecContext::new();
        let i8 = random_inputs(&g8, 1);
        let i4 = random_inputs(&g4, 1);
        ctx.execute_owned(&g8, &i8).unwrap();
        assert_eq!(ctx.pooled_buffers(), 0);
        // Shrinking reuses the 64-element buffer from the pool.
        let small = ctx.execute_owned(&g4, &i4).unwrap();
        assert_eq!(small[0].data.len(), 16);
        let fresh = execute(&g4, &i4).unwrap();
        assert_eq!(small[0].data, fresh[0].data);
        // Growing back still agrees with fresh execution.
        let big = ctx.execute_owned(&g8, &i8).unwrap();
        assert_eq!(big[0].data, execute(&g8, &i8).unwrap()[0].data);
    }
}
