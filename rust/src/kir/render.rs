//! CUDA-like source rendering of (graph, schedule) pairs.
//!
//! The paper's agents read and write CUDA source; ours act on KIR, but two
//! parts of the system still need a source-text view:
//!
//! 1. **Token accounting** (§4.10 / Fig. 10): prompt and completion sizes
//!    scale with the rendered kernel source, reproducing the paper's
//!    observation that Level-3 problems are "extremely verbose source
//!    files … diluting LLMs' ability to identify performance signals".
//! 2. **Soft verification** (§4.4): the LLM-based verifier scans the
//!    rendered source for structural red flags (eliminated functionality,
//!    external library calls).
//!
//! The renderer is deterministic and cheap; it does not aim to be
//! compilable CUDA, but it is structurally faithful: one `__global__`
//! function per fusion group, loop nests reflecting the schedule flags.

use super::schedule::{Schedule, Tiling};
use super::{KernelGraph, OpKind};

/// Render the full "source file" for a scheduled kernel.
pub fn render(graph: &KernelGraph, schedule: &Schedule) -> String {
    let mut out = String::with_capacity(4096);
    out.push_str(&format!(
        "// generated kernel: {} ({} launches)\n#include <cuda_runtime.h>\n\n",
        graph.name,
        schedule.n_launches()
    ));
    for (gi, group) in schedule.groups.iter().enumerate() {
        let ops: Vec<&'static str> = group
            .nodes
            .iter()
            .map(|n| graph.nodes[*n].kind.mnemonic())
            .collect();
        out.push_str(&format!(
            "__global__ void kernel_{gi}_{}(/* {} */) {{\n",
            ops.join("_"),
            describe_flags(group)
        ));
        if group.opts.vendor_lib {
            out.push_str("    // dispatch to vendor library (cudnn/cublas)\n");
            out.push_str(&format!("    cudnnConvolutionForward_or_cublasGemmEx();\n"));
        }
        if let Tiling::Shared { tile } = group.opts.tiling {
            out.push_str(&format!(
                "    __shared__ float s_tile[{tile}][32];  // staged operand tile\n"
            ));
            if group.opts.double_buffer {
                out.push_str(&format!(
                    "    __shared__ float s_tile_next[{tile}][32];  // double buffer\n"
                ));
            }
        }
        if group.opts.ilp > 1 {
            out.push_str(&format!(
                "    float acc[{}];  // independent accumulators (ILP)\n",
                group.opts.ilp
            ));
        }
        for &ni in &group.nodes {
            render_node_body(graph, ni, group.opts.unroll, &mut out);
        }
        if group.opts.warp_shuffle_reduction {
            out.push_str(
                "    for (int o = 16; o > 0; o >>= 1) v += __shfl_down_sync(0xffffffff, v, o);\n",
            );
        }
        if group.opts.split_k > 1 {
            out.push_str(&format!(
                "    atomicAdd(&workspace[out_idx], partial);  // split-K x{}\n",
                group.opts.split_k
            ));
        }
        out.push_str("}\n\n");
        out.push_str(&format!(
            "// launch: <<<{}, {}>>> regs/thread={} {}\n\n",
            group.launch.grid,
            group.launch.block,
            group.opts.regs_per_thread,
            if group.opts.fast_math { "-use_fast_math" } else { "" }
        ));
    }
    out
}

fn describe_flags(group: &super::schedule::FusionGroup) -> String {
    let o = &group.opts;
    let mut parts = Vec::new();
    if !matches!(o.tiling, Tiling::None) {
        parts.push("smem-tiled".to_string());
    }
    if o.tensor_core {
        parts.push("wmma".to_string());
    }
    if o.vector_width > 1 {
        parts.push(format!("vec{}", o.vector_width));
    }
    if o.coarsening > 1 {
        parts.push(format!("coarsen{}", o.coarsening));
    }
    if o.simplified_control_flow {
        parts.push("branchless".to_string());
    }
    if parts.is_empty() {
        parts.push("naive".to_string());
    }
    parts.join(",")
}

fn render_node_body(graph: &KernelGraph, ni: usize, unroll: usize, out: &mut String) {
    let node = &graph.nodes[ni];
    let pragma = if unroll > 1 {
        format!("    #pragma unroll {unroll}\n")
    } else {
        String::new()
    };
    match &node.kind {
        OpKind::Matmul => {
            out.push_str(&pragma);
            out.push_str(&format!(
                "    for (int k = 0; k < K; ++k) acc += a[row*K+k] * b[k*N+col];  // matmul {}\n",
                node.shape
            ));
        }
        OpKind::Conv2d { stride, pad } => {
            out.push_str(&pragma);
            out.push_str(&format!(
                "    for (int ic=0;ic<C;++ic) for (int ky=0;ky<KH;++ky) for (int kx=0;kx<KW;++kx)\n        acc += x[...] * w[...];  // conv2d s={stride} p={pad} {}\n",
                node.shape
            ));
        }
        OpKind::MaxPool2d { k, .. } => {
            out.push_str(&format!(
                "    for (int i=0;i<{k}*{k};++i) m = fmaxf(m, window[i]);  // maxpool\n"
            ));
        }
        OpKind::AvgPool2d { k, .. } => {
            out.push_str(&format!(
                "    for (int i=0;i<{k}*{k};++i) s += window[i]; s /= {};  // avgpool\n",
                k * k
            ));
        }
        OpKind::LogSumExp { axis } => {
            out.push_str(&format!(
                "    m = rowmax(x); v = m + logf(rowsum(expf(x - m)));  // logsumexp axis={axis}\n"
            ));
        }
        OpKind::Softmax { axis } => {
            out.push_str(&format!(
                "    m = rowmax(x); e = expf(x - m); v = e / rowsum(e);  // softmax axis={axis}\n"
            ));
        }
        OpKind::ReduceSum { axis } | OpKind::ReduceMean { axis } => {
            out.push_str(&pragma);
            out.push_str(&format!(
                "    for (int i = tid; i < R; i += blockDim.x) acc += x[i];  // reduce axis={axis}\n"
            ));
        }
        OpKind::ReduceMax { axis } => {
            out.push_str(&format!(
                "    for (int i = tid; i < R; i += blockDim.x) m = fmaxf(m, x[i]);  // reduce_max axis={axis}\n"
            ));
        }
        OpKind::Identity => {
            out.push_str("    y[idx] = x[idx];  // identity (COPY — verify this is intended)\n");
        }
        other => {
            out.push_str(&format!(
                "    y[idx] = {}(x[idx]);  // {} {}\n",
                other.mnemonic(),
                other.mnemonic(),
                node.shape
            ));
        }
    }
}

/// Token count model: ~1 token per 4 source characters (the usual BPE rule
/// of thumb). Used by the cost accounting in Fig. 10 / §6.4.
pub fn token_count(source: &str) -> usize {
    source.len().div_ceil(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::schedule::{Schedule, Tiling};
    use crate::kir::{GraphBuilder, OpKind};

    fn small() -> (KernelGraph, Schedule) {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[8, 8]);
        let w = b.input("w", &[8, 8]);
        let mm = b.op(OpKind::Matmul, &[x, w]);
        let r = b.op(OpKind::Relu, &[mm]);
        b.output(r);
        let g = b.finish();
        let s = Schedule::naive(&g);
        (g, s)
    }

    #[test]
    fn renders_one_function_per_group() {
        let (g, s) = small();
        let src = render(&g, &s);
        assert_eq!(src.matches("__global__").count(), 2);
        assert!(src.contains("matmul"));
        assert!(src.contains("relu"));
    }

    #[test]
    fn fused_renders_single_function() {
        let (g, mut s) = small();
        s.fuse(0, 1);
        let src = render(&g, &s);
        assert_eq!(src.matches("__global__").count(), 1);
        assert!(src.contains("kernel_0_matmul_relu"));
    }

    #[test]
    fn flags_visible_in_source() {
        let (g, mut s) = small();
        s.groups[0].opts.tiling = Tiling::Shared { tile: 32 };
        s.groups[0].opts.ilp = 8;
        s.groups[0].opts.split_k = 4;
        s.groups[0].opts.warp_shuffle_reduction = true;
        let src = render(&g, &s);
        assert!(src.contains("__shared__ float s_tile[32]"));
        assert!(src.contains("float acc[8]"));
        assert!(src.contains("atomicAdd"));
        assert!(src.contains("__shfl_down_sync"));
    }

    #[test]
    fn vendor_lib_marker_present() {
        let (g, mut s) = small();
        s.groups[0].opts.vendor_lib = true;
        let src = render(&g, &s);
        assert!(src.contains("cudnn") || src.contains("cublas"));
    }

    #[test]
    fn token_count_scales_with_source() {
        let (g, s) = small();
        let t1 = token_count(&render(&g, &s));
        assert!(t1 > 50);
        assert_eq!(token_count("abcd"), 1);
        assert_eq!(token_count("abcde"), 2);
    }

    #[test]
    fn identity_is_flagged_in_source() {
        let mut b = GraphBuilder::new("hack");
        let x = b.input("x", &[4, 4]);
        let i = b.op(OpKind::Identity, &[x]);
        b.output(i);
        let g = b.finish();
        let src = render(&g, &Schedule::naive(&g));
        assert!(src.contains("COPY"));
    }
}
