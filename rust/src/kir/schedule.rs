//! Execution schedules over KIR graphs.
//!
//! A [`Schedule`] partitions the graph's nodes into [`FusionGroup`]s — each
//! group is one simulated kernel launch — and attaches per-group execution
//! attributes ([`GroupOpts`]) that the optimization techniques mutate:
//! tiling, vectorization, ILP/unrolling, tensor-core use, split-K, launch
//! geometry, and so on. The GPU performance model consumes (graph, schedule)
//! pairs; the optimization catalog transforms them.
//!
//! Legality rules enforced here (the "compile check" for schedules):
//! - every node belongs to exactly one group;
//! - groups are topologically ordered and internally contiguous enough to
//!   execute (a group may only read group-external values produced earlier);
//! - a fused group's *interior* values must not escape (only the group's
//!   last-produced values may be consumed by later groups or graph outputs),
//!   matching the constraint that a fused CUDA kernel materializes only its
//!   final stores.

use super::{KernelGraph, ValueRef};

/// Memory layout of the group's primary operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemLayout {
    /// Naive row-major, potentially strided access.
    Naive,
    /// Coalesced global accesses (vectorized loads possible).
    Coalesced,
    /// Padded / swizzled to avoid bank conflicts.
    Padded,
}

/// Shared-memory-style tiling of the contraction dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tiling {
    /// No staging; operands stream straight from global memory.
    None,
    /// Stage operand tiles through scratch memory; `tile` is the K-tile.
    Shared {
        /// K-dimension tile size.
        tile: usize,
    },
}

/// Per-group launch geometry (CUDA grid/block analog).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaunchConfig {
    /// Blocks in the grid.
    pub grid: usize,
    /// Threads per block.
    pub block: usize,
}

impl LaunchConfig {
    /// Total threads launched.
    pub fn threads(&self) -> usize {
        self.grid * self.block
    }
}

/// Mutable execution attributes of one kernel launch. Every optimization
/// technique in the catalog maps to changes of these fields (or to graph
/// rewrites).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupOpts {
    /// Global-memory access layout.
    pub layout: MemLayout,
    /// Scratch-memory staging of contraction operands.
    pub tiling: Tiling,
    /// Vector width of global loads/stores (1 = scalar, 4 = float4-style).
    pub vector_width: usize,
    /// Independent accumulator count (instruction-level parallelism).
    pub ilp: usize,
    /// Loop unroll factor.
    pub unroll: usize,
    /// Use MMA/tensor-core (MXU) path; requires 16-bit dtype + tiling.
    pub tensor_core: bool,
    /// Split-K factor for contraction kernels (1 = off).
    pub split_k: usize,
    /// Fast-math (reassociation, approx transcendentals).
    pub fast_math: bool,
    /// Warp-shuffle (vs shared-memory atomic) reductions.
    pub warp_shuffle_reduction: bool,
    /// Each thread computes this many outputs (thread coarsening /
    /// work-per-thread increase).
    pub coarsening: usize,
    /// Registers per thread (occupancy pressure).
    pub regs_per_thread: usize,
    /// Double-buffered staging (overlap copy/compute).
    pub double_buffer: bool,
    /// Group dispatches to a vendor library (cuDNN/cuBLAS analog). Only
    /// legal in "+vendor" mode — the soft verifier rejects it otherwise.
    pub vendor_lib: bool,
    /// Branchless / simplified control flow in the inner loop.
    pub simplified_control_flow: bool,
}

impl Default for GroupOpts {
    fn default() -> Self {
        // The "naive CUDA" starting point the paper's §4.6 baseline uses:
        // functionally correct, no optimization techniques applied.
        Self {
            layout: MemLayout::Naive,
            tiling: Tiling::None,
            vector_width: 1,
            ilp: 1,
            unroll: 1,
            tensor_core: false,
            split_k: 1,
            fast_math: false,
            warp_shuffle_reduction: false,
            coarsening: 1,
            regs_per_thread: 64,
            double_buffer: false,
            vendor_lib: false,
            simplified_control_flow: false,
        }
    }
}

/// One simulated kernel launch: a set of graph nodes executed fused.
#[derive(Debug, Clone, PartialEq)]
pub struct FusionGroup {
    /// Node indices, ascending.
    pub nodes: Vec<usize>,
    /// Launch geometry of the fused kernel.
    pub launch: LaunchConfig,
    /// Execution attributes the techniques mutate.
    pub opts: GroupOpts,
}

impl FusionGroup {
    /// One-node group with default opts (naive schedule building block).
    pub fn single(node: usize, launch: LaunchConfig) -> Self {
        Self {
            nodes: vec![node],
            launch,
            opts: GroupOpts::default(),
        }
    }
}

/// A full execution schedule for a graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// Kernel launches in execution order, partitioning the graph.
    pub groups: Vec<FusionGroup>,
}

/// Schedule legality violations (the schedule-side "compile errors").
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ScheduleError {
    /// A node is scheduled zero or multiple times.
    #[error("node {0} appears in {1} groups (must be exactly 1)")]
    BadPartition(usize, usize),
    /// A group consumes a value produced by a later group.
    #[error("group {group} reads value from node {producer} scheduled later")]
    TopologicalViolation {
        /// The consuming group.
        group: usize,
        /// The producing node scheduled too late.
        producer: usize,
    },
    /// A fused group's interior value is consumed outside the group.
    #[error("interior value of node {node} in group {group} escapes the group")]
    InteriorEscape {
        /// The group fusing the node.
        group: usize,
        /// The node whose value escapes.
        node: usize,
    },
    /// A group schedules no nodes.
    #[error("group {0} is empty")]
    EmptyGroup(usize),
    /// Grid or block size is zero.
    #[error("invalid launch config in group {0}: grid/block must be positive")]
    BadLaunch(usize),
    /// Tensor-core execution without its 16-bit + tiling prerequisites.
    #[error("group {0}: tensor_core requires 16-bit dtype and shared tiling")]
    TensorCoreIllegal(usize),
}

impl Schedule {
    /// The naive default: one launch per node, heuristic geometry (one
    /// thread per output element, 256-thread blocks) — the paper's
    /// "functionally correct CUDA generated from PyTorch" starting state.
    pub fn naive(graph: &KernelGraph) -> Self {
        let groups = graph
            .nodes
            .iter()
            .enumerate()
            .map(|(i, node)| {
                let outputs = node.shape.numel().max(1);
                let block = 256;
                let grid = outputs.div_ceil(block).max(1);
                FusionGroup::single(i, LaunchConfig { grid, block })
            })
            .collect();
        Self { groups }
    }

    /// Number of kernel launches.
    pub fn n_launches(&self) -> usize {
        self.groups.len()
    }

    /// Index of the group containing `node`.
    pub fn group_of(&self, node: usize) -> Option<usize> {
        self.groups.iter().position(|g| g.nodes.contains(&node))
    }

    /// Validate partition, ordering, fusion legality, and flag coherence.
    pub fn validate(&self, graph: &KernelGraph) -> Result<(), ScheduleError> {
        // Exact partition.
        let mut seen = vec![0usize; graph.nodes.len()];
        for (gi, g) in self.groups.iter().enumerate() {
            if g.nodes.is_empty() {
                return Err(ScheduleError::EmptyGroup(gi));
            }
            if g.launch.grid == 0 || g.launch.block == 0 {
                return Err(ScheduleError::BadLaunch(gi));
            }
            for n in &g.nodes {
                seen[*n] += 1;
            }
        }
        for (n, count) in seen.iter().enumerate() {
            if *count != 1 {
                return Err(ScheduleError::BadPartition(n, *count));
            }
        }
        // Group order vs dataflow: a node's group-external deps must come
        // from strictly earlier groups.
        let group_of: Vec<usize> = {
            let mut v = vec![0usize; graph.nodes.len()];
            for (gi, g) in self.groups.iter().enumerate() {
                for n in &g.nodes {
                    v[*n] = gi;
                }
            }
            v
        };
        for (gi, g) in self.groups.iter().enumerate() {
            for n in &g.nodes {
                for dep in &graph.nodes[*n].deps {
                    if let ValueRef::Node(p) = dep {
                        if group_of[*p] > gi {
                            return Err(ScheduleError::TopologicalViolation {
                                group: gi,
                                producer: *p,
                            });
                        }
                    }
                }
            }
            // Interior-escape: values produced in this group and consumed
            // outside it must be "group outputs". We allow escape only for
            // nodes that are maximal in the group (no in-group consumer
            // *after* materialization is fine — a fused kernel can store
            // more than one output — but we forbid escape of values that
            // the group *recomputes past*, i.e. any non-final node that has
            // both in-group and out-of-group users).
            for n in &g.nodes {
                let users = graph.users_of(ValueRef::Node(*n));
                let in_group = users.iter().any(|u| group_of[*u] == gi);
                let out_group = users.iter().any(|u| group_of[*u] != gi)
                    || graph.outputs.contains(&ValueRef::Node(*n));
                if in_group && out_group {
                    return Err(ScheduleError::InteriorEscape {
                        group: gi,
                        node: *n,
                    });
                }
            }
            // Flag coherence.
            if g.opts.tensor_core {
                let has_16bit = g
                    .nodes
                    .iter()
                    .any(|n| graph.nodes[*n].dtype != super::DType::F32);
                let tiled = !matches!(g.opts.tiling, Tiling::None);
                if !has_16bit || !tiled {
                    return Err(ScheduleError::TensorCoreIllegal(gi));
                }
            }
        }
        Ok(())
    }

    /// Whether fusing the groups containing `a` and `b` would be legal
    /// (adjacent in the group order, dataflow-connected or independent).
    pub fn can_fuse(&self, graph: &KernelGraph, ga: usize, gb: usize) -> bool {
        if ga + 1 != gb || gb >= self.groups.len() {
            return false;
        }
        let mut merged = self.clone();
        let moved = merged.groups.remove(gb);
        merged.groups[ga].nodes.extend(moved.nodes);
        merged.groups[ga].nodes.sort_unstable();
        merged.validate(graph).is_ok()
    }

    /// Fuse group `gb` into `ga` (must be adjacent, ga < gb). The merged
    /// group keeps `ga`'s opts and the larger launch of the two.
    pub fn fuse(&mut self, ga: usize, gb: usize) {
        assert!(ga < gb && gb < self.groups.len());
        let moved = self.groups.remove(gb);
        let g = &mut self.groups[ga];
        g.nodes.extend(moved.nodes);
        g.nodes.sort_unstable();
        if moved.launch.threads() > g.launch.threads() {
            g.launch = moved.launch;
        }
    }

    /// Mirror a graph-side node removal: drop `node` from its group (the
    /// group itself is removed if it becomes empty) and shift all higher
    /// node indices down by one. Keeps the schedule aligned with
    /// [`KernelGraph::remove_node`].
    pub fn remove_node(&mut self, node: usize) {
        for g in &mut self.groups {
            g.nodes.retain(|n| *n != node);
            for n in &mut g.nodes {
                if *n > node {
                    *n -= 1;
                }
            }
        }
        self.groups.retain(|g| !g.nodes.is_empty());
    }

    /// Feature-space distance to another schedule — how far apart two
    /// execution plans are, for similarity-aware deduplication
    /// (the beam frontier's near-duplicate pruning in
    /// [`crate::icrl::driver`]).
    ///
    /// Schedules that partition the graph differently (different group
    /// count or node sets) describe structurally different kernels: the
    /// distance is `f64::INFINITY`. Over an identical partition the
    /// distance sums per-group attribute gaps: categorical attributes
    /// (layout, tiling kind, each boolean flag) count 1 per mismatch;
    /// power-of-two numeric knobs (tile size, vector width, ILP,
    /// unroll, split-K, coarsening, registers, launch geometry) count
    /// `|log2 a − log2 b|` — one doubling = distance 1, so "same plan,
    /// slightly different tile" lands well under 1 while "tiled vs
    /// untiled" is at least 1. Symmetric; 0.0 exactly when the
    /// schedules are equal.
    pub fn distance(&self, other: &Schedule) -> f64 {
        if self.groups.len() != other.groups.len() {
            return f64::INFINITY;
        }
        let log_gap = |x: usize, y: usize| {
            ((x.max(1) as f64).log2() - (y.max(1) as f64).log2()).abs()
        };
        let mut d = 0.0;
        for (a, b) in self.groups.iter().zip(&other.groups) {
            if a.nodes != b.nodes {
                return f64::INFINITY;
            }
            let (oa, ob) = (&a.opts, &b.opts);
            if oa.layout != ob.layout {
                d += 1.0;
            }
            d += match (oa.tiling, ob.tiling) {
                (Tiling::None, Tiling::None) => 0.0,
                (Tiling::Shared { tile: ta }, Tiling::Shared { tile: tb }) => log_gap(ta, tb),
                _ => 1.0,
            };
            d += log_gap(oa.vector_width, ob.vector_width);
            d += log_gap(oa.ilp, ob.ilp);
            d += log_gap(oa.unroll, ob.unroll);
            d += log_gap(oa.split_k, ob.split_k);
            d += log_gap(oa.coarsening, ob.coarsening);
            d += log_gap(oa.regs_per_thread, ob.regs_per_thread);
            for (fa, fb) in [
                (oa.tensor_core, ob.tensor_core),
                (oa.fast_math, ob.fast_math),
                (oa.warp_shuffle_reduction, ob.warp_shuffle_reduction),
                (oa.double_buffer, ob.double_buffer),
                (oa.vendor_lib, ob.vendor_lib),
                (oa.simplified_control_flow, ob.simplified_control_flow),
            ] {
                if fa != fb {
                    d += 1.0;
                }
            }
            d += log_gap(a.launch.grid, b.launch.grid);
            d += log_gap(a.launch.block, b.launch.block);
        }
        d
    }

    /// Total "source verbosity" proxy: used by the render/token model.
    pub fn complexity(&self) -> usize {
        self.groups
            .iter()
            .map(|g| {
                let mut c = 4 + 3 * g.nodes.len();
                if !matches!(g.opts.tiling, Tiling::None) {
                    c += 8;
                }
                if g.opts.tensor_core {
                    c += 12;
                }
                if g.opts.split_k > 1 {
                    c += 10;
                }
                c += g.opts.unroll.min(16) / 2;
                c
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::{GraphBuilder, OpKind};

    fn chain_graph() -> KernelGraph {
        // matmul -> bias -> relu -> reduce_sum
        let mut b = GraphBuilder::new("chain");
        let x = b.input("x", &[8, 16]);
        let w = b.input("w", &[16, 4]);
        let bias = b.input("b", &[4]);
        let mm = b.op(OpKind::Matmul, &[x, w]);
        let bi = b.op(OpKind::BiasAdd { axis: 1 }, &[mm, bias]);
        let r = b.op(OpKind::Relu, &[bi]);
        let s = b.op(OpKind::ReduceSum { axis: 1 }, &[r]);
        b.output(s);
        b.finish()
    }

    #[test]
    fn naive_schedule_one_group_per_node() {
        let g = chain_graph();
        let s = Schedule::naive(&g);
        assert_eq!(s.n_launches(), 4);
        assert!(s.validate(&g).is_ok());
        // grid sized to outputs: node 0 is 8x4=32 elems -> 1 block of 256
        assert_eq!(s.groups[0].launch.grid, 1);
    }

    #[test]
    fn fuse_adjacent_groups_valid() {
        let g = chain_graph();
        let mut s = Schedule::naive(&g);
        assert!(s.can_fuse(&g, 0, 1));
        s.fuse(0, 1);
        assert_eq!(s.n_launches(), 3);
        assert!(s.validate(&g).is_ok());
        assert_eq!(s.groups[0].nodes, vec![0, 1]);
    }

    #[test]
    fn fuse_whole_chain() {
        let g = chain_graph();
        let mut s = Schedule::naive(&g);
        while s.n_launches() > 1 {
            assert!(s.can_fuse(&g, 0, 1));
            s.fuse(0, 1);
        }
        assert!(s.validate(&g).is_ok());
        assert_eq!(s.groups[0].nodes, vec![0, 1, 2, 3]);
    }

    #[test]
    fn partition_violation_detected() {
        let g = chain_graph();
        let mut s = Schedule::naive(&g);
        s.groups[1].nodes = vec![0]; // node 0 twice, node 1 missing
        assert!(matches!(
            s.validate(&g),
            Err(ScheduleError::BadPartition(_, _))
        ));
    }

    #[test]
    fn topological_violation_detected() {
        let g = chain_graph();
        let mut s = Schedule::naive(&g);
        s.groups.swap(0, 1);
        assert!(matches!(
            s.validate(&g),
            Err(ScheduleError::TopologicalViolation { .. })
        ));
    }

    #[test]
    fn interior_escape_detected() {
        // Diamond: a -> (b, c); fusing a+b while c reads a from outside
        // means a escapes a group that also consumes it internally.
        let mut bld = GraphBuilder::new("diamond");
        let x = bld.input("x", &[4, 4]);
        let a = bld.op(OpKind::Relu, &[x]);
        let b = bld.op(OpKind::Exp, &[a]);
        let c = bld.op(OpKind::Tanh, &[a]);
        let d = bld.op(OpKind::Add, &[b, c]);
        bld.output(d);
        let g = bld.finish();
        let mut s = Schedule::naive(&g);
        // groups: [a],[b],[c],[d]; fuse a+b -> a is read by c (outside).
        s.fuse(0, 1);
        assert!(matches!(
            s.validate(&g),
            Err(ScheduleError::InteriorEscape { .. })
        ));
        // can_fuse should have predicted this.
        let s2 = Schedule::naive(&g);
        assert!(!s2.can_fuse(&g, 0, 1));
    }

    #[test]
    fn tensor_core_requires_16bit_and_tiling() {
        let g = chain_graph(); // f32 graph
        let mut s = Schedule::naive(&g);
        s.groups[0].opts.tensor_core = true;
        s.groups[0].opts.tiling = Tiling::Shared { tile: 32 };
        assert!(matches!(
            s.validate(&g),
            Err(ScheduleError::TensorCoreIllegal(0))
        ));
    }

    #[test]
    fn bad_launch_detected() {
        let g = chain_graph();
        let mut s = Schedule::naive(&g);
        s.groups[0].launch.grid = 0;
        assert!(matches!(s.validate(&g), Err(ScheduleError::BadLaunch(0))));
    }

    #[test]
    fn complexity_grows_with_features() {
        let g = chain_graph();
        let s = Schedule::naive(&g);
        let base = s.complexity();
        let mut s2 = s.clone();
        s2.groups[0].opts.tiling = Tiling::Shared { tile: 32 };
        s2.groups[0].opts.split_k = 4;
        assert!(s2.complexity() > base);
    }

    #[test]
    fn distance_zero_iff_equal_and_symmetric() {
        let g = chain_graph();
        let s = Schedule::naive(&g);
        assert_eq!(s.distance(&s), 0.0);
        let mut t = s.clone();
        t.groups[0].opts.fast_math = true;
        t.groups[1].opts.vector_width = 4;
        let d = s.distance(&t);
        assert!(d > 0.0 && d.is_finite());
        assert_eq!(s.distance(&t), t.distance(&s), "distance must be symmetric");
        // One boolean flip (1.0) + scalar->float4 (log2 4 = 2.0).
        assert!((d - 3.0).abs() < 1e-12, "d = {d}");
    }

    #[test]
    fn distance_counts_doublings_of_numeric_knobs() {
        let g = chain_graph();
        let s = Schedule::naive(&g);
        let mut t = s.clone();
        t.groups[0].opts.tiling = Tiling::Shared { tile: 32 };
        let mut u = s.clone();
        u.groups[0].opts.tiling = Tiling::Shared { tile: 64 };
        // Tiled-vs-untiled is a categorical unit; tile doubling is 1.
        assert_eq!(s.distance(&t), 1.0);
        assert_eq!(t.distance(&u), 1.0);
        assert!(t.distance(&u) <= s.distance(&u) + s.distance(&t)); // sanity, not a metric proof
    }

    #[test]
    fn distance_infinite_across_partitions() {
        let g = chain_graph();
        let s = Schedule::naive(&g);
        let mut fused = s.clone();
        fused.fuse(0, 1);
        assert_eq!(s.distance(&fused), f64::INFINITY);
        // Same group count but different node partition: also infinite.
        let mut swapped = s.clone();
        swapped.groups[0].nodes = vec![1];
        swapped.groups[1].nodes = vec![0];
        assert_eq!(s.distance(&swapped), f64::INFINITY);
    }

    #[test]
    fn group_of_lookup() {
        let g = chain_graph();
        let mut s = Schedule::naive(&g);
        s.fuse(0, 1);
        assert_eq!(s.group_of(0), Some(0));
        assert_eq!(s.group_of(1), Some(0));
        assert_eq!(s.group_of(2), Some(1));
        assert_eq!(s.group_of(99), None);
    }
}
