//! Real PJRT runtime backend (compiled under `--cfg kb_pjrt` only; needs
//! the `xla` bindings, which are not in the offline registry).
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Artifacts are HLO *text* (see aot.py for
//! the 64-bit-proto-id rationale).

use super::{Result, RuntimeError};
use std::path::PathBuf;
use std::time::Instant;

fn berr(e: impl std::fmt::Display) -> RuntimeError {
    RuntimeError::Backend(e.to_string())
}

/// A compiled executable plus its input signature.
pub struct LoadedModel {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Input shapes (row-major f32) from the artifact manifest.
    pub input_shapes: Vec<Vec<usize>>,
}

/// The PJRT runtime: one CPU client, many loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

impl Runtime {
    /// Construct against an artifact directory (built by `make artifacts`).
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()
                .map_err(|e| RuntimeError::Backend(format!("creating PJRT CPU client: {e}")))?,
            artifact_dir: artifact_dir.into(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Read input shapes for `name` from manifest.json.
    fn manifest_shapes(&self, name: &str) -> Result<Vec<Vec<usize>>> {
        let text = std::fs::read_to_string(self.artifact_dir.join("manifest.json"))
            .map_err(|e| {
                RuntimeError::Backend(format!(
                    "reading artifacts/manifest.json (run `make artifacts`): {e}"
                ))
            })?;
        let j = crate::util::json::Json::parse(&text)
            .map_err(|e| RuntimeError::Backend(format!("parsing manifest.json: {e}")))?;
        let entry = j
            .get(name)
            .ok_or_else(|| RuntimeError::Backend(format!("artifact '{name}' not in manifest")))?;
        let inputs = entry
            .get("inputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| RuntimeError::Backend("manifest entry missing inputs".to_string()))?;
        Ok(inputs
            .iter()
            .map(|shape| {
                shape
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect()
            })
            .collect())
    }

    /// Load + compile one artifact.
    pub fn load(&self, name: &str) -> Result<LoadedModel> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let path_str = path
            .to_str()
            .ok_or_else(|| RuntimeError::Backend("non-utf8 artifact path".to_string()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| RuntimeError::Backend(format!("parsing HLO text {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| RuntimeError::Backend(format!("compiling {name}: {e}")))?;
        Ok(LoadedModel {
            name: name.to_string(),
            exe,
            input_shapes: self.manifest_shapes(name)?,
        })
    }

    /// List the artifact names present on disk.
    pub fn available(&self) -> Vec<String> {
        super::list_artifacts(&self.artifact_dir)
    }
}

impl LoadedModel {
    /// Execute with f32 inputs (one Vec per input, row-major). Returns
    /// the flattened f32 outputs (the artifacts return 1-tuples).
    pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.input_shapes.len() {
            return Err(RuntimeError::Backend(format!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.input_shapes.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&self.input_shapes) {
            let numel: usize = shape.iter().product();
            if numel != data.len() {
                return Err(RuntimeError::Backend(format!(
                    "{}: input length {} != shape numel {numel}",
                    self.name,
                    data.len()
                )));
            }
            let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
            literals.push(
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| RuntimeError::Backend(format!("reshaping input literal: {e}")))?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(berr)?[0][0]
            .to_literal_sync()
            .map_err(|e| RuntimeError::Backend(format!("fetching result literal: {e}")))?;
        // aot.py lowers with return_tuple=True.
        let tuple = result
            .to_tuple()
            .map_err(|e| RuntimeError::Backend(format!("untupling result: {e}")))?;
        tuple
            .into_iter()
            .map(|lit| {
                lit.to_vec::<f32>()
                    .map_err(|e| RuntimeError::Backend(format!("reading f32 output: {e}")))
            })
            .collect()
    }

    /// Time `iters` executions (after `warmup` unmeasured runs); returns
    /// seconds per iteration (min over repeats — standard practice for
    /// wallclock microbenchmarks).
    pub fn bench(&self, inputs: &[Vec<f32>], warmup: usize, iters: usize) -> Result<f64> {
        for _ in 0..warmup {
            self.run_f32(inputs)?;
        }
        let mut best = f64::INFINITY;
        let repeats = 3;
        for _ in 0..repeats {
            let start = Instant::now();
            for _ in 0..iters {
                self.run_f32(inputs)?;
            }
            best = best.min(start.elapsed().as_secs_f64() / iters as f64);
        }
        Ok(best)
    }

    /// Deterministic pseudo-random inputs matching the signature.
    pub fn random_inputs(&self, seed: u64, scale: f32) -> Vec<Vec<f32>> {
        super::random_inputs_for(&self.name, &self.input_shapes, seed, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    fn have_artifacts() -> bool {
        default_artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn runtime_loads_and_runs_q63_pair_with_matching_numerics() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        let platform = rt.platform().to_lowercase();
        assert!(platform == "cpu" || platform == "host", "{platform}");
        let naive = rt.load("q63_naive").unwrap();
        let opt = rt.load("q63_optimized").unwrap();
        let inputs = naive.random_inputs(42, 0.1);
        let a = naive.run_f32(&inputs).unwrap();
        let b = opt.run_f32(&inputs).unwrap();
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].len(), b[0].len());
        let max_diff = a[0]
            .iter()
            .zip(&b[0])
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "naive vs optimized diverge: {max_diff}");
    }

    #[test]
    fn runtime_rejects_bad_inputs() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        let m = rt.load("q63_naive").unwrap();
        assert!(m.run_f32(&[]).is_err());
        let mut inputs = m.random_inputs(1, 0.1);
        inputs[0].pop();
        assert!(m.run_f32(&inputs).is_err());
    }

    #[test]
    fn available_lists_artifacts() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        let names = rt.available();
        assert!(names.iter().any(|n| n == "q18_naive"));
        assert!(names.iter().any(|n| n == "lenet5_optimized"));
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        assert!(rt.load("nonexistent_model").is_err());
    }
}
