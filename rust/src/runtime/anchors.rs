//! Anchor calibration: real PJRT-CPU measurement of the paper's
//! Appendix-8.x kernels.
//!
//! Two anchor classes (DESIGN.md §8):
//!
//! - **Perf anchors** — pairs where the optimization is real on the CPU
//!   backend too: the Q18 algebraic collapse (the row-summed linear is a
//!   matvec, an exact FLOP reduction). Measured wallclock speedup is the
//!   ground truth that the simulator's algebraic-simplification credit
//!   corresponds to a real end-to-end win on a real runtime.
//!
//! - **Correctness anchors** — the Pallas kernels (fused GEMM+epilogue,
//!   fused linear+reduce, LeNet-5). `interpret=True` is mandatory on CPU
//!   PJRT (Mosaic custom-calls cannot run), and interpretation overhead
//!   makes CPU wallclock meaningless as a TPU perf proxy; these anchors
//!   gate *numerics only*, with TPU performance estimated from VMEM
//!   footprint + MXU-shape alignment in DESIGN.md §2/§8.

use super::{Result, Runtime, RuntimeError};

/// Anchor class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnchorKind {
    /// Wallclock ratio is meaningful on CPU PJRT.
    Perf,
    /// Numerics gate only; timing reported for transparency.
    Correctness,
}

/// One anchor pair measurement.
#[derive(Debug, Clone)]
pub struct AnchorResult {
    pub name: &'static str,
    pub kind: AnchorKind,
    pub baseline_s: f64,
    pub candidate_s: f64,
    pub max_abs_diff: f32,
    pub what: &'static str,
}

impl AnchorResult {
    pub fn speedup(&self) -> f64 {
        self.baseline_s / self.candidate_s
    }
}

/// (name, kind, baseline artifact, candidate artifact, description).
pub const ANCHORS: &[(&str, AnchorKind, &str, &str, &str)] = &[
    (
        "q18_algebraic",
        AnchorKind::Perf,
        "q18_naive",
        "q18_algebraic",
        "L2-Q18 algebraic collapse: row-summed linear -> matvec (exact FLOP cut)",
    ),
    (
        "q18_pallas",
        AnchorKind::Correctness,
        "q18_naive",
        "q18_optimized",
        "App. 8.1 fused linear+sum Pallas kernel (interpret mode)",
    ),
    (
        "q63_pallas",
        AnchorKind::Correctness,
        "q63_naive",
        "q63_optimized",
        "App. 8.2 tiled GEMM + fused bias/ReLU/div epilogue (interpret mode)",
    ),
    (
        "lenet5_pallas",
        AnchorKind::Correctness,
        "lenet5_naive",
        "lenet5_optimized",
        "App. 8.3 LeNet-5 with Pallas conv-GEMM/pool/FC kernels (interpret mode)",
    ),
];

/// Measure every anchor pair. `iters` controls timing fidelity.
pub fn calibrate(rt: &Runtime, warmup: usize, iters: usize) -> Result<Vec<AnchorResult>> {
    let mut out = Vec::new();
    for (name, kind, base, cand, what) in ANCHORS {
        let baseline = rt.load(base)?;
        let candidate = rt.load(cand)?;
        let inputs = baseline.random_inputs(42, 0.1);
        // Numeric agreement gate before timing (same contract as the
        // validation harness).
        let a = baseline.run_f32(&inputs)?;
        let b = candidate.run_f32(&inputs)?;
        let mut max_abs_diff = 0.0f32;
        for (x, y) in a.iter().zip(&b) {
            if x.len() != y.len() {
                return Err(RuntimeError::Backend(format!(
                    "{name}: output arity mismatch"
                )));
            }
            for (p, q) in x.iter().zip(y) {
                max_abs_diff = max_abs_diff.max((p - q).abs());
            }
        }
        if max_abs_diff >= 5e-2 {
            return Err(RuntimeError::Backend(format!(
                "{name}: baseline and candidate disagree (max|Δ|={max_abs_diff})"
            )));
        }
        let baseline_s = baseline.bench(&inputs, warmup, iters)?;
        let candidate_s = candidate.bench(&inputs, warmup, iters)?;
        out.push(AnchorResult {
            name,
            kind: *kind,
            baseline_s,
            candidate_s,
            max_abs_diff,
            what,
        });
    }
    Ok(out)
}

/// Render a calibration report table.
pub fn render(results: &[AnchorResult]) -> String {
    let mut t = crate::util::table::Table::new(&[
        "anchor",
        "class",
        "baseline (ms)",
        "candidate (ms)",
        "speedup",
        "max|diff|",
    ]);
    for r in results {
        t.add_row(vec![
            r.name.to_string(),
            match r.kind {
                AnchorKind::Perf => "perf".to_string(),
                AnchorKind::Correctness => "correctness".to_string(),
            },
            format!("{:.3}", r.baseline_s * 1e3),
            format!("{:.3}", r.candidate_s * 1e3),
            format!("{:.2}x", r.speedup()),
            format!("{:.1e}", r.max_abs_diff),
        ]);
    }
    let mut s = t.render();
    s.push_str(
        "perf anchors: wallclock ratio is a real end-to-end win on the PJRT CPU backend.\n\
         correctness anchors: interpret-mode Pallas — numerics gate only; CPU wallclock\n\
         reflects interpreter overhead, NOT TPU performance (DESIGN.md §8).\n",
    );
    for r in results {
        s.push_str(&format!("  {}: {}\n", r.name, r.what));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    #[test]
    fn calibration_runs_when_artifacts_present() {
        if !default_artifact_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = match Runtime::new(default_artifact_dir()) {
            Ok(rt) => rt,
            // Stub build: nothing to calibrate. A real (kb_pjrt) backend
            // failing to initialize with artifacts present is a bug and
            // must fail loudly, not skip.
            Err(e @ RuntimeError::Unavailable(_)) => {
                eprintln!("skipping: {e}");
                return;
            }
            Err(e) => panic!("PJRT init failed with artifacts present: {e}"),
        };
        let results = calibrate(&rt, 1, 3).unwrap();
        assert_eq!(results.len(), ANCHORS.len());
        let text = render(&results);
        assert!(text.contains("q18_algebraic"));
        // The perf anchor must show a real speedup. The FLOP cut is
        // ~1000x at these shapes, but both variants still read all of W
        // (8 MB), so a memory-bound single-core CPU realizes the
        // bandwidth floor (~1.5-2x) rather than the FLOP ratio — still a
        // genuine, measured end-to-end win.
        let perf = results
            .iter()
            .find(|r| r.kind == AnchorKind::Perf)
            .unwrap();
        assert!(
            perf.speedup() > 1.05,
            "algebraic perf anchor too weak: {:.2}x",
            perf.speedup()
        );
        for r in &results {
            assert!(r.max_abs_diff < 5e-2);
        }
    }
}
