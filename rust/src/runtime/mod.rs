//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! Rust — the hot path that proves Python never sits on the request path.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Artifacts are HLO *text* (see aot.py for
//! the 64-bit-proto-id rationale).

pub mod anchors;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// A compiled executable plus its input signature.
pub struct LoadedModel {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Input shapes (row-major f32) from the artifact manifest.
    pub input_shapes: Vec<Vec<usize>>,
}

/// The PJRT runtime: one CPU client, many loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

impl Runtime {
    /// Construct against an artifact directory (built by `make artifacts`).
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            artifact_dir: artifact_dir.into(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Read input shapes for `name` from manifest.json.
    fn manifest_shapes(&self, name: &str) -> Result<Vec<Vec<usize>>> {
        let text = std::fs::read_to_string(self.artifact_dir.join("manifest.json"))
            .context("reading artifacts/manifest.json (run `make artifacts`)")?;
        let j = crate::util::json::Json::parse(&text).context("parsing manifest.json")?;
        let entry = j
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        let inputs = entry
            .get("inputs")
            .and_then(|v| v.as_arr())
            .context("manifest entry missing inputs")?;
        Ok(inputs
            .iter()
            .map(|shape| {
                shape
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect()
            })
            .collect())
    }

    /// Load + compile one artifact.
    pub fn load(&self, name: &str) -> Result<LoadedModel> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(LoadedModel {
            name: name.to_string(),
            exe,
            input_shapes: self.manifest_shapes(name)?,
        })
    }

    /// List the artifact names present on disk.
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.artifact_dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        e.file_name()
                            .to_str()
                            .and_then(|n| n.strip_suffix(".hlo.txt"))
                            .map(String::from)
                    })
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }
}

impl LoadedModel {
    /// Execute with f32 inputs (one Vec per input, row-major). Returns
    /// the flattened f32 outputs (the artifacts return 1-tuples).
    pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            inputs.len() == self.input_shapes.len(),
            "{}: expected {} inputs, got {}",
            self.name,
            self.input_shapes.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&self.input_shapes) {
            let numel: usize = shape.iter().product();
            anyhow::ensure!(
                numel == data.len(),
                "{}: input length {} != shape numel {numel}",
                self.name,
                data.len()
            );
            let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
            literals.push(
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .context("reshaping input literal")?,
            );
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True.
        let tuple = result.to_tuple().context("untupling result")?;
        tuple
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }

    /// Time `iters` executions (after `warmup` unmeasured runs); returns
    /// seconds per iteration (min over repeats — standard practice for
    /// wallclock microbenchmarks).
    pub fn bench(&self, inputs: &[Vec<f32>], warmup: usize, iters: usize) -> Result<f64> {
        for _ in 0..warmup {
            self.run_f32(inputs)?;
        }
        let mut best = f64::INFINITY;
        let repeats = 3;
        for _ in 0..repeats {
            let start = Instant::now();
            for _ in 0..iters {
                self.run_f32(inputs)?;
            }
            best = best.min(start.elapsed().as_secs_f64() / iters as f64);
        }
        Ok(best)
    }

    /// Deterministic pseudo-random inputs matching the signature.
    pub fn random_inputs(&self, seed: u64, scale: f32) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Rng::new(seed).derive(&self.name);
        self.input_shapes
            .iter()
            .map(|shape| {
                let numel: usize = shape.iter().product();
                (0..numel)
                    .map(|_| (rng.f32() * 2.0 - 1.0) * scale)
                    .collect()
            })
            .collect()
    }
}

/// Default artifact dir: `$KB_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("KB_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Relative to the working directory; the Makefile/bench harness runs
    // from the repo root.
    Path::new("artifacts").to_path_buf()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        default_artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn runtime_loads_and_runs_q63_pair_with_matching_numerics() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        let platform = rt.platform().to_lowercase();
        assert!(platform == "cpu" || platform == "host", "{platform}");
        let naive = rt.load("q63_naive").unwrap();
        let opt = rt.load("q63_optimized").unwrap();
        let inputs = naive.random_inputs(42, 0.1);
        let a = naive.run_f32(&inputs).unwrap();
        let b = opt.run_f32(&inputs).unwrap();
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].len(), b[0].len());
        let max_diff = a[0]
            .iter()
            .zip(&b[0])
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "naive vs optimized diverge: {max_diff}");
    }

    #[test]
    fn runtime_rejects_bad_inputs() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        let m = rt.load("q63_naive").unwrap();
        assert!(m.run_f32(&[]).is_err());
        let mut inputs = m.random_inputs(1, 0.1);
        inputs[0].pop();
        assert!(m.run_f32(&inputs).is_err());
    }

    #[test]
    fn available_lists_artifacts() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        let names = rt.available();
        assert!(names.iter().any(|n| n == "q18_naive"));
        assert!(names.iter().any(|n| n == "lenet5_optimized"));
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        assert!(rt.load("nonexistent_model").is_err());
    }
}
