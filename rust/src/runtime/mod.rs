//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! Rust — the hot path that proves Python never sits on the request path.
//! Deliberately *outside* the MAIC-RL loop: the optimization path runs
//! entirely on the [`crate::kir`] interpreter and [`crate::gpu`]
//! simulator; this module only anchors their cost model against real
//! Pallas executions (see [`anchors`], driven by the [`crate::cli`]
//! `calibrate` command).
//!
//! The real backend (the `pjrt`-gated module) drives the PJRT CPU
//! client through the `xla` bindings: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Artifacts are HLO *text* (see aot.py for
//! the 64-bit-proto-id rationale).
//!
//! The `xla` bindings (and their transitive deps) are not in the offline
//! crate registry, so the backend is compiled only when rustc is invoked
//! with `--cfg kb_pjrt` (and the `xla` crate is made available). The
//! default build substitutes a stub with the identical API surface whose
//! constructors return [`RuntimeError::Unavailable`]; the CLI `calibrate`
//! command and the anchor benches degrade gracefully.

pub mod anchors;

#[cfg(kb_pjrt)]
mod pjrt;
#[cfg(kb_pjrt)]
pub use pjrt::{LoadedModel, Runtime};

#[cfg(not(kb_pjrt))]
mod stub;
#[cfg(not(kb_pjrt))]
pub use stub::{LoadedModel, Runtime};

use std::path::{Path, PathBuf};

/// Runtime-layer errors. One shared type for both backends so the rest of
/// the crate (CLI, benches, anchors) is backend-agnostic.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    /// The PJRT backend was not compiled into this binary.
    #[error("PJRT backend unavailable ({0}); rebuild with `--cfg kb_pjrt` and the xla bindings")]
    Unavailable(String),
    /// Any backend-reported failure (compile, execute, IO, manifest).
    #[error("{0}")]
    Backend(String),
}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Default artifact dir: `$KB_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("KB_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Relative to the working directory; the Makefile/bench harness runs
    // from the repo root.
    Path::new("artifacts").to_path_buf()
}

/// List the artifact names present in `dir` (the `*.hlo.txt` basenames,
/// sorted) — shared by both backends; touches no backend state.
pub(crate) fn list_artifacts(dir: &Path) -> Vec<String> {
    let mut names: Vec<String> = std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter_map(|e| {
                    e.file_name()
                        .to_str()
                        .and_then(|n| n.strip_suffix(".hlo.txt"))
                        .map(String::from)
                })
                .collect()
        })
        .unwrap_or_default();
    names.sort();
    names
}

/// Deterministic pseudo-random inputs for an input signature — shared by
/// both backends so stub-mode tests exercise the same generation path.
pub(crate) fn random_inputs_for(
    name: &str,
    input_shapes: &[Vec<usize>],
    seed: u64,
    scale: f32,
) -> Vec<Vec<f32>> {
    let mut rng = crate::util::rng::Rng::new(seed).derive(name);
    input_shapes
        .iter()
        .map(|shape| {
            let numel: usize = shape.iter().product();
            (0..numel)
                .map(|_| (rng.f32() * 2.0 - 1.0) * scale)
                .collect()
        })
        .collect()
}
