//! Stub runtime backend: compiled when `--cfg kb_pjrt` is absent.
//!
//! Presents the same API as the PJRT backend so every consumer
//! typechecks; constructors fail with [`RuntimeError::Unavailable`] and
//! callers (CLI `calibrate`, the hotpath bench's anchor section) report
//! the condition instead of panicking.

use super::{Result, RuntimeError};
use std::path::PathBuf;

/// A compiled executable plus its input signature (stub: never built).
pub struct LoadedModel {
    pub name: String,
    /// Input shapes (row-major f32) from the artifact manifest.
    pub input_shapes: Vec<Vec<usize>>,
}

/// The PJRT runtime facade (stub: construction always fails).
pub struct Runtime {
    artifact_dir: PathBuf,
}

impl Runtime {
    /// Construct against an artifact directory. Always fails in the stub
    /// backend — the binary was built without the xla bindings.
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        let _ = Self {
            artifact_dir: artifact_dir.into(),
        };
        Err(RuntimeError::Unavailable(
            "built without the xla bindings".to_string(),
        ))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Load + compile one artifact (stub: unreachable in practice, since
    /// `new` never succeeds; kept for API parity).
    pub fn load(&self, name: &str) -> Result<LoadedModel> {
        Err(RuntimeError::Unavailable(format!(
            "cannot load '{name}' from {}: built without the xla bindings",
            self.artifact_dir.display()
        )))
    }

    /// List the artifact names present on disk.
    pub fn available(&self) -> Vec<String> {
        super::list_artifacts(&self.artifact_dir)
    }
}

impl LoadedModel {
    /// Execute with f32 inputs (stub: always unavailable).
    pub fn run_f32(&self, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Err(RuntimeError::Unavailable(format!(
            "{}: built without the xla bindings",
            self.name
        )))
    }

    /// Time executions (stub: always unavailable).
    pub fn bench(&self, _inputs: &[Vec<f32>], _warmup: usize, _iters: usize) -> Result<f64> {
        Err(RuntimeError::Unavailable(format!(
            "{}: built without the xla bindings",
            self.name
        )))
    }

    /// Deterministic pseudo-random inputs matching the signature. Works
    /// in the stub too (pure CPU-side generation).
    pub fn random_inputs(&self, seed: u64, scale: f32) -> Vec<Vec<f32>> {
        super::random_inputs_for(&self.name, &self.input_shapes, seed, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::new("artifacts").unwrap_err();
        assert!(matches!(err, RuntimeError::Unavailable(_)));
        assert!(err.to_string().contains("kb_pjrt"));
    }

    #[test]
    fn stub_model_generates_deterministic_inputs() {
        let m = LoadedModel {
            name: "fake".to_string(),
            input_shapes: vec![vec![2, 3], vec![4]],
        };
        let a = m.random_inputs(7, 0.1);
        let b = m.random_inputs(7, 0.1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].len(), 6);
        assert_eq!(a[1].len(), 4);
        assert!(a[0].iter().all(|v| v.abs() <= 0.1));
        assert!(m.run_f32(&a).is_err());
        assert!(m.bench(&a, 1, 1).is_err());
    }
}
