//! Level 1: isolated single-operator tasks (KernelBench Level 1 analog).
//!
//! Each builder returns (full, small) graph pairs with identical structure.
//! Shapes follow KernelBench conventions (large square GEMMs, ImageNet-ish
//! convs, large batched reductions).

use super::{Level, Task};
use crate::kir::{DType, GraphBuilder, KernelGraph, OpKind};

/// Construct all 20 Level-1 tasks.
pub fn tasks() -> Vec<Task> {
    let mut v = Vec::new();
    let mut idx = 0;
    let mut push = |name: &str, full: KernelGraph, small: KernelGraph| {
        idx += 1;
        v.push(Task::new(Level::L1, idx, name, full, small));
    };

    push("matmul_square", matmul(1024, 1024, 1024, DType::F32), matmul(16, 16, 16, DType::F32));
    push("matmul_large", matmul(4096, 4096, 4096, DType::F32), matmul(32, 32, 32, DType::F32));
    push("matmul_tall", matmul(8192, 256, 512, DType::F32), matmul(64, 8, 16, DType::F32));
    push("matmul_wide", matmul(256, 8192, 512, DType::F32), matmul(8, 64, 16, DType::F32));
    push("matmul_f16", matmul(2048, 2048, 2048, DType::F16), matmul(16, 16, 16, DType::F16));
    push("matvec", matmul(4096, 4096, 1, DType::F32), matmul(32, 32, 1, DType::F32));
    push(
        "conv2d_3x3",
        conv(16, 64, 128, 56, 3, 1, 1),
        conv(1, 4, 8, 10, 3, 1, 1),
    );
    push(
        "conv2d_1x1",
        conv(16, 256, 128, 28, 1, 1, 0),
        conv(1, 8, 4, 8, 1, 1, 0),
    );
    push(
        "conv2d_stride2",
        conv(16, 64, 128, 56, 3, 2, 1),
        conv(1, 4, 8, 10, 3, 2, 1),
    );
    push("maxpool2d", pool(32, 64, 112, 2, 2, true), pool(1, 4, 12, 2, 2, true));
    push("avgpool2d", pool(32, 64, 112, 2, 2, false), pool(1, 4, 12, 2, 2, false));
    push("softmax", unary2d(4096, 4096, OpKind::Softmax { axis: 1 }), unary2d(16, 32, OpKind::Softmax { axis: 1 }));
    push("logsumexp", unary2d(4096, 4096, OpKind::LogSumExp { axis: 1 }), unary2d(16, 32, OpKind::LogSumExp { axis: 1 }));
    push("layer_norm", unary2d(4096, 1024, OpKind::LayerNorm), unary2d(8, 64, OpKind::LayerNorm));
    push("relu", unary2d(8192, 8192, OpKind::Relu), unary2d(32, 32, OpKind::Relu));
    push("gelu", unary2d(8192, 4096, OpKind::Gelu), unary2d(32, 32, OpKind::Gelu));
    push("sigmoid", unary2d(8192, 4096, OpKind::Sigmoid), unary2d(32, 32, OpKind::Sigmoid));
    push("tanh_exp_scale", elementwise_chain(8192, 4096), elementwise_chain(32, 32));
    push("reduce_sum", unary2d(8192, 4096, OpKind::ReduceSum { axis: 1 }), unary2d(32, 32, OpKind::ReduceSum { axis: 1 }));
    push("reduce_max", unary2d(8192, 4096, OpKind::ReduceMax { axis: 1 }), unary2d(32, 32, OpKind::ReduceMax { axis: 1 }));

    v
}

fn matmul(m: usize, k: usize, n: usize, dtype: DType) -> KernelGraph {
    let mut b = GraphBuilder::new("matmul");
    let x = b.input_typed("x", &[m, k], dtype);
    let w = b.input_typed("w", &[k, n], dtype);
    let mm = b.op(OpKind::Matmul, &[x, w]);
    b.output(mm);
    b.finish()
}

fn conv(n: usize, c_in: usize, c_out: usize, hw: usize, k: usize, stride: usize, pad: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("conv2d");
    let x = b.input("x", &[n, c_in, hw, hw]);
    let w = b.input("w", &[c_out, c_in, k, k]);
    let c = b.op(OpKind::Conv2d { stride, pad }, &[x, w]);
    b.output(c);
    b.finish()
}

fn pool(n: usize, c: usize, hw: usize, k: usize, stride: usize, is_max: bool) -> KernelGraph {
    let mut b = GraphBuilder::new(if is_max { "maxpool" } else { "avgpool" });
    let x = b.input("x", &[n, c, hw, hw]);
    let p = if is_max {
        b.op(OpKind::MaxPool2d { k, stride }, &[x])
    } else {
        b.op(OpKind::AvgPool2d { k, stride }, &[x])
    };
    b.output(p);
    b.finish()
}

fn unary2d(m: usize, n: usize, kind: OpKind) -> KernelGraph {
    let mut b = GraphBuilder::new(kind.mnemonic());
    let x = b.input("x", &[m, n]);
    let y = b.op(kind, &[x]);
    b.output(y);
    b.finish()
}

fn elementwise_chain(m: usize, n: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("tanh_exp_scale");
    let x = b.input("x", &[m, n]);
    let t = b.op(OpKind::Tanh, &[x]);
    let e = b.op(OpKind::Exp, &[t]);
    let s = b.op(OpKind::Scale { c: 0.5 }, &[e]);
    b.output(s);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twenty_tasks() {
        assert_eq!(tasks().len(), 20);
    }

    #[test]
    fn f16_task_has_16bit_dtype() {
        let ts = tasks();
        let f16 = ts.iter().find(|t| t.id.contains("matmul_f16")).unwrap();
        assert_eq!(f16.graph.inputs[0].dtype, DType::F16);
        assert_eq!(f16.small.inputs[0].dtype, DType::F16);
    }

    #[test]
    fn matmul_task_single_contraction() {
        let ts = tasks();
        let mm = &ts[0];
        let census = mm.graph.op_census();
        assert_eq!(census.contractions, 1);
        assert_eq!(census.total(), 1);
    }
}
