//! The benchmark task suite — our KernelBench analog.
//!
//! KernelBench (Ouyang et al. 2024) structures tasks in three levels:
//! - **Level 1**: isolated single operators (matmul, conv, softmax, …) —
//!   small optimization space, the paper sees modest gains (geomean 1.43×);
//! - **Level 2**: composed operator patterns — fusion and algebraic
//!   opportunities, the paper's biggest wins (geomean 2.50×), including the
//!   Q18 double-logsumexp and Q63 GEMM+epilogue examples reproduced in the
//!   appendix;
//! - **Level 3**: whole models (LeNet5, SqueezeNet Fire, …) — many kernels,
//!   verbose representations (geomean 1.50× on the paper's subset).
//!
//! Each task carries two structurally identical graphs: `graph` at the
//! full benchmark shapes (used by the GPU performance model) and `small`
//! at reduced shapes (used by the numeric-verification oracle — the same
//! practice as validating a CUDA kernel on small inputs before timing the
//! big ones). Graph rewrites are applied to both in lockstep.
//!
//! Role in the loop: tasks are the *inputs* to everything — the driver
//! ([`crate::icrl`]) optimizes them, the harness ([`crate::harness`])
//! verifies against their graphs, baselines ([`crate::baselines`]) and
//! experiments ([`crate::experiments`]) score over the same
//! [`Suite`]. Graphs are built with [`crate::kir::GraphBuilder`].

pub mod level1;
pub mod level2;
pub mod level3;

use crate::kir::KernelGraph;

/// Benchmark level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Level {
    L1,
    L2,
    L3,
}

impl Level {
    pub fn name(&self) -> &'static str {
        match self {
            Level::L1 => "Level 1",
            Level::L2 => "Level 2",
            Level::L3 => "Level 3",
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            Level::L1 => "L1",
            Level::L2 => "L2",
            Level::L3 => "L3",
        }
    }
}

/// One benchmark task.
#[derive(Debug, Clone)]
pub struct Task {
    /// Stable identifier, e.g. "L2/18_linear_logsumexp".
    pub id: String,
    pub level: Level,
    /// Full-shape graph (performance model input).
    pub graph: KernelGraph,
    /// Reduced-shape graph with identical node structure (numeric oracle).
    pub small: KernelGraph,
}

impl Task {
    pub(crate) fn new(level: Level, idx: usize, name: &str, graph: KernelGraph, small: KernelGraph) -> Self {
        assert_eq!(
            graph.nodes.len(),
            small.nodes.len(),
            "task {name}: full/small graphs must be structurally identical"
        );
        for (a, b) in graph.nodes.iter().zip(&small.nodes) {
            assert_eq!(
                std::mem::discriminant(&a.kind),
                std::mem::discriminant(&b.kind),
                "task {name}: node kind mismatch between full and small graphs"
            );
        }
        Task {
            id: format!("{}/{idx:02}_{name}", level.tag()),
            level,
            graph,
            small,
        }
    }
}

/// The full suite.
#[derive(Debug, Clone)]
pub struct Suite {
    pub tasks: Vec<Task>,
}

impl Suite {
    /// Everything: 20 L1 + 20 L2 + 4 L3.
    pub fn full() -> Suite {
        let mut tasks = level1::tasks();
        tasks.extend(level2::tasks());
        tasks.extend(level3::tasks());
        Suite { tasks }
    }

    pub fn level(level: Level) -> Suite {
        Suite {
            tasks: match level {
                Level::L1 => level1::tasks(),
                Level::L2 => level2::tasks(),
                Level::L3 => level3::tasks(),
            },
        }
    }

    pub fn by_id(&self, id: &str) -> Option<&Task> {
        self.tasks.iter().find(|t| t.id == id)
    }

    pub fn of_level(&self, level: Level) -> Vec<&Task> {
        self.tasks.iter().filter(|t| t.level == level).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::interp;

    #[test]
    fn suite_sizes() {
        let s = Suite::full();
        assert_eq!(s.of_level(Level::L1).len(), 20);
        assert_eq!(s.of_level(Level::L2).len(), 20);
        assert_eq!(s.of_level(Level::L3).len(), 4);
        assert_eq!(s.tasks.len(), 44);
    }

    #[test]
    fn ids_unique_and_prefixed() {
        let s = Suite::full();
        let mut ids: Vec<&str> = s.tasks.iter().map(|t| t.id.as_str()).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate task ids");
        for t in &s.tasks {
            assert!(t.id.starts_with(t.level.tag()));
        }
    }

    #[test]
    fn all_graphs_validate() {
        for t in Suite::full().tasks {
            t.graph
                .validate()
                .unwrap_or_else(|e| panic!("{}: full graph invalid: {e}", t.id));
            t.small
                .validate()
                .unwrap_or_else(|e| panic!("{}: small graph invalid: {e}", t.id));
        }
    }

    #[test]
    fn all_small_graphs_execute() {
        for t in Suite::full().tasks {
            let inputs = interp::random_inputs(&t.small, 42);
            let out = interp::execute(&t.small, &inputs)
                .unwrap_or_else(|e| panic!("{}: execution failed: {e}", t.id));
            assert!(!out.is_empty(), "{}: no outputs", t.id);
            for o in &out {
                assert!(
                    o.data.iter().all(|v| v.is_finite()),
                    "{}: non-finite output",
                    t.id
                );
            }
        }
    }

    #[test]
    fn small_graphs_are_actually_small() {
        for t in Suite::full().tasks {
            let numel: usize = t
                .small
                .inputs
                .iter()
                .map(|i| i.shape.numel())
                .sum();
            assert!(numel < 200_000, "{}: small graph too big ({numel})", t.id);
            // ... and full graphs meaningfully bigger.
            let full: usize = t.graph.inputs.iter().map(|i| i.shape.numel()).sum();
            assert!(full >= numel, "{}: full smaller than small", t.id);
        }
    }

    #[test]
    fn by_id_lookup() {
        let s = Suite::full();
        let first = s.tasks[0].id.clone();
        assert!(s.by_id(&first).is_some());
        assert!(s.by_id("L9/nope").is_none());
    }
}
