//! Level 2: composed-operator tasks (KernelBench Level 2 analog).
//!
//! These compositions expose the optimization classes the paper's Level-2
//! wins come from: kernel fusion, algebraic simplification (the Q18
//! double-logsumexp), epilogue folding (the Q63 GEMM+bias+ReLU+divide),
//! and reduction restructuring. Task 18 and 63 are faithful analogs of the
//! kernels reproduced in the paper's Appendix 8.1 and 8.2.

use super::{Level, Task};
use crate::kir::{DType, GraphBuilder, KernelGraph, OpKind};

/// Construct all 20 Level-2 tasks.
pub fn tasks() -> Vec<Task> {
    let mut v = Vec::new();
    let mut push = |idx: usize, name: &str, full: KernelGraph, small: KernelGraph| {
        v.push(Task::new(Level::L2, idx, name, full, small));
    };

    // Shapes follow KernelBench Level-2 conventions: batch 128–256,
    // feature dims 512–2048 — small enough that kernel-launch overhead
    // and intermediate-tensor round-trips are a large cost share, which
    // is exactly the regime where the paper's fusion wins live.
    push(1, "gemm_bias_relu", gemm_bias_act(128, 2048, 512, Act::Relu), gemm_bias_act(8, 32, 16, Act::Relu));
    push(2, "gemm_bias_gelu", gemm_bias_act(128, 1024, 1024, Act::Gelu), gemm_bias_act(8, 32, 16, Act::Gelu));
    push(3, "gemm_bias_sigmoid", gemm_bias_act(256, 1024, 512, Act::Sigmoid), gemm_bias_act(8, 16, 8, Act::Sigmoid));
    push(4, "conv_bias_relu", conv_bias_relu(8, 32, 64, 32, false), conv_bias_relu(1, 4, 8, 10, false));
    push(5, "conv_bias_relu_pool", conv_bias_relu(8, 32, 64, 32, true), conv_bias_relu(1, 4, 8, 10, true));
    push(6, "gemm_softmax", gemm_then(128, 1024, 1024, OpKind::Softmax { axis: 1 }), gemm_then(8, 16, 16, OpKind::Softmax { axis: 1 }));
    push(7, "gemm_layernorm", gemm_then(256, 1024, 512, OpKind::LayerNorm), gemm_then(8, 16, 16, OpKind::LayerNorm));
    push(8, "attention_scores", attention(256, 64, 256), attention(8, 8, 8));
    push(9, "mlp_block", mlp_block(128, 1024, 2048, 1024), mlp_block(4, 16, 32, 16));
    push(10, "residual_gemm", residual_gemm(256, 1024), residual_gemm(16, 16));
    push(11, "glu_gate", glu_gate(128, 1024, 1024), glu_gate(8, 16, 8));
    push(12, "scale_tanh_clip_chain", ew_chain(2048, 2048), ew_chain(32, 32));
    push(13, "softmax_reduce_max", softmax_reduce(2048, 2048), softmax_reduce(16, 32));
    push(14, "exp_sum_log", exp_sum_log(2048, 2048), exp_sum_log(16, 32));
    push(15, "transpose_gemm", transpose_gemm(128, 2048, 512), transpose_gemm(16, 8, 8));
    push(16, "conv1x1_conv3x3", double_conv(8, 64, 128, 28), double_conv(1, 4, 8, 10));
    push(17, "layernorm_gemm", layernorm_gemm(256, 1024, 512), layernorm_gemm(8, 16, 8));
    push(18, "linear_sum_logsumexp2", q18_linear_logsumexp(128, 2048, 1024), q18_linear_logsumexp(4, 32, 16));
    push(19, "gemm_mean_sub", gemm_mean_sub(256, 1024, 512), gemm_mean_sub(8, 16, 8));
    push(63, "gemm_bias_relu_div_f16", q63_gemm_epilogue(256, 2048, 1024), q63_gemm_epilogue(8, 32, 16));

    v
}

enum Act {
    Relu,
    Gelu,
    Sigmoid,
}

fn gemm_bias_act(m: usize, k: usize, n: usize, act: Act) -> KernelGraph {
    let mut b = GraphBuilder::new("gemm_bias_act");
    let x = b.input("x", &[m, k]);
    let w = b.input("w", &[k, n]);
    let bias = b.input("b", &[n]);
    let mm = b.op(OpKind::Matmul, &[x, w]);
    let bi = b.op(OpKind::BiasAdd { axis: 1 }, &[mm, bias]);
    let a = match act {
        Act::Relu => b.op(OpKind::Relu, &[bi]),
        Act::Gelu => b.op(OpKind::Gelu, &[bi]),
        Act::Sigmoid => b.op(OpKind::Sigmoid, &[bi]),
    };
    b.output(a);
    b.finish()
}

fn conv_bias_relu(n: usize, c_in: usize, c_out: usize, hw: usize, with_pool: bool) -> KernelGraph {
    let mut b = GraphBuilder::new("conv_bias_relu");
    let x = b.input("x", &[n, c_in, hw, hw]);
    let w = b.input("w", &[c_out, c_in, 3, 3]);
    let bias = b.input("b", &[c_out]);
    let c = b.op(OpKind::Conv2d { stride: 1, pad: 1 }, &[x, w]);
    let bi = b.op(OpKind::BiasAdd { axis: 1 }, &[c, bias]);
    let r = b.op(OpKind::Relu, &[bi]);
    if with_pool {
        let p = b.op(OpKind::MaxPool2d { k: 2, stride: 2 }, &[r]);
        b.output(p);
    } else {
        b.output(r);
    }
    b.finish()
}

fn gemm_then(m: usize, k: usize, n: usize, then: OpKind) -> KernelGraph {
    let mut b = GraphBuilder::new("gemm_then");
    let x = b.input("x", &[m, k]);
    let w = b.input("w", &[k, n]);
    let mm = b.op(OpKind::Matmul, &[x, w]);
    let t = b.op(then, &[mm]);
    b.output(t);
    b.finish()
}

/// QK^T → scale → softmax → @V (single-head attention core).
fn attention(s: usize, d: usize, s2: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("attention");
    let q = b.input("q", &[s, d]);
    let kt = b.input("kT", &[d, s2]);
    let v = b.input("v", &[s2, d]);
    let scores = b.op(OpKind::Matmul, &[q, kt]);
    let scaled = b.op(
        OpKind::Scale {
            c: 1.0 / (d as f32).sqrt(),
        },
        &[scores],
    );
    let probs = b.op(OpKind::Softmax { axis: 1 }, &[scaled]);
    let out = b.op(OpKind::Matmul, &[probs, v]);
    b.output(out);
    b.finish()
}

fn mlp_block(m: usize, k: usize, hidden: usize, out: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("mlp_block");
    let x = b.input("x", &[m, k]);
    let w1 = b.input("w1", &[k, hidden]);
    let b1 = b.input("b1", &[hidden]);
    let w2 = b.input("w2", &[hidden, out]);
    let b2 = b.input("b2", &[out]);
    let h = b.op(OpKind::Matmul, &[x, w1]);
    let h = b.op(OpKind::BiasAdd { axis: 1 }, &[h, b1]);
    let h = b.op(OpKind::Relu, &[h]);
    let y = b.op(OpKind::Matmul, &[h, w2]);
    let y = b.op(OpKind::BiasAdd { axis: 1 }, &[y, b2]);
    b.output(y);
    b.finish()
}

/// y = relu(x @ w) + x (square gemm residual).
fn residual_gemm(m: usize, n: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("residual_gemm");
    let x = b.input("x", &[m, n]);
    let w = b.input("w", &[n, n]);
    let mm = b.op(OpKind::Matmul, &[x, w]);
    let r = b.op(OpKind::Relu, &[mm]);
    let y = b.op(OpKind::Add, &[r, x]);
    b.output(y);
    b.finish()
}

/// Gated linear unit: (x@w1) * sigmoid(x@w2).
fn glu_gate(m: usize, k: usize, n: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("glu");
    let x = b.input("x", &[m, k]);
    let w1 = b.input("w1", &[k, n]);
    let w2 = b.input("w2", &[k, n]);
    let a = b.op(OpKind::Matmul, &[x, w1]);
    let g = b.op(OpKind::Matmul, &[x, w2]);
    let s = b.op(OpKind::Sigmoid, &[g]);
    let y = b.op(OpKind::Mul, &[a, s]);
    b.output(y);
    b.finish()
}

fn ew_chain(m: usize, n: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("ew_chain");
    let x = b.input("x", &[m, n]);
    let s = b.op(OpKind::Scale { c: 2.0 }, &[x]);
    let t = b.op(OpKind::Tanh, &[s]);
    let a = b.op(OpKind::AddConst { c: 0.5 }, &[t]);
    let r = b.op(OpKind::Relu, &[a]);
    let d = b.op(OpKind::DivConst { c: 3.0 }, &[r]);
    b.output(d);
    b.finish()
}

fn softmax_reduce(m: usize, n: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("softmax_reduce");
    let x = b.input("x", &[m, n]);
    let s = b.op(OpKind::Softmax { axis: 1 }, &[x]);
    let r = b.op(OpKind::ReduceMax { axis: 1 }, &[s]);
    b.output(r);
    b.finish()
}

/// Decomposed logsumexp the agent can recognize: log(sum(exp(x))).
/// (No Log op in KIR: written as logsumexp-after-exp-sum equivalent —
/// exp → reduce_sum → … we keep it as exp/sum followed by a real
/// logsumexp over the size-1 axis, which is itself removable.)
fn exp_sum_log(m: usize, n: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("exp_sum_log");
    let x = b.input("x", &[m, n]);
    let e = b.op(OpKind::Exp, &[x]);
    let s = b.op(OpKind::ReduceSum { axis: 1 }, &[e]);
    let l = b.op(OpKind::LogSumExp { axis: 1 }, &[s]);
    b.output(l);
    b.finish()
}

fn transpose_gemm(m: usize, k: usize, n: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("transpose_gemm");
    let xt = b.input("xT", &[k, m]);
    let w = b.input("w", &[k, n]);
    let x = b.op(OpKind::Transpose, &[xt]);
    let y = b.op(OpKind::Matmul, &[x, w]);
    b.output(y);
    b.finish()
}

fn double_conv(n: usize, c_in: usize, c_mid: usize, hw: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("double_conv");
    let x = b.input("x", &[n, c_in, hw, hw]);
    let w1 = b.input("w1", &[c_mid, c_in, 1, 1]);
    let w2 = b.input("w2", &[c_in, c_mid, 3, 3]);
    let c1 = b.op(OpKind::Conv2d { stride: 1, pad: 0 }, &[x, w1]);
    let r1 = b.op(OpKind::Relu, &[c1]);
    let c2 = b.op(OpKind::Conv2d { stride: 1, pad: 1 }, &[r1, w2]);
    let r2 = b.op(OpKind::Relu, &[c2]);
    b.output(r2);
    b.finish()
}

fn layernorm_gemm(m: usize, k: usize, n: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("layernorm_gemm");
    let x = b.input("x", &[m, k]);
    let w = b.input("w", &[k, n]);
    let ln = b.op(OpKind::LayerNorm, &[x]);
    let y = b.op(OpKind::Matmul, &[ln, w]);
    b.output(y);
    b.finish()
}

/// KernelBench L2 Q18 analog (paper Appendix 8.1): linear → row-sum →
/// logsumexp → logsumexp. After the row-sum the tensor is (batch, 1), so
/// both logsumexp ops are algebraically removable — the 20.17× win.
fn q18_linear_logsumexp(batch: usize, in_f: usize, out_f: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("linear_sum_logsumexp2");
    let x = b.input("x", &[batch, in_f]);
    let w = b.input("w", &[in_f, out_f]);
    let bias = b.input("b", &[out_f]);
    let mm = b.op(OpKind::Matmul, &[x, w]);
    let bi = b.op(OpKind::BiasAdd { axis: 1 }, &[mm, bias]);
    let s = b.op(OpKind::ReduceSum { axis: 1 }, &[bi]);
    let l1 = b.op(OpKind::LogSumExp { axis: 1 }, &[s]);
    let l2 = b.op(OpKind::LogSumExp { axis: 1 }, &[l1]);
    b.output(l2);
    b.finish()
}

fn gemm_mean_sub(m: usize, k: usize, n: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("gemm_mean_sub");
    let x = b.input("x", &[m, k]);
    let w = b.input("w", &[k, n]);
    let mm = b.op(OpKind::Matmul, &[x, w]);
    let mu = b.op(OpKind::ReduceMean { axis: 1 }, &[mm]);
    // broadcast-sub via bias-like pattern is not expressible; use
    // mean-keepdim then subtract after reshaping row-wise: emulate with
    // LayerNorm-style centering via Sub over equal shapes is not possible
    // (mu is [m,1]). Instead: logsumexp-free centering chain — softmax
    // ends the task (a reduce + normalize composition).
    let sm = b.op(OpKind::Softmax { axis: 1 }, &[mm]);
    let _ = mu;
    b.output(sm);
    b.finish()
}

/// KernelBench L2 Q63 analog (paper Appendix 8.2): fp16 GEMM with fused
/// bias + ReLU + scalar-divide epilogue (the WMMA/split-K kernel).
fn q63_gemm_epilogue(m: usize, k: usize, n: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("gemm_bias_relu_div_f16");
    let x = b.input_typed("x", &[m, k], DType::F16);
    let w = b.input_typed("w", &[k, n], DType::F16);
    let bias = b.input_typed("b", &[n], DType::F16);
    let mm = b.op(OpKind::Matmul, &[x, w]);
    let bi = b.op(OpKind::BiasAdd { axis: 1 }, &[mm, bias]);
    let r = b.op(OpKind::Relu, &[bi]);
    let d = b.op(OpKind::DivConst { c: 2.0 }, &[r]);
    b.output(d);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::interp::{self, allclose, Tensor};
    use crate::kir::Shape;

    #[test]
    fn twenty_tasks() {
        assert_eq!(tasks().len(), 20);
    }

    #[test]
    fn q18_logsumexp_is_removable() {
        // Algebraic ground truth for the paper's headline Q18 claim:
        // removing both logsumexp ops leaves the result unchanged.
        let full = q18_linear_logsumexp(4, 32, 16);
        let mut truncated = full.clone();
        // Drop the two logsumexp nodes and output the reduce_sum.
        truncated.nodes.truncate(3);
        truncated.outputs = vec![crate::kir::ValueRef::Node(2)];
        truncated.validate().unwrap();
        let inputs = interp::random_inputs(&full, 7);
        let a = interp::execute(&full, &inputs).unwrap();
        let b = interp::execute(&truncated, &inputs).unwrap();
        assert!(allclose(&a[0], &b[0], 1e-5, 1e-5));
    }

    #[test]
    fn attention_rows_normalized() {
        let g = attention(8, 8, 8);
        let inputs = interp::random_inputs(&g, 1);
        let out = interp::execute(&g, &inputs).unwrap();
        assert_eq!(out[0].shape, Shape(vec![8, 8]));
    }

    #[test]
    fn glu_is_diamond() {
        // x feeds two matmuls — fusion legality must respect the diamond.
        let g = glu_gate(8, 16, 8);
        let users = g.users_of(crate::kir::ValueRef::Input(0));
        assert_eq!(users.len(), 2);
    }

    #[test]
    fn q63_is_f16() {
        let g = q63_gemm_epilogue(8, 32, 16);
        assert!(g.inputs.iter().all(|i| i.dtype == DType::F16));
        assert_eq!(g.nodes.len(), 4);
    }

    #[test]
    fn residual_uses_input_twice() {
        let g = residual_gemm(16, 16);
        let inputs = vec![
            Tensor::zeros(Shape(vec![16, 16])),
            Tensor::zeros(Shape(vec![16, 16])),
        ];
        let out = interp::execute(&g, &inputs).unwrap();
        assert!(out[0].data.iter().all(|v| *v == 0.0));
    }
}
