//! Level 3: whole-model tasks (KernelBench Level 3 analog).
//!
//! The paper evaluates a subset of Level 3 and reports LeNet5 at 2.68× and
//! SqueezeNetFireModule at 1.95× over PyTorch (§4.9). Both are built here
//! faithfully, plus an MNIST MLP and a small ConvNet, matching the paper's
//! "subset of Level 3" scope (ValidRate 67% over a small set).

use super::{Level, Task};
use crate::kir::{GraphBuilder, KernelGraph, OpKind, Shape};

/// Construct the 4 Level-3 tasks.
pub fn tasks() -> Vec<Task> {
    vec![
        Task::new(Level::L3, 1, "lenet5", lenet5(128), lenet5(2)),
        Task::new(Level::L3, 2, "squeezenet_fire", fire_module(16, 96, 16, 64, 54), fire_module(1, 8, 2, 4, 10)),
        Task::new(Level::L3, 3, "mnist_mlp", mlp3(256, 784, 512, 256), mlp3(4, 48, 32, 16)),
        Task::new(Level::L3, 4, "convnet", convnet(64), convnet(2)),
    ]
}

/// Classic LeNet-5 on 32×32 inputs: conv(6@5×5) → relu → pool → conv(16@5×5)
/// → relu → pool → flatten → fc120 → relu → fc84 → relu → fc10.
fn lenet5(batch: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("lenet5");
    let x = b.input("x", &[batch, 1, 32, 32]);
    let w1 = b.input("conv1_w", &[6, 1, 5, 5]);
    let b1 = b.input("conv1_b", &[6]);
    let w2 = b.input("conv2_w", &[16, 6, 5, 5]);
    let b2 = b.input("conv2_b", &[16]);
    let fw1 = b.input("fc1_w", &[400, 120]);
    let fb1 = b.input("fc1_b", &[120]);
    let fw2 = b.input("fc2_w", &[120, 84]);
    let fb2 = b.input("fc2_b", &[84]);
    let fw3 = b.input("fc3_w", &[84, 10]);
    let fb3 = b.input("fc3_b", &[10]);

    let c1 = b.op(OpKind::Conv2d { stride: 1, pad: 0 }, &[x, w1]); // 6x28x28
    let c1 = b.op(OpKind::BiasAdd { axis: 1 }, &[c1, b1]);
    let c1 = b.op(OpKind::Relu, &[c1]);
    let p1 = b.op(OpKind::MaxPool2d { k: 2, stride: 2 }, &[c1]); // 6x14x14
    let c2 = b.op(OpKind::Conv2d { stride: 1, pad: 0 }, &[p1, w2]); // 16x10x10
    let c2 = b.op(OpKind::BiasAdd { axis: 1 }, &[c2, b2]);
    let c2 = b.op(OpKind::Relu, &[c2]);
    let p2 = b.op(OpKind::MaxPool2d { k: 2, stride: 2 }, &[c2]); // 16x5x5
    let flat = b.op(
        OpKind::Reshape {
            shape: Shape(vec![batch, 400]),
        },
        &[p2],
    );
    let f1 = b.op(OpKind::Matmul, &[flat, fw1]);
    let f1 = b.op(OpKind::BiasAdd { axis: 1 }, &[f1, fb1]);
    let f1 = b.op(OpKind::Relu, &[f1]);
    let f2 = b.op(OpKind::Matmul, &[f1, fw2]);
    let f2 = b.op(OpKind::BiasAdd { axis: 1 }, &[f2, fb2]);
    let f2 = b.op(OpKind::Relu, &[f2]);
    let f3 = b.op(OpKind::Matmul, &[f2, fw3]);
    let f3 = b.op(OpKind::BiasAdd { axis: 1 }, &[f3, fb3]);
    b.output(f3);
    b.finish()
}

/// SqueezeNet Fire module: squeeze 1×1 → relu → {expand 1×1, expand 3×3}
/// → relu each → channel concat.
fn fire_module(
    batch: usize,
    c_in: usize,
    squeeze: usize,
    expand: usize,
    hw: usize,
) -> KernelGraph {
    let mut b = GraphBuilder::new("squeezenet_fire");
    let x = b.input("x", &[batch, c_in, hw, hw]);
    let sq_w = b.input("squeeze_w", &[squeeze, c_in, 1, 1]);
    let sq_b = b.input("squeeze_b", &[squeeze]);
    let e1_w = b.input("expand1_w", &[expand, squeeze, 1, 1]);
    let e1_b = b.input("expand1_b", &[expand]);
    let e3_w = b.input("expand3_w", &[expand, squeeze, 3, 3]);
    let e3_b = b.input("expand3_b", &[expand]);

    let s = b.op(OpKind::Conv2d { stride: 1, pad: 0 }, &[x, sq_w]);
    let s = b.op(OpKind::BiasAdd { axis: 1 }, &[s, sq_b]);
    let s = b.op(OpKind::Relu, &[s]);
    let e1 = b.op(OpKind::Conv2d { stride: 1, pad: 0 }, &[s, e1_w]);
    let e1 = b.op(OpKind::BiasAdd { axis: 1 }, &[e1, e1_b]);
    let e1 = b.op(OpKind::Relu, &[e1]);
    let e3 = b.op(OpKind::Conv2d { stride: 1, pad: 1 }, &[s, e3_w]);
    let e3 = b.op(OpKind::BiasAdd { axis: 1 }, &[e3, e3_b]);
    let e3 = b.op(OpKind::Relu, &[e3]);
    let cat = b.op(OpKind::Concat { axis: 1 }, &[e1, e3]);
    b.output(cat);
    b.finish()
}

/// MNIST MLP: in → h1 → h2 → 10 with ReLU (784→512→256→10 at full size).
fn mlp3(batch: usize, in_f: usize, h1_f: usize, h2_f: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("mnist_mlp");
    let x = b.input("x", &[batch, in_f]);
    let w1 = b.input("w1", &[in_f, h1_f]);
    let b1 = b.input("b1", &[h1_f]);
    let w2 = b.input("w2", &[h1_f, h2_f]);
    let b2 = b.input("b2", &[h2_f]);
    let w3 = b.input("w3", &[h2_f, 10]);
    let b3 = b.input("b3", &[10]);
    let h1 = b.op(OpKind::Matmul, &[x, w1]);
    let h1 = b.op(OpKind::BiasAdd { axis: 1 }, &[h1, b1]);
    let h1 = b.op(OpKind::Relu, &[h1]);
    let h2 = b.op(OpKind::Matmul, &[h1, w2]);
    let h2 = b.op(OpKind::BiasAdd { axis: 1 }, &[h2, b2]);
    let h2 = b.op(OpKind::Relu, &[h2]);
    let y = b.op(OpKind::Matmul, &[h2, w3]);
    let y = b.op(OpKind::BiasAdd { axis: 1 }, &[y, b3]);
    b.output(y);
    b.finish()
}

/// Small CIFAR-style ConvNet: conv(32) relu pool conv(64) relu pool fc.
fn convnet(batch: usize) -> KernelGraph {
    let mut b = GraphBuilder::new("convnet");
    let x = b.input("x", &[batch, 3, 32, 32]);
    let w1 = b.input("w1", &[32, 3, 3, 3]);
    let b1 = b.input("b1", &[32]);
    let w2 = b.input("w2", &[64, 32, 3, 3]);
    let b2 = b.input("b2", &[64]);
    let fw = b.input("fc_w", &[4096, 10]);
    let fb = b.input("fc_b", &[10]);
    let c1 = b.op(OpKind::Conv2d { stride: 1, pad: 1 }, &[x, w1]); // 32x32x32
    let c1 = b.op(OpKind::BiasAdd { axis: 1 }, &[c1, b1]);
    let c1 = b.op(OpKind::Relu, &[c1]);
    let p1 = b.op(OpKind::MaxPool2d { k: 2, stride: 2 }, &[c1]); // 32x16x16
    let c2 = b.op(OpKind::Conv2d { stride: 1, pad: 1 }, &[p1, w2]); // 64x16x16
    let c2 = b.op(OpKind::BiasAdd { axis: 1 }, &[c2, b2]);
    let c2 = b.op(OpKind::Relu, &[c2]);
    let p2 = b.op(OpKind::MaxPool2d { k: 2, stride: 2 }, &[c2]); // 64x8x8
    let flat = b.op(
        OpKind::Reshape {
            shape: Shape(vec![batch, 4096]),
        },
        &[p2],
    );
    let y = b.op(OpKind::Matmul, &[flat, fw]);
    let y = b.op(OpKind::BiasAdd { axis: 1 }, &[y, fb]);
    b.output(y);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::interp;

    #[test]
    fn four_tasks() {
        assert_eq!(tasks().len(), 4);
    }

    #[test]
    fn lenet_output_shape() {
        let g = lenet5(2);
        let out_ref = g.outputs[0];
        assert_eq!(g.shape_of(out_ref), &Shape(vec![2, 10]));
        // 17 nodes of real model structure.
        assert!(g.nodes.len() >= 17, "{}", g.nodes.len());
    }

    #[test]
    fn lenet_small_executes() {
        let g = lenet5(2);
        let inputs = interp::random_inputs(&g, 3);
        let out = interp::execute(&g, &inputs).unwrap();
        assert_eq!(out[0].shape, Shape(vec![2, 10]));
    }

    #[test]
    fn fire_module_concat_channels() {
        let g = fire_module(1, 8, 2, 4, 10);
        let out_ref = g.outputs[0];
        // expand channels double via concat: 4 + 4 = 8
        assert_eq!(g.shape_of(out_ref), &Shape(vec![1, 8, 10, 10]));
        let inputs = interp::random_inputs(&g, 5);
        let out = interp::execute(&g, &inputs).unwrap();
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fire_module_full_shapes_match_squeezenet() {
        let g = fire_module(16, 96, 16, 64, 54);
        let out_ref = g.outputs[0];
        assert_eq!(g.shape_of(out_ref), &Shape(vec![16, 128, 54, 54]));
    }

    #[test]
    fn mlp_and_convnet_execute() {
        for g in [mlp3(2, 48, 32, 16), convnet(2)] {
            let inputs = interp::random_inputs(&g, 11);
            let out = interp::execute(&g, &inputs).unwrap();
            assert_eq!(out[0].shape.dim(1), 10);
        }
    }
}
