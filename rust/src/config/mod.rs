//! Run configuration: JSON config files + CLI overrides.
//!
//! A deployment-grade launcher needs reproducible run configs. This
//! module defines the full configuration surface of a KernelBlaster run
//! (driver hyperparameters from [`crate::icrl`], agent failure model from
//! [`crate::agents`], harness policy from [`crate::harness`], GPU target
//! from [`crate::gpu`], KB load/save/warm-start paths for
//! [`crate::kb`]) with JSON (de)serialization, so experiments are
//! launchable as `kernelblaster run --config run.json` and the exact
//! configuration can be archived next to the results. The [`crate::cli`]
//! is the only consumer; nothing here sits on the optimization loop.

use crate::agents::AgentConfig;
use crate::gpu::GpuArch;
use crate::harness::staged::VerifyConfig;
use crate::harness::HarnessConfig;
use crate::icrl::{FleetConfig, IcrlConfig, KbMode, PolicyConfig, PolicyKind, Schedule, SkillsConfig};
use crate::kb::lifecycle::TransferPolicy;
use crate::util::json::{Json, JsonObj};
use std::collections::BTreeMap;
use std::path::Path;

/// Complete run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub gpu: String,
    pub icrl: IcrlConfig,
    /// Batch-serving knobs for `kernelblaster batch` (see
    /// `icrl::fleet`); ignored by the single-task subcommands.
    pub fleet: FleetConfig,
    /// Optional KB to load before the run.
    pub kb_load: Option<String>,
    /// Optional path to save the KB after the run.
    pub kb_save: Option<String>,
    /// Prior KB paths to warm-start from: each is cross-arch transferred
    /// to `gpu` when its recorded arch differs, then all are merged with
    /// `kb_load` (see `kb::lifecycle::warm_start`).
    pub warm_start: Vec<String>,
    /// Transfer policy applied to warm-start priors.
    pub transfer: TransferPolicy,
    /// Task id filter (empty = whole suite).
    pub tasks: Vec<String>,
    /// Per-tenant admission weights for `kernelblaster serve` (see
    /// `serve`'s weighted-fair scheduler). Tenants not named here get
    /// weight 1; empty = every tenant equal.
    pub tenant_quotas: BTreeMap<String, u64>,
    /// Optional shared read-only base KB that warm-starts every new
    /// serve tenant (one-way: tenants never write back to it).
    pub serve_base_kb: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            gpu: "H100".to_string(),
            icrl: IcrlConfig::default(),
            fleet: FleetConfig::default(),
            kb_load: None,
            kb_save: None,
            warm_start: Vec::new(),
            transfer: TransferPolicy::default(),
            tasks: Vec::new(),
            tenant_quotas: BTreeMap::new(),
            serve_base_kb: None,
        }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("json: {0}")]
    Json(#[from] crate::util::json::JsonError),
    #[error("invalid config: {0}")]
    Invalid(String),
}

/// Serialize one search-policy block (the root `policy` section and each
/// `fleet.epoch_policies` entry share this shape).
fn policy_to_json(p: &PolicyConfig) -> JsonObj {
    let mut o = JsonObj::new();
    o.set("kind", p.kind.name());
    o.set("epsilon", p.epsilon);
    o.set("ucb_c", p.ucb_c);
    o.set("beam_width", p.beam_width);
    o.set("schedule", p.schedule.name());
    o.set("schedule_rate", p.schedule.rate());
    o.set("dedup_distance", p.dedup_distance);
    o
}

/// Parse one search-policy block over a base config (absent keys inherit
/// the base — the root section inherits the crate defaults, an
/// `epoch_policies` entry inherits the run's policy, so a mix entry can
/// name just a `kind` and keep the batch's hyperparameters).
fn policy_from_json(p: &Json, base: &PolicyConfig) -> Result<PolicyConfig, ConfigError> {
    let kind = match p.get("kind").and_then(Json::as_str) {
        None => base.kind,
        Some(name) => PolicyKind::from_name(name).ok_or_else(|| {
            ConfigError::Invalid(format!(
                "unknown policy '{name}' (known: {})",
                PolicyKind::known_names()
            ))
        })?,
    };
    let schedule = match p.get("schedule").and_then(Json::as_str) {
        None => match p.get("schedule_rate").and_then(Json::as_f64) {
            None => base.schedule,
            // A bare rate re-rates the inherited schedule's kind — but a
            // constant base has no rate to re-rate: silently dropping the
            // key would hide a config mistake, so reject it.
            Some(rate) => {
                if base.schedule == Schedule::Constant {
                    return Err(ConfigError::Invalid(
                        "policy.schedule_rate has no effect on the constant schedule; \
                         set policy.schedule to harmonic or exponential"
                            .into(),
                    ));
                }
                Schedule::from_parts(base.schedule.name(), rate)
                    .expect("own names always parse")
            }
        },
        Some(name) => {
            let rate = p
                .get("schedule_rate")
                .and_then(Json::as_f64)
                .unwrap_or(Schedule::DEFAULT_RATE);
            Schedule::from_parts(name, rate).ok_or_else(|| {
                ConfigError::Invalid(format!(
                    "unknown schedule '{name}' (known: {})",
                    Schedule::known_names()
                ))
            })?
        }
    };
    Ok(PolicyConfig {
        kind,
        epsilon: p.get("epsilon").and_then(Json::as_f64).unwrap_or(base.epsilon),
        ucb_c: p.get("ucb_c").and_then(Json::as_f64).unwrap_or(base.ucb_c),
        beam_width: p
            .get("beam_width")
            .and_then(Json::as_usize)
            .unwrap_or(base.beam_width),
        schedule,
        dedup_distance: p
            .get("dedup_distance")
            .and_then(Json::as_f64)
            .unwrap_or(base.dedup_distance),
    })
}

impl RunConfig {
    pub fn resolve_arch(&self) -> Result<GpuArch, ConfigError> {
        GpuArch::by_name(&self.gpu)
            .ok_or_else(|| ConfigError::Invalid(format!("unknown GPU '{}'", self.gpu)))
    }

    pub fn to_json(&self) -> Json {
        let mut root = JsonObj::new();
        root.set("gpu", self.gpu.as_str());
        let mut icrl = JsonObj::new();
        icrl.set("trajectories", self.icrl.trajectories);
        icrl.set("rollout_steps", self.icrl.rollout_steps);
        icrl.set("top_k", self.icrl.top_k);
        icrl.set("seed", self.icrl.seed);
        icrl.set("cycles_only", self.icrl.cycles_only);
        icrl.set("parallel_explore", self.icrl.parallel_explore);
        icrl.set(
            "kb_mode",
            match self.icrl.kb_mode {
                KbMode::Persistent => "persistent",
                KbMode::EphemeralPerTask => "ephemeral",
            },
        );
        root.set("icrl", icrl);
        root.set("policy", policy_to_json(&self.icrl.policy));
        let mut fleet = JsonObj::new();
        fleet.set("workers", self.fleet.workers);
        fleet.set("epoch_size", self.fleet.epoch_size);
        fleet.set("checkpoint_every", self.fleet.checkpoint_every);
        fleet.set("shards", self.fleet.shards);
        fleet.set("commit_queue", self.fleet.commit_queue);
        if self.fleet.auto_epoch_policies {
            // "auto" (KB-maturity tuning) supersedes any hand-written mix.
            fleet.set("epoch_policies", "auto");
        } else if !self.fleet.epoch_policies.is_empty() {
            fleet.set(
                "epoch_policies",
                Json::Arr(
                    self.fleet
                        .epoch_policies
                        .iter()
                        .map(|p| Json::Obj(policy_to_json(p)))
                        .collect(),
                ),
            );
        }
        root.set("fleet", fleet);
        let mut agent = JsonObj::new();
        agent.set("state_misclassify_rate", self.icrl.agent.state_misclassify_rate);
        agent.set("lowering_bug_rate", self.icrl.agent.lowering_bug_rate);
        agent.set("lowering_fail_rate", self.icrl.agent.lowering_fail_rate);
        agent.set("reward_hack_rate", self.icrl.agent.reward_hack_rate);
        agent.set("retry_limit", self.icrl.agent.retry_limit);
        root.set("agent", agent);
        let mut harness = JsonObj::new();
        harness.set("verify_seeds", self.icrl.harness.verify_seeds);
        harness.set("noise_sigma", self.icrl.harness.noise_sigma);
        harness.set("allow_vendor", self.icrl.harness.allow_vendor);
        root.set("harness", harness);
        // Staged verification: emitted only when something differs from
        // the defaults, keeping pre-staging config files byte-stable.
        if self.icrl.verify != VerifyConfig::default() {
            let v = &self.icrl.verify;
            let mut verify = JsonObj::new();
            verify.set("staged", v.staged);
            verify.set("screen", v.screen);
            verify.set("probe", v.probe);
            verify.set("screen_margin", v.screen_margin);
            verify.set("probe_seeds", v.probe_seeds);
            if v.memo_max_entries != 0 {
                verify.set("memo_max_entries", v.memo_max_entries);
            }
            if let Some(p) = &v.memo_path {
                verify.set("memo", p.as_str());
            }
            root.set("verify", verify);
        }
        // Skill drawing: emitted only when something differs from the
        // defaults, keeping pre-skills config files byte-stable.
        if self.icrl.skills != SkillsConfig::default() {
            let s = &self.icrl.skills;
            let mut skills = JsonObj::new();
            skills.set("enabled", s.enabled);
            skills.set("max_len", s.max_len);
            skills.set("min_support", s.min_support);
            skills.set("min_gain", s.min_gain);
            skills.set("max_per_state", s.max_per_state);
            root.set("skills", skills);
        }
        if let Some(p) = &self.kb_load {
            root.set("kb_load", p.as_str());
        }
        if let Some(p) = &self.kb_save {
            root.set("kb_save", p.as_str());
        }
        if !self.warm_start.is_empty() {
            root.set(
                "warm_start",
                Json::Arr(
                    self.warm_start
                        .iter()
                        .map(|p| Json::Str(p.clone()))
                        .collect(),
                ),
            );
            let mut transfer = JsonObj::new();
            transfer.set("decay", self.transfer.decay);
            transfer.set("rekey_threshold", self.transfer.rekey_threshold);
            root.set("transfer", transfer);
        }
        if !self.tasks.is_empty() {
            root.set(
                "tasks",
                Json::Arr(self.tasks.iter().map(|t| Json::Str(t.clone())).collect()),
            );
        }
        // Multi-tenant serving: emitted only when something differs from
        // the defaults, keeping pre-tenancy config files byte-stable.
        if !self.tenant_quotas.is_empty() || self.serve_base_kb.is_some() {
            let mut serve = JsonObj::new();
            if !self.tenant_quotas.is_empty() {
                let mut quotas = JsonObj::new();
                for (name, w) in &self.tenant_quotas {
                    quotas.set(name.as_str(), *w);
                }
                serve.set("tenant_quotas", quotas);
            }
            if let Some(p) = &self.serve_base_kb {
                serve.set("base_kb", p.as_str());
            }
            root.set("serve", serve);
        }
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Result<RunConfig, ConfigError> {
        let mut cfg = RunConfig::default();
        if let Some(gpu) = j.get("gpu").and_then(Json::as_str) {
            cfg.gpu = gpu.to_string();
        }
        if let Some(icrl) = j.get("icrl") {
            let d = IcrlConfig::default();
            cfg.icrl.trajectories = icrl
                .get("trajectories")
                .and_then(Json::as_usize)
                .unwrap_or(d.trajectories);
            cfg.icrl.rollout_steps = icrl
                .get("rollout_steps")
                .and_then(Json::as_usize)
                .unwrap_or(d.rollout_steps);
            cfg.icrl.top_k = icrl.get("top_k").and_then(Json::as_usize).unwrap_or(d.top_k);
            cfg.icrl.seed = icrl
                .get("seed")
                .and_then(Json::as_f64)
                .map(|v| v as u64)
                .unwrap_or(d.seed);
            cfg.icrl.cycles_only = icrl
                .get("cycles_only")
                .and_then(Json::as_bool)
                .unwrap_or(false);
            cfg.icrl.parallel_explore = icrl
                .get("parallel_explore")
                .and_then(Json::as_bool)
                .unwrap_or(d.parallel_explore);
            cfg.icrl.kb_mode = match icrl.get("kb_mode").and_then(Json::as_str) {
                Some("ephemeral") => KbMode::EphemeralPerTask,
                Some("persistent") | None => KbMode::Persistent,
                Some(other) => {
                    return Err(ConfigError::Invalid(format!("kb_mode '{other}'")))
                }
            };
        }
        if let Some(p) = j.get("policy") {
            cfg.icrl.policy = policy_from_json(p, &PolicyConfig::default())?;
        }
        if let Some(fleet) = j.get("fleet") {
            let d = FleetConfig::default();
            let mut epoch_policies = Vec::new();
            let mut auto_epoch_policies = false;
            match fleet.get("epoch_policies") {
                // `"epoch_policies": "auto"` → derive each epoch's policy
                // from KB maturity instead of a hand-written mix.
                Some(Json::Str(s)) if s == "auto" => auto_epoch_policies = true,
                Some(Json::Str(other)) => {
                    return Err(ConfigError::Invalid(format!(
                        "fleet.epoch_policies must be \"auto\" or a policy list, got \"{other}\""
                    )));
                }
                Some(p) => {
                    if let Some(arr) = p.as_arr() {
                        // Mix entries inherit the run's policy (parsed
                        // above), so `[{"kind":"epsilon_greedy"},
                        // {"kind":"ucb_bandit"}]` keeps the batch's
                        // ε / c / schedule knobs.
                        for p in arr {
                            epoch_policies.push(policy_from_json(p, &cfg.icrl.policy)?);
                        }
                    }
                }
                None => {}
            }
            cfg.fleet = FleetConfig {
                workers: fleet
                    .get("workers")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.workers),
                epoch_size: fleet
                    .get("epoch_size")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.epoch_size),
                checkpoint_every: fleet
                    .get("checkpoint_every")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.checkpoint_every),
                shards: fleet
                    .get("shards")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.shards),
                commit_queue: fleet
                    .get("commit_queue")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.commit_queue),
                epoch_policies,
                auto_epoch_policies,
            };
        }
        if let Some(agent) = j.get("agent") {
            let d = AgentConfig::default();
            let f = |k: &str, dv: f64| agent.get(k).and_then(Json::as_f64).unwrap_or(dv);
            cfg.icrl.agent = AgentConfig {
                state_misclassify_rate: f("state_misclassify_rate", d.state_misclassify_rate),
                lowering_bug_rate: f("lowering_bug_rate", d.lowering_bug_rate),
                lowering_fail_rate: f("lowering_fail_rate", d.lowering_fail_rate),
                reward_hack_rate: f("reward_hack_rate", d.reward_hack_rate),
                retry_limit: agent
                    .get("retry_limit")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.retry_limit),
            };
        }
        if let Some(h) = j.get("harness") {
            let d = HarnessConfig::default();
            cfg.icrl.harness = HarnessConfig {
                verify_seeds: h
                    .get("verify_seeds")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.verify_seeds),
                noise_sigma: h
                    .get("noise_sigma")
                    .and_then(Json::as_f64)
                    .unwrap_or(d.noise_sigma),
                allow_vendor: h
                    .get("allow_vendor")
                    .and_then(Json::as_bool)
                    .unwrap_or(d.allow_vendor),
                ..d
            };
        }
        if let Some(v) = j.get("verify") {
            let d = VerifyConfig::default();
            cfg.icrl.verify = VerifyConfig {
                staged: v.get("staged").and_then(Json::as_bool).unwrap_or(d.staged),
                screen: v.get("screen").and_then(Json::as_bool).unwrap_or(d.screen),
                probe: v.get("probe").and_then(Json::as_bool).unwrap_or(d.probe),
                screen_margin: v
                    .get("screen_margin")
                    .and_then(Json::as_f64)
                    .unwrap_or(d.screen_margin),
                probe_seeds: v
                    .get("probe_seeds")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.probe_seeds),
                memo_path: v.get("memo").and_then(Json::as_str).map(String::from),
                memo_max_entries: v
                    .get("memo_max_entries")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.memo_max_entries),
            };
        }
        if let Some(s) = j.get("skills") {
            let d = SkillsConfig::default();
            cfg.icrl.skills = SkillsConfig {
                enabled: s.get("enabled").and_then(Json::as_bool).unwrap_or(d.enabled),
                max_len: s
                    .get("max_len")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.max_len),
                min_support: s
                    .get("min_support")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.min_support),
                min_gain: s
                    .get("min_gain")
                    .and_then(Json::as_f64)
                    .unwrap_or(d.min_gain),
                max_per_state: s
                    .get("max_per_state")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.max_per_state),
            };
        }
        cfg.kb_load = j.get("kb_load").and_then(Json::as_str).map(String::from);
        cfg.kb_save = j.get("kb_save").and_then(Json::as_str).map(String::from);
        if let Some(ws) = j.get("warm_start").and_then(Json::as_arr) {
            cfg.warm_start = ws
                .iter()
                .filter_map(|p| p.as_str().map(String::from))
                .collect();
        }
        if let Some(t) = j.get("transfer") {
            let d = TransferPolicy::default();
            cfg.transfer = TransferPolicy {
                decay: t.get("decay").and_then(Json::as_f64).unwrap_or(d.decay),
                rekey_threshold: t
                    .get("rekey_threshold")
                    .and_then(Json::as_f64)
                    .unwrap_or(d.rekey_threshold),
            };
        }
        if let Some(tasks) = j.get("tasks").and_then(Json::as_arr) {
            cfg.tasks = tasks
                .iter()
                .filter_map(|t| t.as_str().map(String::from))
                .collect();
        }
        if let Some(serve) = j.get("serve") {
            if let Some(quotas) = serve.get("tenant_quotas").and_then(Json::as_obj) {
                for (name, w) in quotas.iter() {
                    let w = w.as_usize().ok_or_else(|| {
                        ConfigError::Invalid(format!(
                            "serve.tenant_quotas.{name} must be a positive integer"
                        ))
                    })? as u64;
                    cfg.tenant_quotas.insert(name.to_string(), w);
                }
            }
            cfg.serve_base_kb = serve.get("base_kb").and_then(Json::as_str).map(String::from);
        }
        // Validation.
        if cfg.icrl.trajectories == 0 || cfg.icrl.rollout_steps == 0 || cfg.icrl.top_k == 0 {
            return Err(ConfigError::Invalid(
                "trajectories/rollout_steps/top_k must be positive".into(),
            ));
        }
        if cfg.fleet.workers == 0 || cfg.fleet.epoch_size == 0 {
            return Err(ConfigError::Invalid(
                "fleet.workers/epoch_size must be positive".into(),
            ));
        }
        if cfg.fleet.shards == 0 || cfg.fleet.commit_queue == 0 {
            return Err(ConfigError::Invalid(
                "fleet.shards/commit_queue must be positive".into(),
            ));
        }
        if !(0.0..=1.0).contains(&cfg.transfer.decay) {
            return Err(ConfigError::Invalid(format!(
                "transfer.decay must be in [0, 1], got {}",
                cfg.transfer.decay
            )));
        }
        for (name, w) in &cfg.tenant_quotas {
            if !crate::kb::store::valid_tenant_name(name) {
                return Err(ConfigError::Invalid(format!(
                    "serve.tenant_quotas: invalid tenant name '{name}'"
                )));
            }
            if *w == 0 {
                return Err(ConfigError::Invalid(format!(
                    "serve.tenant_quotas.{name} must be a positive integer"
                )));
            }
        }
        cfg.icrl.policy.validate().map_err(ConfigError::Invalid)?;
        for (i, p) in cfg.fleet.epoch_policies.iter().enumerate() {
            p.validate()
                .map_err(|e| ConfigError::Invalid(format!("fleet.epoch_policies[{i}]: {e}")))?;
        }
        cfg.icrl.verify.validate().map_err(ConfigError::Invalid)?;
        cfg.icrl.skills.validate().map_err(ConfigError::Invalid)?;
        cfg.resolve_arch()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<RunConfig, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<(), ConfigError> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips() {
        let cfg = RunConfig::default();
        let j = cfg.to_json();
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back.gpu, cfg.gpu);
        assert_eq!(back.icrl.trajectories, cfg.icrl.trajectories);
        assert_eq!(back.icrl.rollout_steps, cfg.icrl.rollout_steps);
        assert_eq!(back.icrl.agent.retry_limit, cfg.icrl.agent.retry_limit);
        assert!(
            (back.icrl.harness.noise_sigma - cfg.icrl.harness.noise_sigma).abs() < 1e-12
        );
    }

    #[test]
    fn partial_json_fills_defaults() {
        let j = Json::parse(r#"{"gpu":"L40S","icrl":{"trajectories":4}}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.gpu, "L40S");
        assert_eq!(cfg.icrl.trajectories, 4);
        assert_eq!(cfg.icrl.rollout_steps, IcrlConfig::default().rollout_steps);
    }

    #[test]
    fn rejects_bad_values() {
        let j = Json::parse(r#"{"gpu":"V100"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"icrl":{"trajectories":0}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"icrl":{"kb_mode":"weird"}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn warm_start_roundtrips_and_validates() {
        let cfg = RunConfig {
            warm_start: vec!["a.json".into(), "b.json".into()],
            transfer: TransferPolicy {
                decay: 0.7,
                ..Default::default()
            },
            ..Default::default()
        };
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.warm_start, cfg.warm_start);
        assert!((back.transfer.decay - 0.7).abs() < 1e-12);
        assert!(
            (back.transfer.rekey_threshold - cfg.transfer.rekey_threshold).abs() < 1e-12
        );
        // Absent = defaults.
        let plain = RunConfig::from_json(&Json::parse(r#"{"gpu":"H100"}"#).unwrap()).unwrap();
        assert!(plain.warm_start.is_empty());
        // Out-of-range decay rejected.
        let j = Json::parse(
            r#"{"warm_start":["a.json"],"transfer":{"decay":1.5}}"#,
        )
        .unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn policy_roundtrips_and_validates() {
        let cfg = RunConfig {
            icrl: IcrlConfig {
                policy: PolicyConfig {
                    kind: PolicyKind::BeamSearch,
                    epsilon: 0.3,
                    ucb_c: 1.25,
                    beam_width: 4,
                    schedule: Schedule::Harmonic { rate: 0.5 },
                    dedup_distance: 1.5,
                },
                ..Default::default()
            },
            ..Default::default()
        };
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.icrl.policy, cfg.icrl.policy);
        // Absent section = default policy (back-compat with pre-policy
        // config files).
        let plain = RunConfig::from_json(&Json::parse(r#"{"gpu":"H100"}"#).unwrap()).unwrap();
        assert_eq!(plain.icrl.policy, PolicyConfig::default());
        // Partial section fills defaults.
        let j = Json::parse(r#"{"policy":{"kind":"ucb_bandit"}}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.icrl.policy.kind, PolicyKind::UcbBandit);
        assert_eq!(c.icrl.policy.ucb_c, PolicyConfig::default().ucb_c);
        // Unknown kind and bad hyperparameters rejected.
        let j = Json::parse(r#"{"policy":{"kind":"quantum_annealing"}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"policy":{"epsilon":1.5}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"policy":{"kind":"beam_search","beam_width":0}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"policy":{"ucb_c":-1}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn schedule_and_dedup_roundtrip_and_validate() {
        // Named schedule with explicit rate.
        let j = Json::parse(
            r#"{"policy":{"kind":"epsilon_greedy","schedule":"exponential","schedule_rate":0.5,"dedup_distance":2.0}}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.icrl.policy.schedule, Schedule::Exponential { rate: 0.5 });
        assert_eq!(c.icrl.policy.dedup_distance, 2.0);
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.icrl.policy, c.icrl.policy);
        // Named schedule without a rate takes the default.
        let j = Json::parse(r#"{"policy":{"schedule":"harmonic"}}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(
            c.icrl.policy.schedule,
            Schedule::Harmonic {
                rate: Schedule::DEFAULT_RATE
            }
        );
        // Absent schedule keys = constant (the bit-identity default).
        let plain = RunConfig::from_json(&Json::parse(r#"{"policy":{"kind":"ucb_bandit"}}"#).unwrap())
            .unwrap();
        assert_eq!(plain.icrl.policy.schedule, Schedule::Constant);
        assert_eq!(plain.icrl.policy.dedup_distance, 0.0);
        // A bare rate over a non-constant inherited schedule re-rates it…
        let j = Json::parse(
            r#"{"policy":{"schedule":"harmonic"},
                "fleet":{"epoch_policies":[{"kind":"ucb_bandit","schedule_rate":0.75}]}}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(
            c.fleet.epoch_policies[0].schedule,
            Schedule::Harmonic { rate: 0.75 }
        );
        // Unknown schedule name, bad rates/thresholds, and a bare rate
        // over the constant schedule (nothing to re-rate) rejected.
        for bad in [
            r#"{"policy":{"schedule":"cosine"}}"#,
            r#"{"policy":{"schedule":"harmonic","schedule_rate":-0.5}}"#,
            r#"{"policy":{"dedup_distance":-1.0}}"#,
            r#"{"policy":{"schedule_rate":0.5}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(RunConfig::from_json(&j).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn epoch_policy_mix_roundtrips_inherits_and_validates() {
        // Entries inherit the run policy's hyperparameters: name just a
        // kind, keep the batch's ε and schedule.
        let j = Json::parse(
            r#"{"policy":{"epsilon":0.4,"schedule":"harmonic","schedule_rate":0.5},
                "fleet":{"epoch_size":2,"epoch_policies":[
                    {"kind":"epsilon_greedy"},
                    {"kind":"epsilon_greedy","epsilon":0.1},
                    {"kind":"ucb_bandit"}]}}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.fleet.epoch_policies.len(), 3);
        assert_eq!(c.fleet.epoch_policies[0].kind, PolicyKind::EpsilonGreedy);
        assert_eq!(c.fleet.epoch_policies[0].epsilon, 0.4, "inherits run ε");
        assert_eq!(
            c.fleet.epoch_policies[0].schedule,
            Schedule::Harmonic { rate: 0.5 },
            "inherits run schedule"
        );
        assert_eq!(c.fleet.epoch_policies[1].epsilon, 0.1, "own ε wins");
        assert_eq!(c.fleet.epoch_policies[2].kind, PolicyKind::UcbBandit);
        // Full file roundtrip preserves the mix.
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.fleet.epoch_policies, c.fleet.epoch_policies);
        // Absent = empty (the pre-mix fleet).
        let plain = RunConfig::from_json(&Json::parse(r#"{"gpu":"H100"}"#).unwrap()).unwrap();
        assert!(plain.fleet.epoch_policies.is_empty());
        // Invalid entries are rejected with their index.
        let j = Json::parse(
            r#"{"fleet":{"epoch_policies":[{"kind":"epsilon_greedy","epsilon":2.0}]}}"#,
        )
        .unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("epoch_policies[0]"), "{err}");
        let j = Json::parse(r#"{"fleet":{"epoch_policies":[{"kind":"bogus"}]}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn fleet_roundtrips_and_validates() {
        let cfg = RunConfig {
            fleet: FleetConfig {
                workers: 8,
                epoch_size: 16,
                checkpoint_every: 5,
                shards: 4,
                commit_queue: 32,
                ..Default::default()
            },
            ..Default::default()
        };
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.fleet, cfg.fleet);
        // Absent section = defaults.
        let plain = RunConfig::from_json(&Json::parse(r#"{"gpu":"H100"}"#).unwrap()).unwrap();
        assert_eq!(plain.fleet, FleetConfig::default());
        // Absent sharding keys = defaults (pre-shard config files).
        let j = Json::parse(r#"{"fleet":{"workers":3}}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.fleet.shards, FleetConfig::default().shards);
        assert_eq!(c.fleet.commit_queue, FleetConfig::default().commit_queue);
        // Zero workers/epoch/shards/queue rejected.
        let j = Json::parse(r#"{"fleet":{"workers":0}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"fleet":{"epoch_size":0}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"fleet":{"shards":0}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"fleet":{"commit_queue":0}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn verify_section_roundtrips_and_validates() {
        // Absent section = defaults, and the default config emits no
        // "verify" key at all — pre-staging config files stay byte-stable.
        let plain = RunConfig::from_json(&Json::parse(r#"{"gpu":"H100"}"#).unwrap()).unwrap();
        assert_eq!(plain.icrl.verify, VerifyConfig::default());
        let default_text = RunConfig::default().to_json().to_string_pretty();
        assert!(
            !default_text.contains("\"verify\""),
            "default config must not emit a verify section:\n{default_text}"
        );
        // Non-default section roundtrips every knob.
        let cfg = RunConfig {
            icrl: IcrlConfig {
                verify: VerifyConfig {
                    staged: true,
                    screen: false,
                    probe: true,
                    screen_margin: 2.0,
                    probe_seeds: 2,
                    memo_path: Some("/tmp/memo.json".into()),
                    memo_max_entries: 64,
                },
                ..Default::default()
            },
            ..Default::default()
        };
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.icrl.verify, cfg.icrl.verify);
        // Partial section inherits the remaining defaults.
        let j = Json::parse(r#"{"verify":{"staged":true}}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(c.icrl.verify.staged);
        assert!(c.icrl.verify.screen);
        assert_eq!(c.icrl.verify.probe_seeds, 1);
        assert_eq!(c.icrl.verify.memo_path, None);
        // Invalid knobs are rejected.
        let j = Json::parse(r#"{"verify":{"screen_margin":0.9}}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("screen_margin"), "{err}");
        let j = Json::parse(r#"{"verify":{"probe_seeds":0}}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("probe_seeds"), "{err}");
    }

    #[test]
    fn skills_section_roundtrips_and_validates() {
        // Absent section = defaults, and the default config emits no
        // "skills" key at all — pre-skills config files stay byte-stable.
        let plain = RunConfig::from_json(&Json::parse(r#"{"gpu":"H100"}"#).unwrap()).unwrap();
        assert_eq!(plain.icrl.skills, SkillsConfig::default());
        let default_text = RunConfig::default().to_json().to_string_pretty();
        assert!(
            !default_text.contains("\"skills\""),
            "default config must not emit a skills section:\n{default_text}"
        );
        // Non-default section roundtrips every knob.
        let cfg = RunConfig {
            icrl: IcrlConfig {
                skills: SkillsConfig {
                    enabled: true,
                    max_len: 4,
                    min_support: 3,
                    min_gain: 1.2,
                    max_per_state: 2,
                },
                ..Default::default()
            },
            ..Default::default()
        };
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.icrl.skills, cfg.icrl.skills);
        // Partial section inherits the remaining defaults.
        let j = Json::parse(r#"{"skills":{"enabled":true}}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(c.icrl.skills.enabled);
        assert_eq!(c.icrl.skills.max_len, SkillsConfig::default().max_len);
        // Invalid knobs are rejected.
        let j = Json::parse(r#"{"skills":{"max_len":1}}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("max_len"), "{err}");
        let j = Json::parse(r#"{"skills":{"min_support":0}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"skills":{"max_per_state":0}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn auto_epoch_policies_roundtrips_and_rejects_bad_strings() {
        let j = Json::parse(r#"{"fleet":{"epoch_policies":"auto"}}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(c.fleet.auto_epoch_policies);
        assert!(c.fleet.epoch_policies.is_empty());
        // to_json emits the string form, and it roundtrips.
        let text = c.to_json().to_string_compact();
        assert!(text.contains("\"epoch_policies\":\"auto\""), "{text}");
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert!(back.fleet.auto_epoch_policies);
        // Any other string is an error, not silently ignored.
        let j = Json::parse(r#"{"fleet":{"epoch_policies":"bogus"}}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(
            err.contains("must be \"auto\" or a policy list"),
            "{err}"
        );
    }

    #[test]
    fn serve_section_roundtrips_and_validates() {
        // Absent section = defaults, and the default config emits no
        // "serve" key at all — pre-tenancy config files stay byte-stable.
        let plain = RunConfig::from_json(&Json::parse(r#"{"gpu":"H100"}"#).unwrap()).unwrap();
        assert!(plain.tenant_quotas.is_empty());
        assert_eq!(plain.serve_base_kb, None);
        let default_text = RunConfig::default().to_json().to_string_pretty();
        assert!(
            !default_text.contains("\"serve\""),
            "default config must not emit a serve section:\n{default_text}"
        );
        // Non-default section roundtrips quotas and base KB.
        let cfg = RunConfig {
            tenant_quotas: [("acme".to_string(), 3), ("zeta".to_string(), 1)]
                .into_iter()
                .collect(),
            serve_base_kb: Some("/tmp/base_kb.json".into()),
            ..Default::default()
        };
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.tenant_quotas, cfg.tenant_quotas);
        assert_eq!(back.serve_base_kb, cfg.serve_base_kb);
        // Partial section: quotas without a base KB, base KB without quotas.
        let j = Json::parse(r#"{"serve":{"tenant_quotas":{"acme":2}}}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.tenant_quotas.get("acme"), Some(&2));
        assert_eq!(c.serve_base_kb, None);
        let j = Json::parse(r#"{"serve":{"base_kb":"kb.json"}}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(c.tenant_quotas.is_empty());
        assert_eq!(c.serve_base_kb.as_deref(), Some("kb.json"));
        // Invalid tenant names and non-positive weights are rejected
        // with the offending key in the message.
        let j = Json::parse(r#"{"serve":{"tenant_quotas":{"a/b":1}}}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("invalid tenant name 'a/b'"), "{err}");
        let j = Json::parse(r#"{"serve":{"tenant_quotas":{"acme":0}}}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("tenant_quotas.acme"), "{err}");
        let j = Json::parse(r#"{"serve":{"tenant_quotas":{"acme":"three"}}}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("positive integer"), "{err}");
    }

    #[test]
    fn file_roundtrip() {
        let cfg = RunConfig {
            tasks: vec!["L2/18_linear_sum_logsumexp2".into()],
            kb_save: Some("/tmp/kb.json".into()),
            icrl: IcrlConfig {
                harness: HarnessConfig {
                    allow_vendor: true,
                    ..Default::default()
                },
                ..Default::default()
            },
            ..Default::default()
        };
        let dir = std::env::temp_dir().join("kb_config_test");
        let path = dir.join("run.json");
        cfg.save(&path).unwrap();
        let back = RunConfig::load(&path).unwrap();
        assert_eq!(back.tasks, cfg.tasks);
        assert_eq!(back.kb_save, cfg.kb_save);
        assert!(back.icrl.harness.allow_vendor);
        std::fs::remove_dir_all(&dir).ok();
    }
}
