//! Minimal JSON implementation (value model, parser, writer).
//!
//! The offline crate registry has no `serde`/`serde_json`, and the Knowledge
//! Base (a ~50 KB JSON document per the paper §5), experiment reports, and
//! the CSV/JSON figure outputs all need structured (de)serialization. This
//! module is a complete, tested implementation of RFC 8259 JSON sufficient
//! for those uses: objects preserve insertion order (important so KB dumps
//! diff cleanly), numbers are f64, strings support the full escape set.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve insertion order via a parallel key vector.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Insertion-ordered string→Json map.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, key: impl Into<String>, value: impl Into<Json>) -> &mut Self {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value.into());
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn remove(&mut self, key: &str) -> Option<Json> {
        self.keys.retain(|k| k != key);
        self.map.remove(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.keys.iter().map(move |k| (k.as_str(), &self.map[k]))
    }
}

impl Json {
    pub fn obj() -> JsonObj {
        JsonObj::new()
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience path lookup: `j.get("a").get("b")` chains via Option.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, Some(2), 0);
        out
    }

    /// Parse a JSON document. Trailing whitespace allowed; trailing garbage
    /// is an error.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.parse_value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<JsonObj> for Json {
    fn from(o: JsonObj) -> Self {
        Json::Obj(o)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

// ---------------------------------------------------------------- writer

fn write_value(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => write_number(*n, out),
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Json::Obj(obj) => {
            if obj.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, item)) in obj.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..(width * depth) {
            out.push(' ');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; emit null like serde_json does by default.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\x08' => out.push_str("\\b"),
            '\x0C' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- parser

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn parse_value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_object(),
            Some(b'[') => self.parse_array(),
            Some(b'"') => Ok(Json::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Json::Bool(true)),
            Some(b'f') => self.parse_lit("false", Json::Bool(false)),
            Some(b'n') => self.parse_lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.parse_number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn parse_number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("invalid number '{text}'")))
    }

    fn parse_string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\x08'),
                        Some(b'f') => s.push('\x0C'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.parse_hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.parse_hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("invalid surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                            };
                            s.push(c);
                            continue; // parse_hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn parse_hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn parse_array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn parse_object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.parse_value()?;
            obj.set(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3.25e2", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let back = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back, "src={src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn object_preserves_insertion_order() {
        let mut o = JsonObj::new();
        o.set("zeta", 1.0).set("alpha", 2.0).set("mid", 3.0);
        let keys: Vec<&str> = o.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["zeta", "alpha", "mid"]);
        // Overwrite keeps position.
        o.set("alpha", 9.0);
        let keys: Vec<&str> = o.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["zeta", "alpha", "mid"]);
        assert_eq!(o.get("alpha").unwrap().as_f64(), Some(9.0));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\ slash \u{1F600} ctrl:\u{0001}";
        let j = Json::Str(s.to_string());
        let text = j.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.as_str(), Some(s));
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        let v = Json::parse(r#""A😀""#).unwrap();
        assert_eq!(v.as_str(), Some("A😀"));
    }

    #[test]
    fn numbers_integer_rendering() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
        assert_eq!(Json::Num(-0.25).to_string_compact(), "-0.25");
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let mut o = JsonObj::new();
        o.set("list", vec![1.0, 2.0, 3.0]);
        let mut inner = JsonObj::new();
        inner.set("k", "v");
        o.set("obj", inner);
        o.set("empty_arr", Json::Arr(vec![]));
        o.set("empty_obj", Json::Obj(JsonObj::new()));
        let j = Json::Obj(o);
        let pretty = j.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn from_impls() {
        let j: Json = vec!["a", "b"].into();
        assert_eq!(j.as_arr().unwrap().len(), 2);
        let j: Json = 42u64.into();
        assert_eq!(j.as_usize(), Some(42));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" \n\t{ \"a\" : [ 1 , 2 ] } \r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn fuzz_roundtrip_random_structures() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(1234);
        for _ in 0..200 {
            let v = random_json(&mut rng, 0);
            let text = v.to_string_compact();
            let back = Json::parse(&text).unwrap_or_else(|e| panic!("{e}: {text}"));
            assert_eq!(v, back);
        }
    }

    fn random_json(rng: &mut crate::util::rng::Rng, depth: usize) -> Json {
        let choice = if depth > 3 { rng.index(4) } else { rng.index(6) };
        match choice {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.f64() * 1000.0).round() / 8.0),
            3 => Json::Str(format!("s{}", rng.below(1000))),
            4 => Json::Arr((0..rng.index(4)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => {
                let mut o = JsonObj::new();
                for i in 0..rng.index(4) {
                    o.set(format!("k{i}"), random_json(rng, depth + 1));
                }
                Json::Obj(o)
            }
        }
    }
}
