//! Substrate utilities built from scratch (offline registry has no
//! rand/serde_json/proptest): deterministic RNG, JSON, statistics, table
//! rendering, and a mini property-test harness.

pub mod hash;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;

/// Format a byte count human-readably (KB dumps report their size budget).
pub fn human_bytes(n: usize) -> String {
    if n < 1024 {
        format!("{n} B")
    } else if n < 1024 * 1024 {
        format!("{:.1} KiB", n as f64 / 1024.0)
    } else {
        format!("{:.2} MiB", n as f64 / (1024.0 * 1024.0))
    }
}

/// Format a duration in engineering units.
pub fn human_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(12), "12 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_duration_units() {
        assert_eq!(human_duration(2.5e-9), "2.5 ns");
        assert_eq!(human_duration(1.5e-5), "15.00 µs");
        assert_eq!(human_duration(0.002), "2.00 ms");
        assert_eq!(human_duration(3.0), "3.00 s");
    }
}
