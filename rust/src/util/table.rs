//! ASCII table and plot rendering for experiment reports.
//!
//! Every paper table/figure regenerator prints a human-readable artifact to
//! stdout and writes machine-readable CSV next to it; this module owns the
//! human-readable half.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, col: usize, align: Align) -> Self {
        if col < self.aligns.len() {
            self.aligns[col] = align;
        }
        self
    }

    pub fn add_row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
        self
    }

    /// A separator row (rendered as a rule).
    pub fn add_rule(&mut self) -> &mut Self {
        self.rows.push(Vec::new());
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.iter().filter(|r| !r.is_empty()).count()
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let rule: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&rule);
        out.push('\n');
        out.push_str(&render_row(&self.headers, &widths, &vec![Align::Left; ncols]));
        out.push('\n');
        out.push_str(&rule);
        out.push('\n');
        for row in &self.rows {
            if row.is_empty() {
                out.push_str(&rule);
            } else {
                out.push_str(&render_row(row, &widths, &self.aligns));
            }
            out.push('\n');
        }
        out.push_str(&rule);
        out.push('\n');
        out
    }

    /// CSV rendering (headers + data rows; rules skipped).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&csv_row(&self.headers));
        for row in &self.rows {
            if !row.is_empty() {
                out.push_str(&csv_row(row));
            }
        }
        out
    }
}

fn csv_row(cells: &[String]) -> String {
    let quoted: Vec<String> = cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect();
    format!("{}\n", quoted.join(","))
}

fn render_row(cells: &[String], widths: &[usize], aligns: &[Align]) -> String {
    let mut s = String::from("|");
    for (i, cell) in cells.iter().enumerate() {
        let pad = widths[i].saturating_sub(cell.chars().count());
        match aligns[i] {
            Align::Left => {
                s.push(' ');
                s.push_str(cell);
                s.push_str(&" ".repeat(pad + 1));
            }
            Align::Right => {
                s.push_str(&" ".repeat(pad + 1));
                s.push_str(cell);
                s.push(' ');
            }
        }
        s.push('|');
    }
    s
}

/// Format a float with `prec` decimals, trimming to a compact form.
pub fn fnum(x: f64, prec: usize) -> String {
    if x.is_nan() {
        return "-".to_string();
    }
    format!("{x:.prec$}")
}

/// Format a fraction 0..1 as a percentage.
pub fn fpct(x: f64) -> String {
    if x.is_nan() {
        return "-".to_string();
    }
    format!("{:.1}%", x * 100.0)
}

/// Render a horizontal ASCII bar chart: (label, value) pairs.
pub fn bar_chart(items: &[(String, f64)], width: usize) -> String {
    if items.is_empty() {
        return String::new();
    }
    let max_val = items.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-12);
    let label_w = items.iter().map(|(l, _)| l.chars().count()).max().unwrap_or(0);
    let mut out = String::new();
    for (label, value) in items {
        let n = ((value / max_val) * width as f64).round() as usize;
        out.push_str(&format!(
            "{label:<label_w$} | {}{} {:.3}\n",
            "█".repeat(n),
            " ".repeat(width - n),
            value
        ));
    }
    out
}

/// Render an ASCII line plot of one or more series over shared x values.
/// Series are (name, ys); all ys must have the same length as xs.
pub fn line_plot(xs: &[f64], series: &[(String, Vec<f64>)], height: usize, width: usize) -> String {
    if xs.is_empty() || series.is_empty() {
        return String::new();
    }
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let all: Vec<f64> = series.iter().flat_map(|(_, ys)| ys.iter().copied()).collect();
    let ymin = all.iter().copied().fold(f64::INFINITY, f64::min);
    let ymax = all.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let yspan = (ymax - ymin).max(1e-12);
    let xmin = xs[0];
    let xmax = *xs.last().unwrap();
    let xspan = (xmax - xmin).max(1e-12);

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for (x, y) in xs.iter().zip(ys) {
            let col = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let row = (((ymax - y) / yspan) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let yval = ymax - (i as f64 / (height - 1).max(1) as f64) * yspan;
        out.push_str(&format!("{yval:>9.3} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>9} +{}\n{:>11}{:<w$.3}{:>w2$.3}\n",
        "",
        "-".repeat(width),
        "",
        xmin,
        xmax,
        w = width / 2,
        w2 = width - width / 2
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", marks[si % marks.len()], name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.add_row(vec!["alpha".into(), "1.00".into()]);
        t.add_rule();
        t.add_row(vec!["b".into(), "12.50".into()]);
        let r = t.render();
        assert!(r.contains("| name  | value |"), "{r}");
        assert!(r.contains("| alpha |  1.00 |"), "{r}");
        assert!(r.contains("| b     | 12.50 |"), "{r}");
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["k", "v"]);
        t.add_row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "k,v\n\"has,comma\",\"has\"\"quote\"\n");
    }

    #[test]
    fn fnum_fpct() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fnum(f64::NAN, 2), "-");
        assert_eq!(fpct(0.5), "50.0%");
    }

    #[test]
    fn bar_chart_scales() {
        let items = vec![("a".to_string(), 2.0), ("bb".to_string(), 1.0)];
        let s = bar_chart(&items, 10);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].matches('█').count() == 10);
        assert!(lines[1].matches('█').count() == 5);
    }

    #[test]
    fn line_plot_basic() {
        let xs = vec![0.0, 1.0, 2.0, 3.0];
        let series = vec![("up".to_string(), vec![0.0, 1.0, 2.0, 3.0])];
        let s = line_plot(&xs, &series, 5, 20);
        assert!(s.contains('*'));
        assert!(s.contains("up"));
    }
}
