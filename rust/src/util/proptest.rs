//! Minimal property-based testing harness.
//!
//! The offline registry has no `proptest`/`quickcheck`; this module provides
//! the subset we need: run a property over N seeded random cases, and on
//! failure report the failing case index and seed so the case is exactly
//! reproducible. Used by the coordinator-invariant property tests (routing,
//! batching, KB state machine) per the repro guidance.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 256, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cfg.cases` random cases. `prop` receives a fresh RNG per
/// case (derived deterministically) and returns `Err(reason)` to fail.
///
/// Panics with a reproduction hint on the first failing case.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).derive(&format!("{name}/{case}"));
        if let Err(reason) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (seed {:#x}): {reason}\n\
                 reproduce with PropConfig {{ cases: 1, seed: {:#x} }} and case index {case}",
                cfg.seed, cfg.seed
            );
        }
    }
}

/// Convenience: run with the default config.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, PropConfig::default(), prop);
}

/// Generators for common shapes.
pub mod gen {
    use crate::util::rng::Rng;

    /// Random vector of f64 in [lo, hi), length in [min_len, max_len].
    pub fn vec_f64(rng: &mut Rng, min_len: usize, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let len = min_len + rng.index(max_len - min_len + 1);
        (0..len).map(|_| rng.range_f64(lo, hi)).collect()
    }

    /// Random vector of f32 in [lo, hi).
    pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| lo + (hi - lo) * rng.f32()).collect()
    }

    /// Random dims: each in [1, cap].
    pub fn dims(rng: &mut Rng, n: usize, cap: usize) -> Vec<usize> {
        (0..n).map(|_| 1 + rng.index(cap)).collect()
    }

    /// Random identifier-ish string.
    pub fn ident(rng: &mut Rng, max_len: usize) -> String {
        let len = 1 + rng.index(max_len);
        (0..len)
            .map(|_| (b'a' + rng.index(26) as u8) as char)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-true", PropConfig { cases: 50, seed: 1 }, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed at case 0")]
    fn failing_property_panics_with_case() {
        check("always-false", PropConfig { cases: 10, seed: 1 }, |_rng| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first_run = Vec::new();
        check("det", PropConfig { cases: 5, seed: 7 }, |rng| {
            first_run.push(rng.next_u64());
            Ok(())
        });
        let mut second_run = Vec::new();
        check("det", PropConfig { cases: 5, seed: 7 }, |rng| {
            second_run.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first_run, second_run);
    }

    #[test]
    fn gen_shapes_in_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = gen::vec_f64(&mut rng, 2, 6, -1.0, 1.0);
            assert!((2..=6).contains(&v.len()));
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
            let d = gen::dims(&mut rng, 3, 8);
            assert!(d.iter().all(|x| (1..=8).contains(x)));
            let s = gen::ident(&mut rng, 5);
            assert!(!s.is_empty() && s.len() <= 5);
        }
    }
}
