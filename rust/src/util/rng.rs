//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, and determinism is load-bearing
//! for this system: every experiment in the paper reproduction must be
//! bit-identical given the same `--seed`. We implement SplitMix64 (for
//! seeding / stream derivation) and Xoshiro256** (the workhorse generator),
//! both public-domain algorithms by Blackman & Vigna.

/// SplitMix64: a tiny, fast generator used to expand a single `u64` seed
/// into the 256-bit state Xoshiro needs, and to derive independent streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256**: the main generator. Period 2^256−1, passes BigCrush.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 via SplitMix64 expansion (the canonical way).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        // All-zero state is invalid; SplitMix64 expansion cannot produce it
        // for any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            return Self { s: [1, 2, 3, 4] };
        }
        Self { s }
    }

    /// Derive an independent child stream, keyed by a label. Used to give
    /// each agent / subsystem its own stream so adding a draw in one place
    /// does not perturb every downstream decision.
    pub fn derive(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a offset basis
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(h ^ self.s[0] ^ self.s[2].rotate_left(17))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). n must be > 0.
    /// Lemire's nearly-divisionless rejection method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (we don't need ziggurat speed here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        }
    }

    /// Lognormal with the given sigma around 1.0 (median 1.0). This is the
    /// measurement-noise model: multiplicative noise around a true value.
    pub fn lognormal_around_one(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Sample an index proportionally to `weights` (must be non-negative,
    /// not all zero — falls back to uniform if degenerate).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
        if total <= 0.0 || !total.is_finite() {
            return self.index(weights.len().max(1));
        }
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if *w > 0.0 && w.is_finite() {
                target -= w;
                if target <= 0.0 {
                    return i;
                }
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.index(xs.len())])
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values from the public-domain C implementation, seed 0.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn derive_is_stable_and_independent() {
        let root = Rng::new(7);
        let mut c1 = root.derive("agent");
        let mut c1b = root.derive("agent");
        let mut c2 = root.derive("profiler");
        assert_eq!(c1.next_u64(), c1b.next_u64());
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn below_one_is_zero() {
        let mut r = Rng::new(9);
        for _ in 0..10 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(11);
        let w = [0.0, 10.0, 0.0, 1.0];
        let mut counts = [0usize; 4];
        for _ in 0..10_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[2], 0);
        // ~10:1 ratio, loose bounds
        assert!(counts[1] > counts[3] * 5, "counts={counts:?}");
    }

    #[test]
    fn weighted_index_degenerate_uniform() {
        let mut r = Rng::new(13);
        let w = [0.0, 0.0, 0.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[r.weighted_index(&w)] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_median_near_one() {
        let mut r = Rng::new(17);
        let mut xs: Vec<f64> = (0..9999).map(|_| r.lognormal_around_one(0.05)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[xs.len() / 2];
        assert!((med - 1.0).abs() < 0.01, "median={med}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(29);
        let s = r.sample_indices(10, 4);
        assert_eq!(s.len(), 4);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 4);
        // k > n clamps
        assert_eq!(r.sample_indices(3, 10).len(), 3);
    }
}
