//! Shared content-hash primitive: FNV-1a 64.
//!
//! One hash, three consumers — [`crate::util::rng::Rng::derive`]'s label
//! hash, the verify-memo's candidate keys
//! ([`crate::harness::memo::candidate_key`]), and the log-structured KB
//! store's journal-record checksums ([`crate::kb::store`]). Keeping the
//! constants in one place pins all three to the same function, so the
//! memo's key format and the journal's checksum format can never drift
//! apart silently.

/// FNV-1a 64-bit hash of a byte string.
pub fn fnv1a64_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a 64-bit hash of a string (the UTF-8 bytes).
pub fn fnv1a64(s: &str) -> u64 {
    fnv1a64_bytes(s.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Public FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64_bytes(b"a"), fnv1a64("a"));
    }
}
