//! Summary statistics used throughout the evaluation: geometric mean,
//! median, percentiles, IQR, and the Table-3 style summary block.
//!
//! # Degenerate-input convention
//!
//! All functions are defined over `&[f64]` and follow one convention in
//! **both debug and release builds**: an undefined statistic is `NaN`,
//! never a silently fabricated number.
//!
//! - empty input → `NaN` (`mean`, `geomean`, `median`, `percentile`,
//!   `quartiles`, `min`, `max`, `frac_above`, `stddev`);
//! - `stddev` additionally returns `NaN` for a single sample (the n−1
//!   sample variance is undefined);
//! - `geomean` returns `NaN` when any input is non-finite or ≤ 0 — an
//!   invalid 0.0 "speedup" must surface as NaN, not inflate the mean.
//!   Callers aggregating task speedups filter to valid runs first
//!   (`metrics::summarize` and every `experiments/*` call site do).
//!
//! Sorting-based statistics (`percentile`, `quartiles`) still panic on
//! non-finite input — those are caller bugs, not degenerate data.

/// Arithmetic mean. Returns NaN for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean via log-space accumulation (avoids overflow/underflow).
/// Returns NaN for empty input, and NaN when any input is non-finite or
/// ≤ 0 — identically in debug and release builds (a 0.0 from an invalid
/// run must poison the aggregate visibly, not be clamped away).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|x| !x.is_finite() || *x <= 0.0) {
        return f64::NAN;
    }
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Percentile with linear interpolation (the "linear" / type-7 definition
/// that numpy uses by default). `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("non-finite value in percentile"));
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Nearest-rank percentile over integer samples (queue-sim tick counts).
/// `p` in [0, 1]. Returns NaN for empty input per the module convention —
/// an empty latency series must surface as NaN, not a fabricated 0.
///
/// This is the shared home of the helper the queue simulator
/// (`experiments::simqueue`) and the serve/fleet benchmark reports use;
/// it intentionally differs from [`percentile`] (type-7 linear
/// interpolation, `p` in [0, 100]) — tick latencies are discrete, so the
/// reported percentile is always an observed sample.
pub fn percentile_nearest_rank(xs: &[u64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_unstable();
    let idx = ((v.len() - 1) as f64 * p.clamp(0.0, 1.0)).round() as usize;
    v[idx] as f64
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// (Q1, median, Q3).
pub fn quartiles(xs: &[f64]) -> (f64, f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN, f64::NAN);
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        percentile_sorted(&v, 25.0),
        percentile_sorted(&v, 50.0),
        percentile_sorted(&v, 75.0),
    )
}

/// Interquartile range.
pub fn iqr(xs: &[f64]) -> f64 {
    let (q1, _, q3) = quartiles(xs);
    q3 - q1
}

/// Smallest value. Returns NaN for empty input.
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Largest value. Returns NaN for empty input.
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Sample standard deviation (n−1 denominator). Returns NaN for n < 2:
/// the sample variance is undefined there, and 0.0 would fake perfect
/// agreement out of no evidence (see the module's degenerate-input
/// convention).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Fraction of values strictly greater than `threshold`.
pub fn frac_above(xs: &[f64], threshold: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().filter(|x| **x > threshold).count() as f64 / xs.len() as f64
}

/// The summary block Table 3 reports for a set of per-task speedups.
///
/// Contract: the input is the speedups of *valid* runs only — finite and
/// strictly positive (`metrics::summarize` applies the valid filter
/// before calling [`SpeedupSummary::from_speedups`]). An invalid 0.0
/// sneaking in makes `geomean` NaN by the module convention, which is
/// the intended loud failure, not a reporting mode.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeedupSummary {
    pub n: usize,
    pub average: f64,
    pub geomean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    /// Fraction (0..1) of tasks with speedup > 1.0.
    pub frac_gt_1x: f64,
    /// Fraction (0..1) of tasks with speedup <= 1.0.
    pub frac_lt_1x: f64,
}

impl SpeedupSummary {
    pub fn from_speedups(speedups: &[f64]) -> Self {
        let gt = frac_above(speedups, 1.0);
        Self {
            n: speedups.len(),
            average: mean(speedups),
            geomean: geomean(speedups),
            median: median(speedups),
            min: min(speedups),
            max: max(speedups),
            frac_gt_1x: gt,
            frac_lt_1x: 1.0 - gt,
        }
    }
}

/// Pearson correlation coefficient (used by the Fig. 10 cost analysis).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_no_overflow() {
        let xs = vec![1e300, 1e300, 1e-300, 1e-300];
        let g = geomean(&xs);
        assert!((g - 1.0).abs() < 1e-9, "g={g}");
    }

    #[test]
    fn geomean_empty_is_nan() {
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn geomean_rejects_nonpositive_and_nonfinite_in_all_profiles() {
        // The old release-build behavior clamped 0.0 to MIN_POSITIVE and
        // produced a tiny-but-finite geomean; the contract is now NaN in
        // both profiles (this test has no debug_assert dependence).
        assert!(geomean(&[1.0, 0.0, 2.0]).is_nan());
        assert!(geomean(&[-1.0]).is_nan());
        assert!(geomean(&[1.0, f64::NAN]).is_nan());
        assert!(geomean(&[1.0, f64::INFINITY]).is_nan());
        // Valid inputs are unaffected.
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs_are_nan_uniformly() {
        assert!(mean(&[]).is_nan());
        assert!(min(&[]).is_nan());
        assert!(max(&[]).is_nan());
        assert!(stddev(&[]).is_nan());
        assert!(stddev(&[3.0]).is_nan(), "sample stddev undefined for n=1");
        assert!(frac_above(&[], 1.0).is_nan());
        // n >= 2 still works.
        assert!((stddev(&[1.0, 3.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn percentile_matches_numpy_type7() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank_is_an_observed_sample() {
        let xs = [5u64, 1, 9, 3];
        assert_eq!(percentile_nearest_rank(&xs, 0.0), 1.0);
        assert_eq!(percentile_nearest_rank(&xs, 1.0), 9.0);
        // rank = (4-1) * 0.5 = 1.5 → rounds to index 2 of [1,3,5,9] = 5.
        assert_eq!(percentile_nearest_rank(&xs, 0.5), 5.0);
        // p95 of a small sample is the max (rank 2.85 → index 3).
        assert_eq!(percentile_nearest_rank(&xs, 0.95), 9.0);
        assert_eq!(percentile_nearest_rank(&[7], 0.5), 7.0);
    }

    #[test]
    fn percentile_nearest_rank_empty_is_nan() {
        assert!(percentile_nearest_rank(&[], 0.5).is_nan());
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn quartiles_and_iqr() {
        let xs: Vec<f64> = (1..=9).map(|i| i as f64).collect();
        let (q1, q2, q3) = quartiles(&xs);
        assert_eq!(q2, 5.0);
        assert_eq!(q1, 3.0);
        assert_eq!(q3, 7.0);
        assert_eq!(iqr(&xs), 4.0);
    }

    #[test]
    fn summary_block() {
        let s = SpeedupSummary::from_speedups(&[0.5, 1.0, 2.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.average - 1.875).abs() < 1e-12);
        assert!((s.geomean - 2.0f64.sqrt()).abs() < 1e-9);
        assert_eq!(s.min, 0.5);
        assert_eq!(s.max, 4.0);
        assert!((s.frac_gt_1x - 0.5).abs() < 1e-12);
        assert!((s.frac_lt_1x - 0.5).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0];
        assert!((pearson(&xs, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        // population sd = 2; sample sd = sqrt(32/7)
        assert!((stddev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }
}
