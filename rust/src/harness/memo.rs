//! Persistent cross-run candidate-verification memo — the
//! `kernelblaster-memo-v1` wire format.
//!
//! Verification verdicts are pure functions of (task identity, candidate
//! program, harness tolerances): the same candidate graph + schedule
//! verified under the same config always passes or fails the same way.
//! [`VerifyMemo`] exploits that by memoizing verdicts across picks,
//! tasks, epochs, and *sessions*, keyed by a canonical content hash
//! ([`candidate_key`]). A repeat encounter skips the screen/probe tiers
//! and the full multi-seed oracle entirely; passing candidates are still
//! re-profiled (profiles are noisy measurements, not verdicts — see
//! [`super::staged`]).
//!
//! # What is (and is not) memoizable
//!
//! Recorded verdicts must be deterministic functions of the key alone:
//! - **pass** — recorded only after the full tier-2 oracle (all seeds +
//!   soft verify) accepted the candidate;
//! - **compile_error / wrong_numerics / soft_rejected** — the harness's
//!   deterministic rejections, replayed verbatim on a hit.
//!
//! Tier-0 screen rejections are **never** recorded: they depend on the
//! run's current-best time, which is not part of the key.
//!
//! # Sharing discipline (fleet)
//!
//! Like the KB, the memo flows snapshot-in / delta-out through the fleet:
//! workers read an epoch-start snapshot, collect [`MemoDelta`]s, and the
//! scheduler commits them insert-or-ignore in task order. Because every
//! entry is a deterministic function of its key, commit order cannot
//! change a value — saved memos are byte-identical for any worker count
//! (the entries serialize sorted by key).
//!
//! # Wire format
//!
//! A single ordered-JSON document, `format` key first, entries sorted by
//! key; written with the same atomic tmp+rename discipline as KB
//! checkpoints. Parse → serialize is the identity on every v1 document
//! this crate writes. Corrupt or missing files degrade to a cold (empty)
//! memo with a stderr notice — a damaged cache must never fail a run.
//!
//! # Compaction (bounded growth)
//!
//! Left alone the memo only grows. [`VerifyMemo::compact`] enforces a
//! size bound by evicting non-`pass` verdicts first (cheap to
//! rediscover — a failed candidate just fails again), then passes, both
//! in least-recently-hit order (the `last_hit` epoch, ties by key).
//! Recency is tracked by a caller-advanced epoch counter
//! ([`VerifyMemo::advance_epoch`]) — the fleet never advances it on its
//! own, so worker-count invariance and sequential parity are untouched.
//! Both the root `epoch` and per-entry `last_hit` serialize as
//! **strictly optional** fields, emitted only when non-zero: every
//! pre-compaction document, and every memo that never advances its
//! epoch, stays byte-identical on the wire.
//!
//! Long-lived serving wires the same pass in continuously:
//! [`VerifyMemo::enforce_cap`] (driven by `verify.memo_max_entries`, 0 =
//! unbounded) applies the compaction policy after each serve-loop memo
//! commit, so a daemon's memo stays size-bounded without changing any
//! batch-path byte contract.

use super::{HarnessConfig, Outcome};
use crate::kir::schedule::{MemLayout, Schedule, Tiling};
use crate::kir::{KernelGraph, OpKind, ValueRef};
use crate::opts::Candidate;
use crate::util::json::{Json, JsonObj};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// FNV-1a 64-bit hash of a string — the memo's content-hash primitive.
/// Delegates to the shared [`crate::util::hash`] module (the same
/// function checksums the log-structured KB store's journal records and
/// seeds [`crate::util::rng::Rng::derive`]'s label hash).
pub fn fnv1a64(s: &str) -> u64 {
    crate::util::hash::fnv1a64(s)
}

/// A memoized verification verdict — the deterministic part of an
/// [`Outcome`] (profiles are excluded: they carry measurement noise).
#[derive(Debug, Clone, PartialEq)]
pub enum MemoVerdict {
    /// The candidate passed the full oracle (all seeds + soft verify).
    /// On a hit the caller skips re-verification and goes straight to
    /// profiling.
    Pass,
    /// Structural validation / execution failure, with its feedback.
    CompileError(String),
    /// Numeric mismatch at a verification seed. `max_abs_diff` is stored
    /// bit-exactly on the wire so replayed feedback is byte-identical.
    WrongNumerics {
        /// The failing verification seed.
        seed: u64,
        /// Largest elementwise |Δ| observed at that seed.
        max_abs_diff: f32,
    },
    /// Soft-verify (reward-hacking guard) rejection, with its reason.
    SoftRejected(String),
}

impl MemoVerdict {
    /// The memoizable verdict of a harness outcome; `None` for outcomes
    /// that must not be recorded (tier-0 screens depend on run state).
    pub fn of(outcome: &Outcome) -> Option<MemoVerdict> {
        match outcome {
            Outcome::Ok(_) => Some(MemoVerdict::Pass),
            Outcome::CompileError(e) => Some(MemoVerdict::CompileError(e.clone())),
            Outcome::WrongNumerics { seed, max_abs_diff } => Some(MemoVerdict::WrongNumerics {
                seed: *seed,
                max_abs_diff: *max_abs_diff,
            }),
            Outcome::SoftVerifyRejected(r) => Some(MemoVerdict::SoftRejected(r.clone())),
            Outcome::ScreenedOut(_) => None,
        }
    }

    /// Replay the verdict as an [`Outcome`]. `None` for [`Self::Pass`]:
    /// a pass carries no profile — the caller must re-profile.
    pub fn to_outcome(&self) -> Option<Outcome> {
        match self {
            MemoVerdict::Pass => None,
            MemoVerdict::CompileError(e) => Some(Outcome::CompileError(e.clone())),
            MemoVerdict::WrongNumerics { seed, max_abs_diff } => Some(Outcome::WrongNumerics {
                seed: *seed,
                max_abs_diff: *max_abs_diff,
            }),
            MemoVerdict::SoftRejected(r) => Some(Outcome::SoftVerifyRejected(r.clone())),
        }
    }

    /// Stable wire name of the verdict variant.
    pub fn kind_name(&self) -> &'static str {
        match self {
            MemoVerdict::Pass => "pass",
            MemoVerdict::CompileError(_) => "compile_error",
            MemoVerdict::WrongNumerics { .. } => "wrong_numerics",
            MemoVerdict::SoftRejected(_) => "soft_rejected",
        }
    }
}

/// A stored verdict plus the recency stamp compaction orders by.
#[derive(Debug, Clone, PartialEq)]
struct MemoSlot {
    verdict: MemoVerdict,
    /// Epoch of the most recent insert/re-encounter of this key. Stays 0
    /// unless the caller advances the epoch, keeping legacy wire bytes.
    last_hit: u64,
}

/// The persistent candidate-verification memo: verdicts keyed by the
/// canonical content hash of (task id, candidate, harness fingerprint).
/// Sorted storage keeps serialization byte-stable regardless of insert
/// order — the fleet's worker-count-invariance anchor.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VerifyMemo {
    entries: BTreeMap<String, MemoSlot>,
    /// Caller-advanced recency clock; stamps `last_hit` on insert.
    epoch: u64,
}

impl VerifyMemo {
    /// An empty (cold) memo.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of memoized verdicts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up the verdict for a candidate key.
    pub fn get(&self, key: &str) -> Option<&MemoVerdict> {
        self.entries.get(key).map(|s| &s.verdict)
    }

    /// The current recency epoch (0 until [`Self::advance_epoch`]).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Tick the recency clock. Strictly caller-driven: `kernelblaster
    /// memo compact` advances once per compaction (closing an "era" — runs
    /// between compactions stamp the new epoch); the fleet and the driver
    /// never call this, so all their equality/byte-stability contracts
    /// hold trivially at epoch 0.
    pub fn advance_epoch(&mut self) {
        self.epoch += 1;
    }

    /// The `last_hit` epoch recorded for a key (tests and tooling).
    pub fn last_hit(&self, key: &str) -> Option<u64> {
        self.entries.get(key).map(|s| s.last_hit)
    }

    /// Record a verdict. Insert-or-ignore: verdicts are deterministic
    /// functions of their key, so the first record is as good as any
    /// later one and commit order can never change the memo's content.
    /// A re-encounter of an existing key refreshes its `last_hit` stamp
    /// (monotonically — commit order still cannot change the memo).
    /// Returns true when the key was new.
    pub fn insert(&mut self, key: String, verdict: MemoVerdict) -> bool {
        let epoch = self.epoch;
        match self.entries.entry(key) {
            std::collections::btree_map::Entry::Vacant(v) => {
                v.insert(MemoSlot {
                    verdict,
                    last_hit: epoch,
                });
                true
            }
            std::collections::btree_map::Entry::Occupied(mut o) => {
                let slot = o.get_mut();
                slot.last_hit = slot.last_hit.max(epoch);
                false
            }
        }
    }

    /// Merge a delta (insert-or-ignore, see [`Self::insert`]).
    pub fn apply_delta(&mut self, delta: &MemoDelta) {
        for (k, v) in &delta.added {
            self.insert(k.clone(), v.clone());
        }
    }

    /// Iterate entries in key order (tests and serialization).
    pub fn iter(&self) -> impl Iterator<Item = (&str, &MemoVerdict)> {
        self.entries.iter().map(|(k, s)| (k.as_str(), &s.verdict))
    }

    /// Enforce a size bound, returning how many entries were evicted.
    ///
    /// Eviction order: non-`pass` verdicts first (a failed candidate
    /// simply fails verification again — the cheapest knowledge to
    /// rediscover), then passes; within each class least-recently-hit
    /// first (`last_hit` ascending), ties broken by key so the result is
    /// deterministic for any insertion history.
    pub fn compact(&mut self, max_entries: usize) -> usize {
        if self.entries.len() <= max_entries {
            return 0;
        }
        let excess = self.entries.len() - max_entries;
        let mut order: Vec<(bool, u64, String)> = self
            .entries
            .iter()
            .map(|(k, s)| {
                (
                    matches!(s.verdict, MemoVerdict::Pass),
                    s.last_hit,
                    k.clone(),
                )
            })
            .collect();
        // (false, …) sorts before (true, …): failures evict first.
        order.sort();
        for (_, _, key) in order.into_iter().take(excess) {
            self.entries.remove(&key);
        }
        excess
    }

    /// Enforce an optional size cap: a no-op when `max_entries` is 0
    /// (unbounded — the default, preserving every legacy byte contract)
    /// or when the memo already fits; otherwise a [`Self::compact`] down
    /// to `max_entries`. This is the long-lived-serving guard: the serve
    /// commit loop calls it after each memo-delta fold so a daemon that
    /// runs for days cannot grow its memo without bound. Returns the
    /// number of evicted entries.
    pub fn enforce_cap(&mut self, max_entries: usize) -> usize {
        if max_entries == 0 || self.entries.len() <= max_entries {
            return 0;
        }
        self.compact(max_entries)
    }
}

/// Verdicts a run recorded beyond its input snapshot — the memo analog
/// of `kb::lifecycle::KbDelta`, committed by the fleet in task order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemoDelta {
    /// New (key, verdict) records, in the order the run produced them.
    pub added: Vec<(String, MemoVerdict)>,
}

impl MemoDelta {
    /// A delta with no records.
    pub fn empty() -> Self {
        Self::default()
    }

    /// True when the run recorded nothing new.
    pub fn is_empty(&self) -> bool {
        self.added.is_empty()
    }

    /// Number of new records.
    pub fn len(&self) -> usize {
        self.added.len()
    }
}

fn push_value_ref(out: &mut String, r: ValueRef) {
    match r {
        ValueRef::Input(i) => {
            let _ = write!(out, "i{i}");
        }
        ValueRef::Node(i) => {
            let _ = write!(out, "n{i}");
        }
    }
}

fn push_refs(out: &mut String, refs: &[ValueRef]) {
    for (i, r) in refs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_value_ref(out, *r);
    }
}

fn push_dims(out: &mut String, dims: &[usize]) {
    for (i, d) in dims.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{d}");
    }
}

/// Canonical op spelling. Exhaustive over [`OpKind`] **by design**: a new
/// op added without extending this writer is a compile error, not a
/// silent hash collision. Float parameters are spelled as raw IEEE bits
/// so the canonical form is exact.
fn push_op(out: &mut String, op: &OpKind) {
    let _ = match op {
        OpKind::Matmul => write!(out, "matmul"),
        OpKind::Conv2d { stride, pad } => write!(out, "conv2d(s={stride},p={pad})"),
        OpKind::MaxPool2d { k, stride } => write!(out, "maxpool2d(k={k},s={stride})"),
        OpKind::AvgPool2d { k, stride } => write!(out, "avgpool2d(k={k},s={stride})"),
        OpKind::BiasAdd { axis } => write!(out, "bias_add(a={axis})"),
        OpKind::Relu => write!(out, "relu"),
        OpKind::Gelu => write!(out, "gelu"),
        OpKind::Sigmoid => write!(out, "sigmoid"),
        OpKind::Tanh => write!(out, "tanh"),
        OpKind::Exp => write!(out, "exp"),
        OpKind::Scale { c } => write!(out, "scale(c={:08x})", c.to_bits()),
        OpKind::AddConst { c } => write!(out, "add_const(c={:08x})", c.to_bits()),
        OpKind::Add => write!(out, "add"),
        OpKind::Sub => write!(out, "sub"),
        OpKind::Mul => write!(out, "mul"),
        OpKind::DivConst { c } => write!(out, "div_const(c={:08x})", c.to_bits()),
        OpKind::Softmax { axis } => write!(out, "softmax(a={axis})"),
        OpKind::LogSumExp { axis } => write!(out, "logsumexp(a={axis})"),
        OpKind::ReduceSum { axis } => write!(out, "reduce_sum(a={axis})"),
        OpKind::ReduceMax { axis } => write!(out, "reduce_max(a={axis})"),
        OpKind::ReduceMean { axis } => write!(out, "reduce_mean(a={axis})"),
        OpKind::Transpose => write!(out, "transpose"),
        OpKind::Reshape { shape } => {
            out.push_str("reshape(");
            push_dims(out, &shape.0);
            write!(out, ")")
        }
        OpKind::LayerNorm => write!(out, "layer_norm"),
        OpKind::Concat { axis } => write!(out, "concat(a={axis})"),
        OpKind::Identity => write!(out, "identity"),
    };
}

fn push_graph(out: &mut String, label: &str, g: &KernelGraph) {
    let _ = writeln!(out, "graph={label} name={}", g.name);
    for inp in &g.inputs {
        let _ = write!(out, "in {}:{}:", inp.name, inp.dtype.name());
        push_dims(out, &inp.shape.0);
        out.push('\n');
    }
    for (i, node) in g.nodes.iter().enumerate() {
        let _ = write!(out, "node {i} ");
        push_op(out, &node.kind);
        out.push_str(" deps=");
        push_refs(out, &node.deps);
        out.push_str(" shape=");
        push_dims(out, &node.shape.0);
        let _ = writeln!(out, " dtype={}", node.dtype.name());
    }
    out.push_str("out ");
    push_refs(out, &g.outputs);
    out.push('\n');
}

fn push_schedule(out: &mut String, s: &Schedule) {
    out.push_str("schedule\n");
    for g in &s.groups {
        out.push_str("group nodes=");
        push_dims(out, &g.nodes);
        let _ = write!(out, " grid={} block={}", g.launch.grid, g.launch.block);
        let o = &g.opts;
        let layout = match o.layout {
            MemLayout::Naive => "naive",
            MemLayout::Coalesced => "coalesced",
            MemLayout::Padded => "padded",
        };
        let _ = write!(out, " layout={layout}");
        match o.tiling {
            Tiling::None => out.push_str(" tiling=none"),
            Tiling::Shared { tile } => {
                let _ = write!(out, " tiling=shared({tile})");
            }
        }
        let _ = writeln!(
            out,
            " vw={} ilp={} unroll={} tc={} splitk={} fm={} wsr={} coarse={} regs={} db={} vendor={} scf={}",
            o.vector_width,
            o.ilp,
            o.unroll,
            o.tensor_core as u8,
            o.split_k as u64,
            o.fast_math as u8,
            o.warp_shuffle_reduction as u8,
            o.coarsening,
            o.regs_per_thread,
            o.double_buffer as u8,
            o.vendor_lib as u8,
            o.simplified_control_flow as u8,
        );
    }
}

/// The canonical text a candidate key hashes: task id, the
/// verdict-relevant harness fingerprint, both graphs, and the schedule.
/// Exposed so tests can pin the spelling against a checked-in fixture
/// (hash-stability drift pin).
///
/// The fingerprint includes exactly the config fields a verdict depends
/// on — `verify_seeds` and the tolerances (as raw IEEE bits) plus
/// `allow_vendor` — and deliberately excludes `noise_sigma`, which only
/// shapes profiles, never verdicts.
pub fn canonical_string(task_id: &str, cand: &Candidate, cfg: &HarnessConfig) -> String {
    let mut out = String::with_capacity(1024);
    let _ = writeln!(out, "memo-v1 task={task_id}");
    let _ = writeln!(
        out,
        "cfg seeds={} rtol={:08x} atol={:08x} rtol_reduced={:08x} vendor={}",
        cfg.verify_seeds,
        cfg.rtol.to_bits(),
        cfg.atol.to_bits(),
        cfg.rtol_reduced.to_bits(),
        cfg.allow_vendor as u8,
    );
    push_graph(&mut out, "full", &cand.full);
    push_graph(&mut out, "small", &cand.small);
    push_schedule(&mut out, &cand.schedule);
    out
}

/// The memo key of a candidate: 16 lowercase hex digits of the FNV-1a 64
/// hash of [`canonical_string`].
pub fn candidate_key(task_id: &str, cand: &Candidate, cfg: &HarnessConfig) -> String {
    format!("{:016x}", fnv1a64(&canonical_string(task_id, cand, cfg)))
}

/// Everything that can go wrong loading/saving a memo document.
#[derive(Debug, thiserror::Error)]
pub enum MemoError {
    /// Filesystem failure reading or writing the document.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    /// The file is not valid JSON.
    #[error("json: {0}")]
    Json(#[from] crate::util::json::JsonError),
    /// Valid JSON, but not a well-formed `kernelblaster-memo-v1` document.
    #[error("schema: {0}")]
    Schema(String),
}

/// Serialize a memo into the ordered-JSON v1 document (entries sorted by
/// key — byte-stable for any insertion history). The recency fields
/// (`epoch`, `last_hit`) are emitted only when non-zero, so documents
/// written before compaction existed — and memos that never advance
/// their epoch — reproduce the original v1 bytes exactly.
pub fn to_json(memo: &VerifyMemo) -> Json {
    let mut root = JsonObj::new();
    root.set("format", "kernelblaster-memo-v1");
    if memo.epoch > 0 {
        root.set("epoch", memo.epoch);
    }
    let entries: Vec<Json> = memo
        .entries
        .iter()
        .map(|(key, slot)| {
            let mut o = JsonObj::new();
            o.set("key", key.as_str());
            o.set("verdict", slot.verdict.kind_name());
            match &slot.verdict {
                MemoVerdict::Pass => {}
                MemoVerdict::CompileError(reason) | MemoVerdict::SoftRejected(reason) => {
                    o.set("reason", reason.as_str());
                }
                MemoVerdict::WrongNumerics { seed, max_abs_diff } => {
                    o.set("seed", *seed);
                    o.set("max_abs_diff_bits", max_abs_diff.to_bits());
                }
            }
            if slot.last_hit > 0 {
                o.set("last_hit", slot.last_hit);
            }
            Json::Obj(o)
        })
        .collect();
    root.set("entries", Json::Arr(entries));
    Json::Obj(root)
}

/// Parse a v1 document back into a [`VerifyMemo`].
pub fn from_json(j: &Json) -> Result<VerifyMemo, MemoError> {
    let bad = |m: &str| MemoError::Schema(m.to_string());
    let fmt = j
        .get("format")
        .and_then(Json::as_str)
        .ok_or_else(|| bad("missing format"))?;
    if fmt != "kernelblaster-memo-v1" {
        return Err(bad(&format!("unknown format '{fmt}'")));
    }
    let mut memo = VerifyMemo::new();
    if let Some(ej) = j.get("epoch") {
        memo.epoch = ej
            .as_f64()
            .ok_or_else(|| bad("epoch must be a number"))? as u64;
    }
    for ej in j
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("missing entries"))?
    {
        let key = ej
            .get("key")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("entry missing key"))?;
        let kind = ej
            .get("verdict")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("entry missing verdict"))?;
        let verdict = match kind {
            "pass" => MemoVerdict::Pass,
            "compile_error" => MemoVerdict::CompileError(
                ej.get("reason")
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad("compile_error missing reason"))?
                    .to_string(),
            ),
            "soft_rejected" => MemoVerdict::SoftRejected(
                ej.get("reason")
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad("soft_rejected missing reason"))?
                    .to_string(),
            ),
            "wrong_numerics" => {
                let seed = ej
                    .get("seed")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| bad("wrong_numerics missing seed"))?
                    as u64;
                let bits = ej
                    .get("max_abs_diff_bits")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| bad("wrong_numerics missing max_abs_diff_bits"))?
                    as u32;
                MemoVerdict::WrongNumerics {
                    seed,
                    max_abs_diff: f32::from_bits(bits),
                }
            }
            other => return Err(bad(&format!("unknown verdict '{other}'"))),
        };
        let last_hit = match ej.get("last_hit") {
            Some(lj) => lj
                .as_f64()
                .ok_or_else(|| bad("last_hit must be a number"))? as u64,
            None => 0,
        };
        memo.entries
            .entry(key.to_string())
            .or_insert(MemoSlot { verdict, last_hit });
    }
    Ok(memo)
}

/// Save atomically: write a `.tmp` sibling, then rename over the target
/// (the same crash-safety discipline as `icrl::fleet::checkpoint_atomic`).
pub fn save(memo: &VerifyMemo, path: &Path) -> Result<(), MemoError> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let tmp_name = match path.file_name() {
        Some(n) => {
            let mut t = n.to_os_string();
            t.push(".tmp");
            t
        }
        None => return Err(MemoError::Schema(format!("bad memo path {}", path.display()))),
    };
    let tmp = path.with_file_name(tmp_name);
    std::fs::write(&tmp, to_json(memo).to_string_pretty())?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Strict load (tests and tooling; runs should use [`load_or_cold`]).
pub fn load(path: &Path) -> Result<VerifyMemo, MemoError> {
    let text = std::fs::read_to_string(path)?;
    from_json(&Json::parse(&text)?)
}

/// Load a memo, degrading to a cold (empty) one when the file is missing
/// or damaged: the memo is a cache, and a damaged cache must cost a
/// re-verification, never a failed run. A notice goes to stderr for
/// anything other than a cleanly missing file.
pub fn load_or_cold(path: &Path) -> VerifyMemo {
    match load(path) {
        Ok(memo) => memo,
        Err(MemoError::Io(e)) if e.kind() == std::io::ErrorKind::NotFound => VerifyMemo::new(),
        Err(e) => {
            eprintln!(
                "verify-memo: ignoring unreadable {} ({e}); starting cold",
                path.display()
            );
            VerifyMemo::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Suite;

    fn sample_memo() -> VerifyMemo {
        let mut m = VerifyMemo::new();
        m.insert("00ff00ff00ff00ff".into(), MemoVerdict::Pass);
        m.insert(
            "0123456789abcdef".into(),
            MemoVerdict::WrongNumerics {
                seed: 0x5EED_0000,
                max_abs_diff: 0.125,
            },
        );
        m.insert(
            "fedcba9876543210".into(),
            MemoVerdict::CompileError("candidate failed: boom".into()),
        );
        m.insert(
            "deadbeefdeadbeef".into(),
            MemoVerdict::SoftRejected("kernel dispatches to an external vendor library".into()),
        );
        m
    }

    #[test]
    fn fnv1a64_known_vectors() {
        // Public FNV-1a 64 test vectors — pins the hash the keys use.
        assert_eq!(fnv1a64(""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64("a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn roundtrip_is_identity_on_bytes() {
        let m = sample_memo();
        let first = to_json(&m).to_string_pretty();
        let back = from_json(&Json::parse(&first).unwrap()).unwrap();
        assert_eq!(back, m);
        assert_eq!(to_json(&back).to_string_pretty(), first);
    }

    #[test]
    fn serialization_is_insert_order_independent() {
        let m = sample_memo();
        let mut reversed = VerifyMemo::new();
        let pairs: Vec<_> = m.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
        for (k, v) in pairs.into_iter().rev() {
            reversed.insert(k, v);
        }
        assert_eq!(
            to_json(&m).to_string_pretty(),
            to_json(&reversed).to_string_pretty()
        );
    }

    #[test]
    fn insert_is_insert_or_ignore() {
        let mut m = VerifyMemo::new();
        assert!(m.insert("aa".into(), MemoVerdict::Pass));
        assert!(!m.insert("aa".into(), MemoVerdict::CompileError("later".into())));
        assert_eq!(m.get("aa"), Some(&MemoVerdict::Pass));
        let delta = MemoDelta {
            added: vec![
                ("aa".into(), MemoVerdict::SoftRejected("ignored".into())),
                ("bb".into(), MemoVerdict::Pass),
            ],
        };
        m.apply_delta(&delta);
        assert_eq!(m.len(), 2);
        assert_eq!(m.get("aa"), Some(&MemoVerdict::Pass));
        assert_eq!(m.get("bb"), Some(&MemoVerdict::Pass));
    }

    #[test]
    fn candidate_key_is_stable_and_content_sensitive() {
        let task = Suite::full().by_id("L1/01_matmul_square").unwrap().clone();
        let cfg = HarnessConfig::default();
        let cand = Candidate::naive(&task);
        let k1 = candidate_key(&task.id, &cand, &cfg);
        let k2 = candidate_key(&task.id, &cand, &cfg);
        assert_eq!(k1, k2);
        assert_eq!(k1.len(), 16);
        assert_eq!(
            k1,
            format!("{:016x}", fnv1a64(&canonical_string(&task.id, &cand, &cfg)))
        );
        // Any content change — schedule, config, task id — moves the key.
        let mut tweaked = cand.clone();
        tweaked.schedule.groups[0].opts.unroll = 4;
        assert_ne!(candidate_key(&task.id, &tweaked, &cfg), k1);
        let mut vcfg = cfg.clone();
        vcfg.allow_vendor = true;
        assert_ne!(candidate_key(&task.id, &cand, &vcfg), k1);
        assert_ne!(candidate_key("L1/other", &cand, &cfg), k1);
        // …but noise_sigma is profile-only and must NOT move the key.
        let mut ncfg = cfg.clone();
        ncfg.noise_sigma = 0.5;
        assert_eq!(candidate_key(&task.id, &cand, &ncfg), k1);
    }

    #[test]
    fn verdict_outcome_conversions() {
        let rep_free = [
            Outcome::CompileError("x".into()),
            Outcome::WrongNumerics {
                seed: 7,
                max_abs_diff: 1.5,
            },
            Outcome::SoftVerifyRejected("y".into()),
        ];
        for o in &rep_free {
            let v = MemoVerdict::of(o).unwrap();
            let back = v.to_outcome().unwrap();
            assert_eq!(back.feedback(), o.feedback());
        }
        assert_eq!(MemoVerdict::of(&Outcome::ScreenedOut("cost".into())), None);
        assert_eq!(MemoVerdict::Pass.to_outcome(), None);
    }

    #[test]
    fn file_roundtrip_and_cold_degradation() {
        let dir = std::env::temp_dir().join("kb_memo_persist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("memo.json");
        let m = sample_memo();
        save(&m, &path).unwrap();
        // tmp sibling cleaned up by the rename.
        assert!(!dir.join("memo.json.tmp").exists());
        assert_eq!(load(&path).unwrap(), m);
        assert_eq!(load_or_cold(&path), m);
        // Missing file → cold, silently.
        assert!(load_or_cold(&dir.join("absent.json")).is_empty());
        // Corrupt file → cold with a notice, never an error.
        std::fs::write(&path, "{ not json").unwrap();
        assert!(load_or_cold(&path).is_empty());
        // Wrong format → schema error on strict load, cold on soft load.
        std::fs::write(&path, r#"{"format":"other","entries":[]}"#).unwrap();
        assert!(matches!(load(&path), Err(MemoError::Schema(_))));
        assert!(load_or_cold(&path).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn epoch_zero_memo_emits_no_recency_fields() {
        // Every pre-compaction document — and every memo whose epoch was
        // never advanced — must keep the original v1 bytes exactly.
        let m = sample_memo();
        let text = to_json(&m).to_string_pretty();
        assert!(!text.contains("epoch"), "epoch-0 memo leaked an epoch field");
        assert!(!text.contains("last_hit"), "zero last_hit leaked to the wire");
    }

    #[test]
    fn recency_fields_roundtrip_byte_stably() {
        let mut m = VerifyMemo::new();
        m.insert("aaaa".into(), MemoVerdict::Pass);
        m.advance_epoch();
        m.advance_epoch();
        m.insert("bbbb".into(), MemoVerdict::CompileError("late".into()));
        assert_eq!(m.epoch(), 2);
        assert_eq!(m.last_hit("aaaa"), Some(0));
        assert_eq!(m.last_hit("bbbb"), Some(2));

        let first = to_json(&m).to_string_pretty();
        assert!(first.contains("\"epoch\""));
        assert!(first.contains("\"last_hit\""));
        let back = from_json(&Json::parse(&first).unwrap()).unwrap();
        assert_eq!(back, m);
        assert_eq!(to_json(&back).to_string_pretty(), first);
    }

    #[test]
    fn reencounter_refreshes_last_hit_monotonically() {
        let mut m = VerifyMemo::new();
        m.insert("aa".into(), MemoVerdict::Pass);
        m.advance_epoch();
        // Re-encounter at epoch 1: verdict ignored, recency refreshed.
        assert!(!m.insert("aa".into(), MemoVerdict::CompileError("x".into())));
        assert_eq!(m.get("aa"), Some(&MemoVerdict::Pass));
        assert_eq!(m.last_hit("aa"), Some(1));
        // Replaying a delta never rolls recency back either.
        let delta = MemoDelta {
            added: vec![("aa".into(), MemoVerdict::Pass)],
        };
        m.apply_delta(&delta);
        assert_eq!(m.last_hit("aa"), Some(1));
    }

    #[test]
    fn compact_evicts_failures_first_then_lru_passes() {
        let mut m = VerifyMemo::new();
        m.insert("p_old".into(), MemoVerdict::Pass);
        m.insert("f_old".into(), MemoVerdict::CompileError("a".into()));
        m.advance_epoch();
        m.insert("p_new".into(), MemoVerdict::Pass);
        m.insert("f_new".into(), MemoVerdict::SoftRejected("b".into()));
        assert_eq!(m.len(), 4);

        // Bound not exceeded → no-op.
        assert_eq!(m.compact(4), 0);
        assert_eq!(m.len(), 4);

        // Evict one: the oldest failure goes, every pass survives.
        assert_eq!(m.compact(3), 1);
        assert!(m.get("f_old").is_none());
        assert!(m.get("f_new").is_some());
        assert!(m.get("p_old").is_some() && m.get("p_new").is_some());

        // Evict down to one: remaining failure first, then the LRU pass.
        assert_eq!(m.compact(1), 2);
        assert!(m.get("f_new").is_none());
        assert!(m.get("p_old").is_none());
        assert_eq!(m.get("p_new"), Some(&MemoVerdict::Pass));
    }

    #[test]
    fn compact_ties_break_by_key_deterministically() {
        let mut m1 = VerifyMemo::new();
        for k in ["cc", "aa", "bb", "dd"] {
            m1.insert(k.into(), MemoVerdict::Pass);
        }
        let mut m2 = VerifyMemo::new();
        for k in ["dd", "bb", "aa", "cc"] {
            m2.insert(k.into(), MemoVerdict::Pass);
        }
        assert_eq!(m1.compact(2), 2);
        assert_eq!(m2.compact(2), 2);
        assert_eq!(
            to_json(&m1).to_string_pretty(),
            to_json(&m2).to_string_pretty()
        );
        // All-equal recency: lexicographically smallest keys evict first.
        assert!(m1.get("aa").is_none() && m1.get("bb").is_none());
        assert!(m1.get("cc").is_some() && m1.get("dd").is_some());
    }

    #[test]
    fn enforce_cap_zero_is_unbounded() {
        let mut m = VerifyMemo::new();
        for k in ["aa", "bb", "cc"] {
            m.insert(k.into(), MemoVerdict::Pass);
        }
        // 0 = unbounded: nothing evicts no matter the size.
        assert_eq!(m.enforce_cap(0), 0);
        assert_eq!(m.len(), 3);
        // Cap not exceeded → still a no-op.
        assert_eq!(m.enforce_cap(3), 0);
        assert_eq!(m.len(), 3);
        // Over the cap → compacts down with the same eviction policy.
        assert_eq!(m.enforce_cap(1), 2);
        assert_eq!(m.len(), 1);
        assert!(m.get("cc").is_some());
    }

    #[test]
    fn compacted_memo_save_is_byte_stable() {
        let dir = std::env::temp_dir().join("kb_memo_compact_persist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("memo.json");
        let mut m = sample_memo();
        m.advance_epoch();
        m.insert("ffffffffffffffff".into(), MemoVerdict::Pass);
        m.compact(3);
        save(&m, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded, m);
        save(&loaded, &path).unwrap();
        assert_eq!(load(&path).unwrap(), m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_malformed_entries() {
        for doc in [
            r#"{"entries":[]}"#,
            r#"{"format":"kernelblaster-memo-v1"}"#,
            r#"{"format":"kernelblaster-memo-v1","entries":[{"verdict":"pass"}]}"#,
            r#"{"format":"kernelblaster-memo-v1","entries":[{"key":"aa"}]}"#,
            r#"{"format":"kernelblaster-memo-v1","entries":[{"key":"aa","verdict":"maybe"}]}"#,
            r#"{"format":"kernelblaster-memo-v1","entries":[{"key":"aa","verdict":"wrong_numerics"}]}"#,
            r#"{"format":"kernelblaster-memo-v1","entries":[{"key":"aa","verdict":"compile_error"}]}"#,
        ] {
            assert!(from_json(&Json::parse(doc).unwrap()).is_err(), "{doc}");
        }
    }
}
