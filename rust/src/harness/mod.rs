//! Execution and validation harness (paper §4.3–§4.4).
//!
//! Mirrors the paper's three-stage pipeline:
//! 1. **Compile check** — structural validation of the candidate; failures
//!    return compiler-style feedback to the lowering agent.
//! 2. **Numeric verification** — the candidate's small graph is executed
//!    against the *original task graph* on multiple randomized seeds
//!    ("multiple randomized seeds to ensure correctness and prevent
//!    overfitting", Table 2) with dtype-aware tolerances.
//! 3. **Soft verification** — an LLM-style structural scan of the rendered
//!    source guarding against reward hacking: functionality elimination
//!    (the AI CUDA Engineer failure mode §4.4) and illegal external
//!    library dispatch.
//!
//! Only candidates passing all three are profiled (stage 4) and scored.

use crate::gpu::{profiler, GpuArch, NcuReport};
use crate::kir::{interp, render, OpKind};
use crate::opts::Candidate;
use crate::tasks::Task;
use crate::util::rng::Rng;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct HarnessConfig {
    /// Number of randomized verification seeds.
    pub verify_seeds: usize,
    /// Tolerances for f32 candidates.
    pub rtol: f32,
    pub atol: f32,
    /// Looser tolerances once reduced precision is in play.
    pub rtol_reduced: f32,
    /// Profiling measurement noise (lognormal sigma; 0 = exact).
    pub noise_sigma: f64,
    /// Whether vendor-library dispatch is permitted (the "+cuDNN" mode of
    /// Figs. 8/11). Outside it, the soft verifier rejects vendor calls.
    pub allow_vendor: bool,
}

impl Default for HarnessConfig {
    fn default() -> Self {
        Self {
            verify_seeds: 3,
            rtol: 1e-4,
            atol: 1e-4,
            rtol_reduced: 3e-2,
            noise_sigma: 0.02,
            allow_vendor: false,
        }
    }
}

/// Outcome of one harness pass.
#[derive(Debug, Clone)]
pub enum Outcome {
    /// Structural validation failed — "compilation feedback … returned to
    /// the code-lowering agent".
    CompileError(String),
    /// Numeric mismatch against the reference.
    WrongNumerics {
        seed: u64,
        max_abs_diff: f32,
    },
    /// Soft verifier rejected the kernel (reward-hacking guard).
    SoftVerifyRejected(String),
    /// All checks passed; the profile is attached.
    Ok(NcuReport),
}

impl Outcome {
    pub fn is_ok(&self) -> bool {
        matches!(self, Outcome::Ok(_))
    }

    /// Feedback line handed back to the agents.
    pub fn feedback(&self) -> String {
        match self {
            Outcome::CompileError(e) => format!("compile error: {e}"),
            Outcome::WrongNumerics { seed, max_abs_diff } => {
                format!("numeric verification failed (seed {seed}): max|Δ|={max_abs_diff:.3e}")
            }
            Outcome::SoftVerifyRejected(r) => format!("soft-verify rejected: {r}"),
            Outcome::Ok(rep) => format!(
                "ok: {} kernels, {:.0} cycles",
                rep.kernels.len(),
                rep.total_cycles
            ),
        }
    }
}

/// Run the full pipeline for `cand` derived from `task` on `arch`.
pub fn run(
    task: &Task,
    cand: &Candidate,
    arch: &GpuArch,
    cfg: &HarnessConfig,
    rng: &mut Rng,
) -> Outcome {
    // Stage 1: compile check.
    if let Err(e) = cand.validate() {
        return Outcome::CompileError(e);
    }
    // Stage 2: numeric verification, multiple seeds.
    let rtol = if cand.has_reduced_precision() {
        cfg.rtol_reduced
    } else {
        cfg.rtol
    };
    for i in 0..cfg.verify_seeds {
        let seed = 0x5EED_0000 + i as u64;
        let inputs = interp::random_inputs(&task.small, seed);
        // §Perf: the reference outputs are invariant per (task, seed) —
        // cache them instead of re-executing the reference graph on every
        // candidate evaluation (this halves verification cost, the hot
        // path of the whole driver).
        let reference = match cached_reference(task, seed, &inputs) {
            Ok(r) => r,
            Err(e) => return Outcome::CompileError(format!("reference failed: {e}")),
        };
        let got = match interp::execute(&cand.small, &inputs) {
            Ok(g) => g,
            Err(e) => return Outcome::CompileError(format!("candidate failed: {e}")),
        };
        if reference.len() != got.len() {
            return Outcome::CompileError(format!(
                "output arity mismatch: {} vs {}",
                reference.len(),
                got.len()
            ));
        }
        for (r, g) in reference.iter().zip(&got) {
            if !interp::allclose(g, r, rtol, cfg.atol) {
                return Outcome::WrongNumerics {
                    seed,
                    max_abs_diff: interp::max_abs_diff(g, r),
                };
            }
        }
    }
    // Stage 3: soft verification.
    if let Err(reason) = soft_verify(task, cand, cfg) {
        return Outcome::SoftVerifyRejected(reason);
    }
    // Stage 4: profile.
    Outcome::Ok(profiler::profile(
        arch,
        &cand.full,
        &cand.schedule,
        cfg.noise_sigma,
        rng,
    ))
}

thread_local! {
    /// (task id, seed) → reference outputs. Keyed by id: task graphs are
    /// immutable per id within a process.
    static REF_CACHE: std::cell::RefCell<std::collections::HashMap<(String, u64), std::rc::Rc<Vec<interp::Tensor>>>> =
        std::cell::RefCell::new(std::collections::HashMap::new());
}

fn cached_reference(
    task: &Task,
    seed: u64,
    inputs: &[interp::Tensor],
) -> Result<std::rc::Rc<Vec<interp::Tensor>>, interp::InterpError> {
    let key = (task.id.clone(), seed);
    if let Some(hit) = REF_CACHE.with(|c| c.borrow().get(&key).cloned()) {
        return Ok(hit);
    }
    let computed = std::rc::Rc::new(interp::execute(&task.small, inputs)?);
    REF_CACHE.with(|c| c.borrow_mut().insert(key, computed.clone()));
    Ok(computed)
}

/// The LLM-soft-verification analog: structural scans of the rendered
/// kernel source plus graph invariants. Returns Err(reason) on rejection.
pub fn soft_verify(task: &Task, cand: &Candidate, cfg: &HarnessConfig) -> Result<(), String> {
    let source = render::render(&cand.full, &cand.schedule);
    // Guard 1: external/vendor libraries outside +vendor mode ("generated
    // kernels only use native CUDA functionality", §4.4).
    if !cfg.allow_vendor && (source.contains("cudnn") || source.contains("cublas")) {
        return Err("kernel dispatches to an external vendor library".to_string());
    }
    // Guard 2: functionality elimination — the candidate must retain the
    // original contraction work (an agent deleting the matmul and copying
    // inputs would otherwise score a huge "speedup").
    let orig = task.graph.op_census();
    let now = cand.full.op_census();
    if now.contractions < orig.contractions {
        return Err(format!(
            "contraction work eliminated ({} -> {})",
            orig.contractions, now.contractions
        ));
    }
    // Guard 3: stub detection — Identity nodes feeding outputs where the
    // original computed something.
    for out in &cand.full.outputs {
        if let crate::kir::ValueRef::Node(i) = out {
            if matches!(cand.full.nodes[*i].kind, OpKind::Identity) {
                return Err("output produced by a bare copy (stubbed work)".to_string());
            }
        }
    }
    Ok(())
}

/// Result of profiling the unmodified naive candidate (the initial CUDA
/// state) — convenience for the ICRL driver and baselines.
pub fn profile_naive(task: &Task, arch: &GpuArch, cfg: &HarnessConfig, rng: &mut Rng) -> NcuReport {
    let cand = Candidate::naive(task);
    profiler::profile(arch, &cand.full, &cand.schedule, cfg.noise_sigma, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::ValueRef;
    use crate::opts::{apply, Technique};
    use crate::tasks::Suite;

    fn setup(id: &str) -> (Task, Candidate, GpuArch, HarnessConfig, Rng) {
        let task = Suite::full().by_id(id).unwrap().clone();
        let cand = Candidate::naive(&task);
        (
            task,
            cand,
            GpuArch::h100(),
            HarnessConfig {
                noise_sigma: 0.0,
                ..Default::default()
            },
            Rng::new(7),
        )
    }

    #[test]
    fn naive_candidate_passes() {
        let (task, cand, arch, cfg, mut rng) = setup("L2/01_gemm_bias_relu");
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(out.is_ok(), "{}", out.feedback());
    }

    #[test]
    fn legit_transform_passes() {
        let (task, cand, arch, cfg, mut rng) = setup("L2/18_linear_sum_logsumexp2");
        let a = apply::apply(Technique::AlgebraicSimplification, &cand, 0).unwrap();
        let b = apply::apply(Technique::AlgebraicSimplification, &a, 0).unwrap();
        let out = run(&task, &b, &arch, &cfg, &mut rng);
        assert!(out.is_ok(), "{}", out.feedback());
    }

    #[test]
    fn semantic_bug_caught_by_numeric_check() {
        let (task, mut cand, arch, cfg, mut rng) = setup("L2/01_gemm_bias_relu");
        // Inject a lowering bug: drop the ReLU by rewiring the output to
        // the bias-add (a classic "forgot the epilogue" bug).
        let bias_node = ValueRef::Node(1);
        cand.full.outputs = vec![bias_node];
        cand.small.outputs = vec![bias_node];
        // (schedule keeps all nodes; graph still validates — only the
        // semantics changed.)
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(
            matches!(out, Outcome::WrongNumerics { .. }),
            "{}",
            out.feedback()
        );
    }

    #[test]
    fn wrong_axis_bug_caught() {
        let (task, mut cand, arch, cfg, mut rng) = setup("L1/19_reduce_sum");
        // Reduce along axis 0 instead of 1 — shapes change, so this is a
        // compile error (shape mismatch), like real CUDA would fail.
        cand.full.nodes[0].kind = OpKind::ReduceSum { axis: 0 };
        cand.small.nodes[0].kind = OpKind::ReduceSum { axis: 0 };
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(
            matches!(out, Outcome::CompileError(_)),
            "{}",
            out.feedback()
        );
    }

    #[test]
    fn vendor_dispatch_rejected_without_vendor_mode() {
        let (task, cand, arch, cfg, mut rng) = setup("L1/01_matmul_square");
        let v = apply::apply(Technique::VendorLibraryDispatch, &cand, 0).unwrap();
        let out = run(&task, &v, &arch, &cfg, &mut rng);
        assert!(
            matches!(out, Outcome::SoftVerifyRejected(_)),
            "{}",
            out.feedback()
        );
        // …but allowed in +vendor mode (Fig. 8's "+cuDNN").
        let mut vcfg = cfg.clone();
        vcfg.allow_vendor = true;
        let out2 = run(&task, &v, &arch, &vcfg, &mut rng);
        assert!(out2.is_ok(), "{}", out2.feedback());
    }

    #[test]
    fn functionality_elimination_rejected() {
        let (task, mut cand, arch, cfg, mut rng) = setup("L1/01_matmul_square");
        // Reward hack: replace the matmul with an Identity on x… which
        // also changes shapes — so emulate the sneaky version where shapes
        // happen to match (square matmul): identity passes shape check but
        // must be caught by soft verify (census) or numerics.
        cand.full.nodes[0].kind = OpKind::Identity;
        cand.full.nodes[0].deps = vec![ValueRef::Input(0)];
        cand.small.nodes[0].kind = OpKind::Identity;
        cand.small.nodes[0].deps = vec![ValueRef::Input(0)];
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(!out.is_ok());
    }

    #[test]
    fn stub_output_rejected_even_if_numerically_plausible() {
        // Build a task whose output could accidentally match a copy: use
        // soft_verify directly on an Identity-terminated graph.
        let (task, mut cand, _arch, cfg, _rng) = setup("L1/15_relu");
        cand.full.nodes[0].kind = OpKind::Identity;
        cand.small.nodes[0].kind = OpKind::Identity;
        let err = soft_verify(&task, &cand, &cfg).unwrap_err();
        assert!(err.contains("copy"), "{err}");
    }

    #[test]
    fn multi_seed_verification_catches_seed_dependent_luck() {
        // A candidate that zeroes its output matches the reference only if
        // the reference happens to be zero — never for random seeds.
        let (task, mut cand, arch, cfg, mut rng) = setup("L1/15_relu");
        cand.small.nodes[0].kind = OpKind::Scale { c: 0.0 };
        cand.full.nodes[0].kind = OpKind::Scale { c: 0.0 };
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(matches!(out, Outcome::WrongNumerics { .. }));
    }

    #[test]
    fn reduced_precision_gets_loose_tolerance() {
        let (task, cand, arch, cfg, mut rng) = setup("L1/05_matmul_f16");
        // f16 inputs: rounding error must not fail verification.
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(out.is_ok(), "{}", out.feedback());
    }

    #[test]
    fn feedback_strings_informative() {
        let (task, cand, arch, cfg, mut rng) = setup("L1/01_matmul_square");
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(out.feedback().starts_with("ok:"));
        let ce = Outcome::CompileError("boom".into());
        assert!(ce.feedback().contains("boom"));
    }
}
