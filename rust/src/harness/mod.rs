//! Execution and validation harness (paper §4.3–§4.4).
//!
//! Mirrors the paper's three-stage pipeline:
//! 1. **Compile check** — structural validation of the candidate; failures
//!    return compiler-style feedback to the lowering agent.
//! 2. **Numeric verification** — the candidate's small graph is executed
//!    against the *original task graph* on multiple randomized seeds
//!    ("multiple randomized seeds to ensure correctness and prevent
//!    overfitting", Table 2) with dtype-aware tolerances.
//! 3. **Soft verification** — an LLM-style structural scan of the rendered
//!    source guarding against reward hacking: functionality elimination
//!    (the AI CUDA Engineer failure mode §4.4) and illegal external
//!    library dispatch.
//!
//! Only candidates passing all three are profiled (stage 4) and scored.
//!
//! # Performance architecture (§Perf)
//!
//! The reference outputs of stage 2 are invariant per (task, seed): the
//! task graph never changes during a run, while hundreds of candidates are
//! verified against it. [`VerifyCache`] memoizes those reference outputs
//! (and the random inputs they were produced from). Ownership scales with
//! the serving mode: a one-task run owns one cache
//! (`icrl::optimize_task`), while each fleet worker owns one cache for
//! *all* the tasks it serves (`icrl::optimize_task_in` takes the cache by
//! `&mut`; entries are keyed by task id and [`VerifyCache::warm`] is
//! idempotent, so repeated task ids in a batch hit the same fixtures).
//! Within a run the cache is handed out as shared references to every
//! candidate evaluation — including concurrent ones: entries are `Arc`ed
//! and reads are lock-free (`&VerifyCache`). The plain [`run`] entry point
//! stays cache-free for one-shot callers.
//!
//! Position in the MAIC-RL loop (profile → state-extract → KB-match →
//! lower → **verify**): the driver ([`crate::icrl`]) hands every lowered
//! candidate ([`crate::agents::lowering`]) here; numerics run on the
//! [`crate::kir::interp`] oracle, soft verification scans
//! [`crate::kir::render`] output, and passing candidates get their
//! [`crate::gpu`] profile — the reward signal the KB ([`crate::kb`])
//! integrates.
//!
//! # Tiered verification (§staged)
//!
//! The [`staged`] submodule wraps this pipeline in a screen → probe →
//! full-oracle cascade with a persistent cross-run verdict memo
//! ([`memo`]), spending the expensive stages only on candidates the
//! cheap tiers cannot reject. The full oracle here remains the only
//! committing gate; staging is opt-in (`verify.staged`) and off by
//! default, in which case this module's behavior is bit-identical to
//! the pre-staging crate.

#![deny(missing_docs)]

pub mod memo;
pub mod staged;

use crate::gpu::{profiler, GpuArch, NcuReport};
use crate::kir::{interp, render, OpKind};
use crate::opts::Candidate;
use crate::tasks::Task;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct HarnessConfig {
    /// Number of randomized verification seeds.
    pub verify_seeds: usize,
    /// Relative tolerance for f32 candidates.
    pub rtol: f32,
    /// Absolute tolerance for f32 candidates.
    pub atol: f32,
    /// Looser tolerances once reduced precision is in play.
    pub rtol_reduced: f32,
    /// Profiling measurement noise (lognormal sigma; 0 = exact).
    pub noise_sigma: f64,
    /// Whether vendor-library dispatch is permitted (the "+cuDNN" mode of
    /// Figs. 8/11). Outside it, the soft verifier rejects vendor calls.
    pub allow_vendor: bool,
}

impl Default for HarnessConfig {
    fn default() -> Self {
        Self {
            verify_seeds: 3,
            rtol: 1e-4,
            atol: 1e-4,
            rtol_reduced: 3e-2,
            noise_sigma: 0.02,
            allow_vendor: false,
        }
    }
}

/// The i-th verification seed (stable across the codebase: the paper's
/// "multiple randomized seeds" are fixed per harness run).
pub fn verify_seed(i: usize) -> u64 {
    0x5EED_0000 + i as u64
}

/// One memoized verification fixture: the randomized inputs for a seed
/// and the task graph's outputs on them.
#[derive(Debug)]
pub struct VerifyEntry {
    /// The verification seed the inputs were drawn from.
    pub seed: u64,
    /// The randomized inputs for that seed.
    pub inputs: Vec<interp::Tensor>,
    /// The task graph's outputs on those inputs (ground truth).
    pub reference: Vec<interp::Tensor>,
}

/// Memoized reference-oracle outputs per (task, seed) — see §Perf above.
/// Owned by the driver; shared immutably with candidate evaluations.
#[derive(Debug, Default)]
pub struct VerifyCache {
    /// task id → per-seed entries (index = seed index).
    entries: HashMap<String, Vec<Arc<VerifyEntry>>>,
}

impl VerifyCache {
    /// An empty (cold) cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Compute-and-store the reference fixtures for every verification
    /// seed of `task` (idempotent; extends if `verify_seeds` grew).
    pub fn warm(&mut self, task: &Task, cfg: &HarnessConfig) -> Result<(), String> {
        let slot = self.entries.entry(task.id.clone()).or_default();
        if slot.len() >= cfg.verify_seeds {
            return Ok(());
        }
        let mut ctx = interp::ExecContext::new();
        for i in slot.len()..cfg.verify_seeds {
            let seed = verify_seed(i);
            let inputs = interp::random_inputs(&task.small, seed);
            let reference = ctx
                .execute_owned(&task.small, &inputs)
                .map_err(|e| format!("reference failed: {e}"))?;
            slot.push(Arc::new(VerifyEntry {
                seed,
                inputs,
                reference,
            }));
        }
        Ok(())
    }

    /// Fixture for seed index `i` of `task_id`, if warmed.
    pub fn get(&self, task_id: &str, i: usize) -> Option<&Arc<VerifyEntry>> {
        self.entries.get(task_id).and_then(|v| v.get(i))
    }

    /// Number of memoized (task, seed) fixtures.
    pub fn len(&self) -> usize {
        self.entries.values().map(Vec::len).sum()
    }

    /// True when nothing has been warmed yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Outcome of one harness pass.
#[derive(Debug, Clone)]
pub enum Outcome {
    /// Structural validation failed — "compilation feedback … returned to
    /// the code-lowering agent".
    CompileError(String),
    /// Numeric mismatch against the reference.
    WrongNumerics {
        seed: u64,
        max_abs_diff: f32,
    },
    /// Soft verifier rejected the kernel (reward-hacking guard).
    SoftVerifyRejected(String),
    /// Tier-0 static screen rejected the candidate before any execution
    /// (staged pipeline only, [`staged`]): the cost model estimates it
    /// clearly dominated by the current best. Carries the cost-model
    /// feedback string so the textgrad loop still learns from it.
    ScreenedOut(String),
    /// All checks passed; the profile is attached.
    Ok(NcuReport),
}

impl Outcome {
    /// True when every check passed and a profile is attached.
    pub fn is_ok(&self) -> bool {
        matches!(self, Outcome::Ok(_))
    }

    /// Feedback line handed back to the agents.
    pub fn feedback(&self) -> String {
        match self {
            Outcome::CompileError(e) => format!("compile error: {e}"),
            Outcome::WrongNumerics { seed, max_abs_diff } => {
                format!("numeric verification failed (seed {seed}): max|Δ|={max_abs_diff:.3e}")
            }
            Outcome::SoftVerifyRejected(r) => format!("soft-verify rejected: {r}"),
            Outcome::ScreenedOut(r) => format!("static screen rejected: {r}"),
            Outcome::Ok(rep) => format!(
                "ok: {} kernels, {:.0} cycles",
                rep.kernels.len(),
                rep.total_cycles
            ),
        }
    }
}

/// Stage-2 numeric verification. Returns `Some(failure)` on mismatch.
/// Cached fixtures are used when available; misses fall back to computing
/// the reference inline (without mutating the cache — lookups stay
/// lock-free for concurrent evaluators).
fn verify_numerics(
    task: &Task,
    cand: &Candidate,
    cfg: &HarnessConfig,
    cache: Option<&VerifyCache>,
    cand_ctx: &mut interp::ExecContext,
) -> Option<Outcome> {
    verify_numerics_range(task, cand, cfg, cache, cand_ctx, 0, cfg.verify_seeds).0
}

/// Stage-2 verification over the seed-index range `[from, to)` — the
/// building block the staged pipeline ([`staged`]) splits the oracle
/// with (probe seeds first, the remainder at tier 2). Also returns how
/// many seed checks actually ran (the staged op counter). Checking
/// `[0, p)` then `[p, n)` is exactly equivalent to checking `[0, n)`:
/// seeds are independent and the loop fails on the first mismatch in
/// index order either way.
pub(crate) fn verify_numerics_range(
    task: &Task,
    cand: &Candidate,
    cfg: &HarnessConfig,
    cache: Option<&VerifyCache>,
    cand_ctx: &mut interp::ExecContext,
    from: usize,
    to: usize,
) -> (Option<Outcome>, usize) {
    let rtol = if cand.has_reduced_precision() {
        cfg.rtol_reduced
    } else {
        cfg.rtol
    };
    // Reference context only materializes on cache misses.
    let mut ref_ctx: Option<interp::ExecContext> = None;
    let mut executed = 0usize;
    for i in from..to {
        let seed = verify_seed(i);
        executed += 1;
        let bad = match cache.and_then(|c| c.get(&task.id, i)) {
            Some(entry) => check_one_seed(
                cand,
                rtol,
                cfg.atol,
                seed,
                &entry.inputs,
                &entry.reference,
                cand_ctx,
            ),
            None => {
                let rctx = ref_ctx.get_or_insert_with(interp::ExecContext::new);
                let inputs = interp::random_inputs(&task.small, seed);
                let reference = match rctx.execute_owned(&task.small, &inputs) {
                    Ok(r) => r,
                    Err(e) => {
                        return (
                            Some(Outcome::CompileError(format!("reference failed: {e}"))),
                            executed,
                        )
                    }
                };
                check_one_seed(cand, rtol, cfg.atol, seed, &inputs, &reference, cand_ctx)
            }
        };
        if bad.is_some() {
            return (bad, executed);
        }
    }
    (None, executed)
}

/// Execute the candidate on one seed's inputs and compare to the
/// reference. Returns `Some(failure)` on any mismatch.
fn check_one_seed(
    cand: &Candidate,
    rtol: f32,
    atol: f32,
    seed: u64,
    inputs: &[interp::Tensor],
    reference: &[interp::Tensor],
    cand_ctx: &mut interp::ExecContext,
) -> Option<Outcome> {
    let got = match cand_ctx.execute(&cand.small, inputs) {
        Ok(g) => g,
        Err(e) => return Some(Outcome::CompileError(format!("candidate failed: {e}"))),
    };
    if reference.len() != got.len() {
        return Some(Outcome::CompileError(format!(
            "output arity mismatch: {} vs {}",
            reference.len(),
            got.len()
        )));
    }
    for (r, &g) in reference.iter().zip(&got) {
        if !interp::allclose(g, r, rtol, atol) {
            return Some(Outcome::WrongNumerics {
                seed,
                max_abs_diff: interp::max_abs_diff(g, r),
            });
        }
    }
    None
}

/// Run the full pipeline for `cand` derived from `task` on `arch`,
/// without a reference cache (one-shot callers; hot paths use
/// [`run_cached`]).
pub fn run(
    task: &Task,
    cand: &Candidate,
    arch: &GpuArch,
    cfg: &HarnessConfig,
    rng: &mut Rng,
) -> Outcome {
    run_cached(task, cand, arch, cfg, None, rng)
}

/// Run the full pipeline with a (possibly pre-warmed) reference cache.
/// Semantically identical to [`run`]; the cache only skips re-executing
/// the unchanged task graph.
pub fn run_cached(
    task: &Task,
    cand: &Candidate,
    arch: &GpuArch,
    cfg: &HarnessConfig,
    cache: Option<&VerifyCache>,
    rng: &mut Rng,
) -> Outcome {
    let mut ctx = interp::ExecContext::new();
    run_cached_in(task, cand, arch, cfg, cache, &mut ctx, rng)
}

/// [`run_cached`] with a caller-owned interpreter arena, so buffer pools
/// and evaluation plans amortize across many candidate evaluations (the
/// driver holds one per pick, covering all lowering retries × seeds).
pub fn run_cached_in(
    task: &Task,
    cand: &Candidate,
    arch: &GpuArch,
    cfg: &HarnessConfig,
    cache: Option<&VerifyCache>,
    ctx: &mut interp::ExecContext,
    rng: &mut Rng,
) -> Outcome {
    // Stage 1: compile check.
    if let Err(e) = cand.validate() {
        return Outcome::CompileError(e);
    }
    // Stage 2: numeric verification, multiple seeds.
    if let Some(bad) = verify_numerics(task, cand, cfg, cache, ctx) {
        return bad;
    }
    // Stage 3: soft verification.
    if let Err(reason) = soft_verify(task, cand, cfg) {
        return Outcome::SoftVerifyRejected(reason);
    }
    // Stage 4: profile.
    Outcome::Ok(profiler::profile(
        arch,
        &cand.full,
        &cand.schedule,
        cfg.noise_sigma,
        rng,
    ))
}

/// The LLM-soft-verification analog: structural scans of the rendered
/// kernel source plus graph invariants. Returns Err(reason) on rejection.
pub fn soft_verify(task: &Task, cand: &Candidate, cfg: &HarnessConfig) -> Result<(), String> {
    let source = render::render(&cand.full, &cand.schedule);
    // Guard 1: external/vendor libraries outside +vendor mode ("generated
    // kernels only use native CUDA functionality", §4.4).
    if !cfg.allow_vendor && (source.contains("cudnn") || source.contains("cublas")) {
        return Err("kernel dispatches to an external vendor library".to_string());
    }
    // Guard 2: functionality elimination — the candidate must retain the
    // original contraction work (an agent deleting the matmul and copying
    // inputs would otherwise score a huge "speedup").
    let orig = task.graph.op_census();
    let now = cand.full.op_census();
    if now.contractions < orig.contractions {
        return Err(format!(
            "contraction work eliminated ({} -> {})",
            orig.contractions, now.contractions
        ));
    }
    // Guard 3: stub detection — Identity nodes feeding outputs where the
    // original computed something.
    for out in &cand.full.outputs {
        if let crate::kir::ValueRef::Node(i) = out {
            if matches!(cand.full.nodes[*i].kind, OpKind::Identity) {
                return Err("output produced by a bare copy (stubbed work)".to_string());
            }
        }
    }
    Ok(())
}

/// Result of profiling the unmodified naive candidate (the initial CUDA
/// state) — convenience for the ICRL driver and baselines.
pub fn profile_naive(task: &Task, arch: &GpuArch, cfg: &HarnessConfig, rng: &mut Rng) -> NcuReport {
    let cand = Candidate::naive(task);
    profiler::profile(arch, &cand.full, &cand.schedule, cfg.noise_sigma, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::ValueRef;
    use crate::opts::{apply, Technique};
    use crate::tasks::Suite;

    fn setup(id: &str) -> (Task, Candidate, GpuArch, HarnessConfig, Rng) {
        let task = Suite::full().by_id(id).unwrap().clone();
        let cand = Candidate::naive(&task);
        (
            task,
            cand,
            GpuArch::h100(),
            HarnessConfig {
                noise_sigma: 0.0,
                ..Default::default()
            },
            Rng::new(7),
        )
    }

    #[test]
    fn naive_candidate_passes() {
        let (task, cand, arch, cfg, mut rng) = setup("L2/01_gemm_bias_relu");
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(out.is_ok(), "{}", out.feedback());
    }

    #[test]
    fn legit_transform_passes() {
        let (task, cand, arch, cfg, mut rng) = setup("L2/18_linear_sum_logsumexp2");
        let a = apply::apply(Technique::AlgebraicSimplification, &cand, 0).unwrap();
        let b = apply::apply(Technique::AlgebraicSimplification, &a, 0).unwrap();
        let out = run(&task, &b, &arch, &cfg, &mut rng);
        assert!(out.is_ok(), "{}", out.feedback());
    }

    #[test]
    fn semantic_bug_caught_by_numeric_check() {
        let (task, mut cand, arch, cfg, mut rng) = setup("L2/01_gemm_bias_relu");
        // Inject a lowering bug: drop the ReLU by rewiring the output to
        // the bias-add (a classic "forgot the epilogue" bug).
        let bias_node = ValueRef::Node(1);
        cand.full.outputs = vec![bias_node];
        cand.small.outputs = vec![bias_node];
        // (schedule keeps all nodes; graph still validates — only the
        // semantics changed.)
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(
            matches!(out, Outcome::WrongNumerics { .. }),
            "{}",
            out.feedback()
        );
    }

    #[test]
    fn wrong_axis_bug_caught() {
        let (task, mut cand, arch, cfg, mut rng) = setup("L1/19_reduce_sum");
        // Reduce along axis 0 instead of 1 — shapes change, so this is a
        // compile error (shape mismatch), like real CUDA would fail.
        cand.full.nodes[0].kind = OpKind::ReduceSum { axis: 0 };
        cand.small.nodes[0].kind = OpKind::ReduceSum { axis: 0 };
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(
            matches!(out, Outcome::CompileError(_)),
            "{}",
            out.feedback()
        );
    }

    #[test]
    fn vendor_dispatch_rejected_without_vendor_mode() {
        let (task, cand, arch, cfg, mut rng) = setup("L1/01_matmul_square");
        let v = apply::apply(Technique::VendorLibraryDispatch, &cand, 0).unwrap();
        let out = run(&task, &v, &arch, &cfg, &mut rng);
        assert!(
            matches!(out, Outcome::SoftVerifyRejected(_)),
            "{}",
            out.feedback()
        );
        // …but allowed in +vendor mode (Fig. 8's "+cuDNN").
        let mut vcfg = cfg.clone();
        vcfg.allow_vendor = true;
        let out2 = run(&task, &v, &arch, &vcfg, &mut rng);
        assert!(out2.is_ok(), "{}", out2.feedback());
    }

    #[test]
    fn functionality_elimination_rejected() {
        let (task, mut cand, arch, cfg, mut rng) = setup("L1/01_matmul_square");
        // Reward hack: replace the matmul with an Identity on x… which
        // also changes shapes — so emulate the sneaky version where shapes
        // happen to match (square matmul): identity passes shape check but
        // must be caught by soft verify (census) or numerics.
        cand.full.nodes[0].kind = OpKind::Identity;
        cand.full.nodes[0].deps = vec![ValueRef::Input(0)];
        cand.small.nodes[0].kind = OpKind::Identity;
        cand.small.nodes[0].deps = vec![ValueRef::Input(0)];
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(!out.is_ok());
    }

    #[test]
    fn stub_output_rejected_even_if_numerically_plausible() {
        // Build a task whose output could accidentally match a copy: use
        // soft_verify directly on an Identity-terminated graph.
        let (task, mut cand, _arch, cfg, _rng) = setup("L1/15_relu");
        cand.full.nodes[0].kind = OpKind::Identity;
        cand.small.nodes[0].kind = OpKind::Identity;
        let err = soft_verify(&task, &cand, &cfg).unwrap_err();
        assert!(err.contains("copy"), "{err}");
    }

    #[test]
    fn multi_seed_verification_catches_seed_dependent_luck() {
        // A candidate that zeroes its output matches the reference only if
        // the reference happens to be zero — never for random seeds.
        let (task, mut cand, arch, cfg, mut rng) = setup("L1/15_relu");
        cand.small.nodes[0].kind = OpKind::Scale { c: 0.0 };
        cand.full.nodes[0].kind = OpKind::Scale { c: 0.0 };
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(matches!(out, Outcome::WrongNumerics { .. }));
    }

    #[test]
    fn reduced_precision_gets_loose_tolerance() {
        let (task, cand, arch, cfg, mut rng) = setup("L1/05_matmul_f16");
        // f16 inputs: rounding error must not fail verification.
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(out.is_ok(), "{}", out.feedback());
    }

    #[test]
    fn feedback_strings_informative() {
        let (task, cand, arch, cfg, mut rng) = setup("L1/01_matmul_square");
        let out = run(&task, &cand, &arch, &cfg, &mut rng);
        assert!(out.feedback().starts_with("ok:"));
        let ce = Outcome::CompileError("boom".into());
        assert!(ce.feedback().contains("boom"));
    }

    #[test]
    fn cached_run_matches_uncached() {
        let (task, cand, arch, cfg, _rng) = setup("L2/09_mlp_block");
        let mut cache = VerifyCache::new();
        cache.warm(&task, &cfg).unwrap();
        assert_eq!(cache.len(), cfg.verify_seeds);
        // Same rng seed both ways → identical profiles.
        let a = run(&task, &cand, &arch, &cfg, &mut Rng::new(3));
        let b = run_cached(&task, &cand, &arch, &cfg, Some(&cache), &mut Rng::new(3));
        match (a, b) {
            (Outcome::Ok(ra), Outcome::Ok(rb)) => {
                assert_eq!(ra.total_cycles, rb.total_cycles);
                assert_eq!(ra.kernels.len(), rb.kernels.len());
            }
            (x, y) => panic!("outcomes diverged: {} vs {}", x.feedback(), y.feedback()),
        }
        // Warm is idempotent.
        cache.warm(&task, &cfg).unwrap();
        assert_eq!(cache.len(), cfg.verify_seeds);
    }

    #[test]
    fn cached_run_still_catches_bugs() {
        let (task, mut cand, arch, cfg, mut rng) = setup("L1/15_relu");
        let mut cache = VerifyCache::new();
        cache.warm(&task, &cfg).unwrap();
        cand.small.nodes[0].kind = OpKind::Scale { c: 0.0 };
        cand.full.nodes[0].kind = OpKind::Scale { c: 0.0 };
        let out = run_cached(&task, &cand, &arch, &cfg, Some(&cache), &mut rng);
        assert!(matches!(out, Outcome::WrongNumerics { .. }));
    }

    #[test]
    fn verify_cache_entries_are_deterministic_fixtures() {
        let (task, _cand, _arch, cfg, _rng) = setup("L1/12_softmax");
        let mut c1 = VerifyCache::new();
        let mut c2 = VerifyCache::new();
        c1.warm(&task, &cfg).unwrap();
        c2.warm(&task, &cfg).unwrap();
        for i in 0..cfg.verify_seeds {
            let a = c1.get(&task.id, i).unwrap();
            let b = c2.get(&task.id, i).unwrap();
            assert_eq!(a.seed, verify_seed(i));
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.reference, b.reference);
        }
        assert!(c1.get("L9/nope", 0).is_none());
    }
}
