//! Tiered candidate verification: screen → probe → full oracle.
//!
//! The four-stage harness ([`super::run_cached_in`]) charges every
//! lowered candidate the full bill — all verification seeds plus the
//! soft-verify scan — even when a static cost model could discard it
//! instantly or a prior run already verified the identical program. This
//! module stages that spend (the profile-guided economy of paper
//! §4.3–§4.4, and the hardware-feedback triage CudaForge argues for):
//!
//! - **Tier 0 — static screen.** A deterministic roofline estimate
//!   ([`crate::gpu::estimate_schedule`], built on `kir::cost`) rejects
//!   candidates whose estimated time is clearly dominated by the current
//!   best (`screen_margin`× worse). Rejections return
//!   [`Outcome::ScreenedOut`] with a cost-model feedback string, so the
//!   textgrad loop still learns from them. No candidate execution at all.
//! - **Tier 1 — low-fidelity probe.** Numeric verification on
//!   `probe_seeds` seeds (default 1) instead of all `verify_seeds`,
//!   reusing [`super::VerifyCache`] fixtures — wrong numerics fail fast.
//! - **Tier 2 — the unchanged full oracle.** The remaining seeds, the
//!   soft-verify reward-hacking guards, and the profile. Because seeds
//!   are checked independently and in the same order, probe + remainder
//!   is *exactly* the full multi-seed oracle, split: no candidate can
//!   pass staged verification that the unstaged harness would reject,
//!   and vice versa.
//!
//! **The full oracle is the only committing gate.** [`Outcome::Ok`] is
//! produced by tier 2 alone (or by re-profiling a memo-verified pass);
//! tiers 0–1 can only reject. The driver commits to the KB and picks
//! step winners exclusively from `Ok` outcomes, so every committed
//! candidate passed all seeds + soft verify — bitwise the same guards as
//! the unstaged path.
//!
//! The cross-run memo ([`super::memo`]) short-circuits the whole
//! pipeline on repeat encounters: a recorded failure replays verbatim
//! (zero executions), a recorded pass skips straight to re-profiling
//! (profiles stay fresh; verdicts don't age).
//!
//! With `staged: false` (the default) the driver never calls into this
//! module — behavior is bit-identical to the pre-staging crate, asserted
//! by `tests/staged.rs`.

use super::memo::{self, MemoVerdict, VerifyMemo};
use super::{soft_verify, verify_numerics_range, HarnessConfig, Outcome, VerifyCache};
use crate::gpu::{profiler, GpuArch};
use crate::kir::interp;
use crate::opts::Candidate;
use crate::tasks::Task;
use crate::util::rng::Rng;

/// Staged-verification configuration — the `verify` config section and
/// the `--staged` family of CLI flags.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyConfig {
    /// Master switch. Off (the default) bypasses this module entirely:
    /// the driver runs the classic four-stage harness, bit-identical to
    /// the pre-staging crate.
    pub staged: bool,
    /// Tier 0: static cost-model screen (only consulted when `staged`).
    pub screen: bool,
    /// Tier 1: low-fidelity numeric probe (only consulted when `staged`).
    pub probe: bool,
    /// Tier-0 dominance margin: reject when the estimate exceeds
    /// `margin ×` the current best's time. ≥ 1.0 (1.0 = aggressive,
    /// anything estimated slower than best is screened).
    pub screen_margin: f64,
    /// Tier-1 seed count (clamped to `verify_seeds`; ≥ 1).
    pub probe_seeds: usize,
    /// Path of the persistent cross-run memo; `None` keeps the memo
    /// in-memory for the run (fleet batches still share it across tasks).
    pub memo_path: Option<String>,
    /// Size cap the serving path enforces on the memo
    /// ([`VerifyMemo::enforce_cap`] after each serve-loop memo commit,
    /// and the `memo compact` default). 0 (the default) = unbounded —
    /// batch and optimize never evict implicitly, preserving every
    /// legacy byte contract.
    pub memo_max_entries: usize,
}

impl Default for VerifyConfig {
    fn default() -> Self {
        Self {
            staged: false,
            screen: true,
            probe: true,
            screen_margin: 1.5,
            probe_seeds: 1,
            memo_path: None,
            memo_max_entries: 0,
        }
    }
}

impl VerifyConfig {
    /// Knob sanity: a finite margin ≥ 1 and at least one probe seed.
    pub fn validate(&self) -> Result<(), String> {
        if !self.screen_margin.is_finite() || self.screen_margin < 1.0 {
            return Err(format!(
                "verify.screen_margin must be finite and >= 1, got {}",
                self.screen_margin
            ));
        }
        if self.probe_seeds == 0 {
            return Err("verify.probe_seeds must be >= 1".to_string());
        }
        Ok(())
    }
}

/// Per-tier activity counters. Deliberately kept *outside* `TaskRun` so
/// result records stay comparable across staged and unstaged runs; the
/// driver aggregates these alongside the run and `experiment verify`
/// reports them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Candidates rejected by the tier-0 static screen.
    pub screen_rejected: usize,
    /// Candidates rejected by the tier-1 probe.
    pub probe_rejected: usize,
    /// Memo hits (pass or fail) that skipped tiers 0–1.
    pub memo_hits: usize,
    /// Candidates that entered the full tier-2 oracle.
    pub full_verifications: usize,
    /// Candidate-seed executions performed — the verification-op count
    /// the benchmark reports (the container has no wall-clock worth
    /// trusting; op counts are exact and deterministic).
    pub seeds_executed: usize,
}

impl TierStats {
    /// Accumulate another stats block into this one.
    pub fn add(&mut self, other: &TierStats) {
        self.screen_rejected += other.screen_rejected;
        self.probe_rejected += other.probe_rejected;
        self.memo_hits += other.memo_hits;
        self.full_verifications += other.full_verifications;
        self.seeds_executed += other.seeds_executed;
    }
}

/// One staged-verification request. Bundles the borrow-heavy inputs so
/// the entry point stays a readable three-argument call.
pub struct StagedRequest<'a> {
    /// The task the candidate was derived from.
    pub task: &'a Task,
    /// The candidate under verification.
    pub cand: &'a Candidate,
    /// Profiling architecture.
    pub arch: &'a GpuArch,
    /// Harness tolerances and seed count.
    pub cfg: &'a HarnessConfig,
    /// Staging knobs.
    pub verify: &'a VerifyConfig,
    /// The current best wall time (seconds) the tier-0 screen compares
    /// against — the frontier node's profiled time in the driver. Pass
    /// `f64::INFINITY` to disable dominance screening for this call.
    pub best_time_s: f64,
    /// Reference-fixture cache (shared, lock-free reads).
    pub cache: Option<&'a VerifyCache>,
    /// Verdict memo snapshot; `None` disables memoization.
    pub memo: Option<&'a VerifyMemo>,
}

/// The result of a staged run: the outcome, the verdict to merge into
/// the working memo (if this evaluation produced a new memoizable one),
/// and what each tier did.
pub struct StagedOutcome {
    /// The harness outcome (same meaning as the unstaged pipeline, plus
    /// [`Outcome::ScreenedOut`] for tier-0 rejections).
    pub outcome: Outcome,
    /// `Some((key, verdict))` when this evaluation produced a verdict
    /// the memo did not already hold. The driver merges these in pick
    /// order, keeping parallel and sequential exploration identical.
    pub memo_record: Option<(String, MemoVerdict)>,
    /// Tier activity of this single evaluation.
    pub stats: TierStats,
}

impl StagedOutcome {
    fn plain(outcome: Outcome, stats: TierStats) -> Self {
        Self {
            outcome,
            memo_record: None,
            stats,
        }
    }

    fn recorded(outcome: Outcome, key: Option<String>, stats: TierStats) -> Self {
        let memo_record = key.and_then(|k| MemoVerdict::of(&outcome).map(|v| (k, v)));
        Self {
            outcome,
            memo_record,
            stats,
        }
    }
}

/// Run the staged pipeline for one candidate. RNG discipline matches
/// [`super::run_cached_in`] exactly: verification consumes zero draws,
/// only the profile draws — so a memo-verified pass re-profiles on the
/// identical stream a cold pass would have used, and staged-off /
/// staged-on runs stay comparable draw-for-draw on passing candidates.
pub fn run_staged_in(
    req: &StagedRequest<'_>,
    ctx: &mut interp::ExecContext,
    rng: &mut Rng,
) -> StagedOutcome {
    let mut stats = TierStats::default();
    let cfg = req.cfg;

    // Cross-run memo: a repeat encounter skips every tier.
    let pending_key = match req.memo {
        Some(m) => {
            let key = memo::candidate_key(&req.task.id, req.cand, cfg);
            if let Some(verdict) = m.get(&key) {
                stats.memo_hits += 1;
                return match verdict.to_outcome() {
                    // Recorded failure replays verbatim, zero executions.
                    Some(fail) => StagedOutcome::plain(fail, stats),
                    // Recorded pass: skip re-verification, NOT
                    // re-profiling — profiles are measurements.
                    None => {
                        let rep = profiler::profile(
                            req.arch,
                            &req.cand.full,
                            &req.cand.schedule,
                            cfg.noise_sigma,
                            rng,
                        );
                        StagedOutcome::plain(Outcome::Ok(rep), stats)
                    }
                };
            }
            Some(key)
        }
        None => None,
    };

    // Stage 1 (all tiers): structural compile check.
    if let Err(e) = req.cand.validate() {
        return StagedOutcome::recorded(Outcome::CompileError(e), pending_key, stats);
    }

    // Tier 0: static dominance screen. Never memoized — the verdict
    // depends on the run's current best, which is not part of the key.
    if req.verify.screen {
        let est = crate::gpu::estimate_schedule(req.arch, &req.cand.full, &req.cand.schedule);
        let cutoff = req.best_time_s * req.verify.screen_margin;
        if req.best_time_s.is_finite() && est.total_time_s > cutoff {
            stats.screen_rejected += 1;
            let reason = format!(
                "cost model estimates {:.3e}s vs current best {:.3e}s \
                 (>{:.2}x margin); dominated before execution",
                est.total_time_s, req.best_time_s, req.verify.screen_margin
            );
            return StagedOutcome::plain(Outcome::ScreenedOut(reason), stats);
        }
    }

    // Tier 1: low-fidelity probe on the first `probe_seeds` seeds.
    let probe_n = if req.verify.probe {
        req.verify.probe_seeds.min(cfg.verify_seeds)
    } else {
        0
    };
    if probe_n > 0 {
        let (bad, executed) =
            verify_numerics_range(req.task, req.cand, cfg, req.cache, ctx, 0, probe_n);
        stats.seeds_executed += executed;
        if let Some(fail) = bad {
            stats.probe_rejected += 1;
            return StagedOutcome::recorded(fail, pending_key, stats);
        }
    }

    // Tier 2: the full oracle — remaining seeds, soft verify, profile.
    // Seeds [0, probe_n) were already checked by the probe with the very
    // comparisons the full loop would run, so probe + remainder is the
    // complete multi-seed oracle.
    stats.full_verifications += 1;
    let (bad, executed) = verify_numerics_range(
        req.task,
        req.cand,
        cfg,
        req.cache,
        ctx,
        probe_n,
        cfg.verify_seeds,
    );
    stats.seeds_executed += executed;
    if let Some(fail) = bad {
        return StagedOutcome::recorded(fail, pending_key, stats);
    }
    if let Err(reason) = soft_verify(req.task, req.cand, cfg) {
        return StagedOutcome::recorded(
            Outcome::SoftVerifyRejected(reason),
            pending_key,
            stats,
        );
    }
    let rep = profiler::profile(
        req.arch,
        &req.cand.full,
        &req.cand.schedule,
        cfg.noise_sigma,
        rng,
    );
    StagedOutcome::recorded(Outcome::Ok(rep), pending_key, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::OpKind;
    use crate::tasks::Suite;

    fn setup(id: &str) -> (Task, Candidate, GpuArch, HarnessConfig) {
        let task = Suite::full().by_id(id).unwrap().clone();
        let cand = Candidate::naive(&task);
        (
            task,
            cand,
            GpuArch::h100(),
            HarnessConfig {
                noise_sigma: 0.0,
                ..Default::default()
            },
        )
    }

    fn full_staging() -> VerifyConfig {
        VerifyConfig {
            staged: true,
            ..Default::default()
        }
    }

    fn request<'a>(
        task: &'a Task,
        cand: &'a Candidate,
        arch: &'a GpuArch,
        cfg: &'a HarnessConfig,
        verify: &'a VerifyConfig,
        memo: Option<&'a VerifyMemo>,
    ) -> StagedRequest<'a> {
        StagedRequest {
            task,
            cand,
            arch,
            cfg,
            verify,
            best_time_s: f64::INFINITY,
            cache: None,
            memo,
        }
    }

    #[test]
    fn config_defaults_are_off_and_valid() {
        let v = VerifyConfig::default();
        assert!(!v.staged);
        assert!(v.screen && v.probe);
        assert!(v.validate().is_ok());
        for bad in [
            VerifyConfig {
                screen_margin: 0.9,
                ..Default::default()
            },
            VerifyConfig {
                screen_margin: f64::NAN,
                ..Default::default()
            },
            VerifyConfig {
                screen_margin: f64::INFINITY,
                ..Default::default()
            },
            VerifyConfig {
                probe_seeds: 0,
                ..Default::default()
            },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn staged_pass_matches_unstaged_bit_for_bit() {
        // Probe + remainder must be the same oracle and the same RNG
        // consumption as the classic pipeline.
        let (task, cand, arch, cfg) = setup("L2/01_gemm_bias_relu");
        let vcfg = full_staging();
        let mut ctx = interp::ExecContext::new();
        let mut rng_a = Rng::new(3);
        let a = super::super::run_cached_in(&task, &cand, &arch, &cfg, None, &mut ctx, &mut rng_a);
        let mut rng_b = Rng::new(3);
        let b = run_staged_in(
            &request(&task, &cand, &arch, &cfg, &vcfg, None),
            &mut ctx,
            &mut rng_b,
        );
        match (a, b.outcome) {
            (Outcome::Ok(ra), Outcome::Ok(rb)) => {
                assert_eq!(ra.total_cycles, rb.total_cycles);
                assert_eq!(ra.kernels.len(), rb.kernels.len());
            }
            (x, y) => panic!("diverged: {} vs {}", x.feedback(), y.feedback()),
        }
        assert_eq!(rng_a, rng_b, "staged must consume the same draws");
        assert_eq!(b.stats.full_verifications, 1);
        assert_eq!(b.stats.seeds_executed, cfg.verify_seeds);
        assert_eq!(b.stats.screen_rejected + b.stats.probe_rejected, 0);
    }

    #[test]
    fn screen_rejects_dominated_candidates_with_cost_feedback() {
        let (task, cand, arch, cfg) = setup("L1/01_matmul_square");
        let vcfg = VerifyConfig {
            screen_margin: 1.0,
            ..full_staging()
        };
        let est = crate::gpu::estimate_schedule(&arch, &cand.full, &cand.schedule);
        let mut req = request(&task, &cand, &arch, &cfg, &vcfg, None);
        // Best is 10× faster than the estimate → dominated.
        req.best_time_s = est.total_time_s / 10.0;
        let mut ctx = interp::ExecContext::new();
        let out = run_staged_in(&req, &mut ctx, &mut Rng::new(1));
        match &out.outcome {
            Outcome::ScreenedOut(reason) => {
                assert!(reason.contains("cost model"), "{reason}");
                assert!(out.outcome.feedback().contains("screen"), "feedback must name the tier");
            }
            other => panic!("expected screen-out, got {}", other.feedback()),
        }
        assert_eq!(out.stats.screen_rejected, 1);
        assert_eq!(out.stats.seeds_executed, 0, "no execution on screen-out");
        assert!(out.memo_record.is_none(), "screen verdicts are run-local");
        // An infinite best disables the screen.
        req.best_time_s = f64::INFINITY;
        let out2 = run_staged_in(&req, &mut ctx, &mut Rng::new(1));
        assert!(out2.outcome.is_ok(), "{}", out2.outcome.feedback());
    }

    #[test]
    fn probe_fails_fast_on_wrong_numerics() {
        let (task, mut cand, arch, cfg) = setup("L1/15_relu");
        cand.small.nodes[0].kind = OpKind::Scale { c: 0.0 };
        cand.full.nodes[0].kind = OpKind::Scale { c: 0.0 };
        let vcfg = full_staging();
        let mut ctx = interp::ExecContext::new();
        let out = run_staged_in(
            &request(&task, &cand, &arch, &cfg, &vcfg, None),
            &mut ctx,
            &mut Rng::new(5),
        );
        assert!(matches!(out.outcome, Outcome::WrongNumerics { .. }));
        assert_eq!(out.stats.probe_rejected, 1);
        assert_eq!(out.stats.seeds_executed, 1, "one probe seed, not all {}", cfg.verify_seeds);
        assert_eq!(out.stats.full_verifications, 0);
    }

    #[test]
    fn reward_hacking_guards_hold_under_full_staging() {
        // Vendor dispatch and stubbed work must still be rejected by the
        // tier-2 soft verifier — staging never bypasses the guards.
        let (task, cand, arch, cfg) = setup("L1/01_matmul_square");
        let vendor = crate::opts::apply::apply(
            crate::opts::Technique::VendorLibraryDispatch,
            &cand,
            0,
        )
        .unwrap();
        let vcfg = full_staging();
        let mut ctx = interp::ExecContext::new();
        let out = run_staged_in(
            &request(&task, &vendor, &arch, &cfg, &vcfg, None),
            &mut ctx,
            &mut Rng::new(2),
        );
        assert!(matches!(out.outcome, Outcome::SoftVerifyRejected(_)));
        // …and the deterministic rejection is memoizable.
        let memo = VerifyMemo::new();
        let out2 = run_staged_in(
            &request(&task, &vendor, &arch, &cfg, &vcfg, Some(&memo)),
            &mut ctx,
            &mut Rng::new(2),
        );
        let (_, verdict) = out2.memo_record.expect("soft rejection must be recorded");
        assert!(matches!(verdict, MemoVerdict::SoftRejected(_)));
    }

    #[test]
    fn memo_hits_replay_failures_and_reprofile_passes() {
        let (task, cand, arch, cfg) = setup("L2/09_mlp_block");
        let vcfg = full_staging();
        let mut ctx = interp::ExecContext::new();
        // Cold run records a pass.
        let cold_memo = VerifyMemo::new();
        let cold = run_staged_in(
            &request(&task, &cand, &arch, &cfg, &vcfg, Some(&cold_memo)),
            &mut ctx,
            &mut Rng::new(9),
        );
        let (key, verdict) = cold.memo_record.expect("cold pass must be recorded");
        assert_eq!(verdict, MemoVerdict::Pass);
        assert_eq!(cold.stats.memo_hits, 0);
        // Warm run: same RNG stream → identical profile, zero seeds run.
        let mut warm_memo = VerifyMemo::new();
        warm_memo.insert(key, verdict);
        let warm = run_staged_in(
            &request(&task, &cand, &arch, &cfg, &vcfg, Some(&warm_memo)),
            &mut ctx,
            &mut Rng::new(9),
        );
        assert_eq!(warm.stats.memo_hits, 1);
        assert_eq!(warm.stats.seeds_executed, 0);
        assert!(warm.memo_record.is_none(), "hits record nothing new");
        match (&cold.outcome, &warm.outcome) {
            (Outcome::Ok(a), Outcome::Ok(b)) => assert_eq!(a.total_cycles, b.total_cycles),
            (x, y) => panic!("diverged: {} vs {}", x.feedback(), y.feedback()),
        }
        // Failure verdicts replay verbatim.
        let mut fail_memo = VerifyMemo::new();
        let fail_key = memo::candidate_key(&task.id, &cand, &cfg);
        fail_memo.insert(
            fail_key,
            MemoVerdict::WrongNumerics {
                seed: 0x5EED_0000,
                max_abs_diff: 0.5,
            },
        );
        let replay = run_staged_in(
            &request(&task, &cand, &arch, &cfg, &vcfg, Some(&fail_memo)),
            &mut ctx,
            &mut Rng::new(9),
        );
        assert!(matches!(replay.outcome, Outcome::WrongNumerics { .. }));
        assert_eq!(replay.stats.memo_hits, 1);
        assert_eq!(replay.stats.seeds_executed, 0);
    }

    #[test]
    fn probe_disabled_still_runs_the_full_oracle() {
        let (task, mut cand, arch, cfg) = setup("L1/15_relu");
        cand.small.nodes[0].kind = OpKind::Scale { c: 0.0 };
        cand.full.nodes[0].kind = OpKind::Scale { c: 0.0 };
        let vcfg = VerifyConfig {
            probe: false,
            screen: false,
            ..full_staging()
        };
        let mut ctx = interp::ExecContext::new();
        let out = run_staged_in(
            &request(&task, &cand, &arch, &cfg, &vcfg, None),
            &mut ctx,
            &mut Rng::new(4),
        );
        assert!(matches!(out.outcome, Outcome::WrongNumerics { .. }));
        assert_eq!(out.stats.probe_rejected, 0);
        assert_eq!(out.stats.full_verifications, 1);
    }

    #[test]
    fn tier_stats_accumulate() {
        let mut a = TierStats {
            screen_rejected: 1,
            probe_rejected: 2,
            memo_hits: 3,
            full_verifications: 4,
            seeds_executed: 5,
        };
        let b = a;
        a.add(&b);
        assert_eq!(
            a,
            TierStats {
                screen_rejected: 2,
                probe_rejected: 4,
                memo_hits: 6,
                full_verifications: 8,
                seeds_executed: 10,
            }
        );
    }
}
