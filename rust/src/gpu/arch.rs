//! GPU architecture models for the four devices the paper evaluates:
//! A6000 and A100 (Ampere), H100 (Hopper), L40S (Ada Lovelace).
//!
//! Parameters are the public datasheet numbers (SM count, clocks, DRAM
//! bandwidth, peak FP32/tensor throughput, shared-memory and register
//! capacities). The performance model ([`super::model`]) consumes these;
//! cross-architecture differences are what make the paper's Fig. 16
//! (knowledge-base transfer across GPUs) and Fig. 9 (per-arch fast_p
//! curves) meaningful in this reproduction.
//!
//! The per-[`Bottleneck`] capacity hints ([`GpuArch::bottleneck_capacity`])
//! are also the *scaling model* behind the KB lifecycle's cross-arch
//! transfer ([`crate::kb::lifecycle::transfer`]): when a target generation
//! relieves a state's primary bottleneck much more than its secondary one,
//! the transferred state is re-keyed accordingly.

use super::profiler::Bottleneck;

/// GPU generation (drives architecture-conditional optimizations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuGen {
    Ampere,
    Hopper,
    Ada,
}

/// Static architecture description.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuArch {
    pub name: &'static str,
    pub gen: GpuGen,
    pub sms: usize,
    /// Boost clock in GHz.
    pub clock_ghz: f64,
    /// DRAM bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Peak FP32 throughput, TFLOP/s (CUDA cores).
    pub fp32_tflops: f64,
    /// Peak FP16/BF16 tensor-core throughput, TFLOP/s (dense).
    pub tc_tflops: f64,
    /// Shared memory per SM, bytes.
    pub smem_per_sm: usize,
    /// 32-bit registers per SM.
    pub regs_per_sm: usize,
    /// Max resident threads per SM.
    pub max_threads_per_sm: usize,
    /// L2 cache, bytes.
    pub l2_bytes: usize,
    /// Kernel launch overhead, microseconds.
    pub launch_overhead_us: f64,
    /// SFU (transcendental) throughput as a fraction of FP32.
    pub sfu_ratio: f64,
}

impl GpuArch {
    pub fn a6000() -> Self {
        GpuArch {
            name: "A6000",
            gen: GpuGen::Ampere,
            sms: 84,
            clock_ghz: 1.80,
            mem_bw_gbs: 768.0,
            fp32_tflops: 38.7,
            tc_tflops: 155.0,
            smem_per_sm: 100 * 1024,
            regs_per_sm: 65536,
            max_threads_per_sm: 1536,
            l2_bytes: 6 * 1024 * 1024,
            launch_overhead_us: 4.0,
            sfu_ratio: 0.25,
        }
    }

    pub fn a100() -> Self {
        GpuArch {
            name: "A100",
            gen: GpuGen::Ampere,
            sms: 108,
            clock_ghz: 1.41,
            mem_bw_gbs: 1555.0,
            fp32_tflops: 19.5,
            tc_tflops: 312.0,
            smem_per_sm: 164 * 1024,
            regs_per_sm: 65536,
            max_threads_per_sm: 2048,
            l2_bytes: 40 * 1024 * 1024,
            launch_overhead_us: 4.0,
            sfu_ratio: 0.25,
        }
    }

    pub fn h100() -> Self {
        GpuArch {
            name: "H100",
            gen: GpuGen::Hopper,
            sms: 132,
            clock_ghz: 1.83,
            mem_bw_gbs: 3350.0,
            fp32_tflops: 66.9,
            tc_tflops: 989.0,
            smem_per_sm: 228 * 1024,
            regs_per_sm: 65536,
            max_threads_per_sm: 2048,
            l2_bytes: 50 * 1024 * 1024,
            launch_overhead_us: 3.5,
            sfu_ratio: 0.25,
        }
    }

    pub fn l40s() -> Self {
        GpuArch {
            name: "L40S",
            gen: GpuGen::Ada,
            sms: 142,
            clock_ghz: 2.52,
            mem_bw_gbs: 864.0,
            fp32_tflops: 91.6,
            tc_tflops: 366.0,
            smem_per_sm: 100 * 1024,
            regs_per_sm: 65536,
            max_threads_per_sm: 1536,
            l2_bytes: 96 * 1024 * 1024,
            launch_overhead_us: 4.0,
            sfu_ratio: 0.25,
        }
    }

    /// All four evaluation targets, paper order.
    pub fn all() -> Vec<GpuArch> {
        vec![Self::a6000(), Self::a100(), Self::h100(), Self::l40s()]
    }

    pub fn by_name(name: &str) -> Option<GpuArch> {
        match name.to_ascii_uppercase().as_str() {
            "A6000" => Some(Self::a6000()),
            "A100" => Some(Self::a100()),
            "H100" => Some(Self::h100()),
            "L40S" => Some(Self::l40s()),
            _ => None,
        }
    }

    /// Peak FLOP/s (not TFLOP/s) for the scalar pipeline.
    pub fn fp32_flops(&self) -> f64 {
        self.fp32_tflops * 1e12
    }

    /// Peak FLOP/s for tensor cores.
    pub fn tc_flops(&self) -> f64 {
        self.tc_tflops * 1e12
    }

    /// DRAM bandwidth in bytes/s.
    pub fn mem_bw_bytes(&self) -> f64 {
        self.mem_bw_gbs * 1e9
    }

    /// Ridge point of the FP32 roofline (FLOP/byte).
    pub fn ridge_fp32(&self) -> f64 {
        self.fp32_flops() / self.mem_bw_bytes()
    }

    /// Capacity of the hardware resource that *relieves* a bottleneck
    /// class, in arbitrary-but-consistent per-class units. Absolute values
    /// are meaningless across classes; only same-class **ratios between
    /// two architectures** are used — they are the scaling hints the KB
    /// lifecycle consumes when transferring state signatures across
    /// generations ([`crate::kb::lifecycle::transfer`]).
    pub fn bottleneck_capacity(&self, b: Bottleneck) -> f64 {
        match b {
            Bottleneck::MemoryBandwidth => self.mem_bw_gbs,
            // Latency-bound kernels are relieved by cache capacity.
            Bottleneck::MemoryLatency => self.l2_bytes as f64,
            Bottleneck::ComputeThroughput => self.fp32_tflops,
            Bottleneck::Transcendental => self.fp32_tflops * self.sfu_ratio,
            // More resident warps hide more latency.
            Bottleneck::Occupancy => (self.sms * self.max_threads_per_sm) as f64,
            Bottleneck::Parallelism => self.sms as f64,
            // Lower launch overhead = more capacity.
            Bottleneck::LaunchOverhead => 1.0 / self.launch_overhead_us,
        }
    }

    /// How much more (>1) or less (<1) headroom `to` has than `self` for a
    /// bottleneck class — the relief ratio driving transfer re-keying.
    pub fn relief_ratio(&self, to: &GpuArch, b: Bottleneck) -> f64 {
        to.bottleneck_capacity(b) / self.bottleneck_capacity(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_archs_registered() {
        let all = GpuArch::all();
        assert_eq!(all.len(), 4);
        let names: Vec<&str> = all.iter().map(|a| a.name).collect();
        assert_eq!(names, vec!["A6000", "A100", "H100", "L40S"]);
    }

    #[test]
    fn lookup_by_name_case_insensitive() {
        assert_eq!(GpuArch::by_name("h100").unwrap().name, "H100");
        assert_eq!(GpuArch::by_name("L40s").unwrap().name, "L40S");
        assert!(GpuArch::by_name("V100").is_none());
    }

    #[test]
    fn h100_dominates_bandwidth_and_tc() {
        let h = GpuArch::h100();
        for other in [GpuArch::a6000(), GpuArch::a100(), GpuArch::l40s()] {
            assert!(h.mem_bw_gbs > other.mem_bw_gbs);
            assert!(h.tc_tflops > other.tc_tflops);
        }
    }

    #[test]
    fn ridge_points_sane() {
        // FP32 ridge between ~10 and ~110 FLOP/byte for these parts.
        for a in GpuArch::all() {
            let r = a.ridge_fp32();
            assert!((5.0..150.0).contains(&r), "{}: ridge={r}", a.name);
        }
    }

    #[test]
    fn relief_ratios_track_datasheet_deltas() {
        let a = GpuArch::a6000();
        let h = GpuArch::h100();
        // H100 relieves bandwidth-bound states far more than an A6000.
        assert!(a.relief_ratio(&h, Bottleneck::MemoryBandwidth) > 4.0);
        // The reverse direction inverts the ratio.
        let fwd = a.relief_ratio(&h, Bottleneck::ComputeThroughput);
        let back = h.relief_ratio(&a, Bottleneck::ComputeThroughput);
        assert!((fwd * back - 1.0).abs() < 1e-12);
        // Identity transfer: every class is exactly 1.0.
        for b in Bottleneck::all() {
            assert!((a.relief_ratio(&a, b) - 1.0).abs() < 1e-12);
            assert!(a.bottleneck_capacity(b) > 0.0);
        }
    }

    #[test]
    fn generations() {
        assert_eq!(GpuArch::a100().gen, GpuGen::Ampere);
        assert_eq!(GpuArch::h100().gen, GpuGen::Hopper);
        assert_eq!(GpuArch::l40s().gen, GpuGen::Ada);
    }
}
