//! NCU-like profiler reports.
//!
//! The paper's state extractor consumes "the performance information for
//! every executed kernel from the 'Details' section of an Nsight Compute
//! report" (§3). This module renders the performance model's estimates
//! into that form: per-kernel metrics, a primary/secondary bottleneck
//! classification, and a stall-source breakdown. Measurement noise is
//! applied here (profiling replays kernels; readings jitter run to run).

use super::arch::GpuArch;
use super::model::{self, LaunchEstimate};
use crate::kir::schedule::Schedule;
use crate::kir::KernelGraph;
use crate::util::rng::Rng;

/// Coarse bottleneck classes — the axes of the Knowledge Base's
/// performance-state taxonomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Bottleneck {
    /// DRAM bandwidth saturated (long-scoreboard stalls dominate).
    MemoryBandwidth,
    /// Poor access pattern: bandwidth wasted on uncoalesced transactions.
    MemoryLatency,
    /// FP pipes saturated.
    ComputeThroughput,
    /// SFU/transcendental-limited.
    Transcendental,
    /// Too few resident warps (low occupancy) to hide latency.
    Occupancy,
    /// Grid too small to fill the device.
    Parallelism,
    /// Kernel launch overhead dominates.
    LaunchOverhead,
}

impl Bottleneck {
    pub fn name(&self) -> &'static str {
        match self {
            Bottleneck::MemoryBandwidth => "memory_bandwidth",
            Bottleneck::MemoryLatency => "memory_latency",
            Bottleneck::ComputeThroughput => "compute_throughput",
            Bottleneck::Transcendental => "transcendental",
            Bottleneck::Occupancy => "occupancy",
            Bottleneck::Parallelism => "parallelism",
            Bottleneck::LaunchOverhead => "launch_overhead",
        }
    }

    pub fn all() -> [Bottleneck; 7] {
        [
            Bottleneck::MemoryBandwidth,
            Bottleneck::MemoryLatency,
            Bottleneck::ComputeThroughput,
            Bottleneck::Transcendental,
            Bottleneck::Occupancy,
            Bottleneck::Parallelism,
            Bottleneck::LaunchOverhead,
        ]
    }

    pub fn from_name(name: &str) -> Option<Bottleneck> {
        Self::all().into_iter().find(|b| b.name() == name)
    }
}

/// Per-kernel profile — the "Details" section analog.
#[derive(Debug, Clone)]
pub struct KernelProfile {
    /// Kernel name (from the source renderer's naming scheme).
    pub name: String,
    pub elapsed_cycles: f64,
    pub time_us: f64,
    pub dram_util: f64,
    pub compute_util: f64,
    pub occupancy: f64,
    pub utilization: f64,
    pub grid: usize,
    pub block: usize,
    pub flops: f64,
    pub bytes: f64,
    pub primary: Bottleneck,
    pub secondary: Bottleneck,
    /// Stall breakdown (name, share) summing to ~1.
    pub stalls: Vec<(&'static str, f64)>,
}

/// Whole-report: one entry per kernel launch, in execution order (the
/// paper profiles "all instances of kernels … in the order they were
/// executed").
#[derive(Debug, Clone)]
pub struct NcuReport {
    pub arch_name: String,
    pub kernels: Vec<KernelProfile>,
    pub total_cycles: f64,
    pub total_time_s: f64,
}

impl NcuReport {
    /// Dominant bottleneck across the report, weighted by kernel time.
    pub fn dominant_bottleneck(&self) -> Bottleneck {
        let mut weights: Vec<(Bottleneck, f64)> = Vec::new();
        for k in &self.kernels {
            match weights.iter_mut().find(|(b, _)| *b == k.primary) {
                Some((_, w)) => *w += k.time_us,
                None => weights.push((k.primary, k.time_us)),
            }
        }
        weights
            .into_iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(b, _)| b)
            .unwrap_or(Bottleneck::LaunchOverhead)
    }

    /// Render the "Details" text an agent would read.
    pub fn render_details(&self) -> String {
        let mut out = format!(
            "== NCU report ({}) : {} kernels, {:.0} total cycles ==\n",
            self.arch_name,
            self.kernels.len(),
            self.total_cycles
        );
        for k in &self.kernels {
            out.push_str(&format!(
                "kernel {} <<<{},{}>>> {:.1}us cycles={:.0} dram={:.0}% sm={:.0}% occ={:.0}% | {} / {}\n",
                k.name,
                k.grid,
                k.block,
                k.time_us,
                k.elapsed_cycles,
                k.dram_util * 100.0,
                k.compute_util * 100.0,
                k.occupancy * 100.0,
                k.primary.name(),
                k.secondary.name(),
            ));
            for (stall, share) in &k.stalls {
                out.push_str(&format!("    stall.{stall}: {:.0}%\n", share * 100.0));
            }
        }
        out
    }
}

/// Classify the (primary, secondary) bottleneck of a launch estimate.
///
/// `layout_naive` attributes memory time to access-pattern latency rather
/// than raw bandwidth. `untuned_contraction` marks a contraction kernel
/// with no operand staging: its low issue rate is *latency-serialized*
/// (long-scoreboard stalls in NCU terms), so the compute share is folded
/// into memory latency — which is what a real profile shows for a naive
/// GEMM, and what points the agent at tiling first (the prep→compute
/// ordering of §5).
pub fn classify(
    est: &LaunchEstimate,
    layout_naive: bool,
    untuned_contraction: bool,
) -> (Bottleneck, Bottleneck) {
    // Candidate (bottleneck, weight) list; weight = estimated time share.
    let exec = (est.time_s - est.launch_overhead_s).max(1e-12);
    let mut cands: Vec<(Bottleneck, f64)> = Vec::new();
    let mem_kind = if layout_naive {
        Bottleneck::MemoryLatency
    } else {
        Bottleneck::MemoryBandwidth
    };
    let (mem_w, compute_w) = if untuned_contraction {
        (est.mem_time_s + est.compute_time_s, est.compute_time_s * 0.5)
    } else {
        (est.mem_time_s, est.compute_time_s)
    };
    cands.push((mem_kind, mem_w));
    if est.transcendental_share > 0.4 {
        cands.push((Bottleneck::Transcendental, compute_w));
    } else {
        cands.push((Bottleneck::ComputeThroughput, compute_w));
    }
    cands.push((Bottleneck::LaunchOverhead, est.launch_overhead_s * 1.0));
    if est.occupancy < 0.25 {
        cands.push((Bottleneck::Occupancy, exec * (0.25 - est.occupancy) * 4.0));
    }
    if est.utilization < 0.25 {
        cands.push((Bottleneck::Parallelism, exec * (0.25 - est.utilization) * 4.0));
    }
    cands.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let primary = cands[0].0;
    let secondary = cands.get(1).map(|c| c.0).unwrap_or(primary);
    (primary, secondary)
}

fn stall_breakdown(est: &LaunchEstimate, primary: Bottleneck) -> Vec<(&'static str, f64)> {
    let mut stalls = match primary {
        Bottleneck::MemoryBandwidth => vec![("long_scoreboard", 0.55), ("drain", 0.10)],
        Bottleneck::MemoryLatency => vec![("long_scoreboard", 0.45), ("lg_throttle", 0.25)],
        Bottleneck::ComputeThroughput => vec![("math_pipe_throttle", 0.50), ("not_selected", 0.15)],
        Bottleneck::Transcendental => vec![("mio_throttle", 0.50), ("math_pipe_throttle", 0.20)],
        Bottleneck::Occupancy => vec![("not_selected", 0.40), ("no_instruction", 0.20)],
        Bottleneck::Parallelism => vec![("idle_sm", 0.60)],
        Bottleneck::LaunchOverhead => vec![("launch_latency", 0.70)],
    };
    let rest: f64 = 1.0 - stalls.iter().map(|s| s.1).sum::<f64>();
    stalls.push(("misc", rest.max(0.0)));
    let _ = est;
    stalls
}

/// Profile a scheduled kernel on an architecture. `noise_sigma` models
/// run-to-run measurement jitter (multiplicative lognormal on times);
/// pass 0.0 for noiseless profiling.
pub fn profile(
    arch: &GpuArch,
    graph: &KernelGraph,
    schedule: &Schedule,
    noise_sigma: f64,
    rng: &mut Rng,
) -> NcuReport {
    let est = model::estimate_schedule(arch, graph, schedule);
    let mut kernels = Vec::with_capacity(est.launches.len());
    for (gi, (le, group)) in est.launches.iter().zip(&schedule.groups).enumerate() {
        let noise = if noise_sigma > 0.0 {
            rng.lognormal_around_one(noise_sigma)
        } else {
            1.0
        };
        let time_s = le.time_s * noise;
        let layout_naive = group.opts.layout == crate::kir::schedule::MemLayout::Naive
            && !group.opts.vendor_lib;
        let untuned_contraction = !group.opts.vendor_lib
            && matches!(group.opts.tiling, crate::kir::schedule::Tiling::None)
            && group
                .nodes
                .iter()
                .any(|n| graph.nodes[*n].kind.is_contraction());
        let (primary, secondary) = classify(le, layout_naive, untuned_contraction);
        let ops: Vec<&'static str> = group
            .nodes
            .iter()
            .map(|n| graph.nodes[*n].kind.mnemonic())
            .collect();
        kernels.push(KernelProfile {
            name: format!("kernel_{gi}_{}", ops.join("_")),
            elapsed_cycles: time_s * arch.clock_ghz * 1e9,
            time_us: time_s * 1e6,
            dram_util: le.dram_util,
            compute_util: le.compute_util,
            occupancy: le.occupancy,
            utilization: le.utilization,
            grid: group.launch.grid,
            block: group.launch.block,
            flops: le.cost.flops,
            bytes: le.cost.bytes_total(),
            primary,
            secondary,
            stalls: stall_breakdown(le, primary),
        });
    }
    let total_time_s: f64 = kernels.iter().map(|k| k.time_us * 1e-6).sum();
    NcuReport {
        arch_name: arch.name.to_string(),
        total_cycles: kernels.iter().map(|k| k.elapsed_cycles).sum(),
        total_time_s,
        kernels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::schedule::{MemLayout, Schedule, Tiling};
    use crate::kir::{GraphBuilder, OpKind};

    fn big_matmul() -> KernelGraph {
        let mut b = GraphBuilder::new("mm");
        let x = b.input("x", &[2048, 2048]);
        let w = b.input("w", &[2048, 2048]);
        let mm = b.op(OpKind::Matmul, &[x, w]);
        b.output(mm);
        b.finish()
    }

    #[test]
    fn naive_big_matmul_is_memory_latency_bound() {
        let arch = GpuArch::a100();
        let g = big_matmul();
        let s = Schedule::naive(&g);
        let mut rng = Rng::new(1);
        let rep = profile(&arch, &g, &s, 0.0, &mut rng);
        assert_eq!(rep.kernels.len(), 1);
        assert_eq!(rep.kernels[0].primary, Bottleneck::MemoryLatency);
    }

    #[test]
    fn tuned_big_matmul_moves_to_compute_bound() {
        let arch = GpuArch::a6000();
        let g = big_matmul();
        let mut s = Schedule::naive(&g);
        s.groups[0].opts.tiling = Tiling::Shared { tile: 128 };
        s.groups[0].opts.layout = MemLayout::Coalesced;
        s.groups[0].opts.ilp = 8;
        let mut rng = Rng::new(1);
        let rep = profile(&arch, &g, &s, 0.0, &mut rng);
        assert_eq!(rep.kernels[0].primary, Bottleneck::ComputeThroughput);
    }

    #[test]
    fn tiny_kernel_is_launch_bound() {
        let arch = GpuArch::h100();
        let mut b = GraphBuilder::new("tiny");
        let x = b.input("x", &[8, 8]);
        let y = b.op(OpKind::Relu, &[x]);
        b.output(y);
        let g = b.finish();
        let s = Schedule::naive(&g);
        let mut rng = Rng::new(1);
        let rep = profile(&arch, &g, &s, 0.0, &mut rng);
        assert_eq!(rep.kernels[0].primary, Bottleneck::LaunchOverhead);
    }

    #[test]
    fn transcendental_kernel_classified() {
        let arch = GpuArch::a100();
        let mut b = GraphBuilder::new("exp");
        let x = b.input("x", &[4096, 4096]);
        let y = b.op(OpKind::Exp, &[x]);
        b.output(y);
        let g = b.finish();
        let mut s = Schedule::naive(&g);
        s.groups[0].opts.layout = MemLayout::Coalesced;
        let mut rng = Rng::new(1);
        let rep = profile(&arch, &g, &s, 0.0, &mut rng);
        // exp over coalesced memory: either memory-bandwidth or
        // transcendental primary; transcendental must appear.
        let k = &rep.kernels[0];
        assert!(
            k.primary == Bottleneck::Transcendental || k.secondary == Bottleneck::Transcendental,
            "{:?}/{:?}",
            k.primary,
            k.secondary
        );
    }

    #[test]
    fn noise_perturbs_but_zero_noise_is_exact() {
        let arch = GpuArch::a100();
        let g = big_matmul();
        let s = Schedule::naive(&g);
        let mut rng = Rng::new(7);
        let a = profile(&arch, &g, &s, 0.0, &mut rng).total_cycles;
        let b = profile(&arch, &g, &s, 0.0, &mut rng).total_cycles;
        assert_eq!(a, b);
        let c = profile(&arch, &g, &s, 0.05, &mut rng).total_cycles;
        let d = profile(&arch, &g, &s, 0.05, &mut rng).total_cycles;
        assert_ne!(c, d);
        // Noise stays within a few sigma.
        assert!((c / a - 1.0).abs() < 0.3);
    }

    #[test]
    fn stalls_sum_to_one() {
        let arch = GpuArch::l40s();
        let g = big_matmul();
        let s = Schedule::naive(&g);
        let mut rng = Rng::new(1);
        let rep = profile(&arch, &g, &s, 0.0, &mut rng);
        for k in &rep.kernels {
            let sum: f64 = k.stalls.iter().map(|s| s.1).sum();
            assert!((sum - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn report_renders_details() {
        let arch = GpuArch::a6000();
        let g = big_matmul();
        let s = Schedule::naive(&g);
        let mut rng = Rng::new(1);
        let rep = profile(&arch, &g, &s, 0.0, &mut rng);
        let text = rep.render_details();
        assert!(text.contains("kernel_0_matmul"));
        assert!(text.contains("stall."));
        assert!(text.contains("A6000"));
    }

    #[test]
    fn dominant_bottleneck_weighted_by_time() {
        let arch = GpuArch::a100();
        let mut b = GraphBuilder::new("mix");
        let x = b.input("x", &[2048, 2048]);
        let w = b.input("w", &[2048, 2048]);
        let mm = b.op(OpKind::Matmul, &[x, w]);
        let r = b.op(OpKind::Relu, &[mm]);
        b.output(r);
        let g = b.finish();
        let s = Schedule::naive(&g);
        let mut rng = Rng::new(1);
        let rep = profile(&arch, &g, &s, 0.0, &mut rng);
        // The matmul dwarfs the relu; dominant = matmul's bottleneck.
        assert_eq!(rep.dominant_bottleneck(), rep.kernels[0].primary);
    }

    #[test]
    fn bottleneck_name_roundtrip() {
        for b in Bottleneck::all() {
            assert_eq!(Bottleneck::from_name(b.name()), Some(b));
        }
        assert_eq!(Bottleneck::from_name("bogus"), None);
    }
}
