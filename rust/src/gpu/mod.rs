//! GPU substrate: architecture models, the analytical performance model,
//! and the NCU-like profiler. See DESIGN.md §1 for why these substitute
//! for the paper's physical GPUs + Nsight Compute.

pub mod arch;
pub mod model;
pub mod profiler;

pub use arch::{GpuArch, GpuGen};
pub use model::{estimate_group, estimate_schedule, LaunchEstimate, ScheduleEstimate};
pub use profiler::{profile, Bottleneck, KernelProfile, NcuReport};
