//! GPU substrate: architecture models ([`arch`]), the analytical
//! performance model ([`model`]), and the NCU-like profiler
//! ([`profiler`]). See DESIGN.md §1 for why these substitute for the
//! paper's physical GPUs + Nsight Compute.
//!
//! Position in the MAIC-RL loop (**profile** → state-extract → KB-match →
//! lower → verify): [`profiler::profile`] turns a
//! ([`crate::kir::KernelGraph`], schedule) pair into the [`NcuReport`]
//! the state extractor ([`crate::agents::state_extractor`]) reads; the
//! harness ([`crate::harness`]) calls it on every validated candidate;
//! and the per-[`Bottleneck`] capacities of [`GpuArch`] double as the
//! scaling hints behind cross-arch KB transfer
//! ([`crate::kb::lifecycle`]).

pub mod arch;
pub mod model;
pub mod profiler;

pub use arch::{GpuArch, GpuGen};
pub use model::{estimate_group, estimate_schedule, LaunchEstimate, ScheduleEstimate};
pub use profiler::{profile, Bottleneck, KernelProfile, NcuReport};
