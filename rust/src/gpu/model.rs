//! Analytical GPU performance model.
//!
//! Estimates per-launch execution time for a (graph, schedule) pair on a
//! [`GpuArch`]: a roofline core (memory vs compute bound) extended with the
//! effects every optimization technique in the catalog manipulates —
//! operand-reuse/tiling traffic multipliers, access-pattern bandwidth
//! efficiency, ILP/unroll compute efficiency, tensor-core throughput,
//! occupancy limits from registers/shared-memory/threads, wave utilization
//! for small grids, SFU throughput for transcendentals, and fixed launch
//! overhead.
//!
//! The model does not chase absolute silicon accuracy; it reproduces the
//! *structure* the paper's agents learn from: which resource saturates,
//! what the profiler reports, and how schedule changes move the bottleneck.

use super::arch::GpuArch;
use crate::kir::cost::{self, OpCost};
use crate::kir::schedule::{FusionGroup, MemLayout, Schedule, Tiling};
use crate::kir::{KernelGraph, OpKind};

/// Detailed timing estimate for one kernel launch (fusion group).
#[derive(Debug, Clone, PartialEq)]
pub struct LaunchEstimate {
    /// Total wall time, seconds (execution + launch overhead).
    pub time_s: f64,
    /// Elapsed device cycles (time × clock), the paper's §4.1 metric.
    pub cycles: f64,
    pub mem_time_s: f64,
    pub compute_time_s: f64,
    pub launch_overhead_s: f64,
    /// Achieved occupancy (0..1].
    pub occupancy: f64,
    /// Wave utilization (how full the device is, 0..1].
    pub utilization: f64,
    /// DRAM bandwidth utilization during execution (0..1).
    pub dram_util: f64,
    /// Compute-pipe utilization during execution (0..1).
    pub compute_util: f64,
    /// Fraction of compute time spent on SFU transcendentals.
    pub transcendental_share: f64,
    pub cost: OpCost,
}

/// Estimate one fusion group.
pub fn estimate_group(arch: &GpuArch, graph: &KernelGraph, group: &FusionGroup) -> LaunchEstimate {
    let cost = cost::group_cost(graph, group);
    let opts = &group.opts;

    // ---------------- occupancy ----------------
    let block = group.launch.block.max(1);
    let scratch = cost::group_scratch_bytes(graph, group).max(1);
    let by_smem = (arch.smem_per_sm / scratch).max(if scratch > arch.smem_per_sm { 0 } else { 1 });
    let regs_per_block = opts.regs_per_thread.max(16) * block;
    let by_regs = (arch.regs_per_sm / regs_per_block.max(1)).max(1);
    let by_threads = (arch.max_threads_per_sm / block.min(arch.max_threads_per_sm)).max(1);
    let blocks_per_sm = by_smem.min(by_regs).min(by_threads).max(1);
    let occupancy =
        ((blocks_per_sm * block) as f64 / arch.max_threads_per_sm as f64).clamp(0.05, 1.0);

    // ---------------- wave utilization ----------------
    let total_threads = (group.launch.grid * block) as f64;
    let resident = (arch.sms as f64) * arch.max_threads_per_sm as f64 * occupancy;
    let utilization = (total_threads / resident).clamp(0.02, 1.0);

    // ---------------- contraction reuse / traffic ----------------
    let k_dim = contraction_k(graph, group);
    let traffic_mult = if opts.vendor_lib {
        1.1
    } else if let Some(k) = k_dim {
        // Untiled contractions re-read operands once per output element;
        // caches recover some locality, but effective traffic still scales
        // with K. Shared-memory tiling recovers reuse ∝ tile width. This is
        // the dominant effect behind the paper's "naive CUDA up to 100×
        // slower" observation (§4.6).
        let naive_mult = (k as f64 / 8.0).clamp(1.0, 64.0);
        match opts.tiling {
            Tiling::None => naive_mult,
            Tiling::Shared { tile } => {
                let reuse = (tile as f64 / 4.0).max(1.0);
                (naive_mult / reuse).clamp(1.0, naive_mult)
            }
        }
    } else {
        1.0
    };

    // ---------------- bandwidth efficiency ----------------
    let layout_eff = if opts.vendor_lib {
        0.85
    } else {
        match opts.layout {
            MemLayout::Naive => 0.35,
            MemLayout::Coalesced => 0.70,
            MemLayout::Padded => 0.80,
        }
    };
    let vec_bonus = 1.0 + 0.10 * (opts.vector_width.max(1) as f64).log2();
    let coarsen_bonus = 1.0 + 0.04 * ((opts.coarsening.min(8) as f64) - 1.0).max(0.0);
    let db_bonus = if opts.double_buffer { 1.08 } else { 1.0 };
    let bw_eff = (layout_eff * vec_bonus * coarsen_bonus * db_bonus).clamp(0.05, 0.92);
    // Latency hiding: low occupancy starves the memory pipe.
    let occ_bw = occupancy.sqrt();

    let bytes_eff = cost.bytes_total() * traffic_mult;
    // DRAM bandwidth saturates with ~16 active SMs (memory parallelism is
    // not per-SM); compute throughput, by contrast, scales with the full
    // wave utilization below.
    let active_sms = group.launch.grid.min(arch.sms) as f64;
    let bw_parallel = (active_sms / 16.0).clamp(1.0 / 16.0, 1.0);
    let mem_time = bytes_eff / (arch.mem_bw_bytes() * bw_eff * occ_bw * bw_parallel);

    // ---------------- compute efficiency ----------------
    let tc_active = opts.tensor_core && k_dim.is_some();
    let (peak_flops, compute_eff) = if opts.vendor_lib {
        // Vendor libraries pick tensor cores when dtype permits.
        let has_16bit = group
            .nodes
            .iter()
            .any(|n| graph.nodes[*n].dtype != crate::kir::DType::F32);
        if has_16bit {
            (arch.tc_flops(), 0.62)
        } else {
            (arch.fp32_flops(), 0.80)
        }
    } else if tc_active {
        let tile_bonus: f64 = match opts.tiling {
            Tiling::Shared { tile } if tile >= 64 => 0.20,
            Tiling::Shared { tile } if tile >= 32 => 0.12,
            Tiling::Shared { .. } => 0.05,
            Tiling::None => 0.0,
        };
        let ilp_bonus = if opts.ilp >= 4 { 0.08 } else { 0.0 };
        let db = if opts.double_buffer { 0.08 } else { 0.0 };
        let pad = if opts.layout == MemLayout::Padded { 0.05 } else { 0.0 };
        (arch.tc_flops(), (0.22 + tile_bonus + ilp_bonus + db + pad).min(0.65))
    } else {
        // Scalar-pipeline efficiency is multiplicative in the classic
        // levers: naive one-thread-per-output code issues ~6% of peak
        // (memory-latency-serialized); smem staging, independent
        // accumulators (ILP), unrolling, coarsening and branchless inner
        // loops each recover a factor, saturating near 75% of peak —
        // the shape of a hand-tuned SGEMM progression.
        let base = 0.06;
        let tiling_mult = match opts.tiling {
            Tiling::Shared { tile } if tile >= 64 => 3.0,
            Tiling::Shared { .. } => 2.2,
            Tiling::None => 1.0,
        };
        let ilp_mult = 1.0 + 0.5 * (opts.ilp.clamp(1, 8) as f64).log2();
        let unroll_mult = if opts.unroll >= 4 { 1.2 } else { 1.0 };
        let coarsen_mult = 1.0 + 0.10 * ((opts.coarsening.min(8) as f64) - 1.0).max(0.0);
        let scf_mult = if opts.simplified_control_flow { 1.15 } else { 1.0 };
        let ws_mult = if opts.warp_shuffle_reduction { 1.10 } else { 1.0 };
        (
            arch.fp32_flops(),
            (base * tiling_mult * ilp_mult * unroll_mult * coarsen_mult * scf_mult * ws_mult)
                .min(0.75),
        )
    };

    let tf = cost.transcendental_frac;
    let sfu_mult = if opts.fast_math { 2.0 } else { 1.0 };
    let sfu_flops = arch.fp32_flops() * arch.sfu_ratio * sfu_mult;
    let main_time = cost.flops * (1.0 - tf) / (peak_flops * compute_eff);
    let trans_time = cost.flops * tf / (sfu_flops * compute_eff.max(0.3));
    let compute_time = (main_time + trans_time) / utilization;
    let transcendental_share = if compute_time > 0.0 {
        (trans_time / utilization) / compute_time
    } else {
        0.0
    };

    // ---------------- combine ----------------
    // Partial overlap of memory and compute (0.85 of the smaller hides).
    let exec = mem_time.max(compute_time) + 0.15 * mem_time.min(compute_time);
    // Very low occupancy adds a latency penalty even on the critical path.
    let exec = if occupancy < 0.25 {
        exec * (0.25 / occupancy).powf(0.3)
    } else {
        exec
    };
    let launch_overhead_s = arch.launch_overhead_us * 1e-6;
    let time_s = exec + launch_overhead_s;

    LaunchEstimate {
        time_s,
        cycles: time_s * arch.clock_ghz * 1e9,
        mem_time_s: mem_time,
        compute_time_s: compute_time,
        launch_overhead_s,
        occupancy,
        utilization,
        dram_util: (mem_time / exec).clamp(0.0, 1.0),
        compute_util: (compute_time / exec).clamp(0.0, 1.0),
        transcendental_share,
        cost,
    }
}

/// Extract the contraction K dimension if the group contains one (matmul
/// K, or conv `c_in*kh*kw`). Used for the operand-reuse model.
pub fn contraction_k(graph: &KernelGraph, group: &FusionGroup) -> Option<usize> {
    group.nodes.iter().find_map(|&ni| {
        let node = &graph.nodes[ni];
        match &node.kind {
            OpKind::Matmul => Some(graph.shape_of(node.deps[0]).dim(1)),
            OpKind::Conv2d { .. } => {
                let w = graph.shape_of(node.deps[1]);
                Some(w.dim(1) * w.dim(2) * w.dim(3))
            }
            _ => None,
        }
    })
}

/// Whole-schedule estimate: per-launch estimates plus totals.
#[derive(Debug, Clone)]
pub struct ScheduleEstimate {
    pub launches: Vec<LaunchEstimate>,
    pub total_time_s: f64,
    pub total_cycles: f64,
}

pub fn estimate_schedule(
    arch: &GpuArch,
    graph: &KernelGraph,
    schedule: &Schedule,
) -> ScheduleEstimate {
    let launches: Vec<LaunchEstimate> = schedule
        .groups
        .iter()
        .map(|g| estimate_group(arch, graph, g))
        .collect();
    let total_time_s = launches.iter().map(|l| l.time_s).sum();
    let total_cycles = launches.iter().map(|l| l.cycles).sum();
    ScheduleEstimate {
        launches,
        total_time_s,
        total_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::schedule::Schedule;
    use crate::kir::{DType, GraphBuilder, OpKind};

    fn matmul_graph(m: usize, k: usize, n: usize) -> KernelGraph {
        let mut b = GraphBuilder::new("mm");
        let x = b.input("x", &[m, k]);
        let w = b.input("w", &[k, n]);
        let mm = b.op(OpKind::Matmul, &[x, w]);
        b.output(mm);
        b.finish()
    }

    fn matmul_graph_16bit(m: usize, k: usize, n: usize) -> KernelGraph {
        let mut b = GraphBuilder::new("mm16");
        let x = b.input_typed("x", &[m, k], DType::F16);
        let w = b.input_typed("w", &[k, n], DType::F16);
        let mm = b.op(OpKind::Matmul, &[x, w]);
        b.output(mm);
        b.finish()
    }

    #[test]
    fn tiling_speeds_up_large_matmul() {
        let arch = GpuArch::a100();
        let g = matmul_graph(1024, 1024, 1024);
        let naive = Schedule::naive(&g);
        let base = estimate_schedule(&arch, &g, &naive).total_time_s;
        let mut tiled = naive.clone();
        tiled.groups[0].opts.tiling = Tiling::Shared { tile: 64 };
        tiled.groups[0].opts.layout = MemLayout::Coalesced;
        let t = estimate_schedule(&arch, &g, &tiled).total_time_s;
        assert!(t < base * 0.5, "tiled={t} naive={base}");
    }

    #[test]
    fn tensor_core_beats_fp32_on_large_16bit_gemm() {
        let arch = GpuArch::h100();
        let g = matmul_graph_16bit(2048, 2048, 2048);
        let mut s = Schedule::naive(&g);
        s.groups[0].opts.tiling = Tiling::Shared { tile: 64 };
        s.groups[0].opts.layout = MemLayout::Coalesced;
        let fp32_time = estimate_schedule(&arch, &g, &s).total_time_s;
        s.groups[0].opts.tensor_core = true;
        assert!(s.validate(&g).is_ok());
        let tc_time = estimate_schedule(&arch, &g, &s).total_time_s;
        assert!(tc_time < fp32_time * 0.6, "tc={tc_time} fp32={fp32_time}");
    }

    #[test]
    fn vendor_lib_is_strong_baseline() {
        let arch = GpuArch::l40s();
        let g = matmul_graph(512, 512, 512);
        let naive = Schedule::naive(&g);
        let base = estimate_schedule(&arch, &g, &naive).total_time_s;
        let mut vendor = naive.clone();
        vendor.groups[0].opts.vendor_lib = true;
        let v = estimate_schedule(&arch, &g, &vendor).total_time_s;
        assert!(v < base * 0.25, "vendor={v} naive={base}");
    }

    #[test]
    fn launch_overhead_dominates_tiny_kernels() {
        let arch = GpuArch::h100();
        let g = matmul_graph(4, 4, 4);
        let s = Schedule::naive(&g);
        let est = &estimate_schedule(&arch, &g, &s).launches[0];
        assert!(est.launch_overhead_s / est.time_s > 0.5);
    }

    #[test]
    fn fusion_reduces_total_time_on_elementwise_chain() {
        let arch = GpuArch::a6000();
        let mut b = GraphBuilder::new("chain");
        let x = b.input("x", &[1024, 1024]);
        let a = b.op(OpKind::Relu, &[x]);
        let c = b.op(OpKind::Scale { c: 2.0 }, &[a]);
        let d = b.op(OpKind::AddConst { c: 1.0 }, &[c]);
        b.output(d);
        let g = b.finish();
        let naive = Schedule::naive(&g);
        let base = estimate_schedule(&arch, &g, &naive).total_time_s;
        let mut fused = naive.clone();
        fused.fuse(0, 1);
        fused.fuse(0, 1);
        assert!(fused.validate(&g).is_ok());
        let t = estimate_schedule(&arch, &g, &fused).total_time_s;
        assert!(t < base * 0.6, "fused={t} naive={base}");
    }

    #[test]
    fn elementwise_is_memory_bound() {
        let arch = GpuArch::a100();
        let mut b = GraphBuilder::new("ew");
        let x = b.input("x", &[4096, 4096]);
        let y = b.op(OpKind::Relu, &[x]);
        b.output(y);
        let g = b.finish();
        let mut s = Schedule::naive(&g);
        s.groups[0].opts.layout = MemLayout::Coalesced;
        let est = &estimate_schedule(&arch, &g, &s).launches[0];
        assert!(est.mem_time_s > est.compute_time_s * 3.0);
    }

    #[test]
    fn big_tiled_matmul_is_compute_bound() {
        let arch = GpuArch::a6000();
        let g = matmul_graph(4096, 4096, 4096);
        let mut s = Schedule::naive(&g);
        s.groups[0].opts.tiling = Tiling::Shared { tile: 128 };
        s.groups[0].opts.layout = MemLayout::Coalesced;
        s.groups[0].opts.ilp = 8;
        let est = &estimate_schedule(&arch, &g, &s).launches[0];
        assert!(est.compute_time_s > est.mem_time_s, "{est:?}");
    }

    #[test]
    fn fast_math_helps_transcendental_kernels() {
        let arch = GpuArch::a100();
        let mut b = GraphBuilder::new("exp");
        let x = b.input("x", &[4096, 4096]);
        let y = b.op(OpKind::Exp, &[x]);
        b.output(y);
        let g = b.finish();
        let s = Schedule::naive(&g);
        let base = estimate_schedule(&arch, &g, &s).launches[0].compute_time_s;
        let mut fm = s.clone();
        fm.groups[0].opts.fast_math = true;
        let t = estimate_schedule(&arch, &g, &fm).launches[0].compute_time_s;
        assert!(t < base);
    }

    #[test]
    fn excess_registers_reduce_occupancy() {
        let arch = GpuArch::a100();
        let g = matmul_graph(1024, 1024, 1024);
        let mut s = Schedule::naive(&g);
        s.groups[0].opts.regs_per_thread = 32;
        let high_occ = estimate_schedule(&arch, &g, &s).launches[0].occupancy;
        s.groups[0].opts.regs_per_thread = 255;
        let low_occ = estimate_schedule(&arch, &g, &s).launches[0].occupancy;
        assert!(low_occ < high_occ);
    }

    #[test]
    fn small_grid_underutilizes() {
        let arch = GpuArch::h100();
        let g = matmul_graph(256, 256, 256);
        let mut s = Schedule::naive(&g);
        s.groups[0].launch.grid = 1; // one block on a 132-SM part
        let est = estimate_schedule(&arch, &g, &s);
        assert!(est.launches[0].utilization < 0.05);
    }

    #[test]
    fn cross_arch_ordering_h100_fastest_on_bandwidth_bound() {
        let mut b = GraphBuilder::new("ew");
        let x = b.input("x", &[8192, 8192]);
        let y = b.op(OpKind::Relu, &[x]);
        b.output(y);
        let g = b.finish();
        let s = Schedule::naive(&g);
        let t_h100 = estimate_schedule(&GpuArch::h100(), &g, &s).total_time_s;
        let t_a6000 = estimate_schedule(&GpuArch::a6000(), &g, &s).total_time_s;
        assert!(t_h100 < t_a6000);
    }

    #[test]
    fn estimates_deterministic() {
        let arch = GpuArch::a100();
        let g = matmul_graph(128, 128, 128);
        let s = Schedule::naive(&g);
        let a = estimate_schedule(&arch, &g, &s).total_time_s;
        let b = estimate_schedule(&arch, &g, &s).total_time_s;
        assert_eq!(a, b);
    }

    #[test]
    fn contraction_k_extraction() {
        let g = matmul_graph(8, 77, 8);
        let s = Schedule::naive(&g);
        assert_eq!(contraction_k(&g, &s.groups[0]), Some(77));
        let mut b = GraphBuilder::new("c");
        let x = b.input("x", &[1, 3, 8, 8]);
        let w = b.input("w", &[4, 3, 5, 5]);
        let c = b.op(OpKind::Conv2d { stride: 1, pad: 2 }, &[x, w]);
        b.output(c);
        let g2 = b.finish();
        let s2 = Schedule::naive(&g2);
        assert_eq!(contraction_k(&g2, &s2.groups[0]), Some(75));
    }
}
