//! The optimization catalog: every technique in the paper's Figs. 12–14,
//! implemented as a real transformation over a [`Candidate`] (graph pair +
//! schedule), with an applicability predicate and a prior expected gain.
//!
//! Two technique classes:
//! - **schedule techniques** mutate [`crate::kir::schedule::GroupOpts`]/launch geometry of one
//!   fusion group (tiling, ILP, vectorization, …);
//! - **graph techniques** rewrite the dataflow graph itself (kernel fusion,
//!   algebraic simplification, dead-code elimination, mixed precision) —
//!   these are applied to the full-shape and small-shape graphs in
//!   lockstep so the numeric oracle stays aligned.
//!
//! The paper's "prep → compute" interaction structure (§5: tiling before
//! tensor cores ≈2.41×, layout before fusion ≈1.95×, control flow before
//! tensor-core tuning ≈1.42×) is *structural* here: `TensorCoreUtilization`
//! is inapplicable until a tiling technique has run, so the high-yield
//! sequences the paper discovers are exactly the sequences that are legal.
//!
//! Position in the MAIC-RL loop (profile → state-extract → KB-match →
//! **lower** → verify): the KB ([`crate::kb`]) scores these
//! [`Technique`]s per state, the lowering agent
//! ([`crate::agents::lowering`]) applies them through [`apply`] onto
//! [`crate::kir`] (graph, schedule) pairs, and the harness
//! ([`crate::harness`]) validates the result.

pub mod apply;
pub mod catalog;

pub use catalog::{Technique, TechniqueClass};

use crate::kir::schedule::Schedule;
use crate::kir::KernelGraph;

/// A candidate program state: the unit the agents transform, verify,
/// profile and score. `full` drives the performance model; `small` drives
/// the numeric oracle; `schedule` partitions both (identical node sets).
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub full: KernelGraph,
    pub small: KernelGraph,
    pub schedule: Schedule,
    /// Names of techniques applied so far, in order (trajectory log).
    pub applied: Vec<&'static str>,
}

impl Candidate {
    /// Naive starting state for a task: default one-launch-per-node
    /// schedule, no techniques applied — §4.6's "naive CUDA" baseline.
    pub fn naive(task: &crate::tasks::Task) -> Candidate {
        Candidate {
            full: task.graph.clone(),
            small: task.small.clone(),
            schedule: Schedule::naive(&task.graph),
            applied: Vec::new(),
        }
    }

    /// Consistency check: graphs validate, schedule validates against the
    /// full graph, and graphs stay structurally aligned.
    pub fn validate(&self) -> Result<(), String> {
        self.full.validate().map_err(|e| format!("full: {e}"))?;
        self.small.validate().map_err(|e| format!("small: {e}"))?;
        self.schedule
            .validate(&self.full)
            .map_err(|e| format!("schedule: {e}"))?;
        if self.full.nodes.len() != self.small.nodes.len() {
            return Err(format!(
                "graph desync: full has {} nodes, small has {}",
                self.full.nodes.len(),
                self.small.nodes.len()
            ));
        }
        for (i, (a, b)) in self.full.nodes.iter().zip(&self.small.nodes).enumerate() {
            if std::mem::discriminant(&a.kind) != std::mem::discriminant(&b.kind) {
                return Err(format!("graph desync at node {i}"));
            }
        }
        Ok(())
    }

    /// Schedule-space distance to another candidate, for similarity-aware
    /// beam-frontier dedup ([`crate::icrl::driver`]; threshold
    /// `policy.dedup_distance`). Candidates whose dataflow graphs differ
    /// (graph-rewrite techniques ran on one but not the other) are
    /// structurally different kernels — the distance is infinite.
    /// Otherwise it is the schedules' feature distance
    /// ([`crate::kir::schedule::Schedule::distance`]). Symmetric; 0.0
    /// means same graph and same schedule (the `applied` trajectory log
    /// may still differ — two routes to one program are one program).
    pub fn schedule_distance(&self, other: &Candidate) -> f64 {
        if self.full != other.full {
            return f64::INFINITY;
        }
        self.schedule.distance(&other.schedule)
    }

    /// True if any node computes in reduced precision (affects the
    /// verification tolerance, like fp16 CUDA kernels do).
    pub fn has_reduced_precision(&self) -> bool {
        self.full
            .nodes
            .iter()
            .any(|n| n.dtype != crate::kir::DType::F32)
            || self
                .full
                .inputs
                .iter()
                .any(|i| i.dtype != crate::kir::DType::F32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Suite;

    #[test]
    fn naive_candidates_valid_for_all_tasks() {
        for task in Suite::full().tasks {
            let c = Candidate::naive(&task);
            c.validate()
                .unwrap_or_else(|e| panic!("{}: {e}", task.id));
            assert_eq!(c.schedule.n_launches(), c.full.nodes.len());
        }
    }

    #[test]
    fn schedule_distance_tracks_schedule_and_graph_changes() {
        let suite = Suite::full();
        let task = suite.by_id("L1/01_matmul_square").unwrap();
        let a = Candidate::naive(task);
        assert_eq!(a.schedule_distance(&a), 0.0);
        // Same graph, nudged schedule: small finite distance.
        let mut b = a.clone();
        b.schedule.groups[0].opts.unroll = 2;
        b.applied.push("loop_unrolling");
        let d = a.schedule_distance(&b);
        assert!(d > 0.0 && d.is_finite(), "d = {d}");
        assert_eq!(a.schedule_distance(&b), b.schedule_distance(&a));
        // Different graph (other task): structurally different kernel.
        let other = Candidate::naive(suite.by_id("L1/12_softmax").unwrap());
        assert_eq!(a.schedule_distance(&other), f64::INFINITY);
    }

    #[test]
    fn reduced_precision_detection() {
        let suite = Suite::full();
        let f16 = suite.by_id("L1/05_matmul_f16").unwrap();
        assert!(Candidate::naive(f16).has_reduced_precision());
        let f32t = suite.by_id("L1/01_matmul_square").unwrap();
        assert!(!Candidate::naive(f32t).has_reduced_precision());
    }
}
