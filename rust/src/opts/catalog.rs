//! The technique catalog: names, classes, priors, applicability.
//!
//! The 24 techniques are the union of those named in the paper's Figs.
//! 12–14 and §5 trajectory analysis (instruction_level_parallelism,
//! tensor_core_utilization, grid_size_optimization, shared_memory_tiling,
//! simd_operations, block_size_adaptation, work_per_thread_increase,
//! register_pressure_reduction, fast_math, thread_coarsening, …) plus the
//! graph-level transformations its appendix kernels exhibit (kernel
//! fusion, algebraic simplification, mixed precision, split-K).

use super::Candidate;
use crate::kir::schedule::{MemLayout, Tiling};
use crate::kir::OpKind;

/// Coarse class, used in reports and by the two-tier selection strategy
/// the paper's §5 recommends (cheap local probes vs structured rewrites).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TechniqueClass {
    /// Mutates one group's execution attributes.
    Schedule,
    /// Rewrites the dataflow graph (and mirrors it in the small graph).
    Graph,
}

/// Every optimization technique the agents may select.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Technique {
    // ---- memory access / staging ----
    MemoryCoalescing,
    MemoryLayoutPadding,
    SharedMemoryTiling,
    TilingSizeTuning,
    VectorizedAccess,
    DoubleBuffering,
    // ---- compute shaping ----
    InstructionLevelParallelism,
    LoopUnrolling,
    ThreadCoarsening,
    WorkPerThreadIncrease,
    FastMath,
    ControlFlowSimplification,
    WarpShuffleReduction,
    TensorCoreUtilization,
    MixedPrecision,
    SplitK,
    // ---- launch shaping ----
    GridSizeOptimization,
    BlockSizeAdaptation,
    RegisterPressureReduction,
    OccupancyTuning,
    // ---- graph rewrites ----
    KernelFusion,
    EpilogueFusion,
    AlgebraicSimplification,
    DeadCodeElimination,
    // ---- vendor ----
    VendorLibraryDispatch,
}

impl Technique {
    /// Every technique, stable order (report order of Figs. 13/14).
    pub fn all() -> &'static [Technique] {
        use Technique::*;
        &[
            MemoryCoalescing,
            MemoryLayoutPadding,
            SharedMemoryTiling,
            TilingSizeTuning,
            VectorizedAccess,
            DoubleBuffering,
            InstructionLevelParallelism,
            LoopUnrolling,
            ThreadCoarsening,
            WorkPerThreadIncrease,
            FastMath,
            ControlFlowSimplification,
            WarpShuffleReduction,
            TensorCoreUtilization,
            MixedPrecision,
            SplitK,
            GridSizeOptimization,
            BlockSizeAdaptation,
            RegisterPressureReduction,
            OccupancyTuning,
            KernelFusion,
            EpilogueFusion,
            AlgebraicSimplification,
            DeadCodeElimination,
            VendorLibraryDispatch,
        ]
    }

    pub fn name(&self) -> &'static str {
        use Technique::*;
        match self {
            MemoryCoalescing => "memory_coalescing",
            MemoryLayoutPadding => "memory_layout_padding",
            SharedMemoryTiling => "shared_memory_tiling",
            TilingSizeTuning => "tiling_size_tuning",
            VectorizedAccess => "simd_operations",
            DoubleBuffering => "double_buffering",
            InstructionLevelParallelism => "instruction_level_parallelism",
            LoopUnrolling => "loop_unrolling",
            ThreadCoarsening => "thread_coarsening",
            WorkPerThreadIncrease => "work_per_thread_increase",
            FastMath => "fast_math",
            ControlFlowSimplification => "control_flow_simplification",
            WarpShuffleReduction => "warp_shuffle_reduction",
            TensorCoreUtilization => "tensor_core_utilization",
            MixedPrecision => "mixed_precision",
            SplitK => "split_k",
            GridSizeOptimization => "grid_size_optimization",
            BlockSizeAdaptation => "block_size_adaptation",
            RegisterPressureReduction => "register_pressure_reduction",
            OccupancyTuning => "occupancy_tuning",
            KernelFusion => "kernel_fusion",
            EpilogueFusion => "epilogue_fusion",
            AlgebraicSimplification => "algebraic_simplification",
            DeadCodeElimination => "dead_code_elimination",
            VendorLibraryDispatch => "vendor_library_dispatch",
        }
    }

    pub fn from_name(name: &str) -> Option<Technique> {
        Technique::all().iter().copied().find(|t| t.name() == name)
    }

    pub fn class(&self) -> TechniqueClass {
        use Technique::*;
        match self {
            KernelFusion | EpilogueFusion | AlgebraicSimplification | DeadCodeElimination
            | MixedPrecision => TechniqueClass::Graph,
            _ => TechniqueClass::Schedule,
        }
    }

    /// Prior expected speedup, used to seed Knowledge-Base scores (θ₀):
    /// the "priors used to generate the initial prompt" the paper's RL
    /// loop then corrects with measured rewards.
    pub fn prior_gain(&self) -> f64 {
        use Technique::*;
        match self {
            SharedMemoryTiling => 2.2,
            TensorCoreUtilization => 2.0,
            KernelFusion => 1.5,
            EpilogueFusion => 1.5,
            AlgebraicSimplification => 1.6,
            MemoryCoalescing => 1.8,
            VendorLibraryDispatch => 2.5,
            MixedPrecision => 1.5,
            TilingSizeTuning => 1.3,
            VectorizedAccess => 1.25,
            GridSizeOptimization => 1.2,
            BlockSizeAdaptation => 1.15,
            InstructionLevelParallelism => 1.3,
            WorkPerThreadIncrease => 1.2,
            ThreadCoarsening => 1.15,
            WarpShuffleReduction => 1.2,
            SplitK => 1.3,
            DoubleBuffering => 1.15,
            LoopUnrolling => 1.1,
            FastMath => 1.2,
            ControlFlowSimplification => 1.1,
            RegisterPressureReduction => 1.1,
            OccupancyTuning => 1.15,
            MemoryLayoutPadding => 1.1,
            DeadCodeElimination => 1.05,
        }
    }

    /// Whether the technique can be applied to group `gi` of `cand`.
    /// These predicates encode the structural prerequisites that give rise
    /// to the paper's prep→compute sequences.
    pub fn applicable(&self, cand: &Candidate, gi: usize) -> bool {
        use Technique::*;
        let Some(group) = cand.schedule.groups.get(gi) else {
            return false;
        };
        let o = &group.opts;
        let graph = &cand.full;
        let has_contraction = group
            .nodes
            .iter()
            .any(|n| graph.nodes[*n].kind.is_contraction());
        let has_reduction = group
            .nodes
            .iter()
            .any(|n| graph.nodes[*n].kind.is_reduction());
        let has_transcendental = group.nodes.iter().any(|n| {
            matches!(
                graph.nodes[*n].kind,
                OpKind::Exp
                    | OpKind::Tanh
                    | OpKind::Sigmoid
                    | OpKind::Gelu
                    | OpKind::Softmax { .. }
                    | OpKind::LogSumExp { .. }
            )
        });
        let has_16bit = group
            .nodes
            .iter()
            .any(|n| graph.nodes[*n].dtype != crate::kir::DType::F32);
        if o.vendor_lib {
            // A vendor-dispatched group is a black box.
            return false;
        }
        match self {
            MemoryCoalescing => o.layout == MemLayout::Naive,
            MemoryLayoutPadding => o.layout == MemLayout::Coalesced,
            SharedMemoryTiling => has_contraction && matches!(o.tiling, Tiling::None),
            TilingSizeTuning => matches!(o.tiling, Tiling::Shared { tile } if tile < 128),
            VectorizedAccess => o.vector_width < 8 && o.layout != MemLayout::Naive,
            DoubleBuffering => !o.double_buffer && !matches!(o.tiling, Tiling::None),
            InstructionLevelParallelism => o.ilp < 16,
            LoopUnrolling => o.unroll < 16,
            ThreadCoarsening => o.coarsening < 8,
            WorkPerThreadIncrease => o.coarsening < 8 && group.launch.grid > 1,
            FastMath => !o.fast_math && has_transcendental,
            ControlFlowSimplification => !o.simplified_control_flow,
            WarpShuffleReduction => !o.warp_shuffle_reduction && has_reduction,
            // The prep→compute structure: tensor cores need 16-bit data
            // AND tiling already in place.
            TensorCoreUtilization => {
                !o.tensor_core
                    && has_contraction
                    && has_16bit
                    && !matches!(o.tiling, Tiling::None)
            }
            MixedPrecision => has_contraction && !has_16bit,
            SplitK => {
                has_contraction
                    && o.split_k == 1
                    && crate::gpu::model::contraction_k(graph, group).unwrap_or(0) >= 512
            }
            GridSizeOptimization | BlockSizeAdaptation => true,
            RegisterPressureReduction => o.regs_per_thread > 32,
            OccupancyTuning => true,
            KernelFusion => (0..cand.schedule.groups.len().saturating_sub(1)).any(|a| {
                let consumer_has_contraction = cand.schedule.groups[a + 1]
                    .nodes
                    .iter()
                    .any(|n| graph.nodes[*n].kind.is_contraction());
                !consumer_has_contraction && cand.schedule.can_fuse(graph, a, a + 1)
            }),
            EpilogueFusion => {
                // A contraction group followed by a fusable elementwise group.
                gi + 1 < cand.schedule.groups.len()
                    && has_contraction
                    && cand.schedule.groups[gi + 1]
                        .nodes
                        .iter()
                        .all(|n| graph.nodes[*n].kind.is_elementwise())
                    && cand.schedule.can_fuse(graph, gi, gi + 1)
            }
            AlgebraicSimplification => !super::apply::algebraic_candidates(graph).is_empty(),
            DeadCodeElimination => !graph.dead_nodes().is_empty(),
            VendorLibraryDispatch => has_contraction,
        }
    }

    /// Techniques applicable anywhere in the candidate (any group).
    pub fn applicable_anywhere(&self, cand: &Candidate) -> Option<usize> {
        (0..cand.schedule.groups.len()).find(|gi| self.applicable(cand, *gi))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Suite;

    #[test]
    fn names_unique_and_roundtrip() {
        let mut names: Vec<&str> = Technique::all().iter().map(|t| t.name()).collect();
        let n = names.len();
        assert_eq!(n, 25);
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n);
        for t in Technique::all() {
            assert_eq!(Technique::from_name(t.name()), Some(*t));
        }
    }

    #[test]
    fn tensor_core_requires_prep() {
        let suite = Suite::full();
        let f16 = suite.by_id("L1/05_matmul_f16").unwrap();
        let cand = Candidate::naive(f16);
        // Naive state: no tiling yet → TC inapplicable (prep→compute).
        assert!(!Technique::TensorCoreUtilization.applicable(&cand, 0));
        assert!(Technique::SharedMemoryTiling.applicable(&cand, 0));
    }

    #[test]
    fn fastmath_needs_transcendentals() {
        let suite = Suite::full();
        let mm = Candidate::naive(suite.by_id("L1/01_matmul_square").unwrap());
        assert!(!Technique::FastMath.applicable(&mm, 0));
        let sm = Candidate::naive(suite.by_id("L1/12_softmax").unwrap());
        assert!(Technique::FastMath.applicable(&sm, 0));
    }

    #[test]
    fn fusion_applicable_on_chains() {
        let suite = Suite::full();
        let chain = Candidate::naive(suite.by_id("L2/01_gemm_bias_relu").unwrap());
        assert!(Technique::KernelFusion.applicable(&chain, 0));
        assert!(Technique::EpilogueFusion.applicable(&chain, 0));
        let single = Candidate::naive(suite.by_id("L1/01_matmul_square").unwrap());
        assert!(!Technique::KernelFusion.applicable(&single, 0));
    }

    #[test]
    fn algebraic_applicable_on_q18() {
        let suite = Suite::full();
        let q18 = Candidate::naive(suite.by_id("L2/18_linear_sum_logsumexp2").unwrap());
        assert!(Technique::AlgebraicSimplification.applicable(&q18, 0));
    }

    #[test]
    fn priors_all_above_one() {
        for t in Technique::all() {
            assert!(t.prior_gain() > 1.0, "{}", t.name());
        }
    }

    #[test]
    fn split_k_needs_large_k() {
        let suite = Suite::full();
        // matmul_large has K=4096 → applicable
        let big = Candidate::naive(suite.by_id("L1/02_matmul_large").unwrap());
        assert!(Technique::SplitK.applicable(&big, 0));
        // conv 3x3 on 64ch: K=576 ≥ 512 → applicable; conv1x1 256ch K=256 → not
        let c1 = Candidate::naive(suite.by_id("L1/08_conv2d_1x1").unwrap());
        assert!(!Technique::SplitK.applicable(&c1, 0));
    }
}
