//! Technique application: the actual transformations.
//!
//! `apply(technique, candidate, group)` returns a *new* candidate with the
//! transformation performed, or an error string (the "compilation
//! feedback" an infeasible transformation produces). All transformations
//! are semantics-preserving by construction; semantic *bugs* are injected
//! separately by the lowering agent's failure model, so the validation
//! harness has something real to catch.

use super::catalog::Technique;
use super::Candidate;
use crate::kir::schedule::{MemLayout, Tiling};
use crate::kir::{KernelGraph, OpKind, ValueRef};

/// Apply `tech` to `cand`. Schedule techniques are applied to **every**
/// group where they are applicable (the lowering agent rewrites the whole
/// kernel file, not one launch at a time — matching the paper's
/// whole-source optimization actions); `gi` must name one applicable
/// group and serves as the applicability witness. Graph techniques act
/// globally by nature.
pub fn apply(tech: Technique, cand: &Candidate, gi: usize) -> Result<Candidate, String> {
    if !tech.applicable(cand, gi) {
        return Err(format!(
            "{} not applicable to group {gi} in current state",
            tech.name()
        ));
    }
    let mut next = cand.clone();
    if tech.class() == super::TechniqueClass::Schedule {
        for g in 0..cand.schedule.groups.len() {
            // Re-checked against `next`, not the pristine `cand`: by the
            // time group g is visited, earlier groups have already been
            // mutated, and a predicate that (today or in a future
            // technique) reads anything beyond group g's own state would
            // otherwise act on stale applicability. For the current
            // catalog every schedule predicate is group-local, so the
            // two checks agree — an invariant pinned by the
            // `schedule_applicability_is_group_local_under_mutation`
            // regression test below; this form stays correct even if a
            // cross-group-coupled predicate is ever added.
            if tech.applicable(&next, g) {
                apply_to_group(tech, &mut next, g);
            }
        }
        next.applied.push(tech.name());
        next.validate()
            .map_err(|e| format!("{} produced invalid candidate: {e}", tech.name()))?;
        return Ok(next);
    }
    use Technique::*;
    match tech {
        // ---------------- graph techniques ----------------
        KernelFusion => {
            // Cross-layer fusion as ONE action (the paper's L3 kernels
            // fuse bias+activation into convs and chains across layers in
            // a single rewrite): greedily fuse every legal adjacent pair
            // to a fixed point.
            let mut fused_any = false;
            loop {
                let mut progressed = false;
                let mut a = 0;
                while a + 1 < next.schedule.groups.len() {
                    // Never merge two contraction kernels — real fusion
                    // folds elementwise/reduction consumers into their
                    // producer, not GEMM into GEMM.
                    let consumer_has_contraction = next.schedule.groups[a + 1]
                        .nodes
                        .iter()
                        .any(|n| next.full.nodes[*n].kind.is_contraction());
                    if !consumer_has_contraction && next.schedule.can_fuse(&next.full, a, a + 1) {
                        next.schedule.fuse(a, a + 1);
                        progressed = true;
                        fused_any = true;
                    } else {
                        a += 1;
                    }
                }
                if !progressed {
                    break;
                }
            }
            if !fused_any {
                return Err("no fusable adjacent groups".to_string());
            }
        }
        EpilogueFusion => {
            next.schedule.fuse(gi, gi + 1);
        }
        AlgebraicSimplification => {
            let targets = algebraic_candidates(&next.full);
            let target = *targets.first().ok_or("no algebraic candidates")?;
            remove_noop_node(&mut next, target)?;
        }
        DeadCodeElimination => {
            for idx in next.full.dead_nodes() {
                next.full
                    .remove_node(idx)
                    .map_err(|e| format!("dce(full): {e}"))?;
                next.small
                    .remove_node(idx)
                    .map_err(|e| format!("dce(small): {e}"))?;
                next.schedule.remove_node(idx);
            }
        }
        MixedPrecision => {
            for g in [&mut next.full, &mut next.small] {
                for node in &mut g.nodes {
                    if node.kind.is_contraction() {
                        node.dtype = crate::kir::DType::BF16;
                    }
                }
            }
        }
        // Schedule techniques were handled above.
        _ => unreachable!("schedule technique in graph match arm"),
    }
    next.applied.push(tech.name());
    next.validate()
        .map_err(|e| format!("{} produced invalid candidate: {e}", tech.name()))?;
    Ok(next)
}

/// Mutate one group for a schedule technique (applicability already
/// checked by the caller).
fn apply_to_group(tech: Technique, next: &mut Candidate, gi: usize) {
    use Technique::*;
    match tech {
        MemoryCoalescing => {
            next.schedule.groups[gi].opts.layout = MemLayout::Coalesced;
        }
        MemoryLayoutPadding => {
            next.schedule.groups[gi].opts.layout = MemLayout::Padded;
        }
        SharedMemoryTiling => {
            let o = &mut next.schedule.groups[gi].opts;
            o.tiling = Tiling::Shared { tile: 32 };
            o.regs_per_thread = (o.regs_per_thread + 16).min(255);
        }
        TilingSizeTuning => {
            let o = &mut next.schedule.groups[gi].opts;
            if let Tiling::Shared { tile } = o.tiling {
                o.tiling = Tiling::Shared {
                    tile: (tile * 2).min(128),
                };
            }
        }
        VectorizedAccess => {
            let o = &mut next.schedule.groups[gi].opts;
            o.vector_width = (o.vector_width * 2).min(8);
        }
        DoubleBuffering => {
            next.schedule.groups[gi].opts.double_buffer = true;
        }
        InstructionLevelParallelism => {
            let o = &mut next.schedule.groups[gi].opts;
            o.ilp = (o.ilp * 2).min(16);
            o.regs_per_thread = (o.regs_per_thread + 16).min(255);
        }
        LoopUnrolling => {
            let o = &mut next.schedule.groups[gi].opts;
            o.unroll = (o.unroll * 2).min(16);
            o.regs_per_thread = (o.regs_per_thread + 8).min(255);
        }
        ThreadCoarsening => {
            let g = &mut next.schedule.groups[gi];
            g.opts.coarsening = (g.opts.coarsening * 2).min(8);
            g.launch.grid = (g.launch.grid / 2).max(1);
        }
        WorkPerThreadIncrease => {
            let g = &mut next.schedule.groups[gi];
            g.opts.coarsening = (g.opts.coarsening * 2).min(8);
            g.opts.regs_per_thread = (g.opts.regs_per_thread + 8).min(255);
            g.launch.grid = (g.launch.grid / 2).max(1);
        }
        FastMath => {
            next.schedule.groups[gi].opts.fast_math = true;
        }
        ControlFlowSimplification => {
            next.schedule.groups[gi].opts.simplified_control_flow = true;
        }
        WarpShuffleReduction => {
            next.schedule.groups[gi].opts.warp_shuffle_reduction = true;
        }
        TensorCoreUtilization => {
            next.schedule.groups[gi].opts.tensor_core = true;
        }
        SplitK => {
            next.schedule.groups[gi].opts.split_k = 4;
        }
        GridSizeOptimization => {
            let out_elems: usize = next.schedule.groups[gi]
                .nodes
                .iter()
                .map(|n| next.full.nodes[*n].shape.numel())
                .max()
                .unwrap_or(1);
            let g = &mut next.schedule.groups[gi];
            let per_thread = g.opts.coarsening.max(1);
            g.launch.grid = out_elems.div_ceil(g.launch.block * per_thread).max(1);
        }
        BlockSizeAdaptation => {
            let g = &mut next.schedule.groups[gi];
            let total = g.launch.threads();
            g.launch.block = match g.launch.block {
                256 => 128,
                128 => 512,
                _ => 256,
            };
            g.launch.grid = total.div_ceil(g.launch.block).max(1);
        }
        RegisterPressureReduction => {
            let o = &mut next.schedule.groups[gi].opts;
            o.regs_per_thread = (o.regs_per_thread / 2).max(32);
        }
        OccupancyTuning => {
            let g = &mut next.schedule.groups[gi];
            let total = g.launch.threads();
            g.launch.block = 256;
            g.launch.grid = total.div_ceil(256).max(1);
            g.opts.regs_per_thread = g.opts.regs_per_thread.min(64);
        }
        VendorLibraryDispatch => {
            next.schedule.groups[gi].opts.vendor_lib = true;
        }
        _ => unreachable!("graph technique in schedule helper"),
    }
}

/// Node indices that are algebraically removable no-ops, in a stable
/// order. Each can be replaced by its first operand:
/// - `logsumexp` along a size-1 axis (the Q18 pattern),
/// - `scale` by 1.0 / `div_const` by 1.0 / `add_const` 0.0,
/// - `identity`,
/// - `relu(relu(x))` (idempotent) — the outer node.
pub fn algebraic_candidates(graph: &KernelGraph) -> Vec<usize> {
    let mut out = Vec::new();
    for (i, node) in graph.nodes.iter().enumerate() {
        let removable = match &node.kind {
            OpKind::LogSumExp { axis } => graph.shape_of(node.deps[0]).dim(*axis) == 1,
            OpKind::Softmax { axis } => {
                // softmax over size-1 axis is constant 1 — NOT equal to its
                // input; never removable this way.
                let _ = axis;
                false
            }
            OpKind::Scale { c } => *c == 1.0,
            OpKind::DivConst { c } => *c == 1.0,
            OpKind::AddConst { c } => *c == 0.0,
            OpKind::Identity => true,
            OpKind::Relu => matches!(
                node.deps[0],
                ValueRef::Node(d) if matches!(graph.nodes[d].kind, OpKind::Relu)
            ),
            _ => false,
        };
        if removable {
            out.push(i);
        }
    }
    out
}

/// Remove a no-op node from both graphs and the schedule, rewiring users
/// to the node's first operand.
fn remove_noop_node(cand: &mut Candidate, idx: usize) -> Result<(), String> {
    for g in [&mut cand.full, &mut cand.small] {
        let replacement = g.nodes[idx].deps[0];
        g.replace_value(ValueRef::Node(idx), replacement);
        g.remove_node(idx).map_err(|e| format!("remove: {e}"))?;
    }
    cand.schedule.remove_node(idx);
    Ok(())
}

/// Exhaustively simplify: repeat algebraic simplification + DCE until a
/// fixed point. Used by the torch.compile-analog baseline.
pub fn simplify_fixpoint(cand: &Candidate) -> Candidate {
    let mut cur = cand.clone();
    loop {
        let mut changed = false;
        if let Some(&target) = algebraic_candidates(&cur.full).first() {
            if remove_noop_node(&mut cur, target).is_ok() {
                changed = true;
            }
        }
        let dead = cur.full.dead_nodes();
        if !dead.is_empty() {
            for idx in dead {
                let _ = cur.full.remove_node(idx);
                let _ = cur.small.remove_node(idx);
                cur.schedule.remove_node(idx);
            }
            changed = true;
        }
        if !changed {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{estimate_schedule, GpuArch};
    use crate::kir::interp::{self, allclose};
    use crate::tasks::Suite;

    fn cand(id: &str) -> Candidate {
        Candidate::naive(Suite::full().by_id(id).unwrap())
    }

    /// Semantic check: after a transformation, the small graph computes
    /// the same function.
    fn semantics_preserved(before: &Candidate, after: &Candidate) -> bool {
        let inputs = interp::random_inputs(&before.small, 99);
        let a = interp::execute(&before.small, &inputs).unwrap();
        let b = interp::execute(&after.small, &inputs).unwrap();
        let rtol = if after.has_reduced_precision() { 3e-2 } else { 1e-4 };
        a.iter().zip(&b).all(|(x, y)| allclose(x, y, rtol, rtol))
    }

    #[test]
    fn q18_algebraic_simplification_removes_logsumexp() {
        let c = cand("L2/18_linear_sum_logsumexp2");
        let n0 = c.full.nodes.len();
        let once = apply(Technique::AlgebraicSimplification, &c, 0).unwrap();
        assert_eq!(once.full.nodes.len(), n0 - 1);
        assert!(semantics_preserved(&c, &once));
        let twice = apply(Technique::AlgebraicSimplification, &once, 0).unwrap();
        assert_eq!(twice.full.nodes.len(), n0 - 2);
        assert!(semantics_preserved(&c, &twice));
        // Both logsumexp gone → technique no longer applicable.
        assert!(!Technique::AlgebraicSimplification.applicable(&twice, 0));
        // And it is faster on every arch.
        let arch = GpuArch::h100();
        let t0 = estimate_schedule(&arch, &c.full, &c.schedule).total_time_s;
        let t2 = estimate_schedule(&arch, &twice.full, &twice.schedule).total_time_s;
        assert!(t2 < t0);
    }

    #[test]
    fn every_schedule_technique_preserves_semantics() {
        // Schedule techniques never touch the graph; verify semantics and
        // schedule validity over a composed task.
        let c = cand("L2/01_gemm_bias_relu");
        for tech in Technique::all() {
            if let Some(gi) = tech.applicable_anywhere(&c) {
                let next = apply(*tech, &c, gi)
                    .unwrap_or_else(|e| panic!("{}: {e}", tech.name()));
                assert!(
                    semantics_preserved(&c, &next),
                    "{} broke semantics",
                    tech.name()
                );
            }
        }
    }

    #[test]
    fn prep_then_compute_sequence_compounds() {
        // The paper's §5 headline interaction: shared_memory_tiling before
        // tensor_core_utilization. Verify the sequence is (a) only legal
        // in that order, (b) compounds to a large gain.
        let c = cand("L2/63_gemm_bias_relu_div_f16");
        let arch = GpuArch::l40s();
        let t_naive = estimate_schedule(&arch, &c.full, &c.schedule).total_time_s;
        assert!(apply(Technique::TensorCoreUtilization, &c, 0).is_err());
        let tiled = apply(Technique::SharedMemoryTiling, &c, 0).unwrap();
        let tc = apply(Technique::TensorCoreUtilization, &tiled, 0).unwrap();
        let t_tc = estimate_schedule(&arch, &tc.full, &tc.schedule).total_time_s;
        assert!(
            t_naive / t_tc > 2.0,
            "sequence gain {:.2} too small",
            t_naive / t_tc
        );
        assert!(semantics_preserved(&c, &tc));
    }

    #[test]
    fn fusion_reduces_launches_and_preserves_semantics() {
        let c = cand("L2/12_scale_tanh_clip_chain");
        let mut cur = c.clone();
        while let Some(gi) = Technique::KernelFusion.applicable_anywhere(&cur) {
            cur = apply(Technique::KernelFusion, &cur, gi).unwrap();
        }
        assert_eq!(cur.schedule.n_launches(), 1);
        assert!(semantics_preserved(&c, &cur));
    }

    #[test]
    fn dead_code_elimination_on_gemm_mean_sub() {
        let c = cand("L2/19_gemm_mean_sub");
        assert!(Technique::DeadCodeElimination.applicable(&c, 0));
        let next = apply(Technique::DeadCodeElimination, &c, 0).unwrap();
        assert!(next.full.dead_nodes().is_empty());
        assert!(next.full.nodes.len() < c.full.nodes.len());
        assert!(semantics_preserved(&c, &next));
    }

    #[test]
    fn mixed_precision_flips_contraction_dtype() {
        let c = cand("L1/01_matmul_square");
        let next = apply(Technique::MixedPrecision, &c, 0).unwrap();
        assert!(next.has_reduced_precision());
        assert!(semantics_preserved(&c, &next));
        // Enables the TC path after tiling.
        let tiled = apply(Technique::SharedMemoryTiling, &next, 0).unwrap();
        assert!(Technique::TensorCoreUtilization.applicable(&tiled, 0));
    }

    #[test]
    fn simplify_fixpoint_cleans_q18_fully() {
        let c = cand("L2/18_linear_sum_logsumexp2");
        let simplified = simplify_fixpoint(&c);
        assert!(algebraic_candidates(&simplified.full).is_empty());
        assert!(simplified.full.dead_nodes().is_empty());
        assert_eq!(simplified.full.nodes.len(), 3); // matmul, bias, reduce
    }

    #[test]
    fn inapplicable_apply_is_error() {
        let c = cand("L1/01_matmul_square");
        assert!(apply(Technique::FastMath, &c, 0).is_err());
        assert!(apply(Technique::KernelFusion, &c, 0).is_err());
        assert!(apply(Technique::TensorCoreUtilization, &c, 99).is_err());
    }

    #[test]
    fn applied_log_accumulates() {
        let c = cand("L2/01_gemm_bias_relu");
        let a = apply(Technique::MemoryCoalescing, &c, 0).unwrap();
        let b = apply(Technique::SharedMemoryTiling, &a, 0).unwrap();
        assert_eq!(
            b.applied,
            vec!["memory_coalescing", "shared_memory_tiling"]
        );
    }

    #[test]
    fn grid_size_optimization_fills_outputs() {
        let c = cand("L1/01_matmul_square");
        let mut bad = c.clone();
        bad.schedule.groups[0].launch.grid = 1;
        let fixed = apply(Technique::GridSizeOptimization, &bad, 0).unwrap();
        let g = &fixed.schedule.groups[0];
        assert_eq!(g.launch.grid, (1024 * 1024usize).div_ceil(g.launch.block));
    }

    #[test]
    fn schedule_applicability_is_group_local_under_mutation() {
        // Regression for the stale-applicability bug class: `apply`'s
        // schedule loop re-checks applicability against the partially
        // mutated candidate, which is only equivalent to the old
        // check-the-original behavior if mutating one group can never
        // flip a schedule technique's applicability on a *different*
        // group. Pin that group-locality invariant: for every pair of
        // schedule techniques (t1, t2) on multi-group tasks, applying t1
        // (which mutates exactly the groups where t1 is applicable) must
        // leave t2's applicability unchanged on every group t1 did not
        // touch.
        let suite = Suite::full();
        for id in ["L2/01_gemm_bias_relu", "L2/09_mlp_block", "L3/01_lenet5"] {
            let c = cand(id);
            assert!(c.schedule.groups.len() > 1, "{id}: need multi-group");
            let schedule_techs: Vec<Technique> = Technique::all()
                .iter()
                .copied()
                .filter(|t| t.class() == super::super::TechniqueClass::Schedule)
                .collect();
            for &t1 in &schedule_techs {
                if t1.applicable_anywhere(&c).is_none() {
                    continue;
                }
                let touched: Vec<bool> = (0..c.schedule.groups.len())
                    .map(|g| t1.applicable(&c, g))
                    .collect();
                let gi = touched.iter().position(|&t| t).unwrap();
                let Ok(after) = apply(t1, &c, gi) else {
                    continue;
                };
                for &t2 in &schedule_techs {
                    for g in 0..c.schedule.groups.len() {
                        if touched[g] {
                            continue; // t1 mutated this group — its own change is expected
                        }
                        assert_eq!(
                            t2.applicable(&after, g),
                            t2.applicable(&c, g),
                            "{id}: applying {} to other groups flipped {} on group {g}",
                            t1.name(),
                            t2.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn property_random_technique_sequences_stay_valid() {
        use crate::util::proptest::{check, PropConfig};
        let suite = Suite::full();
        let ids = [
            "L2/01_gemm_bias_relu",
            "L2/09_mlp_block",
            "L2/18_linear_sum_logsumexp2",
            "L3/01_lenet5",
        ];
        check(
            "random-opt-sequences",
            PropConfig { cases: 24, seed: 0xBEEF },
            |rng| {
                let id = ids[rng.index(ids.len())];
                let mut cur = Candidate::naive(suite.by_id(id).unwrap());
                for _ in 0..6 {
                    let tech = Technique::all()[rng.index(Technique::all().len())];
                    let gi = rng.index(cur.schedule.groups.len());
                    if tech.applicable(&cur, gi) {
                        cur = apply(tech, &cur, gi)?;
                        cur.validate()?;
                    }
                }
                // Terminal state must still execute correctly.
                let inputs = interp::random_inputs(&cur.small, 5);
                interp::execute(&cur.small, &inputs).map_err(|e| e.to_string())?;
                Ok(())
            },
        );
    }
}
