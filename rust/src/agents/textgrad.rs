//! The textual-gradient trio (paper Algorithm 2, lines 15–17 and Table 1):
//!
//! - **PolicyEvaluation** (g_k): summarizes, per (state, optimization),
//!   the discrepancy between the Knowledge Base's expected gain and the
//!   measured gain over the replay buffer.
//! - **PerfGapAnalysis** (p_k): reasons about *why* measurements diverged
//!   from expectations — attributing gaps to occupancy collapse, launch
//!   overhead, verification failures, architecture mismatch — and emits
//!   a natural-language note plus a trust-adjusted gain.
//! - **ParameterUpdate** (θ_{k+1}): writes the adjusted scores and notes
//!   back into the Knowledge Base.
//!
//! The trio is the in-context analog of a policy-gradient step: dense
//! semantic feedback in place of numeric gradients.

use super::{tokens, TokenMeter};
use crate::gpu::Bottleneck;
use crate::kb::{KnowledgeBase, StateSig};
use crate::opts::Technique;

/// One replay-buffer sample: what happened when `technique` was applied
/// in `state`.
#[derive(Debug, Clone)]
pub struct Sample {
    pub state: StateSig,
    pub technique: Technique,
    /// KB expectation at selection time.
    pub expected_gain: f64,
    /// Measured speedup of this step (1.0 = no change; <1 = regression).
    /// Failed validation is recorded as 0 gain with `valid = false`.
    pub measured_gain: f64,
    pub valid: bool,
    /// Occupancy/parallelism observed after the step (for attribution).
    pub occupancy: f64,
    pub utilization: f64,
    /// Bottleneck after the step.
    pub new_primary: Bottleneck,
}

/// PolicyEvaluation output: the aggregated discrepancy record g_k.
#[derive(Debug, Clone)]
pub struct Discrepancy {
    pub state: StateSig,
    pub technique: Technique,
    pub expected: f64,
    pub measured_mean: f64,
    pub n: usize,
    pub n_invalid: usize,
    pub mean_occupancy: f64,
    pub mean_utilization: f64,
    pub summary: String,
}

/// PolicyEvaluation: group samples by (state, technique) and summarize
/// expectation-vs-measurement in natural language.
pub fn policy_evaluation(samples: &[Sample], meter: &mut TokenMeter) -> Vec<Discrepancy> {
    let mut groups: Vec<((StateSig, Technique), Vec<&Sample>)> = Vec::new();
    for s in samples {
        let key = (s.state, s.technique);
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => v.push(s),
            None => groups.push((key, vec![s])),
        }
    }
    let mut out = Vec::new();
    for ((state, technique), group) in groups {
        let n = group.len();
        let n_invalid = group.iter().filter(|s| !s.valid).count();
        let valid: Vec<&&Sample> = group.iter().filter(|s| s.valid).collect();
        let measured_mean = if valid.is_empty() {
            0.0
        } else {
            valid.iter().map(|s| s.measured_gain).sum::<f64>() / valid.len() as f64
        };
        let expected = group[0].expected_gain;
        let mean_occupancy =
            group.iter().map(|s| s.occupancy).sum::<f64>() / n as f64;
        let mean_utilization =
            group.iter().map(|s| s.utilization).sum::<f64>() / n as f64;
        let summary = format!(
            "{} in state {}: expected {:.2}x, measured {:.2}x over {} attempts ({} invalid)",
            technique.name(),
            state.id(),
            expected,
            measured_mean,
            n,
            n_invalid
        );
        meter.add(40 * n, tokens::text_tokens(&summary) + 20);
        out.push(Discrepancy {
            state,
            technique,
            expected,
            measured_mean,
            n,
            n_invalid,
            mean_occupancy,
            mean_utilization,
            summary,
        });
    }
    out
}

/// PerfGapAnalysis output: the per-entry update instruction p_k.
#[derive(Debug, Clone)]
pub struct GapInsight {
    pub state: StateSig,
    pub technique: Technique,
    /// The gain value ParameterUpdate should integrate.
    pub adjusted_gain: f64,
    /// The natural-language gradient note.
    pub note: String,
}

/// PerfGapAnalysis: attribute each discrepancy and produce the adjusted
/// gain + note. The attribution rules are the reasoning an LLM performs
/// over the profile deltas.
pub fn perf_gap_analysis(discrepancies: &[Discrepancy], meter: &mut TokenMeter) -> Vec<GapInsight> {
    let mut out = Vec::new();
    for d in discrepancies {
        let reliability = 1.0 - d.n_invalid as f64 / d.n.max(1) as f64;
        let mut note;
        let adjusted_gain;
        if d.n_invalid == d.n {
            // Nothing valid came out of this technique here.
            adjusted_gain = 0.5; // strong negative signal, but not zero —
                                 // lowering may succeed next time.
            note = format!(
                "{}: every attempt failed validation in {} — lowering is error-prone here",
                d.technique.name(),
                d.state.id()
            );
        } else if d.measured_mean < d.expected * 0.6 {
            adjusted_gain = d.measured_mean;
            note = format!(
                "overestimated ({:.2}x expected vs {:.2}x measured)",
                d.expected, d.measured_mean
            );
            if d.mean_occupancy < 0.25 {
                note.push_str("; occupancy collapsed — pair with register/occupancy tuning");
            } else if d.mean_utilization < 0.25 {
                note.push_str("; device underfilled — grid too small after transform");
            } else if d.measured_mean < 1.0 {
                note.push_str("; regression: bottleneck did not match this technique");
            }
        } else if d.measured_mean > d.expected * 1.4 {
            adjusted_gain = d.measured_mean;
            note = format!(
                "underestimated: {:.2}x measured vs {:.2}x expected — prioritize in this state",
                d.measured_mean, d.expected
            );
        } else {
            adjusted_gain = d.measured_mean;
            note = String::new(); // expectation held; no note needed
        }
        // Blend in validation reliability: frequent invalid attempts
        // discount the integrated gain.
        let adjusted_gain = adjusted_gain * reliability + 0.5 * (1.0 - reliability);
        meter.add(tokens::text_tokens(&d.summary) + 60, tokens::text_tokens(&note) + 30);
        out.push(GapInsight {
            state: d.state,
            technique: d.technique,
            adjusted_gain,
            note,
        });
    }
    out
}

/// ParameterUpdate: integrate the insights into the Knowledge Base
/// (θ_{k+1} ← ParameterUpdate(θ_k, p_k)).
///
/// Transferred priors are cited distinctly from native evidence: when the
/// entry being updated is an untested prior carried over from another
/// architecture ([`crate::kb::OptEntry::origin`], set by
/// [`crate::kb::lifecycle::transfer`]), the integrated note names its
/// source arch — even when the gap analysis itself had nothing to say —
/// so the KB records which cross-arch hints were confirmed or revised by
/// this generation's measurements.
pub fn parameter_update(kb: &mut KnowledgeBase, insights: &[GapInsight], meter: &mut TokenMeter) {
    for ins in insights {
        let state_idx = match kb.find_state(ins.state) {
            Some(i) => i,
            None => kb.match_state(ins.state).index(),
        };
        let prior_from = kb.states[state_idx].opt_index(ins.technique).and_then(|i| {
            let o = &kb.states[state_idx].opts[i];
            if o.attempts == 0 {
                o.origin.clone()
            } else {
                None
            }
        });
        let note = match (&prior_from, ins.note.is_empty()) {
            (Some(src), true) => Some(format!(
                "prior from {src}: measured {:.2}x on this arch",
                ins.adjusted_gain
            )),
            (Some(src), false) => Some(format!("prior from {src}: {}", ins.note)),
            (None, true) => None,
            (None, false) => Some(ins.note.clone()),
        };
        meter.add(60, 30);
        kb.update_score(state_idx, ins.technique, ins.adjusted_gain, note);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kb::WorkloadClass;

    fn sig() -> StateSig {
        StateSig {
            primary: Bottleneck::MemoryLatency,
            secondary: Bottleneck::ComputeThroughput,
            workload: WorkloadClass::ContractionHeavy,
        }
    }

    fn sample(gain: f64, valid: bool) -> Sample {
        Sample {
            state: sig(),
            technique: Technique::SharedMemoryTiling,
            expected_gain: 2.2,
            measured_gain: gain,
            valid,
            occupancy: 0.5,
            utilization: 0.9,
            new_primary: Bottleneck::ComputeThroughput,
        }
    }

    #[test]
    fn policy_evaluation_groups_and_averages() {
        let samples = vec![sample(2.0, true), sample(3.0, true), sample(0.0, false)];
        let mut meter = TokenMeter::new();
        let g = policy_evaluation(&samples, &mut meter);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].n, 3);
        assert_eq!(g[0].n_invalid, 1);
        assert!((g[0].measured_mean - 2.5).abs() < 1e-12);
        assert!(g[0].summary.contains("shared_memory_tiling"));
        assert!(meter.total() > 0);
    }

    #[test]
    fn gap_analysis_flags_overestimates_with_occupancy_cause() {
        let mut meter = TokenMeter::new();
        let d = Discrepancy {
            state: sig(),
            technique: Technique::SharedMemoryTiling,
            expected: 2.2,
            measured_mean: 0.8,
            n: 3,
            n_invalid: 0,
            mean_occupancy: 0.1,
            mean_utilization: 0.9,
            summary: "s".into(),
        };
        let p = perf_gap_analysis(&[d], &mut meter);
        assert!((p[0].adjusted_gain - 0.8).abs() < 1e-9);
        assert!(p[0].note.contains("occupancy collapsed"), "{}", p[0].note);
    }

    #[test]
    fn gap_analysis_flags_underestimates() {
        let mut meter = TokenMeter::new();
        let d = Discrepancy {
            state: sig(),
            technique: Technique::AlgebraicSimplification,
            expected: 1.6,
            measured_mean: 12.0,
            n: 1,
            n_invalid: 0,
            mean_occupancy: 0.6,
            mean_utilization: 0.9,
            summary: "s".into(),
        };
        let p = perf_gap_analysis(&[d], &mut meter);
        assert!(p[0].note.contains("underestimated"));
        assert!((p[0].adjusted_gain - 12.0).abs() < 1e-9);
    }

    #[test]
    fn all_invalid_yields_strong_negative() {
        let mut meter = TokenMeter::new();
        let samples = vec![sample(0.0, false), sample(0.0, false)];
        let g = policy_evaluation(&samples, &mut meter);
        let p = perf_gap_analysis(&g, &mut meter);
        assert!(p[0].adjusted_gain <= 0.5 + 1e-9);
        assert!(p[0].note.contains("error-prone"));
    }

    #[test]
    fn full_gradient_step_moves_kb() {
        let mut kb = KnowledgeBase::empty();
        let m = kb.match_state(sig());
        kb.ensure_candidates(m.index(), &[Technique::SharedMemoryTiling]);
        let before = kb.states[0].opts[0].expected_gain;
        let samples = vec![sample(0.7, true), sample(0.9, true)];
        let mut meter = TokenMeter::new();
        let g = policy_evaluation(&samples, &mut meter);
        let p = perf_gap_analysis(&g, &mut meter);
        parameter_update(&mut kb, &p, &mut meter);
        let after = kb.states[0].opts[0].expected_gain;
        assert!(after < before, "KB must move toward measurement");
        assert_eq!(kb.updates, 1);
        assert!(!kb.states[0].opts[0].notes.is_empty());
    }

    #[test]
    fn parameter_update_cites_transferred_priors() {
        let mut kb = KnowledgeBase::empty();
        let m = kb.match_state(sig());
        kb.ensure_candidates(m.index(), &[Technique::SharedMemoryTiling]);
        kb.states[0].opts[0].origin = Some("A6000".into());
        let mut meter = TokenMeter::new();
        // First native measurement against the prior: cited by source,
        // even though the gap analysis produced no note of its own.
        parameter_update(
            &mut kb,
            &[GapInsight {
                state: sig(),
                technique: Technique::SharedMemoryTiling,
                adjusted_gain: 2.1,
                note: String::new(),
            }],
            &mut meter,
        );
        let o = &kb.states[0].opts[0];
        assert_eq!(o.attempts, 1);
        assert!(
            o.notes.last().unwrap().starts_with("prior from A6000:"),
            "{:?}",
            o.notes
        );
        // Once native evidence exists, notes revert to plain form.
        parameter_update(
            &mut kb,
            &[GapInsight {
                state: sig(),
                technique: Technique::SharedMemoryTiling,
                adjusted_gain: 2.0,
                note: "held".into(),
            }],
            &mut meter,
        );
        assert_eq!(kb.states[0].opts[0].notes.last().unwrap(), "held");
    }

    #[test]
    fn parameter_update_discovers_missing_state() {
        let mut kb = KnowledgeBase::empty();
        let insight = GapInsight {
            state: sig(),
            technique: Technique::FastMath,
            adjusted_gain: 1.4,
            note: "works".into(),
        };
        let mut meter = TokenMeter::new();
        parameter_update(&mut kb, &[insight], &mut meter);
        assert_eq!(kb.states.len(), 1);
        assert_eq!(kb.states[0].opts[0].technique, Technique::FastMath);
    }
}
