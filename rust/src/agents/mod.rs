//! The agent roles of the KERNELBLASTER workflow (paper Fig. 6):
//! state extractor, optimization selector, lowering agent, soft verifier
//! (lives in [`crate::harness`]), and the textual-gradient trio
//! (PolicyEvaluation → PerfGapAnalysis → ParameterUpdate).
//!
//! The paper drives these roles with GPT-4.1/GPT-5.0; this reproduction
//! drives them with a *simulated LLM*: seeded-stochastic, boundedly
//! rational (it misreads profiles at a configurable rate, introduces
//! lowering bugs, occasionally attempts the reward hacks §4.4 guards
//! against), and fully token-metered. The ICRL learning dynamics the
//! evaluation measures are independent of who fills the roles; the trait
//! boundary here is where a real LLM backend would plug in.
//!
//! Position in the MAIC-RL loop (profile → **state-extract** → KB-match →
//! **lower** → verify): [`state_extractor`] reads [`crate::gpu`] profiles
//! into the [`crate::kb::StateSig`] the KB matches on; [`lowering`]
//! applies the selected [`crate::opts`] technique (retrying on
//! [`crate::harness`] feedback); and [`textgrad`] writes measured rewards
//! back into the KB — citing cross-arch transferred priors
//! ([`crate::kb::lifecycle`]) distinctly from native evidence. The
//! driver ([`crate::icrl`]) orchestrates all of them.

pub mod lowering;
pub mod state_extractor;
pub mod textgrad;
pub mod tokens;

pub use tokens::TokenMeter;

/// Behavioural parameters of the simulated LLM agents.
#[derive(Debug, Clone)]
pub struct AgentConfig {
    /// Probability the state extractor misreads the profile (picks a
    /// wrong secondary bottleneck).
    pub state_misclassify_rate: f64,
    /// Probability a lowering attempt introduces a semantic bug.
    pub lowering_bug_rate: f64,
    /// Probability a lowering attempt fails to compile outright.
    pub lowering_fail_rate: f64,
    /// Probability the lowering agent attempts a shortcut the soft
    /// verifier must catch (vendor dispatch / stubbed work).
    pub reward_hack_rate: f64,
    /// Re-attempts after harness feedback ("incorrect solutions are
    /// re-attempted", §4.3).
    pub retry_limit: usize,
}

impl Default for AgentConfig {
    fn default() -> Self {
        Self {
            state_misclassify_rate: 0.05,
            lowering_bug_rate: 0.08,
            lowering_fail_rate: 0.05,
            reward_hack_rate: 0.02,
            retry_limit: 2,
        }
    }
}

impl AgentConfig {
    /// A perfectly reliable agent (used by unit tests and ablations that
    /// need determinism of outcomes, not of the policy).
    pub fn reliable() -> Self {
        Self {
            state_misclassify_rate: 0.0,
            lowering_bug_rate: 0.0,
            lowering_fail_rate: 0.0,
            reward_hack_rate: 0.0,
            retry_limit: 2,
        }
    }
}
