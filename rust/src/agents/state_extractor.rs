//! The LLM-powered State Extractor (paper §3): derives a performance
//! signature from runtime profiling information.
//!
//! Consumes the NCU-like report's per-kernel details and the kernel
//! graph, and produces the [`StateSig`] used to key the Knowledge Base.
//! The simulated agent is boundedly rational: with probability
//! `state_misclassify_rate` it misreads the secondary bottleneck, which
//! is exactly the kind of error the textual-gradient loop later detects
//! as an expectation/measurement discrepancy.

use super::{tokens, AgentConfig, TokenMeter};
use crate::gpu::{Bottleneck, NcuReport};
use crate::kb::{StateSig, WorkloadClass};
use crate::kir::KernelGraph;
use crate::util::rng::Rng;

/// Extract the performance state from a profile.
pub fn extract(
    report: &NcuReport,
    graph: &KernelGraph,
    cfg: &AgentConfig,
    meter: &mut TokenMeter,
    rng: &mut Rng,
) -> StateSig {
    // Token cost: the agent reads a condensed profile digest (the state
    // matcher consumes the per-kernel bottleneck lines, not the raw dump);
    // writes a short classification.
    let details = report.render_details();
    meter.add(tokens::text_tokens(&details) / 2 + 120, 40);

    // Time-weighted dominant kernel decides primary; its secondary is the
    // report's secondary.
    let dominant = report
        .kernels
        .iter()
        .max_by(|a, b| a.time_us.total_cmp(&b.time_us));
    let (mut primary, mut secondary) = match dominant {
        Some(k) => (k.primary, k.secondary),
        None => (Bottleneck::LaunchOverhead, Bottleneck::LaunchOverhead),
    };
    // Bounded rationality: occasionally misread.
    if rng.chance(cfg.state_misclassify_rate) {
        let all = Bottleneck::all();
        secondary = all[rng.index(all.len())];
        if rng.chance(0.3) {
            std::mem::swap(&mut primary, &mut secondary);
        }
    }
    StateSig {
        primary,
        secondary,
        workload: WorkloadClass::of_graph(graph),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{profiler, GpuArch};
    use crate::kir::schedule::Schedule;
    use crate::opts::Candidate;
    use crate::tasks::Suite;

    fn report_for(id: &str) -> (NcuReport, KernelGraph) {
        let task = Suite::full().by_id(id).unwrap().clone();
        let cand = Candidate::naive(&task);
        let mut rng = Rng::new(3);
        let rep = profiler::profile(
            &GpuArch::a100(),
            &cand.full,
            &Schedule::naive(&cand.full),
            0.0,
            &mut rng,
        );
        (rep, task.graph.clone())
    }

    #[test]
    fn reliable_agent_reads_dominant_kernel() {
        let (rep, graph) = report_for("L2/01_gemm_bias_relu");
        let mut meter = TokenMeter::new();
        let mut rng = Rng::new(1);
        let sig = extract(&rep, &graph, &AgentConfig::reliable(), &mut meter, &mut rng);
        // GEMM dominates; naive layout → memory_latency primary.
        assert_eq!(sig.primary, Bottleneck::MemoryLatency);
        assert_eq!(sig.workload, WorkloadClass::ContractionHeavy);
        assert!(meter.total() > 100, "profile reading must cost tokens");
    }

    #[test]
    fn extraction_deterministic_given_seed() {
        let (rep, graph) = report_for("L1/12_softmax");
        let cfg = AgentConfig::default();
        let mut m1 = TokenMeter::new();
        let mut m2 = TokenMeter::new();
        let s1 = extract(&rep, &graph, &cfg, &mut m1, &mut Rng::new(9));
        let s2 = extract(&rep, &graph, &cfg, &mut m2, &mut Rng::new(9));
        assert_eq!(s1, s2);
        assert_eq!(m1, m2);
    }

    #[test]
    fn misclassification_rate_manifests() {
        let (rep, graph) = report_for("L2/01_gemm_bias_relu");
        let cfg = AgentConfig {
            state_misclassify_rate: 1.0,
            ..AgentConfig::reliable()
        };
        let reliable_sig = {
            let mut m = TokenMeter::new();
            extract(&rep, &graph, &AgentConfig::reliable(), &mut m, &mut Rng::new(5))
        };
        // With forced misclassification, many draws must differ.
        let mut differs = 0;
        for seed in 0..40 {
            let mut m = TokenMeter::new();
            let s = extract(&rep, &graph, &cfg, &mut m, &mut Rng::new(seed));
            if s != reliable_sig {
                differs += 1;
            }
        }
        assert!(differs > 25, "only {differs}/40 differed");
    }

    #[test]
    fn empty_report_degrades_gracefully() {
        let (mut rep, graph) = report_for("L1/15_relu");
        rep.kernels.clear();
        let mut m = TokenMeter::new();
        let sig = extract(&rep, &graph, &AgentConfig::reliable(), &mut m, &mut Rng::new(1));
        assert_eq!(sig.primary, Bottleneck::LaunchOverhead);
    }
}
