//! Token accounting.
//!
//! System cost in the paper (§4.2, §4.10, §6.4) is "the total number of
//! tokens consumed to optimize the kernel". Every agent call books its
//! prompt and completion tokens here; Fig. 10 (speedup vs tokens) and the
//! §6.4 minimal-agent comparison (2.4× tokens, 0.379× perf/token) are
//! computed from these meters.

/// Cumulative token meter for one optimization run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TokenMeter {
    pub prompt: usize,
    pub completion: usize,
    /// Number of agent invocations.
    pub calls: usize,
}

impl TokenMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Book one agent call.
    pub fn add(&mut self, prompt: usize, completion: usize) {
        self.prompt += prompt;
        self.completion += completion;
        self.calls += 1;
    }

    pub fn total(&self) -> usize {
        self.prompt + self.completion
    }

    pub fn merge(&mut self, other: &TokenMeter) {
        self.prompt += other.prompt;
        self.completion += other.completion;
        self.calls += other.calls;
    }
}

/// Token cost of a text blob (≈1 token / 4 chars — same model as
/// [`crate::kir::render::token_count`]).
pub fn text_tokens(text: &str) -> usize {
    text.len().div_ceil(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates() {
        let mut m = TokenMeter::new();
        m.add(100, 20);
        m.add(50, 10);
        assert_eq!(m.prompt, 150);
        assert_eq!(m.completion, 30);
        assert_eq!(m.total(), 180);
        assert_eq!(m.calls, 2);
    }

    #[test]
    fn merge_combines() {
        let mut a = TokenMeter::new();
        a.add(10, 5);
        let mut b = TokenMeter::new();
        b.add(7, 3);
        a.merge(&b);
        assert_eq!(a.total(), 25);
        assert_eq!(a.calls, 2);
    }

    #[test]
    fn text_token_rule() {
        assert_eq!(text_tokens(""), 0);
        assert_eq!(text_tokens("abcd"), 1);
        assert_eq!(text_tokens("abcdefgh!"), 3);
    }
}
