//! The Lowering Agent (paper §3): implements a selected optimization on
//! the current kernel and hands it to the harness for validation.
//!
//! The simulated agent wraps [`crate::opts::apply`] with the failure
//! modes an LLM writing CUDA exhibits:
//! - **compile failures** (syntax/launch errors) at `lowering_fail_rate`;
//! - **semantic bugs** (dropped epilogues, zeroed accumulators) at
//!   `lowering_bug_rate` — these *pass* structural validation and must be
//!   caught by the harness's randomized numeric checks;
//! - **reward hacks** (dispatching to cuBLAS, stubbing work) at
//!   `reward_hack_rate` — numerically correct or plausibly fast, caught
//!   only by the soft verifier.
//!
//! On harness rejection the driver re-prompts with the feedback
//! ("incorrect solutions are re-attempted", §4.3); retries sharpen the
//! agent, halving its error rates per attempt.

use super::{tokens, AgentConfig, TokenMeter};
use crate::kir::{render, OpKind, ValueRef};
use crate::opts::{apply, Candidate, Technique};
use crate::util::rng::Rng;

/// What the lowering attempt produced.
#[derive(Debug, Clone)]
pub enum Lowered {
    /// Clean application.
    Ok(Candidate),
    /// Looks fine, compiles, is wrong (numeric check will catch).
    SemanticBug(Candidate),
    /// A shortcut (soft verifier's job).
    RewardHack(Candidate),
    /// Did not compile.
    CompileFail(String),
}

impl Lowered {
    pub fn candidate(&self) -> Option<&Candidate> {
        match self {
            Lowered::Ok(c) | Lowered::SemanticBug(c) | Lowered::RewardHack(c) => Some(c),
            Lowered::CompileFail(_) => None,
        }
    }

    /// Consume the attempt, taking ownership of the produced candidate —
    /// the driver's hot path (§Perf: avoids one full graph-pair clone per
    /// lowering attempt).
    pub fn into_candidate(self) -> Option<Candidate> {
        match self {
            Lowered::Ok(c) | Lowered::SemanticBug(c) | Lowered::RewardHack(c) => Some(c),
            Lowered::CompileFail(_) => None,
        }
    }
}

/// One lowering attempt. `attempt` is the retry index (0 = first try);
/// error rates halve with each retry (feedback helps).
pub fn lower(
    tech: Technique,
    cand: &Candidate,
    group: usize,
    cfg: &AgentConfig,
    attempt: usize,
    meter: &mut TokenMeter,
    rng: &mut Rng,
) -> Lowered {
    // Token cost: the hierarchical KB keeps context tight — the lowering
    // agent is prompted with the *targeted kernel* (one fusion group) plus
    // technique context, not the whole source file (the paper's "scalable
    // representation that efficiently utilizes model context"), and
    // writes a patch proportional to schedule complexity.
    let source = render::render(&cand.full, &cand.schedule);
    let group_share = tokens::text_tokens(&source) / cand.schedule.n_launches().max(1);
    let prompt = group_share + 200;
    let completion = 60 + cand.schedule.complexity();
    meter.add(prompt, completion);

    let scale = 0.5f64.powi(attempt as i32);
    if rng.chance(cfg.lowering_fail_rate * scale) {
        return Lowered::CompileFail(format!(
            "error: identifier '__{}_tile' undeclared (first use in kernel_{group})",
            tech.name()
        ));
    }
    let applied = match apply::apply(tech, cand, group) {
        Ok(c) => c,
        Err(e) => return Lowered::CompileFail(e),
    };
    if rng.chance(cfg.reward_hack_rate * scale) {
        return Lowered::RewardHack(inject_reward_hack(&applied, rng));
    }
    if rng.chance(cfg.lowering_bug_rate * scale) {
        if let Some(bugged) = inject_semantic_bug(&applied, rng) {
            return Lowered::SemanticBug(bugged);
        }
    }
    Lowered::Ok(applied)
}

/// Introduce a real semantic bug the numeric harness must catch: pick a
/// mutation that keeps shapes legal but changes values.
fn inject_semantic_bug(cand: &Candidate, rng: &mut Rng) -> Option<Candidate> {
    let mut c = cand.clone();
    // Collect mutable unary elementwise nodes (safe to corrupt without
    // breaking shape inference).
    let targets: Vec<usize> = c
        .full
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| {
            matches!(
                n.kind,
                OpKind::Relu
                    | OpKind::Exp
                    | OpKind::Tanh
                    | OpKind::Sigmoid
                    | OpKind::Gelu
                    | OpKind::Scale { .. }
                    | OpKind::AddConst { .. }
                    | OpKind::DivConst { .. }
                    | OpKind::BiasAdd { .. }
            )
        })
        .map(|(i, _)| i)
        .collect();
    let &target = targets.get(rng.index(targets.len().max(1)))?;
    let bug_kind = match &c.full.nodes[target].kind {
        // "Forgot the epilogue": activation becomes a copy.
        OpKind::Relu | OpKind::Exp | OpKind::Tanh | OpKind::Sigmoid | OpKind::Gelu => {
            OpKind::Identity
        }
        // Constant transcription error.
        OpKind::Scale { c } => OpKind::Scale { c: c * 2.0 },
        OpKind::AddConst { c } => OpKind::AddConst { c: c + 1.0 },
        OpKind::DivConst { c } => OpKind::DivConst { c: c + 1.0 },
        // Bias applied on the wrong axis — or dropped.
        OpKind::BiasAdd { .. } => OpKind::Identity,
        _ => return None,
    };
    // BiasAdd/binary → Identity must also drop the second operand.
    if matches!(bug_kind, OpKind::Identity) {
        for g in [&mut c.full, &mut c.small] {
            g.nodes[target].kind = OpKind::Identity;
            g.nodes[target].deps.truncate(1);
        }
    } else {
        c.full.nodes[target].kind = bug_kind.clone();
        c.small.nodes[target].kind = bug_kind;
    }
    Some(c)
}

/// Introduce a reward hack: either dispatch to a vendor library (fast and
/// numerically fine — illegal outside +vendor mode) or stub the final
/// node into a copy (the AI-CUDA-Engineer-style elimination).
fn inject_reward_hack(cand: &Candidate, rng: &mut Rng) -> Candidate {
    let mut c = cand.clone();
    if rng.chance(0.6) {
        // Vendor shortcut on the heaviest group.
        if let Some(g) = c
            .schedule
            .groups
            .iter_mut()
            .max_by_key(|g| g.nodes.iter().max().copied().unwrap_or(0))
        {
            g.opts.vendor_lib = true;
        }
        c
    } else {
        // Stub the output node if it has a same-shaped dependency.
        if let Some(ValueRef::Node(last)) = c.full.outputs.first().copied() {
            let node = &c.full.nodes[last];
            if let Some(dep) = node.deps.first().copied() {
                if c.full.shape_of(dep) == &node.shape {
                    for g in [&mut c.full, &mut c.small] {
                        g.nodes[last].kind = OpKind::Identity;
                        g.nodes[last].deps.truncate(1);
                    }
                }
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuArch;
    use crate::harness::{self, HarnessConfig, Outcome};
    use crate::tasks::Suite;

    fn cand(id: &str) -> (crate::tasks::Task, Candidate) {
        let t = Suite::full().by_id(id).unwrap().clone();
        let c = Candidate::naive(&t);
        (t, c)
    }

    #[test]
    fn reliable_lowering_matches_direct_apply() {
        let (_t, c) = cand("L2/01_gemm_bias_relu");
        let mut meter = TokenMeter::new();
        let mut rng = Rng::new(1);
        let out = lower(
            Technique::MemoryCoalescing,
            &c,
            0,
            &AgentConfig::reliable(),
            0,
            &mut meter,
            &mut rng,
        );
        let direct = apply::apply(Technique::MemoryCoalescing, &c, 0).unwrap();
        match out {
            Lowered::Ok(got) => {
                assert_eq!(got.schedule, direct.schedule);
                assert_eq!(got.applied, direct.applied);
            }
            other => panic!("expected Ok, got {other:?}"),
        }
        assert!(meter.total() > 100);
    }

    #[test]
    fn forced_bugs_are_caught_by_harness() {
        let (t, c) = cand("L2/01_gemm_bias_relu");
        let cfg = AgentConfig {
            lowering_bug_rate: 1.0,
            lowering_fail_rate: 0.0,
            reward_hack_rate: 0.0,
            ..AgentConfig::reliable()
        };
        let hcfg = HarnessConfig {
            noise_sigma: 0.0,
            ..Default::default()
        };
        let arch = GpuArch::h100();
        let mut caught = 0;
        let mut produced = 0;
        for seed in 0..20 {
            let mut meter = TokenMeter::new();
            let mut rng = Rng::new(seed);
            let out = lower(Technique::MemoryCoalescing, &c, 0, &cfg, 0, &mut meter, &mut rng);
            if let Lowered::SemanticBug(bugged) = out {
                produced += 1;
                let res = harness::run(&t, &bugged, &arch, &hcfg, &mut rng);
                if matches!(res, Outcome::WrongNumerics { .. } | Outcome::SoftVerifyRejected(_)) {
                    caught += 1;
                }
            }
        }
        assert!(produced >= 15, "bug injection produced {produced}/20");
        assert_eq!(caught, produced, "harness must catch every bug");
    }

    #[test]
    fn forced_reward_hacks_are_caught_by_soft_verify() {
        let (t, c) = cand("L1/01_matmul_square");
        let cfg = AgentConfig {
            reward_hack_rate: 1.0,
            lowering_bug_rate: 0.0,
            lowering_fail_rate: 0.0,
            ..AgentConfig::reliable()
        };
        let hcfg = HarnessConfig {
            noise_sigma: 0.0,
            allow_vendor: false,
            ..Default::default()
        };
        let arch = GpuArch::l40s();
        for seed in 0..10 {
            let mut meter = TokenMeter::new();
            let mut rng = Rng::new(seed);
            let out = lower(
                Technique::MemoryCoalescing,
                &c,
                0,
                &cfg,
                0,
                &mut meter,
                &mut rng,
            );
            if let Lowered::RewardHack(hacked) = out {
                let res = harness::run(&t, &hacked, &arch, &hcfg, &mut rng);
                assert!(
                    !res.is_ok(),
                    "reward hack slipped through: {}",
                    res.feedback()
                );
            }
        }
    }

    #[test]
    fn retries_reduce_failure_rate() {
        let (_t, c) = cand("L2/01_gemm_bias_relu");
        let cfg = AgentConfig {
            lowering_fail_rate: 0.6,
            lowering_bug_rate: 0.0,
            reward_hack_rate: 0.0,
            ..AgentConfig::reliable()
        };
        let count_fails = |attempt: usize| {
            let mut fails = 0;
            for seed in 0..200 {
                let mut meter = TokenMeter::new();
                let mut rng = Rng::new(seed);
                if matches!(
                    lower(Technique::MemoryCoalescing, &c, 0, &cfg, attempt, &mut meter, &mut rng),
                    Lowered::CompileFail(_)
                ) {
                    fails += 1;
                }
            }
            fails
        };
        let f0 = count_fails(0);
        let f2 = count_fails(2);
        assert!(f0 > 90, "f0={f0}");
        assert!(f2 < f0 / 2, "f0={f0} f2={f2}");
    }

    #[test]
    fn inapplicable_technique_is_compile_fail() {
        let (_t, c) = cand("L1/01_matmul_square");
        let mut meter = TokenMeter::new();
        let mut rng = Rng::new(1);
        let out = lower(
            Technique::FastMath,
            &c,
            0,
            &AgentConfig::reliable(),
            0,
            &mut meter,
            &mut rng,
        );
        assert!(matches!(out, Lowered::CompileFail(_)));
    }
}
