//! Hyperparameter sweeps: Fig. 17 (number of trajectories / search
//! breadth) and Fig. 18 (trajectory length / search depth).

use super::{Ctx, Report, Section};
use crate::gpu::GpuArch;
use crate::icrl::{self};
use crate::kb::KnowledgeBase;
use crate::tasks::Level;
use crate::util::stats;
use crate::util::table::{fnum, line_plot, Table};

fn sweep(
    ctx: &Ctx,
    values: &[usize],
    set: impl Fn(&mut crate::icrl::IcrlConfig, usize),
) -> Vec<(usize, Vec<f64>)> {
    let arch = GpuArch::h100();
    let tasks = ctx.tasks(Level::L2);
    let mut out = Vec::new();
    for &v in values {
        let mut cfg = ctx.icrl_cfg(false);
        set(&mut cfg, v);
        let mut kb = KnowledgeBase::empty();
        let runs = icrl::run_suite(&tasks, &arch, &mut kb, &cfg);
        let speedups: Vec<f64> = runs
            .iter()
            .filter(|r| r.valid)
            .map(|r| r.speedup_vs_naive())
            .collect();
        out.push((v, speedups));
    }
    out
}

fn quartile_report(
    name: &str,
    title: &str,
    axis: &str,
    data: Vec<(usize, Vec<f64>)>,
    paper_note: &str,
) -> Report {
    let mut t = Table::new(&[axis, "q1", "median", "q3", "geomean", "n"]);
    let mut xs = Vec::new();
    let mut med = Vec::new();
    let mut q1s = Vec::new();
    let mut q3s = Vec::new();
    for (v, speedups) in &data {
        let (q1, q2, q3) = stats::quartiles(speedups);
        t.add_row(vec![
            v.to_string(),
            fnum(q1, 2),
            fnum(q2, 2),
            fnum(q3, 2),
            fnum(stats::geomean(speedups), 2),
            speedups.len().to_string(),
        ]);
        xs.push(*v as f64);
        med.push(q2);
        q1s.push(q1);
        q3s.push(q3);
    }
    let plot = line_plot(
        &xs,
        &[
            ("median".to_string(), med),
            ("q1".to_string(), q1s),
            ("q3".to_string(), q3s),
        ],
        10,
        50,
    );
    Report {
        name: name.into(),
        sections: vec![Section {
            title: title.into(),
            table: t,
            plot: Some(plot),
            notes: vec![paper_note.to_string()],
        }],
    }
}

/// Fig. 17: performance vs number of trajectories (IQR band).
pub fn fig17(ctx: &Ctx) -> Report {
    // Full value grid even in quick mode (quick only subsets the tasks):
    // the figure's claim is about the trend over breadth.
    let values: Vec<usize> = vec![1, 2, 4, 8, 12, 16];
    let data = sweep(ctx, &values, |cfg, v| cfg.trajectories = v);
    quartile_report(
        "fig17",
        "Speedup vs naive CUDA across trajectory count (H100, L2)",
        "trajectories",
        data,
        "Paper: diminishing returns beyond 8 trajectories for median/top-25%; \
         low-25% kernels keep benefiting",
    )
}

/// Fig. 18: performance vs trajectory length (box stats).
pub fn fig18(ctx: &Ctx) -> Report {
    let values: Vec<usize> = vec![1, 2, 4, 6, 8, 10];
    let data = sweep(ctx, &values, |cfg, v| cfg.rollout_steps = v);
    quartile_report(
        "fig18",
        "Speedup vs naive CUDA across trajectory length (H100, L2)",
        "steps",
        data,
        "Paper: diminishing returns beyond depth 4; high-potential kernels keep \
         gaining up to 8 consecutive optimizations",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig17_median_improves_with_breadth() {
        let ctx = Ctx::new(true, 21);
        let data = sweep(&ctx, &[1, 8], |cfg, v| cfg.trajectories = v);
        let m1 = stats::median(&data[0].1);
        let m8 = stats::median(&data[1].1);
        assert!(
            m8 >= m1 * 0.95,
            "breadth should not hurt: median(1)={m1:.2} median(8)={m8:.2}"
        );
    }

    #[test]
    fn fig18_depth_improves_then_saturates() {
        let ctx = Ctx::new(true, 21);
        let data = sweep(&ctx, &[1, 6], |cfg, v| cfg.rollout_steps = v);
        let g1 = stats::geomean(&data[0].1);
        let g6 = stats::geomean(&data[1].1);
        assert!(g6 > g1, "depth must help: geomean(1)={g1:.2} geomean(6)={g6:.2}");
    }

    #[test]
    fn reports_render() {
        let ctx = Ctx::new(true, 21);
        let r = fig17(&ctx);
        assert!(r.render().contains("trajectories"));
    }
}
