//! Serve scenario: replay synthetic **multi-tenant** arrival traces
//! against the daemon's request handler ([`crate::serve::ServeCore`],
//! driven directly — no TCP) and measure serving behavior under three
//! arrival shapes:
//!
//! 1. **uniform** — steady inter-arrival gaps (the provisioning
//!    baseline);
//! 2. **bursty** — tight request bursts separated by idle gaps (CI
//!    fan-out traffic);
//! 3. **heavy_tailed** — Pareto inter-arrivals (traffic where a few
//!    clients dominate).
//!
//! Two tenants share each daemon: `alpha` (weight 3, Level-1 tasks) and
//! `beta` (weight 1, Level-2 tasks) — mixed task levels through one
//! core, each tenant on its own namespaced `LogStore` under one store
//! root. Each trace enqueues both tenants' whole backlogs in merged
//! arrival order and then drains through the core's weighted-fair
//! scheduler ([`ServeCore::admit_next`]), so the admission order
//! genuinely exercises cross-tenant contention — not the queue-of-one
//! FIFO the TCP path sees.
//!
//! Queue dynamics are *simulated deterministically*: the reply's
//! `steps` count is the request's service time in ticks, and the shared
//! FIFO earliest-available-worker queue ([`super::simqueue`]) over the
//! admission-ordered arrival ticks yields per-tenant wait/sojourn
//! percentiles that are a pure function of the seed. Wall-clock enters
//! only as tasks/min (host-dependent; the tick metrics are not).
//!
//! Two cross-tenant verdicts ride along per trace:
//!
//! - **fairness ratio** — each tenant's `admitted / weight` share over
//!   the *contended* admissions (both tenants backlogged), min over
//!   max; 1.0 = perfectly weighted-fair. Computed over **admitted**
//!   counts, never arrivals — arrivals are the workload, admission is
//!   the scheduler's doing.
//! - **isolation verdict** — tenant alpha's requests replayed through a
//!   solo daemon must produce a KB byte-identical to alpha's KB from
//!   the mixed run (`isolation_ok`). The deep bit-level version (store
//!   bytes, worker/shard grid) is pinned in `tests/serve.rs`; the
//!   benchmark re-asserts it on every artifact so a regression shows up
//!   in CI even without the test suite.
//!
//! Reported as a [`Report`] plus machine-readable `BENCH_serve.json`
//! (format `kernelblaster-bench-serve-v2`, per-tenant rows under each
//! trace) — CI runs it at `--quick` scale, uploads the JSON as an
//! artifact, and `scripts/serve_trend.py` gates per-tenant tasks/min
//! against the previous artifact.
//!
//! [`ServeCore::admit_next`]: crate::serve::ServeCore::admit_next

use super::simqueue::{simulate_queue, trace_arrivals};
use super::{Ctx, Report, Section};
use crate::gpu::GpuArch;
use crate::icrl::{FleetConfig, IcrlConfig};
use crate::kb::persist;
use crate::kb::KnowledgeBase;
use crate::serve::ServeCore;
use crate::tasks::{Level, Task};
use crate::util::json::{Json, JsonObj};
use crate::util::stats::{self, percentile_nearest_rank};
use crate::util::table::{fnum, Table};
use std::path::Path;
use std::time::Instant;

/// The three arrival shapes, in report order.
const TRACES: &[&str] = &["uniform", "bursty", "heavy_tailed"];

/// The tenant mix every trace serves: (name, quota weight, task level).
const TENANTS: &[(&str, u64, Level)] = &[("alpha", 3, Level::L1), ("beta", 1, Level::L2)];

/// Snapshot cadence for the per-tenant stores — low enough that even
/// the quick trace exercises at least one journal compaction.
const SNAPSHOT_EVERY: u64 = 4;

/// One tenant's workload in a trace.
struct TenantSpec<'a> {
    name: &'static str,
    weight: u64,
    level: Level,
    tasks: Vec<&'a Task>,
    /// Requests this tenant sends over the trace.
    n: usize,
}

/// One tenant's measured slice of a trace.
struct TenantRun {
    name: &'static str,
    weight: u64,
    arrivals: usize,
    admitted: u64,
    valid: usize,
    geomean: f64,
    commits: u64,
    kb_states: usize,
    wait_p50: f64,
    wait_p95: f64,
    sojourn_p50: f64,
    sojourn_p95: f64,
}

impl TenantRun {
    fn to_json(&self, wall_s: f64) -> Json {
        let mut o = JsonObj::new();
        o.set("tenant", self.name);
        o.set("weight", self.weight);
        o.set("arrivals", self.arrivals);
        o.set("admitted", self.admitted);
        o.set("tasks_per_min", self.arrivals as f64 / (wall_s / 60.0).max(1e-9));
        o.set("valid", self.valid);
        o.set("geomean_vs_naive", self.geomean);
        o.set("commits", self.commits);
        o.set("kb_states", self.kb_states);
        o.set("queue_wait_p50_ticks", self.wait_p50);
        o.set("queue_wait_p95_ticks", self.wait_p95);
        o.set("sojourn_p50_ticks", self.sojourn_p50);
        o.set("sojourn_p95_ticks", self.sojourn_p95);
        Json::Obj(o)
    }
}

/// One trace's measurement across both tenants.
struct TraceRun {
    name: &'static str,
    arrivals: usize,
    wall_s: f64,
    valid: usize,
    geomean: f64,
    commits: u64,
    compactions: u64,
    journal_records: u64,
    span_ticks: u64,
    wait_p50: f64,
    wait_p95: f64,
    sojourn_p50: f64,
    sojourn_p95: f64,
    fairness_ratio: f64,
    isolation_ok: bool,
    tenants: Vec<TenantRun>,
}

impl TraceRun {
    fn tasks_per_min(&self) -> f64 {
        self.arrivals as f64 / (self.wall_s / 60.0).max(1e-9)
    }

    fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.set("name", self.name);
        o.set("arrivals", self.arrivals);
        o.set("wall_s", self.wall_s);
        o.set("tasks_per_min", self.tasks_per_min());
        o.set("valid", self.valid);
        o.set("geomean_vs_naive", self.geomean);
        o.set("commits", self.commits);
        o.set("compactions", self.compactions);
        o.set("journal_records", self.journal_records);
        o.set("span_ticks", self.span_ticks);
        o.set("queue_wait_p50_ticks", self.wait_p50);
        o.set("queue_wait_p95_ticks", self.wait_p95);
        o.set("sojourn_p50_ticks", self.sojourn_p50);
        o.set("sojourn_p95_ticks", self.sojourn_p95);
        o.set("fairness_ratio", self.fairness_ratio);
        o.set("isolation_ok", self.isolation_ok);
        o.set(
            "per_tenant",
            Json::Arr(self.tenants.iter().map(|t| t.to_json(self.wall_s)).collect()),
        );
        Json::Obj(o)
    }
}

/// Weighted fairness over admitted counts: each tenant's
/// `admitted / weight` share, min over max. 1.0 = perfectly
/// weighted-fair; NaN when nothing was admitted (no contention to
/// judge). Input pairs are (admitted, weight) — the caller feeds
/// *admitted* counts from the contended window, never arrival counts.
fn fairness_ratio(admitted_weighted: &[(u64, u64)]) -> f64 {
    let shares: Vec<f64> = admitted_weighted
        .iter()
        .map(|(a, w)| *a as f64 / (*w).max(1) as f64)
        .collect();
    if shares.is_empty() {
        return f64::NAN;
    }
    let hi = shares.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let lo = shares.iter().copied().fold(f64::INFINITY, f64::min);
    if hi <= 0.0 {
        return f64::NAN;
    }
    lo / hi
}

/// The optimize request line for one tenant's `k`-th request.
fn request_line(t: &TenantSpec<'_>, k: usize) -> String {
    let mut req = JsonObj::new();
    req.set("op", "optimize");
    req.set("tenant", t.name);
    req.set("task", t.tasks[k % t.tasks.len()].id.as_str());
    Json::Obj(req).to_string_compact()
}

/// Replay one trace against a fresh store-root-backed multi-tenant
/// core, then replay tenant 0's requests solo for the isolation
/// verdict.
fn run_trace(
    shape: &'static str,
    tenants: &[TenantSpec<'_>],
    arch: &GpuArch,
    cfg: &IcrlConfig,
    fleet_cfg: &FleetConfig,
    seed: u64,
) -> TraceRun {
    let root = std::env::temp_dir().join(format!("kb_serve_exp_{shape}_{seed}"));
    std::fs::remove_dir_all(&root).ok();
    let mut core = ServeCore::new(arch.clone(), cfg.clone(), fleet_cfg.clone(), KnowledgeBase::empty());
    core.store_dir = Some(root.clone());
    core.tenant_snapshot_every = SNAPSHOT_EVERY;
    for t in tenants {
        core.quotas.insert(t.name.to_string(), t.weight);
    }

    // Per-tenant arrival traces, merged into one global arrival order
    // (tick, tenant, per-tenant index — a total order, so the enqueue
    // sequence is a pure function of the seed).
    let arr_by: Vec<Vec<u64>> = tenants
        .iter()
        .enumerate()
        .map(|(ti, t)| trace_arrivals(shape, t.n, seed.wrapping_add(ti as u64)))
        .collect();
    let mut events: Vec<(u64, usize, usize)> = Vec::new();
    for (ti, arr) in arr_by.iter().enumerate() {
        for (k, tick) in arr.iter().enumerate() {
            events.push((*tick, ti, k));
        }
    }
    events.sort_unstable();
    for &(_tick, ti, k) in &events {
        core.enqueue(&request_line(&tenants[ti], k));
    }

    // Drain the backlog through the weighted-fair scheduler, recording
    // the admission order, each admitted request's arrival tick and
    // service time (the reply's step count), and which admissions were
    // contended (both tenants still backlogged when picked).
    let wall = Instant::now();
    let mut admitted_seq: Vec<usize> = Vec::new();
    let mut arrivals_admitted: Vec<u64> = Vec::new();
    let mut service: Vec<u64> = Vec::new();
    let mut cursor = vec![0usize; tenants.len()];
    let mut admitted = vec![0u64; tenants.len()];
    let mut contended_admitted = vec![0u64; tenants.len()];
    let mut speedups_by: Vec<Vec<f64>> = tenants.iter().map(|_| Vec::new()).collect();
    while let Some((tenant, reply)) = core.admit_next() {
        let ti = tenants
            .iter()
            .position(|t| t.name == tenant)
            .expect("admitted tenant is in the spec");
        let contended = tenants
            .iter()
            .zip(&admitted)
            .filter(|(t, a)| **a < t.n as u64)
            .count()
            >= 2;
        let j = Json::parse(&reply.lines[0]).expect("reply is JSON");
        let ok = j.get("ok").and_then(Json::as_bool).unwrap_or(false);
        service.push(j.get("steps").and_then(Json::as_usize).unwrap_or(1).max(1) as u64);
        arrivals_admitted.push(arr_by[ti][cursor[ti]]);
        cursor[ti] += 1;
        if ok && j.get("valid").and_then(Json::as_bool) == Some(true) {
            if let Some(s) = j.get("speedup_vs_naive").and_then(Json::as_f64) {
                speedups_by[ti].push(s);
            }
        }
        if contended {
            contended_admitted[ti] += 1;
        }
        admitted[ti] += 1;
        admitted_seq.push(ti);
    }
    let wall_s = wall.elapsed().as_secs_f64();

    let fairness = fairness_ratio(
        &contended_admitted
            .iter()
            .zip(tenants)
            .map(|(a, t)| (*a, t.weight))
            .collect::<Vec<_>>(),
    );

    // Deterministic queue simulation over the admission order.
    let (waits, sojourns, span) = simulate_queue(&arrivals_admitted, &service, fleet_cfg.workers);
    let split = |xs: &[u64], ti: usize| -> Vec<u64> {
        xs.iter()
            .zip(&admitted_seq)
            .filter(|(_, t)| **t == ti)
            .map(|(x, _)| *x)
            .collect()
    };

    // Per-tenant lane counters from the daemon's own stats op.
    let mut commits_by = vec![0u64; tenants.len()];
    let mut kb_states_by = vec![0usize; tenants.len()];
    let mut store_commits = 0u64;
    let mut compactions = 0u64;
    let mut journal_records = 0u64;
    for (ti, t) in tenants.iter().enumerate() {
        let r = core.handle_line(&format!(r#"{{"op":"stats","tenant":"{}"}}"#, t.name));
        let j = Json::parse(&r.lines[0]).expect("stats reply is JSON");
        commits_by[ti] = j.get("commits").and_then(Json::as_usize).unwrap_or(0) as u64;
        kb_states_by[ti] = j.get("kb_states").and_then(Json::as_usize).unwrap_or(0);
        store_commits += j.get("store_commits").and_then(Json::as_usize).unwrap_or(0) as u64;
        compactions += j.get("store_compactions").and_then(Json::as_usize).unwrap_or(0) as u64;
        journal_records +=
            j.get("store_journal_records").and_then(Json::as_usize).unwrap_or(0) as u64;
    }
    debug_assert_eq!(store_commits, commits_by.iter().sum::<u64>());

    // Isolation verdict: tenant 0's requests through a solo daemon must
    // grow a byte-identical KB (same seeds — per-tenant served counters
    // — same FIFO order within the tenant).
    let solo_root = std::env::temp_dir().join(format!("kb_serve_exp_{shape}_{seed}_solo"));
    std::fs::remove_dir_all(&solo_root).ok();
    let mut solo = ServeCore::new(arch.clone(), cfg.clone(), fleet_cfg.clone(), KnowledgeBase::empty());
    solo.store_dir = Some(solo_root.clone());
    solo.tenant_snapshot_every = SNAPSHOT_EVERY;
    let t0 = &tenants[0];
    for k in 0..t0.n {
        let _ = solo.handle_line(&request_line(t0, k));
    }
    let mixed_bytes = persist::to_json(core.tenant_kb(t0.name).expect("tenant 0 served"))
        .to_string_pretty();
    let solo_bytes = persist::to_json(solo.tenant_kb(t0.name).expect("solo tenant 0 served"))
        .to_string_pretty();
    let isolation_ok = mixed_bytes == solo_bytes;
    std::fs::remove_dir_all(&solo_root).ok();
    std::fs::remove_dir_all(&root).ok();

    let all_speedups: Vec<f64> = speedups_by.iter().flatten().copied().collect();
    let tenant_runs: Vec<TenantRun> = tenants
        .iter()
        .enumerate()
        .map(|(ti, t)| {
            let w = split(&waits, ti);
            let s = split(&sojourns, ti);
            TenantRun {
                name: t.name,
                weight: t.weight,
                arrivals: t.n,
                admitted: admitted[ti],
                valid: speedups_by[ti].len(),
                geomean: stats::geomean(&speedups_by[ti]),
                commits: commits_by[ti],
                kb_states: kb_states_by[ti],
                wait_p50: percentile_nearest_rank(&w, 0.50),
                wait_p95: percentile_nearest_rank(&w, 0.95),
                sojourn_p50: percentile_nearest_rank(&s, 0.50),
                sojourn_p95: percentile_nearest_rank(&s, 0.95),
            }
        })
        .collect();
    TraceRun {
        name: shape,
        arrivals: events.len(),
        wall_s,
        valid: all_speedups.len(),
        geomean: stats::geomean(&all_speedups),
        commits: commits_by.iter().sum(),
        compactions,
        journal_records,
        span_ticks: span,
        wait_p50: percentile_nearest_rank(&waits, 0.50),
        wait_p95: percentile_nearest_rank(&waits, 0.95),
        sojourn_p50: percentile_nearest_rank(&sojourns, 0.50),
        sojourn_p95: percentile_nearest_rank(&sojourns, 0.95),
        fairness_ratio: fairness,
        isolation_ok,
        tenants: tenant_runs,
    }
}

/// Serialize the measurement into `kernelblaster-bench-serve-v2`.
fn write_bench_json(
    arch: &GpuArch,
    n_tasks: usize,
    workers: usize,
    tenants: &[TenantSpec<'_>],
    traces: &[TraceRun],
    path: &Path,
) {
    let mut root = JsonObj::new();
    root.set("format", "kernelblaster-bench-serve-v2");
    root.set("gpu", arch.name);
    root.set("tasks", n_tasks);
    root.set("workers", workers);
    root.set(
        "tenants",
        Json::Arr(
            tenants
                .iter()
                .map(|t| {
                    let mut o = JsonObj::new();
                    o.set("tenant", t.name);
                    o.set("weight", t.weight);
                    o.set("level", t.level.tag());
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    root.set(
        "traces",
        Json::Arr(traces.iter().map(TraceRun::to_json).collect()),
    );
    match std::fs::write(path, Json::Obj(root).to_string_pretty()) {
        Ok(()) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: failed to write {}: {e}", path.display()),
    }
}

/// The `serve` experiment with an explicit JSON output path.
pub fn run_with_output(ctx: &Ctx, out: &Path) -> Report {
    let arch = GpuArch::h100();
    let cfg = ctx.icrl_cfg(false);
    let fleet_cfg = FleetConfig {
        workers: 4,
        epoch_size: 4,
        checkpoint_every: 0,
        ..Default::default()
    };
    // One round of each tenant's task list per trace in quick mode,
    // three in full, so the queue actually builds depth behind the
    // bursts and the quotas see sustained contention.
    let rounds = if ctx.quick { 1 } else { 3 };
    let tenants: Vec<TenantSpec<'_>> = TENANTS
        .iter()
        .map(|(name, weight, level)| {
            let tasks = ctx.tasks(*level);
            let n = tasks.len() * rounds;
            TenantSpec {
                name,
                weight: *weight,
                level: *level,
                tasks,
                n,
            }
        })
        .collect();
    let n_tasks: usize = tenants.iter().map(|t| t.tasks.len()).sum();
    let traces: Vec<TraceRun> = TRACES
        .iter()
        .map(|shape| run_trace(shape, &tenants, &arch, &cfg, &fleet_cfg, ctx.seed))
        .collect();

    let mut t = Table::new(&[
        "trace",
        "tenant",
        "weight",
        "arrivals",
        "admitted",
        "geomean vs naive",
        "wait p50",
        "wait p95",
        "sojourn p95",
        "fairness",
        "isolated",
    ]);
    for tr in &traces {
        for ten in &tr.tenants {
            t.add_row(vec![
                tr.name.to_string(),
                ten.name.to_string(),
                ten.weight.to_string(),
                ten.arrivals.to_string(),
                ten.admitted.to_string(),
                fnum(ten.geomean, 3),
                fnum(ten.wait_p50, 0),
                fnum(ten.wait_p95, 0),
                fnum(ten.sojourn_p95, 0),
                fnum(tr.fairness_ratio, 2),
                if tr.isolation_ok { "yes" } else { "NO" }.to_string(),
            ]);
        }
    }
    write_bench_json(&arch, n_tasks, fleet_cfg.workers, &tenants, &traces, out);
    Report {
        name: "serve".into(),
        sections: vec![Section {
            title: format!(
                "Multi-tenant serving under synthetic arrival traces ({} tenants, {} tasks, \
                 {}, {} simulated workers)",
                tenants.len(),
                n_tasks,
                arch.name,
                fleet_cfg.workers
            ),
            table: t,
            plot: None,
            notes: vec![
                "each trace enqueues both tenants' backlogs and drains through the \
                 weighted-fair scheduler; queue wait/sojourn are deterministic simulated \
                 ticks (service time = the reply's step count)"
                    .into(),
                "fairness = min/max of per-tenant admitted/weight over contended \
                 admissions; isolated = tenant alpha's KB bytes equal a solo replay's"
                    .into(),
                format!(
                    "per-tenant stores are namespaced under one root with a snapshot \
                     every {SNAPSHOT_EVERY} commits"
                ),
                format!("machine-readable: {}", out.display()),
            ],
        }],
    }
}

/// The `serve` experiment registry entry — writes `BENCH_serve.json`
/// beside the working directory like the fleet scenario does.
pub fn run(ctx: &Ctx) -> Report {
    run_with_output(ctx, Path::new("BENCH_serve.json"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_monotone_and_shaped() {
        for shape in TRACES {
            let a = trace_arrivals(shape, 40, 7);
            let b = trace_arrivals(shape, 40, 7);
            assert_eq!(a, b, "{shape}: trace not a pure function of the seed");
            assert_eq!(a.len(), 40);
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{shape}: ticks regressed");
        }
        // Bursty traces repeat ticks inside a burst; uniform never does.
        let bursty = trace_arrivals("bursty", 40, 7);
        assert!(bursty.windows(2).any(|w| w[0] == w[1]));
        let uniform = trace_arrivals("uniform", 40, 7);
        assert!(uniform.windows(2).all(|w| w[0] < w[1]));
        // Heavy-tailed produces at least one gap no uniform trace can.
        let heavy = trace_arrivals("heavy_tailed", 400, 7);
        let max_gap = heavy.windows(2).map(|w| w[1] - w[0]).max().unwrap();
        assert!(max_gap > 6, "heavy tail missing: max gap {max_gap}");
    }

    #[test]
    fn queue_simulation_respects_arrivals_and_capacity() {
        // Two workers, four simultaneous unit jobs: two start at once,
        // two wait one tick.
        let (waits, sojourns, span) = simulate_queue(&[5, 5, 5, 5], &[1, 1, 1, 1], 2);
        assert_eq!(waits, vec![0, 0, 1, 1]);
        assert_eq!(sojourns, vec![1, 1, 2, 2]);
        assert_eq!(span, 7);
        // A single worker serializes everything.
        let (waits, _, span) = simulate_queue(&[0, 0, 0], &[2, 2, 2], 1);
        assert_eq!(waits, vec![0, 2, 4]);
        assert_eq!(span, 6);
        // Idle gaps reset the queue: no waiting when arrivals are sparse.
        let (waits, _, _) = simulate_queue(&[0, 100], &[5, 5], 1);
        assert_eq!(waits, vec![0, 0]);
    }

    #[test]
    fn fairness_ratio_is_weighted_and_over_admitted_counts() {
        // A perfect 3:1 admitted split at weights 3:1 scores 1.0 —
        // whatever the arrival counts were (the function never sees
        // arrivals, by construction).
        assert_eq!(fairness_ratio(&[(9, 3), (3, 1)]), 1.0);
        // Equal weights, a 2:1 admitted skew: 0.5.
        assert_eq!(fairness_ratio(&[(6, 1), (3, 1)]), 0.5);
        // One tenant fully starved: 0.0.
        assert_eq!(fairness_ratio(&[(4, 1), (0, 1)]), 0.0);
        // Nothing admitted (or no tenants): NaN, not a fake 1.0.
        assert!(fairness_ratio(&[]).is_nan());
        assert!(fairness_ratio(&[(0, 1), (0, 3)]).is_nan());
        // A zero weight is clamped to 1, not a division by zero.
        assert_eq!(fairness_ratio(&[(2, 0), (2, 1)]), 1.0);
    }
}
