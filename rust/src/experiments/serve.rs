//! Serve scenario: replay synthetic arrival traces against the daemon's
//! request handler ([`crate::serve::ServeCore`], driven directly — no
//! TCP) and measure serving behavior under three arrival shapes:
//!
//! 1. **uniform** — steady inter-arrival gaps (the provisioning
//!    baseline);
//! 2. **bursty** — tight request bursts separated by idle gaps (CI
//!    fan-out traffic);
//! 3. **heavy_tailed** — Pareto inter-arrivals (multi-tenant traffic
//!    where a few tenants dominate).
//!
//! Each trace gets a fresh core, an empty KB, and its own
//! [`LogStore`] directory, so commit/compaction counters are
//! per-trace. Every request is an `optimize` line through
//! `handle_line` — exactly the serving path, store journaling
//! included. Queue dynamics are *simulated deterministically*: the
//! reply's `steps` count is the request's service time in ticks, and a
//! FIFO earliest-available-worker queue over the arrival ticks yields
//! wait/sojourn percentiles that are a pure function of the seed.
//! Wall-clock enters only as tasks/min (host-dependent; the tick
//! metrics are not).
//!
//! Reported as a [`Report`] plus machine-readable `BENCH_serve.json`
//! (format `kernelblaster-bench-serve-v1`) — CI runs it at `--quick`
//! scale and uploads the JSON as an artifact.

use super::simqueue::{percentile, simulate_queue, trace_arrivals};
use super::{Ctx, Report, Section};
use crate::gpu::GpuArch;
use crate::icrl::{FleetConfig, IcrlConfig};
use crate::kb::store::LogStore;
use crate::kb::KnowledgeBase;
use crate::serve::ServeCore;
use crate::tasks::{Level, Task};
use crate::util::json::{Json, JsonObj};
use crate::util::stats;
use crate::util::table::{fnum, Table};
use std::path::Path;
use std::time::Instant;

/// The three arrival shapes, in report order.
const TRACES: &[&str] = &["uniform", "bursty", "heavy_tailed"];

/// Snapshot cadence for the per-trace store — low enough that even the
/// quick trace exercises at least one journal compaction.
const SNAPSHOT_EVERY: u64 = 4;

/// One trace's measurement. The arrival traces and the FIFO queue
/// simulation live in [`super::simqueue`], shared with the fleet
/// scaling-grid scenario.
struct TraceRun {
    name: &'static str,
    arrivals: usize,
    wall_s: f64,
    valid: usize,
    geomean: f64,
    commits: u64,
    compactions: u64,
    journal_records: u64,
    span_ticks: u64,
    wait_p50: f64,
    wait_p95: f64,
    sojourn_p50: f64,
    sojourn_p95: f64,
}

impl TraceRun {
    fn tasks_per_min(&self) -> f64 {
        self.arrivals as f64 / (self.wall_s / 60.0).max(1e-9)
    }

    fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.set("name", self.name);
        o.set("arrivals", self.arrivals);
        o.set("wall_s", self.wall_s);
        o.set("tasks_per_min", self.tasks_per_min());
        o.set("valid", self.valid);
        o.set("geomean_vs_naive", self.geomean);
        o.set("commits", self.commits);
        o.set("compactions", self.compactions);
        o.set("journal_records", self.journal_records);
        o.set("span_ticks", self.span_ticks);
        o.set("queue_wait_p50_ticks", self.wait_p50);
        o.set("queue_wait_p95_ticks", self.wait_p95);
        o.set("sojourn_p50_ticks", self.sojourn_p50);
        o.set("sojourn_p95_ticks", self.sojourn_p95);
        Json::Obj(o)
    }
}

/// Replay one trace against a fresh store-backed core.
fn run_trace(
    shape: &'static str,
    tasks: &[&Task],
    arch: &GpuArch,
    cfg: &IcrlConfig,
    fleet_cfg: &FleetConfig,
    n: usize,
    seed: u64,
) -> TraceRun {
    let dir = std::env::temp_dir().join(format!("kb_serve_exp_{shape}_{seed}"));
    std::fs::remove_dir_all(&dir).ok();
    let kb = KnowledgeBase::empty();
    let mut store = LogStore::create(&dir, &kb).expect("create trace store");
    store.snapshot_every = SNAPSHOT_EVERY;
    let mut core = ServeCore::new(arch.clone(), cfg.clone(), fleet_cfg.clone(), kb);
    core.store = Some(store);

    let arrivals = trace_arrivals(shape, n, seed);
    let mut service = Vec::with_capacity(n);
    let mut speedups = Vec::new();
    let t = Instant::now();
    for i in 0..n {
        let mut req = JsonObj::new();
        req.set("op", "optimize");
        req.set("task", tasks[i % tasks.len()].id.as_str());
        let reply = core.handle_line(&Json::Obj(req).to_string_compact());
        let j = Json::parse(&reply.lines[0]).expect("reply is JSON");
        let ok = j.get("ok").and_then(Json::as_bool).unwrap_or(false);
        service.push(j.get("steps").and_then(Json::as_usize).unwrap_or(1).max(1) as u64);
        if ok && j.get("valid").and_then(Json::as_bool) == Some(true) {
            if let Some(s) = j.get("speedup_vs_naive").and_then(Json::as_f64) {
                speedups.push(s);
            }
        }
    }
    let wall_s = t.elapsed().as_secs_f64();
    let st = core.store.as_ref().expect("store still attached").stats();
    let (waits, sojourns, span) = simulate_queue(&arrivals, &service, fleet_cfg.workers);
    std::fs::remove_dir_all(&dir).ok();
    TraceRun {
        name: shape,
        arrivals: n,
        wall_s,
        valid: speedups.len(),
        geomean: stats::geomean(&speedups),
        commits: core.commits(),
        compactions: st.compactions,
        journal_records: st.journal_records,
        span_ticks: span,
        wait_p50: percentile(&waits, 0.50),
        wait_p95: percentile(&waits, 0.95),
        sojourn_p50: percentile(&sojourns, 0.50),
        sojourn_p95: percentile(&sojourns, 0.95),
    }
}

/// Serialize the measurement into `kernelblaster-bench-serve-v1`.
fn write_bench_json(arch: &GpuArch, n_tasks: usize, workers: usize, traces: &[TraceRun], path: &Path) {
    let mut root = JsonObj::new();
    root.set("format", "kernelblaster-bench-serve-v1");
    root.set("gpu", arch.name);
    root.set("tasks", n_tasks);
    root.set("workers", workers);
    root.set(
        "traces",
        Json::Arr(traces.iter().map(TraceRun::to_json).collect()),
    );
    match std::fs::write(path, Json::Obj(root).to_string_pretty()) {
        Ok(()) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: failed to write {}: {e}", path.display()),
    }
}

/// The `serve` experiment with an explicit JSON output path.
pub fn run_with_output(ctx: &Ctx, out: &Path) -> Report {
    let arch = GpuArch::h100();
    let cfg = ctx.icrl_cfg(false);
    let fleet_cfg = FleetConfig {
        workers: 4,
        epoch_size: 4,
        checkpoint_every: 0,
        ..Default::default()
    };
    let tasks = ctx.tasks(Level::L1);
    // One round of the task list per trace in quick mode, three in full,
    // so the queue actually builds depth behind the bursts.
    let n = tasks.len() * if ctx.quick { 1 } else { 3 };
    let traces: Vec<TraceRun> = TRACES
        .iter()
        .map(|shape| run_trace(shape, &tasks, &arch, &cfg, &fleet_cfg, n, ctx.seed))
        .collect();

    let mut t = Table::new(&[
        "trace",
        "arrivals",
        "tasks/min",
        "geomean vs naive",
        "commits",
        "compactions",
        "wait p50",
        "wait p95",
        "sojourn p95",
    ]);
    for tr in &traces {
        t.add_row(vec![
            tr.name.to_string(),
            tr.arrivals.to_string(),
            fnum(tr.tasks_per_min(), 1),
            fnum(tr.geomean, 3),
            tr.commits.to_string(),
            tr.compactions.to_string(),
            fnum(tr.wait_p50, 0),
            fnum(tr.wait_p95, 0),
            fnum(tr.sojourn_p95, 0),
        ]);
    }
    write_bench_json(&arch, tasks.len(), fleet_cfg.workers, &traces, out);
    Report {
        name: "serve".into(),
        sections: vec![Section {
            title: format!(
                "Serving daemon under synthetic arrival traces ({} L1 tasks, {n} requests \
                 per trace, {}, {} simulated workers)",
                tasks.len(),
                arch.name,
                fleet_cfg.workers
            ),
            table: t,
            plot: None,
            notes: vec![
                "queue wait/sojourn are deterministic simulated ticks (service time = the \
                 reply's step count); tasks/min is host wall-clock"
                    .into(),
                format!(
                    "each trace runs store-backed with a snapshot every {SNAPSHOT_EVERY} \
                     commits — compaction counts come from the live LogStore"
                ),
                format!("machine-readable: {}", out.display()),
            ],
        }],
    }
}

/// The `serve` experiment registry entry — writes `BENCH_serve.json`
/// beside the working directory like the fleet scenario does.
pub fn run(ctx: &Ctx) -> Report {
    run_with_output(ctx, Path::new("BENCH_serve.json"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_monotone_and_shaped() {
        for shape in TRACES {
            let a = trace_arrivals(shape, 40, 7);
            let b = trace_arrivals(shape, 40, 7);
            assert_eq!(a, b, "{shape}: trace not a pure function of the seed");
            assert_eq!(a.len(), 40);
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{shape}: ticks regressed");
        }
        // Bursty traces repeat ticks inside a burst; uniform never does.
        let bursty = trace_arrivals("bursty", 40, 7);
        assert!(bursty.windows(2).any(|w| w[0] == w[1]));
        let uniform = trace_arrivals("uniform", 40, 7);
        assert!(uniform.windows(2).all(|w| w[0] < w[1]));
        // Heavy-tailed produces at least one gap no uniform trace can.
        let heavy = trace_arrivals("heavy_tailed", 400, 7);
        let max_gap = heavy.windows(2).map(|w| w[1] - w[0]).max().unwrap();
        assert!(max_gap > 6, "heavy tail missing: max gap {max_gap}");
    }

    #[test]
    fn queue_simulation_respects_arrivals_and_capacity() {
        // Two workers, four simultaneous unit jobs: two start at once,
        // two wait one tick.
        let (waits, sojourns, span) = simulate_queue(&[5, 5, 5, 5], &[1, 1, 1, 1], 2);
        assert_eq!(waits, vec![0, 0, 1, 1]);
        assert_eq!(sojourns, vec![1, 1, 2, 2]);
        assert_eq!(span, 7);
        // A single worker serializes everything.
        let (waits, _, span) = simulate_queue(&[0, 0, 0], &[2, 2, 2], 1);
        assert_eq!(waits, vec![0, 2, 4]);
        assert_eq!(span, 6);
        // Idle gaps reset the queue: no waiting when arrivals are sparse.
        let (waits, _, _) = simulate_queue(&[0, 100], &[5, 5], 1);
        assert_eq!(waits, vec![0, 0]);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[1, 2, 3, 4, 5], 0.50), 3.0);
        assert_eq!(percentile(&[1, 2, 3, 4, 5], 0.95), 5.0);
        assert_eq!(percentile(&[7], 0.95), 7.0);
        assert!(percentile(&[], 0.5).is_nan());
    }
}
