//! Skill-mining scenario: no-skills vs mined-skills arms on warm KBs.
//!
//! The claim under test is the [`crate::kb::skills`] contract: chains the
//! miner compressed out of earlier runs' replay logs, drawn as single
//! composite steps ([`crate::icrl::IcrlConfig::skills`]), reach the run's
//! best kernel in fewer rollout steps without moving the speedup.
//!
//! Protocol, per seed:
//!
//! 1. **Warm phase** — grow a KB from empty over the task list (skills
//!    off; the warm runs supply the replay traces).
//! 2. **Mine + install** — [`crate::kb::skills::mine_runs`] over the warm
//!    traces, installed into the warm KB as `origin: "mined"` entries.
//! 3. **Paired arms** — two runs over clones of that mined KB at a fresh
//!    eval seed, identical in everything except `skills.enabled`:
//!    `no_skills` (the pairing baseline — the mined entries sit inert in
//!    the KB) and `mined_skills` (policies may draw them).
//!
//! The efficiency metric is **mean steps-to-best** ([`TaskRun`]'s
//! `steps_to_best`: the 1-based sample index that set the run's final
//! best, averaged over cells that improved at all); quality parity is
//! the paired geomean speedup ratio over both-valid cells. Reported as a
//! [`Report`] plus machine-readable `BENCH_skills.json` (format
//! `kernelblaster-bench-skills-v1`).

use super::pairing::{self, Cell};
use super::{Ctx, Report, Section};
use crate::gpu::GpuArch;
use crate::icrl::{self, IcrlConfig, TaskRun};
use crate::kb::skills::{self as kb_skills, SkillsConfig};
use crate::kb::KnowledgeBase;
use crate::tasks::{Level, Task};
use crate::util::json::{Json, JsonObj};
use crate::util::table::{fnum, Table};
use std::path::Path;

/// One arm's measurements over the `(seed, task)` grid.
struct Arm {
    label: &'static str,
    cells: Vec<Cell>,
    /// Per-cell `steps_to_best` (0 = the run never improved on naive).
    steps_to_best: Vec<usize>,
    /// Chosen steps that applied a whole mined chain, summed over runs.
    skill_draws: usize,
}

impl Arm {
    /// Mean steps-to-best over cells that improved at all (0.0 when
    /// none — consumers must check `improved_cells` first).
    fn mean_steps_to_best(&self) -> f64 {
        let improved: Vec<f64> = self
            .steps_to_best
            .iter()
            .filter(|&&s| s > 0)
            .map(|&s| s as f64)
            .collect();
        let n = improved.len();
        improved.into_iter().sum::<f64>() / n.max(1) as f64
    }

    fn improved_cells(&self) -> usize {
        self.steps_to_best.iter().filter(|&&s| s > 0).count()
    }
}

/// The mining gates the experiment uses: the crate defaults with a
/// looser gain floor so quick grids still surface chains (the default
/// 1.05 floor is tuned for long production traces).
fn mining_cfg() -> SkillsConfig {
    SkillsConfig {
        min_gain: 1.01,
        ..Default::default()
    }
}

fn collect_cells(runs: &[TaskRun], arm: &mut Arm) {
    for run in runs {
        arm.cells.push(Cell {
            valid: run.valid,
            speedup: run.speedup_vs_naive(),
            tokens: run.tokens.total(),
        });
        arm.steps_to_best.push(run.steps_to_best);
        arm.skill_draws += run
            .steps
            .iter()
            .filter(|s| s.chosen && s.skill.is_some())
            .count();
    }
}

/// Run the full protocol: per seed, one warm+mine phase and both eval
/// arms over clones of the same mined KB at a shifted eval seed.
/// Returns (arms, total skills installed over every seed's KB).
fn run_arms(
    tasks: &[&Task],
    arch: &GpuArch,
    base: &IcrlConfig,
    seeds: &[u64],
) -> (Vec<Arm>, usize) {
    let mine = mining_cfg();
    let mut no_skills = Arm {
        label: "no_skills",
        cells: Vec::new(),
        steps_to_best: Vec::new(),
        skill_draws: 0,
    };
    let mut mined_skills = Arm {
        label: "mined_skills",
        cells: Vec::new(),
        steps_to_best: Vec::new(),
        skill_draws: 0,
    };
    let mut installed = 0;
    for &seed in seeds {
        // Warm phase: grow the KB and keep its replay traces.
        let warm_cfg = IcrlConfig {
            seed,
            ..base.clone()
        };
        let mut kb = KnowledgeBase::empty();
        let warm_runs = icrl::run_suite(tasks, arch, &mut kb, &warm_cfg);
        let mined = kb_skills::mine_runs(&warm_runs, &mine);
        kb_skills::install(&mut kb, &mined);
        installed += kb_skills::count(&kb);

        // Eval arms: same mined KB, same fresh seed, drawing toggled.
        let eval_seed = seed + 101;
        for (on, arm) in [(false, &mut no_skills), (true, &mut mined_skills)] {
            let cfg = IcrlConfig {
                seed: eval_seed,
                skills: SkillsConfig {
                    enabled: on,
                    ..mine.clone()
                },
                ..base.clone()
            };
            let mut akb = kb.clone();
            let runs = icrl::run_suite(tasks, arch, &mut akb, &cfg);
            collect_cells(&runs, arm);
        }
    }
    (vec![no_skills, mined_skills], installed)
}

/// Serialize the measurement into `kernelblaster-bench-skills-v1`.
fn write_bench_json(
    arch: &GpuArch,
    base: &IcrlConfig,
    n_tasks: usize,
    seeds: &[u64],
    all: &[Arm],
    installed: usize,
    path: &Path,
) {
    let baseline = &all[0]; // run_arms() leads with "no_skills"
    let mine = mining_cfg();
    let mut root = JsonObj::new();
    root.set("format", "kernelblaster-bench-skills-v1");
    root.set("gpu", arch.name);
    root.set("tasks", n_tasks);
    root.set(
        "seeds",
        Json::Arr(seeds.iter().map(|&s| Json::from(s)).collect()),
    );
    root.set("trajectories", base.trajectories);
    root.set("rollout_steps", base.rollout_steps);
    root.set("mine_max_len", mine.max_len);
    root.set("mine_min_support", mine.min_support);
    root.set("mine_min_gain", mine.min_gain);
    root.set("mine_max_per_state", mine.max_per_state);
    root.set("skills_installed", installed);
    let arms_json: Vec<Json> = all
        .iter()
        .map(|arm| {
            let (ratio, pairs) = pairing::paired_vs(&arm.cells, &baseline.cells);
            let mut o = JsonObj::new();
            o.set("label", arm.label);
            o.set("geomean_vs_naive", pairing::geomean_valid(&arm.cells));
            o.set("valid", pairing::valid_count(&arm.cells));
            o.set("cells", arm.cells.len());
            o.set("vs_no_skills_paired", ratio);
            o.set("paired_cells", pairs);
            o.set("mean_steps_to_best", arm.mean_steps_to_best());
            o.set("improved_cells", arm.improved_cells());
            o.set("tokens_per_task", pairing::tokens_per_cell(&arm.cells));
            o.set("skill_draws", arm.skill_draws);
            Json::Obj(o)
        })
        .collect();
    root.set("arms", Json::Arr(arms_json));
    match std::fs::write(path, Json::Obj(root).to_string_pretty()) {
        Ok(()) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: failed to write {}: {e}", path.display()),
    }
}

/// The `skills` experiment with an explicit JSON output path.
pub fn run_with_output(ctx: &Ctx, out: &Path) -> Report {
    let arch = GpuArch::h100();
    let base = ctx.icrl_cfg(false);
    let seeds: Vec<u64> = if ctx.quick {
        vec![ctx.seed, ctx.seed + 1]
    } else {
        vec![ctx.seed, ctx.seed + 1, ctx.seed + 2]
    };
    let tasks = ctx.tasks(Level::L1);
    let (all, installed) = run_arms(&tasks, &arch, &base, &seeds);
    let baseline = &all[0];

    let mut t = Table::new(&[
        "arm",
        "geomean vs naive",
        "vs no_skills (paired)",
        "valid",
        "mean steps-to-best",
        "improved cells",
        "skill draws",
    ]);
    for arm in &all {
        let (ratio, pairs) = pairing::paired_vs(&arm.cells, &baseline.cells);
        t.add_row(vec![
            arm.label.to_string(),
            fnum(pairing::geomean_valid(&arm.cells), 3),
            format!("{} ({pairs} pairs)", fnum(ratio, 3)),
            format!("{}/{}", pairing::valid_count(&arm.cells), arm.cells.len()),
            fnum(arm.mean_steps_to_best(), 2),
            arm.improved_cells().to_string(),
            arm.skill_draws.to_string(),
        ]);
    }
    write_bench_json(&arch, &base, tasks.len(), &seeds, &all, installed, out);
    Report {
        name: "skills".into(),
        sections: vec![Section {
            title: format!(
                "Mined skills on warm KBs over paired seeds ({} L1 tasks x {} seeds, {}, {} skills installed)",
                tasks.len(),
                seeds.len(),
                arch.name,
                installed
            ),
            table: t,
            plot: None,
            notes: vec![
                "both arms run the same mined KB at the same eval seed; only \
                 skills.enabled differs, so cell pairs isolate the composite-draw \
                 path"
                    .to_string(),
                "steps-to-best is the 1-based sample index that set the run's \
                 final best kernel, averaged over cells that improved at all — \
                 the search-depth analog of wall-clock on a container with no GPU"
                    .to_string(),
                "speedup parity is expected: skills reorder the search, the full \
                 oracle still gates every commit"
                    .to_string(),
                format!("machine-readable: {}", out.display()),
            ],
        }],
    }
}

/// The `skills` experiment registry entry — writes `BENCH_skills.json`
/// beside the working directory like the policy and verify scenarios.
pub fn run(ctx: &Ctx) -> Report {
    run_with_output(ctx, Path::new("BENCH_skills.json"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Suite;

    #[test]
    fn skills_experiment_pairs_arms_and_reports_steps_to_best() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
            suite.by_id("L1/01_matmul_square").unwrap(),
        ];
        let base = IcrlConfig {
            trajectories: 3,
            rollout_steps: 4,
            top_k: 2,
            ..Default::default()
        };
        let arch = GpuArch::h100();
        let seeds = [7u64, 8];
        let (all, installed) = run_arms(&tasks, &arch, &base, &seeds);
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].label, "no_skills");
        assert_eq!(all[1].label, "mined_skills");
        for arm in &all {
            assert_eq!(arm.cells.len(), 6, "{}: 3 tasks x 2 seeds", arm.label);
            assert_eq!(arm.steps_to_best.len(), arm.cells.len());
            assert!(pairing::valid_count(&arm.cells) > 0, "{}", arm.label);
        }
        // The baseline never draws skills even though they sit in its KB.
        assert_eq!(all[0].skill_draws, 0, "drawing must stay gated off");
        assert!(installed > 0, "warm traces must mine at least one skill");

        // The JSON artifact parses and carries both arms with the
        // steps-to-best metric.
        let dir = std::env::temp_dir().join("kb_skills_exp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_skills.json");
        write_bench_json(&arch, &base, tasks.len(), &seeds, &all, installed, &out);
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(
            j.get("format").and_then(Json::as_str),
            Some("kernelblaster-bench-skills-v1")
        );
        let arms_json = j.get("arms").and_then(Json::as_arr).unwrap();
        assert_eq!(arms_json.len(), 2);
        assert_eq!(
            arms_json[0].get("label").and_then(Json::as_str),
            Some("no_skills")
        );
        assert_eq!(
            arms_json[0].get("vs_no_skills_paired").and_then(Json::as_f64),
            Some(1.0)
        );
        for a in arms_json {
            assert!(a.get("mean_steps_to_best").is_some());
            assert!(a.get("improved_cells").and_then(Json::as_usize).is_some());
            assert!(a.get("skill_draws").and_then(Json::as_usize).is_some());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
