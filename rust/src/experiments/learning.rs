//! Learning-rate experiments: Fig. 15 (pretrained vs empty Knowledge
//! Base), Fig. 16 (A6000-trained KB reused across GPUs), and the §6.1
//! no_mem ablation.

use super::{Ctx, Report, Section};
use crate::gpu::GpuArch;
use crate::icrl::{self, KbMode, TaskRun};
use crate::kb::KnowledgeBase;
use crate::tasks::Level;
use crate::util::stats;
use crate::util::table::{fnum, line_plot, Table};

/// Cumulative count of (state, technique) applications that are new
/// *relative to the Knowledge Base at run start* — the "discovery and
/// application of new optimizations" curves of Figs. 15/16. Entries the
/// pretrained KB already holds count as reuse, not discovery.
fn discovery_curve_vs(runs: &[TaskRun], kb_before: &crate::kb::KnowledgeBase) -> Vec<(f64, f64)> {
    let mut seen: std::collections::BTreeSet<(String, &str)> = kb_before
        .states
        .iter()
        .flat_map(|s| {
            s.opts
                .iter()
                .map(move |o| (s.sig.id(), o.technique.name()))
        })
        .collect();
    let baseline = seen.len();
    let mut curve = Vec::new();
    let mut attempts = 0usize;
    for r in runs {
        for s in &r.steps {
            attempts += 1;
            seen.insert((s.state.id(), s.technique.name()));
            curve.push((attempts as f64, (seen.len() - baseline) as f64));
        }
    }
    curve
}

/// Discovery curve from an empty KB (first-pass training).
fn discovery_curve(runs: &[TaskRun]) -> Vec<(f64, f64)> {
    discovery_curve_vs(runs, &crate::kb::KnowledgeBase::empty())
}

fn downsample(curve: &[(f64, f64)], points: usize) -> (Vec<f64>, Vec<f64>) {
    if curve.is_empty() {
        return (vec![0.0], vec![0.0]);
    }
    let step = (curve.len() / points).max(1);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (i, (x, y)) in curve.iter().enumerate() {
        if i % step == 0 || i + 1 == curve.len() {
            xs.push(*x);
            ys.push(*y);
        }
    }
    (xs, ys)
}

/// Train a KB on Level-1 (the paper pretrains on L1) and return it.
pub fn train_kb(ctx: &Ctx, arch: &GpuArch) -> (KnowledgeBase, Vec<TaskRun>) {
    let mut kb = KnowledgeBase::empty();
    let (runs, _) = super::run_ours(ctx, arch, Level::L1, false, &mut kb);
    (kb, runs)
}

/// Figs. 15/16 combined report.
pub fn fig15_16(ctx: &Ctx) -> Report {
    let a6000 = GpuArch::a6000();
    // --- Fig. 15: empty vs pretrained on A6000/L1 ---
    let (trained_kb, first_pass) = train_kb(ctx, &a6000);
    let empty_curve = discovery_curve(&first_pass);
    let mut kb2 = trained_kb.clone();
    let (second_pass, _) = super::run_ours(ctx, &a6000, Level::L1, false, &mut kb2);
    let pre_curve = discovery_curve_vs(&second_pass, &trained_kb);

    let (xs_e, ys_e) = downsample(&empty_curve, 24);
    let (xs_p, ys_p) = downsample(&pre_curve, 24);
    let mut t15 = Table::new(&["attempt", "new entries (empty KB)", "new entries (pretrained)"]);
    for i in 0..xs_e.len().max(xs_p.len()) {
        t15.add_row(vec![
            fnum(*xs_e.get(i).or(xs_p.get(i)).unwrap_or(&0.0), 0),
            ys_e.get(i).map(|v| fnum(*v, 0)).unwrap_or_else(|| "-".into()),
            ys_p.get(i).map(|v| fnum(*v, 0)).unwrap_or_else(|| "-".into()),
        ]);
    }
    let rate_empty = empty_curve.last().map(|(x, y)| y / x).unwrap_or(0.0);
    let rate_pre = pre_curve.last().map(|(x, y)| y / x).unwrap_or(0.0);
    let plot15 = line_plot(
        &xs_e,
        &[("empty".to_string(), ys_e.clone()), ("pretrained".to_string(), {
            let mut v = ys_p.clone();
            v.resize(xs_e.len(), *ys_p.last().unwrap_or(&0.0));
            v
        })],
        10,
        50,
    );

    // --- Fig. 16: A6000-trained KB reused on other GPUs ---
    let mut t16 = Table::new(&["GPU", "geomean vs naive (pretrained KB)", "new-entry rate"]);
    for arch in [GpuArch::a100(), GpuArch::h100(), GpuArch::l40s()] {
        let mut kb = trained_kb.clone();
        let (runs, _) = super::run_ours(ctx, &arch, Level::L1, false, &mut kb);
        let sp: Vec<f64> = runs
            .iter()
            .filter(|r| r.valid)
            .map(|r| r.speedup_vs_naive())
            .collect();
        let curve = discovery_curve_vs(&runs, &trained_kb);
        let rate = curve.last().map(|(x, y)| y / x).unwrap_or(0.0);
        t16.add_row(vec![
            arch.name.to_string(),
            fnum(stats::geomean(&sp), 3),
            fnum(rate, 4),
        ]);
    }

    Report {
        name: "fig15_16".into(),
        sections: vec![
            Section {
                title: "Fig. 15: optimization discovery — empty vs pretrained KB (A6000, L1)"
                    .into(),
                table: t15,
                plot: Some(plot15),
                notes: vec![format!(
                    "new-entry rate: empty {rate_empty:.4}/attempt vs pretrained \
                     {rate_pre:.4}/attempt — pretrained runs re-use existing entries \
                     instead of discovering"
                )],
            },
            Section {
                title: "Fig. 16: A6000-trained KB reused on other GPUs (L1)".into(),
                table: t16,
                plot: None,
                notes: vec![
                    "The KB artifact transfers across architectures (paper Fig. 16)".into(),
                ],
            },
        ],
    }
}

/// §6.1: no_mem_agent ablation — full profiling, empty per-task KB.
/// Paper: no_mem underperforms the full system by 1.67×.
pub fn ablation_mem(ctx: &Ctx) -> Report {
    let arch = GpuArch::h100();
    let mut cfg = ctx.icrl_cfg(false);

    let mut kb = KnowledgeBase::empty();
    let tasks = ctx.tasks(Level::L2);
    let full_runs = icrl::run_suite(&tasks, &arch, &mut kb, &cfg);

    cfg.kb_mode = KbMode::EphemeralPerTask;
    let mut scratch = KnowledgeBase::empty();
    let nomem_runs = icrl::run_suite(&tasks, &arch, &mut scratch, &cfg);

    let gm = |runs: &[TaskRun]| {
        let v: Vec<f64> = runs
            .iter()
            .filter(|r| r.valid)
            .map(|r| r.speedup_vs_naive())
            .collect();
        stats::geomean(&v)
    };
    let g_full = gm(&full_runs);
    let g_nomem = gm(&nomem_runs);

    let mut t = Table::new(&["variant", "geomean speedup vs naive (L2, H100)"]);
    t.add_row(vec!["full (persistent KB)".into(), fnum(g_full, 3)]);
    t.add_row(vec!["no_mem (per-task KB)".into(), fnum(g_nomem, 3)]);
    Report {
        name: "ablation_mem".into(),
        sections: vec![Section {
            title: "§6.1 no_mem ablation".into(),
            table: t,
            plot: None,
            notes: vec![format!(
                "full/no_mem ratio = {:.2}x (paper: no_mem is 1.67x slower)",
                g_full / g_nomem
            )],
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discovery_curve_monotone() {
        let ctx = Ctx::new(true, 13);
        let (_kb, runs) = train_kb(&ctx, &GpuArch::a6000());
        let curve = discovery_curve(&runs);
        assert!(!curve.is_empty());
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1);
            assert!(w[1].0 > w[0].0);
        }
    }

    #[test]
    fn pretrained_discovers_fewer_new_entries() {
        let ctx = Ctx::new(true, 13);
        let a6000 = GpuArch::a6000();
        let (trained_kb, first_pass) = train_kb(&ctx, &a6000);
        let mut kb2 = trained_kb.clone();
        let (second_pass, _) = super::super::run_ours(&ctx, &a6000, Level::L1, false, &mut kb2);
        let empty_rate = {
            let c = discovery_curve(&first_pass);
            c.last().map(|(x, y)| y / x).unwrap_or(0.0)
        };
        let pre_rate = {
            let c = discovery_curve_vs(&second_pass, &trained_kb);
            c.last().map(|(x, y)| y / x).unwrap_or(0.0)
        };
        assert!(
            empty_rate >= pre_rate,
            "empty {empty_rate:.4} must discover at a rate >= pretrained {pre_rate:.4}"
        );
    }

    #[test]
    fn ablation_runs_quick() {
        let ctx = Ctx::new(true, 13);
        let rep = ablation_mem(&ctx);
        assert!(rep.sections[0].notes[0].contains("ratio"));
    }
}
