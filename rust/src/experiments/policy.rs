//! Search-policy scenario: the built-in [`crate::icrl::policy`] arms
//! (one per [`PolicyKind`], each at its default hyperparameters)
//! compared over paired seeds. The per-knob grid is the separate
//! [`super::sweep`] scenario.
//!
//! Same task list, same `(task, seed)` grid for every arm — only the
//! [`crate::icrl::PolicyKind`] differs — so per-cell differences are
//! attributable to the policy alone. Per arm we report the geomean
//! speedup vs naive, the paired geomean ratio against the `greedy_topk`
//! baseline (computed over cells where **both** arms produced a valid
//! kernel, the same pairing discipline as the continual scenario), token
//! cost, and the grown KB's state count. Reported as a [`Report`] plus
//! machine-readable `BENCH_policy.json` (format
//! `kernelblaster-bench-policy-v1`) — CI runs the quick scale and
//! uploads the JSON as an artifact.

use super::pairing::{self, Cell};
use super::{Ctx, Report, Section};
use crate::gpu::GpuArch;
use crate::icrl::{self, IcrlConfig, PolicyConfig, PolicyKind};
use crate::kb::KnowledgeBase;
use crate::tasks::{Level, Task};
use crate::util::json::{Json, JsonObj};
use crate::util::table::{fnum, Table};
use std::path::Path;

/// One policy arm's measurements over the full grid (cells in the
/// [`pairing`] discipline's grid order).
struct Arm {
    kind: PolicyKind,
    cells: Vec<Cell>,
    /// KB states discovered, summed over the per-seed runs.
    kb_states: usize,
}

impl Arm {
    fn geomean_valid(&self) -> f64 {
        pairing::geomean_valid(&self.cells)
    }

    fn valid_count(&self) -> usize {
        pairing::valid_count(&self.cells)
    }

    fn tokens_per_cell(&self) -> f64 {
        pairing::tokens_per_cell(&self.cells)
    }
}

/// Paired comparison of an arm against the baseline arm — the shared
/// both-valid discipline ([`pairing::paired_vs`]; check the pair count
/// before the ratio).
fn paired_vs(arm: &Arm, baseline: &Arm) -> (f64, usize) {
    pairing::paired_vs(&arm.cells, &baseline.cells)
}

/// Run every [`PolicyKind`] arm over an explicit task list and seed set
/// (tests shrink both).
fn arms(tasks: &[&Task], arch: &GpuArch, base: &IcrlConfig, seeds: &[u64]) -> Vec<Arm> {
    PolicyKind::all()
        .iter()
        .map(|kind| {
            let mut cells = Vec::with_capacity(seeds.len() * tasks.len());
            let mut kb_states = 0;
            for &seed in seeds {
                let cfg = IcrlConfig {
                    policy: PolicyConfig::of_kind(*kind),
                    seed,
                    ..base.clone()
                };
                let mut kb = KnowledgeBase::empty();
                let runs = icrl::run_suite(tasks, arch, &mut kb, &cfg);
                kb_states += kb.states.len();
                cells.extend(runs.iter().map(|r| Cell {
                    valid: r.valid,
                    speedup: r.speedup_vs_naive(),
                    tokens: r.tokens.total(),
                }));
            }
            Arm {
                kind: *kind,
                cells,
                kb_states,
            }
        })
        .collect()
}

/// Serialize the measurement into `kernelblaster-bench-policy-v1`.
fn write_bench_json(
    arch: &GpuArch,
    base: &IcrlConfig,
    n_tasks: usize,
    seeds: &[u64],
    all: &[Arm],
    path: &Path,
) {
    let baseline = &all[0]; // PolicyKind::all() leads with GreedyTopK
    let mut root = JsonObj::new();
    root.set("format", "kernelblaster-bench-policy-v1");
    root.set("gpu", arch.name);
    root.set("tasks", n_tasks);
    root.set(
        "seeds",
        Json::Arr(seeds.iter().map(|&s| Json::from(s)).collect()),
    );
    root.set("top_k", base.top_k);
    root.set("trajectories", base.trajectories);
    root.set("rollout_steps", base.rollout_steps);
    let arms_json: Vec<Json> = all
        .iter()
        .map(|arm| {
            let (ratio, pairs) = paired_vs(arm, baseline);
            let mut o = JsonObj::new();
            o.set("policy", arm.kind.name());
            o.set("geomean_vs_naive", arm.geomean_valid());
            o.set("valid", arm.valid_count());
            o.set("cells", arm.cells.len());
            o.set("vs_greedy_paired", ratio);
            o.set("paired_cells", pairs);
            o.set("tokens_per_task", arm.tokens_per_cell());
            o.set("kb_states", arm.kb_states);
            Json::Obj(o)
        })
        .collect();
    root.set("arms", Json::Arr(arms_json));
    match std::fs::write(path, Json::Obj(root).to_string_pretty()) {
        Ok(()) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: failed to write {}: {e}", path.display()),
    }
}

/// The `policy` experiment with an explicit JSON output path.
pub fn run_with_output(ctx: &Ctx, out: &Path) -> Report {
    let arch = GpuArch::h100();
    let base = ctx.icrl_cfg(false);
    let seeds: Vec<u64> = if ctx.quick {
        vec![ctx.seed, ctx.seed + 1]
    } else {
        vec![ctx.seed, ctx.seed + 1, ctx.seed + 2]
    };
    let tasks = ctx.tasks(Level::L1);
    let all = arms(&tasks, &arch, &base, &seeds);
    let baseline = &all[0];

    let mut t = Table::new(&[
        "policy",
        "geomean vs naive",
        "vs greedy (paired)",
        "valid",
        "tokens/task",
        "KB states",
    ]);
    for arm in &all {
        let (ratio, pairs) = paired_vs(arm, baseline);
        t.add_row(vec![
            arm.kind.name().to_string(),
            fnum(arm.geomean_valid(), 3),
            format!("{} ({pairs} pairs)", fnum(ratio, 3)),
            format!("{}/{}", arm.valid_count(), arm.cells.len()),
            fnum(arm.tokens_per_cell(), 0),
            arm.kb_states.to_string(),
        ]);
    }
    write_bench_json(&arch, &base, tasks.len(), &seeds, &all, out);
    Report {
        name: "policy".into(),
        sections: vec![Section {
            title: format!(
                "Search policies over paired seeds ({} L1 tasks x {} seeds, {}, top-k {})",
                tasks.len(),
                seeds.len(),
                arch.name,
                base.top_k
            ),
            table: t,
            plot: None,
            notes: vec![
                "pairing: identical (task, seed) grid per arm; \"vs greedy\" is the \
                 geomean ratio over cells valid in both arms"
                    .to_string(),
                "greedy_topk is the pre-policy-subsystem driver bit-for-bit \
                 (tests/policy.rs); the other arms trade its exploit-heavy draw for \
                 an exploration floor (epsilon_greedy), an evidence-uncertainty bonus \
                 (ucb_bandit), a carried frontier (beam_search), a contrastive \
                 explore/exploit mix arbitrated per state (portfolio), or a \
                 deterministic Beta-posterior draw over per-entry evidence (thompson)"
                    .to_string(),
                format!("machine-readable: {}", out.display()),
            ],
        }],
    }
}

/// The `policy` experiment registry entry — writes `BENCH_policy.json`
/// beside the working directory like the continual and fleet scenarios.
pub fn run(ctx: &Ctx) -> Report {
    run_with_output(ctx, Path::new("BENCH_policy.json"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::HarnessConfig;
    use crate::tasks::Suite;

    #[test]
    fn policy_experiment_compares_all_paired_arms() {
        let suite = Suite::full();
        let tasks: Vec<&Task> = vec![
            suite.by_id("L1/12_softmax").unwrap(),
            suite.by_id("L1/15_relu").unwrap(),
        ];
        let base = IcrlConfig {
            trajectories: 2,
            rollout_steps: 3,
            top_k: 2,
            harness: HarnessConfig {
                noise_sigma: 0.0,
                ..Default::default()
            },
            ..Default::default()
        };
        let arch = GpuArch::a100();
        let seeds = [3u64, 4];
        let all = arms(&tasks, &arch, &base, &seeds);
        assert_eq!(all.len(), PolicyKind::all().len());
        assert_eq!(all.len(), 6);
        assert_eq!(all[0].kind, PolicyKind::GreedyTopK);
        assert_eq!(all[4].kind, PolicyKind::Portfolio);
        assert_eq!(all[5].kind, PolicyKind::Thompson);
        for arm in &all {
            assert_eq!(arm.cells.len(), 4, "{}: 2 tasks x 2 seeds", arm.kind.name());
            assert!(arm.valid_count() > 0, "{}: nothing valid", arm.kind.name());
            assert!(arm.geomean_valid().is_finite());
        }
        // The baseline's paired ratio against itself is exactly 1.
        let (self_ratio, pairs) = paired_vs(&all[0], &all[0]);
        assert_eq!(self_ratio, 1.0);
        assert_eq!(pairs, all[0].valid_count());

        // The JSON artifact parses and carries every arm.
        let dir = std::env::temp_dir().join("kb_policy_exp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_policy.json");
        write_bench_json(&arch, &base, tasks.len(), &seeds, &all, &out);
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(
            j.get("format").and_then(Json::as_str),
            Some("kernelblaster-bench-policy-v1")
        );
        let arms_json = j.get("arms").and_then(Json::as_arr).unwrap();
        assert_eq!(arms_json.len(), 6);
        assert_eq!(
            arms_json[0].get("policy").and_then(Json::as_str),
            Some("greedy_topk")
        );
        assert_eq!(
            arms_json[0].get("vs_greedy_paired").and_then(Json::as_f64),
            Some(1.0)
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
