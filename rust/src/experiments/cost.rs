//! Cost experiments: Fig. 10 (speedup per tokens consumed) and the §6.4
//! minimal-agent comparison.

use super::{Ctx, Report, Section};
use crate::baselines::agentic;
use crate::gpu::GpuArch;
use crate::harness::HarnessConfig;
use crate::icrl;
use crate::kb::KnowledgeBase;
use crate::tasks::Level;
use crate::util::stats;
use crate::util::table::{fnum, fpct, Table};

/// Fig. 10: scatter of speedup-over-naive-CUDA vs total tokens consumed,
/// one point per task (L1 + L2, A6000 — the paper's cost study GPU).
pub fn fig10(ctx: &Ctx) -> Report {
    let arch = GpuArch::a6000();
    let mut kb = KnowledgeBase::empty();
    let (runs1, _) = super::run_ours(ctx, &arch, Level::L1, false, &mut kb);
    let (runs2, _) = super::run_ours(ctx, &arch, Level::L2, false, &mut kb);
    let mut t = Table::new(&["task", "tokens", "speedup_vs_naive"]);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for r in runs1.iter().chain(&runs2) {
        t.add_row(vec![
            r.task_id.clone(),
            r.tokens.total().to_string(),
            fnum(r.speedup_vs_naive(), 3),
        ]);
        xs.push(r.tokens.total() as f64);
        ys.push(r.speedup_vs_naive().ln()); // log-speedup correlation
    }
    let corr = stats::pearson(&xs, &ys);
    Report {
        name: "fig10".into(),
        sections: vec![Section {
            title: "Speedup vs tokens consumed (A6000, L1+L2)".into(),
            table: t,
            plot: None,
            notes: vec![format!(
                "Pearson corr(tokens, log speedup) = {corr:.3} — paper reports a \
                 positive correlation"
            )],
        }],
    }
}

/// §6.4: the minimal agent vs KernelBlaster — token cost ratio, perf per
/// token, and win rate.
pub fn minimal_agent(ctx: &Ctx) -> Report {
    let arch = GpuArch::h100();
    let hcfg = HarnessConfig::default();
    let cfg = ctx.icrl_cfg(false);
    let mut kb = KnowledgeBase::empty();

    let mut rows = Vec::new();
    let mut ours_tokens = 0usize;
    let mut min_tokens = 0usize;
    let mut ours_wins = 0usize;
    let mut total = 0usize;
    let mut ours_perf_per_tok = Vec::new();
    let mut min_perf_per_tok = Vec::new();

    for level in [Level::L1, Level::L2] {
        for task in ctx.tasks(level) {
            let ours = icrl::optimize_task(task, &arch, &mut kb, &cfg, total as u64);
            let min = agentic::minimal_agent(
                task,
                &arch,
                &hcfg,
                cfg.trajectories,
                cfg.rollout_steps,
                ctx.seed,
            );
            total += 1;
            ours_tokens += ours.tokens.total();
            min_tokens += min.tokens.total();
            if ours.best_time_s <= min.best_time_s {
                ours_wins += 1;
            }
            ours_perf_per_tok.push(ours.speedup_vs_naive() / ours.tokens.total() as f64);
            min_perf_per_tok.push(min.speedup_vs_naive() / min.tokens.total() as f64);
            rows.push(vec![
                task.id.clone(),
                ours.tokens.total().to_string(),
                min.tokens.total().to_string(),
                fnum(ours.speedup_vs_naive(), 2),
                fnum(min.speedup_vs_naive(), 2),
            ]);
        }
    }

    let mut t = Table::new(&[
        "task",
        "ours tokens",
        "minimal tokens",
        "ours speedup",
        "minimal speedup",
    ]);
    for r in rows {
        t.add_row(r);
    }
    let token_ratio = min_tokens as f64 / ours_tokens.max(1) as f64;
    let ppt_ratio = stats::mean(&min_perf_per_tok) / stats::mean(&ours_perf_per_tok);
    Report {
        name: "minimal_agent".into(),
        sections: vec![Section {
            title: "Minimal agent vs KernelBlaster (§6.4)".into(),
            table: t,
            plot: None,
            notes: vec![
                format!(
                    "minimal/ours token ratio = {token_ratio:.2}x (paper: 2.4x)"
                ),
                format!(
                    "minimal perf-per-token = {ppt_ratio:.3}x of ours (paper: 0.379x)"
                ),
                format!(
                    "ours better or equal in {} of cases (paper: 71%)",
                    fpct(ours_wins as f64 / total.max(1) as f64)
                ),
            ],
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_quick_positive_correlation_noted() {
        let ctx = Ctx::new(true, 11);
        let rep = fig10(&ctx);
        assert!(rep.sections[0].notes[0].contains("Pearson"));
        assert!(rep.sections[0].table.n_rows() >= 10);
    }

    #[test]
    fn minimal_agent_quick_token_ratio_above_one() {
        let ctx = Ctx::new(true, 11);
        let rep = minimal_agent(&ctx);
        let note = &rep.sections[0].notes[0];
        let ratio: f64 = note
            .split('=')
            .nth(1)
            .unwrap()
            .trim()
            .trim_end_matches("x (paper: 2.4x)")
            .parse()
            .unwrap();
        assert!(ratio > 1.0, "minimal agent must cost more tokens: {ratio}");
    }
}
