//! Experiment registry: one regenerator per paper table/figure, plus the
//! [`continual`] cross-arch lifecycle scenario, the [`fleet`]
//! batch-serving throughput/parity scenario, the [`policy`] search-policy
//! comparison, the [`sweep`] exploration-hyperparameter grid, the
//! [`verify`] tiered-verification op-count benchmark, and the [`skills`]
//! mined-macro-opt efficiency scenario.
//!
//! Every entry produces a [`Report`] — human-readable tables/plots plus
//! machine-readable CSVs — from the same code paths the CLI
//! ([`crate::cli`]) and the bench harness use: runs through
//! [`crate::icrl`], scores through [`crate::metrics`] against
//! [`crate::baselines`], all over the shared [`crate::tasks`] suite.
//! The mapping to the paper's artifacts is in DESIGN.md §6.

pub mod continual;
pub mod cost;
pub mod distribution;
pub mod fastp;
pub mod fidelity;
pub mod fleet;
pub mod hyperparams;
pub mod learning;
pub mod policy;
pub mod serve;
pub mod skills;
pub mod sweep;
pub mod table3;
pub mod verify;

/// Paired-grid measurement plumbing shared by the [`policy`] and
/// [`sweep`] scenarios: every arm runs an identical `(task, seed)` grid
/// (seed-major, task-minor — the pairing key is the cell index), and
/// arm-vs-baseline comparisons use the both-valid pairing discipline.
pub(crate) mod pairing {
    use crate::util::stats;

    /// One `(task, seed)` cell of an arm's grid.
    pub(crate) struct Cell {
        /// The run produced at least one valid kernel.
        pub valid: bool,
        /// Speedup vs naive (meaningful only when `valid`).
        pub speedup: f64,
        /// Token cost of the cell's run.
        pub tokens: usize,
    }

    /// Geomean speedup over the arm's valid cells (NaN when none — the
    /// crate's degenerate-input stats convention).
    pub(crate) fn geomean_valid(cells: &[Cell]) -> f64 {
        let v: Vec<f64> = cells
            .iter()
            .filter(|c| c.valid)
            .map(|c| c.speedup)
            .collect();
        stats::geomean(&v)
    }

    /// Cells that produced a valid kernel.
    pub(crate) fn valid_count(cells: &[Cell]) -> usize {
        cells.iter().filter(|c| c.valid).count()
    }

    /// Mean token cost per cell.
    pub(crate) fn tokens_per_cell(cells: &[Cell]) -> f64 {
        let total: usize = cells.iter().map(|c| c.tokens).sum();
        total as f64 / cells.len().max(1) as f64
    }

    /// Paired comparison against a baseline arm: geomean speedup ratio
    /// over cells valid in BOTH. Returns (ratio, pairs); with zero
    /// both-valid pairs the ratio is NaN (serialized as `null`, rendered
    /// `-`) — consumers must check the pair count first.
    pub(crate) fn paired_vs(arm: &[Cell], baseline: &[Cell]) -> (f64, usize) {
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for (ca, cb) in arm.iter().zip(baseline) {
            if ca.valid && cb.valid {
                a.push(ca.speedup);
                b.push(cb.speedup);
            }
        }
        (stats::geomean(&a) / stats::geomean(&b), a.len())
    }
}

/// Deterministic arrival/queue plumbing shared by the [`serve`] and
/// [`fleet`] scenarios: synthetic arrival traces and a FIFO
/// earliest-available-worker queue. Tick metrics are a pure function of
/// the seed — wall-clock never enters, so CI can compare them across
/// hosts. Percentiles over tick samples live with the other summary
/// statistics ([`crate::util::stats::percentile_nearest_rank`]).
pub(crate) mod simqueue {
    use crate::util::rng::Rng;

    /// Arrival ticks for `n` requests of a trace shape, seeded per shape
    /// (monotone non-decreasing; bursty shapes repeat ticks within a
    /// burst).
    pub(crate) fn trace_arrivals(shape: &str, n: usize, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed).derive(shape);
        let mut ticks = Vec::with_capacity(n);
        let mut t = 0u64;
        match shape {
            "uniform" => {
                for _ in 0..n {
                    t += 3 + rng.below(4); // gaps 3..=6, mean ~4.5
                    ticks.push(t);
                }
            }
            "bursty" => {
                while ticks.len() < n {
                    t += 12 + rng.below(9); // idle gap 12..=20
                    let burst = 2 + rng.index(3); // 2..=4 requests at once
                    for _ in 0..burst.min(n - ticks.len()) {
                        ticks.push(t);
                    }
                }
            }
            "heavy_tailed" => {
                for _ in 0..n {
                    // Pareto(alpha=1.2) inter-arrival: mostly ~1-tick gaps,
                    // occasional large ones (capped so the span stays finite).
                    let u = rng.f64().min(1.0 - 1e-12);
                    let gap = (1.0 - u).powf(-1.0 / 1.2).min(60.0) as u64;
                    t += gap.max(1);
                    ticks.push(t);
                }
            }
            other => panic!("unknown trace shape '{other}'"),
        }
        ticks
    }

    /// Deterministic FIFO queue simulation: each request goes to the
    /// earliest-available of `workers` servers, never before its arrival
    /// tick. Returns per-request (wait, sojourn) in ticks plus the busy
    /// span (last completion tick).
    pub(crate) fn simulate_queue(
        arrivals: &[u64],
        service: &[u64],
        workers: usize,
    ) -> (Vec<u64>, Vec<u64>, u64) {
        let mut avail = vec![0u64; workers.max(1)];
        let mut waits = Vec::with_capacity(arrivals.len());
        let mut sojourns = Vec::with_capacity(arrivals.len());
        let mut span = 0u64;
        for (a, s) in arrivals.iter().zip(service) {
            let wi = (0..avail.len()).min_by_key(|i| avail[*i]).unwrap();
            let start = (*a).max(avail[wi]);
            let finish = start + (*s).max(1);
            avail[wi] = finish;
            waits.push(start - a);
            sojourns.push(finish - a);
            span = span.max(finish);
        }
        (waits, sojourns, span)
    }
}

use crate::baselines;
use crate::gpu::GpuArch;
use crate::harness::HarnessConfig;
use crate::icrl::{self, IcrlConfig, TaskRun};
use crate::kb::KnowledgeBase;
use crate::metrics::TaskScore;
use crate::tasks::{Level, Suite, Task};
use crate::util::table::Table;
use std::path::Path;

/// One rendered experiment section (a table or a data series).
pub struct Section {
    pub title: String,
    pub table: Table,
    /// Optional ASCII plot rendered beneath the table.
    pub plot: Option<String>,
    pub notes: Vec<String>,
}

/// A full experiment report.
pub struct Report {
    pub name: String,
    pub sections: Vec<Section>,
}

impl Report {
    pub fn render(&self) -> String {
        let mut out = format!("##### experiment: {} #####\n\n", self.name);
        for s in &self.sections {
            out.push_str(&format!("--- {} ---\n", s.title));
            out.push_str(&s.table.render());
            if let Some(p) = &s.plot {
                out.push_str(p);
            }
            for n in &s.notes {
                out.push_str(&format!("note: {n}\n"));
            }
            out.push('\n');
        }
        out
    }

    /// Write one CSV per section into `dir` (created if needed).
    pub fn write_csvs(&self, dir: &Path) -> std::io::Result<Vec<std::path::PathBuf>> {
        std::fs::create_dir_all(dir)?;
        let mut written = Vec::new();
        for (i, s) in self.sections.iter().enumerate() {
            let slug: String = s
                .title
                .to_lowercase()
                .chars()
                .map(|c| if c.is_alphanumeric() { c } else { '_' })
                .collect();
            let path = dir.join(format!("{}_{i}_{slug}.csv", self.name));
            std::fs::write(&path, s.table.to_csv())?;
            written.push(path);
        }
        Ok(written)
    }
}

/// Shared experiment context.
pub struct Ctx {
    pub suite: Suite,
    /// Quick mode: reduced trajectories/steps for smoke tests; full mode
    /// reproduces the paper's Table-2 hyperparameters (10 × 10).
    pub quick: bool,
    pub seed: u64,
}

impl Ctx {
    pub fn new(quick: bool, seed: u64) -> Self {
        Self {
            suite: Suite::full(),
            quick,
            seed,
        }
    }

    /// Driver config for "Ours".
    pub fn icrl_cfg(&self, allow_vendor: bool) -> IcrlConfig {
        IcrlConfig {
            trajectories: if self.quick { 3 } else { 10 },
            rollout_steps: if self.quick { 5 } else { 10 },
            harness: HarnessConfig {
                allow_vendor,
                ..Default::default()
            },
            seed: self.seed,
            ..Default::default()
        }
    }

    /// Tasks of a level, optionally subsetted in quick mode.
    pub fn tasks(&self, level: Level) -> Vec<&Task> {
        let all = self.suite.of_level(level);
        if self.quick {
            all.into_iter().step_by(3).collect()
        } else {
            all
        }
    }
}

/// Run "Ours" on a level; returns runs plus speedups vs the PyTorch-best
/// reference (Table 3's 1.0×).
pub fn run_ours(
    ctx: &Ctx,
    arch: &GpuArch,
    level: Level,
    allow_vendor: bool,
    kb: &mut KnowledgeBase,
) -> (Vec<TaskRun>, Vec<TaskScore>) {
    let tasks = ctx.tasks(level);
    let cfg = ctx.icrl_cfg(allow_vendor);
    let runs = icrl::run_suite(&tasks, arch, kb, &cfg);
    let scores = tasks
        .iter()
        .zip(&runs)
        .map(|(t, r)| TaskScore {
            valid: r.valid,
            speedup: baselines::baseline_times(t, arch).best_s() / r.best_time_s,
        })
        .collect();
    (runs, scores)
}

/// AI CUDA Engineer scores vs PyTorch-best.
pub fn run_cudaeng(ctx: &Ctx, arch: &GpuArch, level: Level) -> Vec<TaskScore> {
    let hcfg = HarnessConfig::default();
    ctx.tasks(level)
        .iter()
        .map(|t| {
            let run = baselines::agentic::cuda_engineer(t, arch, &hcfg, ctx.seed);
            TaskScore {
                valid: run.valid,
                speedup: baselines::baseline_times(t, arch).best_s() / run.best_time_s,
            }
        })
        .collect()
}

/// IREE scores vs PyTorch-best (compile failures are invalid).
pub fn run_iree(ctx: &Ctx, arch: &GpuArch, level: Level) -> Vec<TaskScore> {
    ctx.tasks(level)
        .iter()
        .map(|t| match baselines::iree(t, arch) {
            Some(time) => TaskScore {
                valid: true,
                speedup: baselines::baseline_times(t, arch).best_s() / time,
            },
            None => TaskScore {
                valid: false,
                speedup: 0.0,
            },
        })
        .collect()
}

/// The experiment registry: name → runner. Names match DESIGN.md §6.
pub fn registry() -> Vec<(&'static str, fn(&Ctx) -> Report)> {
    vec![
        ("table3", table3::run as fn(&Ctx) -> Report),
        ("fig7", fastp::fig7),
        ("fig8", fastp::fig8),
        ("fig9", fastp::fig9),
        ("fig10", cost::fig10),
        ("fig11", table3::fig11),
        ("fig12", distribution::fig12),
        ("fig13_14", distribution::fig13_14),
        ("fig15_16", learning::fig15_16),
        ("fig17", hyperparams::fig17),
        ("fig18", hyperparams::fig18),
        ("fig19", fidelity::fig19),
        ("ablation_mem", learning::ablation_mem),
        ("minimal_agent", cost::minimal_agent),
        ("continual", continual::run),
        ("fleet", fleet::run),
        ("policy", policy::run),
        ("sweep", sweep::run),
        ("verify", verify::run),
        ("skills", skills::run),
        ("serve", serve::run),
    ]
}

pub fn by_name(name: &str) -> Option<fn(&Ctx) -> Report> {
    registry().into_iter().find(|(n, _)| *n == name).map(|(_, f)| f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique() {
        let mut names: Vec<&str> = registry().iter().map(|(n, _)| *n).collect();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n);
        assert!(by_name("table3").is_some());
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn quick_ctx_subsets_tasks() {
        let q = Ctx::new(true, 1);
        let f = Ctx::new(false, 1);
        assert!(q.tasks(Level::L1).len() < f.tasks(Level::L1).len());
        assert_eq!(f.tasks(Level::L1).len(), 20);
    }

    #[test]
    fn report_renders_and_writes() {
        let mut t = Table::new(&["a", "b"]);
        t.add_row(vec!["x".into(), "1".into()]);
        let r = Report {
            name: "smoke".into(),
            sections: vec![Section {
                title: "Demo".into(),
                table: t,
                plot: None,
                notes: vec!["hello".into()],
            }],
        };
        let text = r.render();
        assert!(text.contains("experiment: smoke"));
        assert!(text.contains("note: hello"));
        let dir = std::env::temp_dir().join("kb_exp_test");
        let files = r.write_csvs(&dir).unwrap();
        assert_eq!(files.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
